"""Chunk-pipeline throughput: chunks/sec of the batched Big-means driver.

Measures steady-state chunks/sec of ``big_means_batched`` for batch sizes
{1, 4, 16} at the paper's default shape (k=25, n=20, s=16384) on the
reference (jnp) path, at a FIXED total chunk budget, plus a row for the
stream-mesh variant (batch sharded over the host's XLA devices — the
in-core analogue of the sharded driver's worker parallelism).

Timing protocol: each variant is run at R and 2R rounds and the throughput
is computed from the *incremental* cost of the extra R rounds (pairwise
per-rep deltas, median).  This cancels compile time and the one-shot cold
K-means++ seeding of round 1, which is a per-stream cost that would bias
the comparison against large batches.

The achievable speedup is host-dependent: chunk compute at this shape is
memory-bandwidth-bound, so on small CPU hosts (e.g. 2-vCPU CI containers)
the batched rows saturate the memory bus and the measured ratio understates
what dispatch-bound hosts and the batched Pallas kernel path deliver.  The
JSON records the host context (cpu count, devices) alongside the rows so
trajectories are compared like-for-like.

Writes BENCH_batched.json at the repo root (committed — the perf
trajectory future PRs regress against) and results/batched_throughput.csv.

A second matrix sweeps the kernel-stack ``precision`` axis (f32 / bf16 /
int8 rows at the same shapes and protocol) and writes BENCH_precision.json
with per-chunk streamed-bytes estimates, effective GB/s, f_best drift vs
f32, and the autotuner-chosen tile sizes for each row — the measured
record of what mixed precision buys on this host.  On CPU hosts the
reduced-precision rows typically measure *slower* (bf16/int8 matmuls are
emulated); the bytes column is the hardware-independent signal, realized
on bandwidth-bound accelerators.  Every row also carries a ``saturated``
flag (see :func:`_saturated`) so chunks/s ratios are read in host context.

    PYTHONPATH=src python -m benchmarks.batched_throughput [--fast]
        [--matrix {all,batched,precision}]
"""
from __future__ import annotations

import argparse
import csv
import os

# Expose the host's cores as XLA devices so the stream-mesh row can shard
# streams across them (must happen before jax initializes its backends).
if "XLA_FLAGS" not in os.environ:
    _cores = os.cpu_count() or 1
    if _cores > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={_cores}"
        )

import time

import jax
import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

K, N, S = 25, 20, 16384          # paper default shape (HEPMASS-like k, n)
BATCHES = (1, 4, 16)


def _saturated(batch: int) -> bool:
    """Whether this host's memory bus is already saturated at ``batch``.

    On CPU backends, once the worker count reaches the core count there are
    no idle cores left for batching to exploit — measured chunks/s ratios on
    such hosts understate what dispatch-bound hosts deliver.  Recorded
    explicitly per row so regression tooling can weight rows accordingly
    instead of re-deriving host heuristics.
    """
    return (jax.default_backend() == "cpu"
            and (os.cpu_count() or 1) <= max(2, batch))


def _chunk_bytes(precision: str) -> int:
    """Streamed bytes to move one [s, n] chunk once under ``precision``.

    f32/bf16 ship the raw array (itemsize 4/2).  int8 ships the quantized
    payload the prefetcher actually transfers: s*n int8 codes plus one f32
    per-feature scale row (see repro.kernels.precision.host_quantize) —
    ~0.25x of f32 at the paper shape.
    """
    if precision == "int8":
        return S * N * 1 + 4 * N
    return S * N * (2 if precision == "bf16" else 4)


def _measure(run, rounds, chunks, reps):
    """Median pairwise (2R - R) delta: steady-state cost of R extra rounds."""
    run(rounds)                              # compile + warm caches
    run(2 * rounds)
    deltas = []
    for _ in range(reps):
        t0 = time.monotonic()
        run(rounds)
        t1 = time.monotonic()
        st = run(2 * rounds)
        deltas.append((time.monotonic() - t1) - (t1 - t0))
    dt = float(np.median(deltas))
    return dt, chunks / dt, st


def bench(total_chunks: int, reps: int, max_iters: int):
    from repro.api import BigMeansConfig, TopologySpec, fit, synthetic

    X = synthetic.gmm_dataset(
        synthetic.GMMSpec(m=200_000, n=N, components=K, seed=12))
    key = jax.random.PRNGKey(0)
    ndev = len(jax.devices())
    rows = []

    def variant(batch, topology, label):
        rounds = max(2, total_chunks // batch)
        cfg = BigMeansConfig(
            k=K, s=S, batch=batch, n_chunks=rounds * batch,
            max_iters=max_iters, impl="ref", topology=topology)

        def run(r):
            res = fit(X, cfg, method="batched", key=key,
                      n_chunks=r * batch)
            return res

        dt, cps, res = _measure(run, rounds, rounds * batch, reps)
        rows.append({
            "variant": label, "batch": batch, "rounds": rounds,
            "chunks": rounds * batch, "k": K, "n": N, "s": S, "impl": "ref",
            "saturated": _saturated(batch),
            "wall_s": round(dt, 3), "chunks_per_s": round(cps, 2),
            "f_best": res.objective,
        })
        print(f"{label:16s} batch={batch:<3d} rounds={rounds:<4d} "
              f"wall={dt:6.2f}s  chunks/s={cps:7.2f}  "
              f"f_best={res.objective:.4e}", flush=True)

    for batch in BATCHES:
        variant(batch, "single", "local")
    if ndev >= 2:
        spec = TopologySpec(kind="stream_mesh", devices=ndev,
                            axes=("streams",))
        batch = max(b for b in BATCHES if b % ndev == 0)
        variant(batch, spec, f"streams-mesh[{ndev}]")

    base = rows[0]["chunks_per_s"]
    for r in rows:
        r["speedup_vs_batch1"] = round(r["chunks_per_s"] / base, 2)
    return rows


def bench_precision(total_chunks: int, reps: int, max_iters: int):
    """f32 / bf16 / int8 matrix: same shapes, same steady-state protocol.

    Each row records the *estimated* per-chunk streamed bytes (see
    :func:`_chunk_bytes` — the HBM/host->device cost of moving one chunk
    once; the Lloyd loop re-reads it every iteration, so total traffic
    scales with ``lloyd_iters_per_chunk + 2`` epilogue passes), the
    effective streamed GB/s implied by the measured chunks/sec, the
    autotuner-chosen tile sizes for the shape key, and the f_best drift
    each reduced-precision row pays relative to its f32 twin (the int8
    acceptance criterion is < 1% on every row).
    """
    from repro.api import BigMeansConfig, fit, synthetic
    from repro.kernels import autotune, ops

    X = synthetic.gmm_dataset(
        synthetic.GMMSpec(m=200_000, n=N, components=K, seed=12))
    key = jax.random.PRNGKey(0)
    # Host-resolved impl: the compiled Pallas kernel on TPU (where
    # autotune=True below makes the tiles column a real tuner choice), the
    # jnp reference path elsewhere.
    impl = ops.resolve_impl("auto")
    rows = []

    for prec in ("f32", "bf16", "int8"):
        bytes_per_chunk = _chunk_bytes(prec)
        for batch in (1, 4):
            rounds = max(2, total_chunks // batch)
            cfg = BigMeansConfig(
                k=K, s=S, batch=batch, n_chunks=rounds * batch,
                max_iters=max_iters, impl=impl, precision=prec,
                autotune=impl.startswith("pallas"))

            def run(r):
                return fit(X, cfg, method="batched", key=key,
                           n_chunks=r * batch)

            dt, cps, res = _measure(run, rounds, rounds * batch, reps)
            iters_per_chunk = res.n_iterations / max(1, res.n_chunks)
            passes = iters_per_chunk + 2          # fused loop + 2-pass epilogue
            eff_gbps = cps * bytes_per_chunk * passes / 1e9
            # Tile metadata is only meaningful for Pallas launches; the jnp
            # reference path has no tiling, so record null rather than
            # passing hardcoded defaults off as tuner choices.
            tiles = (autotune.get_blocks(
                "fused_batched", backend=jax.default_backend(), b=batch,
                m=S, k=K, n=N, precision=prec)
                if impl.startswith("pallas") else None)
            rows.append({
                "precision": prec, "batch": batch, "rounds": rounds,
                "chunks": rounds * batch, "k": K, "n": N, "s": S,
                "impl": impl, "saturated": _saturated(batch),
                "wall_s": round(dt, 3),
                "chunks_per_s": round(cps, 2),
                "bytes_per_chunk": bytes_per_chunk,
                "lloyd_iters_per_chunk": round(iters_per_chunk, 2),
                "est_streamed_gb_per_s": round(eff_gbps, 3),
                "autotune_tiles": tiles,
                "f_best": res.objective,
            })
            print(f"prec={prec:6s} batch={batch:<3d} wall={dt:6.2f}s  "
                  f"chunks/s={cps:7.2f}  bytes/chunk={bytes_per_chunk}  "
                  f"~GB/s={eff_gbps:6.2f}  f_best={res.objective:.4e}",
                  flush=True)

    f32_b1 = next(r for r in rows if r["precision"] == "f32" and r["batch"] == 1)
    for r in rows:
        r["bytes_ratio_vs_f32"] = round(
            r["bytes_per_chunk"] / f32_b1["bytes_per_chunk"], 4)
        r["speedup_vs_f32_batch1"] = round(
            r["chunks_per_s"] / f32_b1["chunks_per_s"], 2)
        # f_best drift vs the f32 row at the same batch (same chunk stream):
        # the quality price of the reduced-precision hot loop.  The int8
        # acceptance criterion (< 1% on every row) is enforced by
        # tests/test_precision.py.
        f32_twin = next(t for t in rows
                        if t["precision"] == "f32" and t["batch"] == r["batch"])
        r["f_best_drift_vs_f32"] = round(
            abs(r["f_best"] - f32_twin["f_best"])
            / abs(f32_twin["f_best"]), 6)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer chunks/reps (CI smoke)")
    ap.add_argument("--matrix", choices=("all", "batched", "precision"),
                    default="all", help="which sweep(s) to run")
    args = ap.parse_args()

    from repro.evalsuite import schema as bench_schema

    total = 64 if args.fast else 128
    reps = 2 if args.fast else 5
    protocol = "steady-state: median pairwise (2R-R) round deltas"
    os.makedirs(os.path.join(REPO, "results"), exist_ok=True)

    if args.matrix in ("all", "batched"):
        rows = bench(total, reps, max_iters=300)

        csv_path = os.path.join(REPO, "results", "batched_throughput.csv")
        with open(csv_path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)

        json_path = bench_schema.write_bench(
            os.path.join(REPO, "BENCH_batched.json"),
            bench_schema.envelope(
                "batched_throughput", rows,
                shape={"k": K, "n": N, "s": S},
                impl="ref",
                protocol=protocol,
            ))
        print(f"# wrote {json_path}")

    if args.matrix in ("all", "precision"):
        prows = bench_precision(total, reps, max_iters=300)

        csv_path = os.path.join(REPO, "results", "precision_matrix.csv")
        with open(csv_path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(prows[0]))
            w.writeheader()
            w.writerows(prows)

        json_path = bench_schema.write_bench(
            os.path.join(REPO, "BENCH_precision.json"),
            bench_schema.envelope(
                "precision_matrix", prows,
                shape={"k": K, "n": N, "s": S},
                impl="ref",
                protocol=protocol,
                bytes_model="bytes_per_chunk: s*n*itemsize for f32/bf16; "
                            "s*n + 4*n for int8 (codes + per-feature scale "
                            "row). Total traffic ~ bytes_per_chunk * "
                            "(lloyd_iters_per_chunk + 2)",
                note="CPU host: bf16/int8 matmuls are emulated, so reduced-"
                     "precision rows can measure slower; bytes_per_chunk "
                     "is the hardware-independent win (2x bf16, ~4x int8) "
                     "realized on bandwidth-bound accelerators.",
            ))
        print(f"# wrote {json_path}")


if __name__ == "__main__":
    main()
