"""Serving chaos: multi-tenant fault injection against `repro.serve`.

The serving twin of ``benchmarks/chaos.py``.  Two phases over identical
seeded request streams:

* **clean** — multi-tenant closed-loop traffic (several healthy tenants on
  "prod", one client on "canary") with no faults: the baseline results and
  latency percentiles.
* **chaos** — the same streams while everything goes wrong at once:

  - "prod" launches fail transiently at a seeded rate (recovered on the
    ref fallback path, invisible to clients);
  - a poisoned tenant submits NaN payloads with validation off, so the
    fault fires *inside* coalesced launches and only batch bisection can
    isolate it;
  - "canary" suffers a launch outage window: its circuit breaker trips,
    fast-fails, probes half-open on the seeded backoff, and recovers when
    the outage ends;
  - a `CheckpointWatcher` on prod's checkpoint dir rides through a hung
    restore (watchdog abandons the poll) and a torn newest checkpoint
    (skipped), converging to the newest *intact* step.

Acceptance (checked before writing, exit code 1 on failure):

* every healthy-tenant request completes, bitwise-identical to the clean
  run (ids always; dists on CPU where primary and fallback share the ref
  kernel) — availability >= 99%;
* only directly-faulted requests fail, and with *typed* exceptions; zero
  hung futures (no client ever hits its assign timeout);
* the canary breaker demonstrably opened and re-closed (observed via
  `Server.health()` polling), and the server ends healthy;
* the watcher recorded the stall, skipped the torn step, and landed on the
  newest intact one;
* chaos p99 stays within 25x clean p99 (floor 250ms) for healthy tenants.

Writes BENCH_serve_chaos.json at the repo root (committed — the serving
resilience trajectory future PRs regress against).

    PYTHONPATH=src python -m benchmarks.serve_chaos [--fast] [--seed 0]
"""
from __future__ import annotations

import argparse
import os
import threading
import time

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

K, N = 25, 20                    # paper default clustering shape
REQ_POINTS = 32                  # one request; buckets to 32/64 with linger


def _centroids(seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).standard_normal((K, N)).astype(
        np.float32) * 3.0


def _stream(seed: int, reqs: int) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((REQ_POINTS, N)).astype(np.float32)
            for _ in range(reqs)]


def _save_ckpt(directory: str, step: int, centroids: np.ndarray) -> None:
    import jax.numpy as jnp

    from repro.cluster import checkpoint
    from repro.core import bigmeans

    k, n = centroids.shape
    state = bigmeans.init_state(k, n)._replace(
        centroids=jnp.asarray(centroids), f_best=jnp.float32(1.0))
    aux = np.asarray([0, 0, 0], dtype=np.int64)
    checkpoint.save(directory, step, ((state, jnp.zeros(2, jnp.uint32)), aux))


def _config(seed: int):
    from repro.serve import ServeConfig

    return ServeConfig(
        min_bucket=32, max_batch=256, max_linger_ms=2.0, queue_depth=256,
        launch_retries=1, breaker_threshold=3, breaker_backoff_s=0.05,
        breaker_backoff_max_s=0.5, seed=seed)


class _Tenant:
    """One closed-loop client: records outcomes per request, in order."""

    def __init__(self, name: str, model_id: str, stream: list[np.ndarray],
                 *, deadline_ms: float, validate: bool = True,
                 pace_s: float = 0.0):
        self.name = name
        self.model_id = model_id
        self.stream = stream
        self.deadline_ms = deadline_ms
        self.validate = validate
        self.pace_s = pace_s
        self.results: list = []        # (ids, dists) per completed request
        self.failures: dict = {}       # exception type name -> count
        self.latencies_ms: list = []

    def run(self, srv, barrier) -> None:
        barrier.wait()
        for pts in self.stream:
            t0 = time.monotonic()
            try:
                r = srv.assign(self.model_id, pts, timeout=60.0,
                               deadline_ms=self.deadline_ms,
                               tenant=self.name, validate=self.validate)
            except Exception as exc:  # noqa: BLE001 — typed faults expected
                kind = type(exc).__name__
                self.failures[kind] = self.failures.get(kind, 0) + 1
            else:
                self.results.append((r.ids, r.dists))
                self.latencies_ms.append((time.monotonic() - t0) * 1e3)
            if self.pace_s:
                time.sleep(self.pace_s)


def _make_tenants(seed: int, *, n_healthy: int, reqs: int,
                  canary_reqs: int, poisoned: bool) -> list[_Tenant]:
    tenants = [
        _Tenant(f"tenant{i}", "prod", _stream(seed + 10 + i, reqs),
                deadline_ms=10_000.0)
        for i in range(n_healthy)
    ]
    tenants.append(_Tenant(
        "canary-client", "canary", _stream(seed + 50, canary_reqs),
        deadline_ms=2_000.0, pace_s=0.02))
    if poisoned:
        bad = _stream(seed + 99, max(reqs // 5, 4))
        for pts in bad:
            pts[1, 2] = np.nan
        tenants.append(_Tenant("poisoned", "prod", bad,
                               deadline_ms=10_000.0, validate=False,
                               pace_s=0.01))
    return tenants


def _run_clients(srv, tenants: list[_Tenant]) -> float:
    barrier = threading.Barrier(len(tenants) + 1)
    threads = [threading.Thread(target=t.run, args=(srv, barrier),
                                daemon=True) for t in tenants]
    for th in threads:
        th.start()
    barrier.wait()
    t0 = time.monotonic()
    for th in threads:
        th.join()
    return time.monotonic() - t0


def _healthy_metrics(tenants: list[_Tenant]) -> dict:
    healthy = [t for t in tenants if t.model_id == "prod"
               and t.name != "poisoned"]
    offered = sum(len(t.stream) for t in healthy)
    done = sum(len(t.results) for t in healthy)
    lats = np.asarray(sum((t.latencies_ms for t in healthy), []),
                      dtype=np.float64)
    return {
        "healthy_offered": offered,
        "healthy_completed": done,
        "availability": round(done / offered, 6) if offered else 0.0,
        "healthy_p50_ms": round(float(np.percentile(lats, 50)), 3)
        if lats.size else 0.0,
        "healthy_p99_ms": round(float(np.percentile(lats, 99)), 3)
        if lats.size else 0.0,
    }


def _wait_until(predicate, timeout_s: float) -> bool:
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout_s:
        if predicate():
            return True
        time.sleep(0.01)
    return predicate()


def run_phase(seed: int, *, chaos: bool, n_healthy: int, reqs: int,
              canary_reqs: int, outage_after: int, outage_len: int,
              ckpt_dir: str | None) -> dict:
    from repro.engine import faults
    from repro.serve import serve

    C_prod, C_canary = _centroids(seed), _centroids(seed + 1)
    tenants = _make_tenants(seed, n_healthy=n_healthy, reqs=reqs,
                            canary_reqs=canary_reqs, poisoned=chaos)
    breaker_states: set = set()
    watcher_report: dict = {}
    row: dict = {"phase": "chaos" if chaos else "clean"}

    with serve({"prod": C_prod, "canary": C_canary}, _config(seed)) as srv:
        watcher = None
        if chaos:
            prod = srv.registry.get("prod")
            canary = srv.registry.get("canary")
            prod.launch = faults.FaultPlan(
                seed=seed, launch_transient_rate=0.08).wrap_launch(
                    prod.launch)
            canary.launch = faults.FaultPlan(
                seed=seed, launch_outage_after=outage_after,
                launch_outage_len=outage_len).wrap_launch(canary.launch)
            if ckpt_dir is not None:
                # Step 1 (same centroids: swaps stay bitwise-invisible)
                # is already on disk; the watcher picks it up and then
                # rides through a hung restore and a torn newest step.
                watcher = srv.watch("prod", ckpt_dir, poll_interval_s=0.02,
                                    poll_timeout_s=0.2)

        stop_poll = threading.Event()

        def poll_health() -> None:
            while not stop_poll.is_set():
                h = srv.health()
                breaker_states.add(h["models"]["canary"]["breaker"]["state"])
                stop_poll.wait(0.01)

        poller = threading.Thread(target=poll_health, daemon=True)
        poller.start()

        runner = threading.Thread(
            target=lambda: row.update(wall_s=round(
                _run_clients(srv, tenants), 3)), daemon=True)
        runner.start()

        if chaos and ckpt_dir is not None:
            time.sleep(0.1)                     # let traffic flow first
            with faults.hung_restore():
                _save_ckpt(ckpt_dir, 2, C_prod)  # new step, hung load
                stall_seen = _wait_until(
                    lambda: watcher.stalled_polls >= 1, 10.0)
            swap_done = _wait_until(lambda: watcher.last_step == 2, 10.0)
            _save_ckpt(ckpt_dir, 3, C_prod)
            faults.corrupt_checkpoint(ckpt_dir, step=3)   # torn write
            time.sleep(0.2)                     # a few polls on the torn dir
            watcher_report = {
                "stall_seen": stall_seen,
                "swap_done": swap_done,
                "torn_step_skipped": watcher.last_step == 2,
                **watcher.describe(),
            }

        runner.join()
        stop_poll.set()
        poller.join()

        canary_recovered = True
        if chaos:
            # The outage window is finite: keep probing until the breaker
            # closes and the canary serves again.
            def probe() -> bool:
                try:
                    srv.assign("canary", _stream(seed + 77, 1)[0],
                               timeout=10.0, tenant="probe")
                    return True
                except Exception:  # noqa: BLE001 — breaker still open
                    return False

            canary_recovered = _wait_until(probe, 20.0)

        stats_prod = srv.stats("prod")
        stats_canary = srv.stats("canary")
        health = srv.health()
        trace_kinds = sorted({e[0] for e in srv.trace})
        if watcher is not None:
            watcher.stop()

    row.update(_healthy_metrics(tenants))
    poisoned = next((t for t in tenants if t.name == "poisoned"), None)
    canary_client = next(t for t in tenants if t.model_id == "canary")
    row.update({
        "prod_launch_faults": stats_prod["n_launch_faults"],
        "prod_ref_retries": stats_prod["n_ref_retries"],
        "prod_failed": stats_prod["n_failed"],
        "canary_launch_faults": stats_canary["n_launch_faults"],
        "canary_breaker_rejected": stats_canary["n_breaker_rejected"],
        "canary_completed": len(canary_client.results),
        "canary_failures": dict(canary_client.failures),
        "canary_breaker_states_seen": sorted(breaker_states),
        "canary_recovered": canary_recovered,
        "poisoned_offered": len(poisoned.stream) if poisoned else 0,
        "poisoned_failed_typed": (poisoned.failures.get("LaunchFault", 0)
                                  if poisoned else 0),
        "poisoned_completed": len(poisoned.results) if poisoned else 0,
        "assign_timeouts": sum(
            t.failures.get("DeadlineExceeded", 0) for t in tenants
            if t.deadline_ms >= 10_000.0),
        "end_health_ok": health["ok"],
        "trace_kinds": trace_kinds,
        "worker_restarts": sum(
            m["worker_restarts"] for m in health["models"].values()),
    })
    if watcher_report:
        row["watcher"] = watcher_report
    # The per-request results ride back for the bitwise check, but stay
    # out of the serialized row.
    row["_tenants"] = tenants
    return row


def _bitwise_check(clean: dict, chaos: dict) -> dict:
    """Healthy tenants must see bitwise-identical results in both phases."""
    import jax

    exact_dists = jax.default_backend() == "cpu"
    clean_t = {t.name: t for t in clean["_tenants"]}
    mismatches = 0
    compared = 0
    for t in chaos["_tenants"]:
        if t.model_id != "prod" or t.name == "poisoned":
            continue
        ref = clean_t[t.name]
        if len(t.results) != len(ref.results):
            mismatches += abs(len(t.results) - len(ref.results))
            continue
        for (ids_a, d_a), (ids_b, d_b) in zip(ref.results, t.results):
            compared += 1
            if not np.array_equal(ids_a, ids_b):
                mismatches += 1
            elif exact_dists and not np.array_equal(d_a, d_b):
                mismatches += 1
    return {"requests_compared": compared, "mismatches": mismatches,
            "exact_dists": exact_dists}


def _acceptance(clean: dict, chaos: dict, bitwise: dict) -> dict:
    problems = []
    if chaos["availability"] < 0.99:
        problems.append(
            f"healthy availability {chaos['availability']} < 0.99")
    if bitwise["mismatches"] or bitwise["requests_compared"] == 0:
        problems.append(
            f"bitwise parity failed: {bitwise['mismatches']} mismatches "
            f"over {bitwise['requests_compared']} requests")
    if chaos["assign_timeouts"]:
        problems.append(
            f"{chaos['assign_timeouts']} hung futures (assign timeouts)")
    if chaos["poisoned_failed_typed"] + chaos["poisoned_completed"] \
            != chaos["poisoned_offered"]:
        problems.append("poisoned requests not all resolved with a typed "
                        "outcome")
    if chaos["prod_failed"] > chaos["poisoned_offered"]:
        problems.append("bisection failed more requests than were poisoned")
    if "open" not in chaos["canary_breaker_states_seen"]:
        problems.append("canary breaker never observed open via health()")
    if not chaos["canary_recovered"]:
        problems.append("canary never recovered after the outage window")
    if not chaos["end_health_ok"]:
        problems.append("server did not end healthy")
    w = chaos.get("watcher", {})
    if w and not (w["stall_seen"] and w["swap_done"]
                  and w["torn_step_skipped"] and w["alive"]):
        problems.append(f"watcher chaos ride-through failed: {w}")
    p99_bound = max(25.0 * clean["healthy_p99_ms"], 250.0)
    if chaos["healthy_p99_ms"] > p99_bound:
        problems.append(
            f"chaos p99 {chaos['healthy_p99_ms']}ms exceeds bound "
            f"{p99_bound}ms")
    summary = {
        "availability": chaos["availability"],
        "bitwise": bitwise,
        "clean_p99_ms": clean["healthy_p99_ms"],
        "chaos_p99_ms": chaos["healthy_p99_ms"],
        "p99_bound_ms": round(p99_bound, 3),
        "breaker_states_seen": chaos["canary_breaker_states_seen"],
        "watcher": {k: w[k] for k in
                    ("stall_seen", "swap_done", "torn_step_skipped",
                     "stalled_polls", "last_step")} if w else {},
        "pass": not problems,
    }
    if problems:
        summary["problems"] = problems
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller streams (CI smoke)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import tempfile

    from repro.evalsuite import schema as bench_schema

    n_healthy = 3 if args.fast else 6
    reqs = 40 if args.fast else 120
    canary_reqs = 40 if args.fast else 80
    outage_after, outage_len = (5, 6) if args.fast else (10, 8)

    kwargs = dict(n_healthy=n_healthy, reqs=reqs, canary_reqs=canary_reqs,
                  outage_after=outage_after, outage_len=outage_len)

    clean = run_phase(args.seed, chaos=False, ckpt_dir=None, **kwargs)
    print(f"clean : avail={clean['availability']}  "
          f"p99={clean['healthy_p99_ms']}ms  wall={clean['wall_s']}s",
          flush=True)

    with tempfile.TemporaryDirectory() as td:
        ckpt_dir = os.path.join(td, "ckpt")
        _save_ckpt(ckpt_dir, 1, _centroids(args.seed))
        chaos = run_phase(args.seed, chaos=True, ckpt_dir=ckpt_dir, **kwargs)
    print(f"chaos : avail={chaos['availability']}  "
          f"p99={chaos['healthy_p99_ms']}ms  wall={chaos['wall_s']}s  "
          f"faults={chaos['prod_launch_faults']}+"
          f"{chaos['canary_launch_faults']}  "
          f"ref_retries={chaos['prod_ref_retries']}  "
          f"breaker={chaos['canary_breaker_states_seen']}  "
          f"watcher_stalls={chaos.get('watcher', {}).get('stalled_polls')}",
          flush=True)

    bitwise = _bitwise_check(clean, chaos)
    summary = _acceptance(clean, chaos, bitwise)
    rows = []
    for row in (clean, chaos):
        row = dict(row)
        row.pop("_tenants")
        rows.append(row)

    json_path = bench_schema.write_bench(
        os.path.join(REPO, "BENCH_serve_chaos.json"),
        bench_schema.envelope(
            "serve_chaos", rows,
            shape={"k": K, "n": N, "req_points": REQ_POINTS,
                   "n_healthy_tenants": n_healthy, "reqs": reqs,
                   "seed": args.seed},
            protocol="two phases over identical seeded streams: clean "
                     "baseline, then chaos (seeded transient launch "
                     "faults recovered on the ref path, NaN-poisoned "
                     "tenant isolated by batch bisection, canary launch "
                     "outage tripping the circuit breaker, checkpoint "
                     "watcher riding a hung restore and a torn step); "
                     "healthy tenants must complete bitwise-identically "
                     "(ids always, dists on CPU) with >=99% availability "
                     "and bounded p99 degradation",
            summary=summary,
        ))
    print(f"# wrote {json_path}")
    if not summary["pass"]:
        raise SystemExit(
            "serve_chaos acceptance failed: " + "; ".join(
                summary["problems"]))


if __name__ == "__main__":
    main()
