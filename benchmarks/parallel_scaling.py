"""Parallel Big-means scaling (paper §2.2 properties 6-7, §3 parallelization).

Runs the sharded driver with 1/2/4/8 workers on forced host devices (its own
subprocess, so the main process keeps its device view), at a FIXED total
chunk budget: more workers process the budget in fewer rounds, and property
7 says quality should hold or improve (more independent incumbent streams =
more shaking).  Writes results/parallel_scaling.csv.

    PYTHONPATH=src python -m benchmarks.parallel_scaling
"""
from __future__ import annotations

import csv
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax
from repro.core import big_means_sharded, full_objective
from repro.data.synthetic import GMMSpec, gmm_dataset
from repro.launch.mesh import make_mesh

X = gmm_dataset(GMMSpec(m=64000, n=16, components=12, seed=6))
TOTAL_CHUNKS = 32
out = []
for w in (1, 2, 4, 8):
    mesh = make_mesh((w, 8 // w), ("data", "model"))
    for sync in (1, 4):
        cpw = TOTAL_CHUNKS // w
        if cpw % sync:
            continue
        t0 = time.monotonic()
        st, _ = big_means_sharded(
            X, jax.random.PRNGKey(0), mesh=mesh, k=12, s=2000,
            chunks_per_worker=cpw, sync_every=sync, axes=("data",))
        st.centroids.block_until_ready()
        wall = time.monotonic() - t0
        f = float(full_objective(X, st.centroids)) / X.shape[0]
        out.append({"workers": w, "sync_every": sync,
                    "chunks_per_worker": cpw, "f_per_point": f,
                    "wall_s": round(wall, 2)})
print("RESULT " + json.dumps(out))
"""


def main() -> None:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    rows = json.loads(line[len("RESULT "):])
    path = os.path.join(REPO, "results", "parallel_scaling.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    for r in rows:
        print(f"workers={r['workers']} sync={r['sync_every']} "
              f"chunks/worker={r['chunks_per_worker']} "
              f"f/point={r['f_per_point']:.4f} wall={r['wall_s']}s")


if __name__ == "__main__":
    main()
