"""Shared benchmark machinery.

The paper's 19 datasets are not reachable offline; each is replaced by a
deterministic GMM surrogate with the same feature dimension and a scaled-down
row count (documented in EXPERIMENTS.md §Quality).  Algorithms, metrics and
the scoring system follow §5.7 of the paper:

    E_A = (f_A - f_best) / f_best * 100%
    S(A, X, q) = 1 - (q_X(A) - min_A' q)/(max_A' q - min_A' q)
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core import big_means, full_objective
from repro.core.baselines import (
    da_mssc, forgy_kmeans, kmeans_parallel, lightweight_coreset_kmeans,
    multistart_kmeans,
)
from repro.data.synthetic import GMMSpec, gmm_dataset

# surrogate suite: (paper dataset name, n features, surrogate m, chunk size s)
SUITE = [
    ("hepmass", 28, 40000, 3000),
    ("uscensus", 68, 25000, 2500),
    ("miniboone", 50, 20000, 2500),
    ("mfcc", 58, 16000, 2000),
    ("sensorless", 48, 16000, 2000),
    ("road3d", 3, 40000, 3000),
    ("kegg", 20, 16000, 2000),
    ("skin", 3, 30000, 2500),
]

K_VALUES = (2, 5, 10, 15)
N_EXEC = 2


@dataclasses.dataclass
class RunResult:
    algo: str
    dataset: str
    k: int
    f: float          # objective on the full dataset
    cpu: float        # wall seconds
    n_d: float        # distance evaluations (analytic counter)


def dataset(name: str, n: int, m: int, seed: int = 0):
    return gmm_dataset(GMMSpec(m=m, n=n, components=25, spread=4.0,
                               seed=hash(name) % (2**31)))


def _nd_lloyd(m, k, iters):
    return float(m) * k * (iters + 1)


def run_algo(algo: str, X, key, k: int, s: int) -> RunResult:
    m = X.shape[0]
    t0 = time.monotonic()
    if algo == "bigmeans":
        st, infos = big_means(X, key, k=k, s=s, n_chunks=30)
        st.centroids.block_until_ready()
        cpu = time.monotonic() - t0
        f = float(full_objective(X, st.centroids))
        n_d = float(st.n_dist_evals)
    elif algo == "forgy":
        res = forgy_kmeans(X, key, k=k)
        res.centroids.block_until_ready()
        cpu = time.monotonic() - t0
        f = float(res.objective)
        n_d = _nd_lloyd(m, k, int(res.iterations))
    elif algo == "kmeans++":
        res = multistart_kmeans(X, key, k=k, n_init=3)
        res.centroids.block_until_ready()
        cpu = time.monotonic() - t0
        f = float(res.objective)
        n_d = 3 * (_nd_lloyd(m, k, int(res.iterations)) + m * k)
    elif algo == "kmeans||":
        res = kmeans_parallel(X, key, k=k, rounds=5)
        res.centroids.block_until_ready()
        cpu = time.monotonic() - t0
        f = float(res.objective)
        n_d = _nd_lloyd(m, k, int(res.iterations)) + 5 * m * 2 * k
    elif algo == "lwcs":
        res = lightweight_coreset_kmeans(X, key, k=k, s=4 * s)
        cpu = time.monotonic() - t0
        f = float(full_objective(X, res.centroids))
        n_d = 2 * m + _nd_lloyd(4 * s, k, int(res.iterations))
    elif algo == "da_mssc":
        res = da_mssc(X, key, k=k, s=s, q=6)
        cpu = time.monotonic() - t0
        f = float(full_objective(X, res.centroids))
        n_d = 6 * _nd_lloyd(s, k, 20) + _nd_lloyd(6 * k, k, 20)
    else:
        raise ValueError(algo)
    return RunResult(algo, "?", k, f, cpu, n_d)


ALGOS = ("bigmeans", "forgy", "kmeans++", "kmeans||", "lwcs", "da_mssc")


def full_sweep(algos=ALGOS, suite=SUITE, k_values=K_VALUES, n_exec=N_EXEC,
               verbose=True):
    rows: list[RunResult] = []
    for name, n, m, s in suite:
        X = dataset(name, n, m)
        for k in k_values:
            for algo in algos:
                for e in range(n_exec):
                    key = jax.random.PRNGKey(hash((name, k, algo, e)) % 2**31)
                    r = run_algo(algo, X, key, k, s)
                    r.dataset = name
                    rows.append(r)
                if verbose:
                    rs = [r for r in rows
                          if r.dataset == name and r.k == k and r.algo == algo]
                    fm = np.mean([r.f for r in rs])
                    cm = np.mean([r.cpu for r in rs])
                    print(f"[bench] {name:12s} k={k:<3d} {algo:10s} "
                          f"f={fm:.4e} cpu={cm:6.2f}s", flush=True)
    return rows


def relative_errors(rows):
    """E_A per (dataset, k, algo) vs the best f seen across all algos."""
    out = {}
    keys = {(r.dataset, r.k) for r in rows}
    for ds, k in keys:
        fs = [r.f for r in rows if (r.dataset, r.k) == (ds, k)]
        f_best = min(fs)
        for algo in {r.algo for r in rows}:
            sub = [r.f for r in rows
                   if (r.dataset, r.k, r.algo) == (ds, k, algo)]
            if not sub:
                continue
            e = [(f - f_best) / f_best * 100.0 for f in sub]
            out[(ds, k, algo)] = {
                "min": min(e), "mean": float(np.mean(e)), "max": max(e),
                "cpu": float(np.mean([r.cpu for r in rows if
                                      (r.dataset, r.k, r.algo) == (ds, k, algo)])),
                "n_d": float(np.mean([r.n_d for r in rows if
                                      (r.dataset, r.k, r.algo) == (ds, k, algo)])),
            }
    return out


def scores(rows):
    """Paper Table 3/4 scoring: per-dataset normalized accuracy/time."""
    err = relative_errors(rows)
    datasets = sorted({r.dataset for r in rows})
    algos = sorted({r.algo for r in rows})
    acc = {a: 0.0 for a in algos}
    cpu = {a: 0.0 for a in algos}
    for ds in datasets:
        # mean E_A / cpu across k per algo on this dataset
        ea = {a: np.mean([err[(ds, k, a)]["mean"] for k in K_VALUES
                          if (ds, k, a) in err]) for a in algos}
        ct = {a: np.mean([err[(ds, k, a)]["cpu"] for k in K_VALUES
                          if (ds, k, a) in err]) for a in algos}
        for table, vals in ((acc, ea), (cpu, ct)):
            lo, hi = min(vals.values()), max(vals.values())
            for a in algos:
                s = 1.0 if hi == lo else 1.0 - (vals[a] - lo) / (hi - lo)
                table[a] += s
    return {"accuracy": acc, "cpu": cpu, "n_datasets": len(datasets)}
