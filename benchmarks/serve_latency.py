"""Serving latency/throughput: coalesced batching vs request-at-a-time.

Measures the `repro.serve` subsystem end to end with closed-loop client
threads (each submits its next request as soon as the previous response
lands, so the offered load is exactly ``clients`` concurrent requests):

* **per_request** — the baseline the batcher replaces: ``max_batch`` is
  one request's bucket, so every launch carries exactly one request.
* **batched** — the coalescing frontend at several ``max_linger_ms``
  settings (0 = launch as soon as the worker is free, >0 = hold the first
  request briefly to pack concurrent clients into one launch).

Every cell records submit-to-completion latency percentiles (queueing and
linger included), request/point throughput, the realized
requests-per-launch, and the jit recompile counter delta after bucket
warmup — which must be **zero**: the power-of-two shape buckets are the
whole point.  A final cell re-runs the batched config while a background
thread hot-swaps the serving centroids mid-traffic and checks that every
offered request completes (no drops) across multiple centroid versions.

Writes BENCH_serve.json at the repo root (committed — the serving perf
trajectory future PRs regress against) and results/serve_latency.csv.

    PYTHONPATH=src python -m benchmarks.serve_latency [--fast]
"""
from __future__ import annotations

import argparse
import csv
import os
import threading
import time

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

K, N = 25, 20                    # paper default clustering shape
REQ_POINTS = 48                  # one client request; buckets to 64


def _centroids(seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).standard_normal((K, N)).astype(
        np.float32) * 3.0


def _client_requests(clients: int, reqs: int) -> list[list[np.ndarray]]:
    rng = np.random.default_rng(1)
    return [[rng.standard_normal((REQ_POINTS, N)).astype(np.float32)
             for _ in range(reqs)] for _ in range(clients)]


def _cell_config(mode: str, linger_ms: float):
    from repro.serve import ServeConfig
    from repro.serve.config import _next_pow2

    bucket = _next_pow2(REQ_POINTS)
    if mode == "per_request":
        # one request per launch, by construction: a second request of
        # REQ_POINTS rows can never fit under max_batch.
        return ServeConfig(min_bucket=bucket, max_batch=bucket,
                           max_linger_ms=0.0, queue_depth=1024)
    return ServeConfig(min_bucket=bucket, max_batch=4096,
                       max_linger_ms=linger_ms, queue_depth=1024)


def _run_cell(mode: str, linger_ms: float, clients: int, reqs: int,
              C: np.ndarray, *, swapper: bool = False) -> dict:
    """One (mode, linger, offered-load) cell of the sweep."""
    from repro.serve import serve

    requests = _client_requests(clients, reqs)
    versions: list[set] = [set() for _ in range(clients)]
    completed = [0] * clients
    errors: list[str] = []

    with serve({"m": C}, _cell_config(mode, linger_ms)) as srv:
        warm = srv.recompiles("m")
        barrier = threading.Barrier(clients + 1)

        def client(cid: int) -> None:
            barrier.wait()
            for pts in requests[cid]:
                try:
                    r = srv.assign("m", pts, timeout=300)
                except Exception as exc:
                    errors.append(f"{type(exc).__name__}: {exc}")
                    return
                versions[cid].add(r.version)
                completed[cid] += 1

        threads = [threading.Thread(target=client, args=(cid,), daemon=True)
                   for cid in range(clients)]
        for t in threads:
            t.start()

        stop_swap = threading.Event()
        n_swaps = 0

        def swap_loop() -> None:
            nonlocal n_swaps
            seed = 100
            while not stop_swap.is_set():
                srv.swap("m", _centroids(seed))
                n_swaps += 1
                seed += 1
                stop_swap.wait(0.02)

        swap_thread = None
        if swapper:
            swap_thread = threading.Thread(target=swap_loop, daemon=True)

        barrier.wait()
        t0 = time.monotonic()
        if swap_thread is not None:
            swap_thread.start()
        for t in threads:
            t.join()
        wall = time.monotonic() - t0
        if swap_thread is not None:
            stop_swap.set()
            swap_thread.join()

        stats = srv.stats("m")
        recompiles_post = srv.recompiles("m") - warm

    offered = clients * reqs
    done = sum(completed)
    seen = set().union(*versions) if versions else set()
    row = {
        "mode": mode,
        "linger_ms": linger_ms,
        "clients": clients,
        "reqs_per_client": reqs,
        "req_points": REQ_POINTS,
        "offered": offered,
        "completed": done,
        "dropped": offered - done,
        "errors": len(errors),
        "wall_s": round(wall, 3),
        "requests_per_s": round(done / wall, 1),
        "points_per_s": round(done * REQ_POINTS / wall, 1),
        "p50_ms": round(stats.get("p50_ms", 0.0), 3),
        "p99_ms": round(stats.get("p99_ms", 0.0), 3),
        "requests_per_batch": round(stats["requests_per_batch"], 2),
        "n_batches": stats["n_batches"],
        "n_rejected": stats["n_rejected"],
        "recompiles_post_warmup": recompiles_post,
        "n_swaps": n_swaps,
        "versions_observed": len(seen),
    }
    if errors:
        row["first_error"] = errors[0]
    return row


def bench(clients_sweep: tuple, reqs: int, lingers: tuple) -> list[dict]:
    C = _centroids()
    rows = []
    for clients in clients_sweep:
        cells = [("per_request", 0.0)] + [("batched", lg) for lg in lingers]
        for mode, linger in cells:
            row = _run_cell(mode, linger, clients, reqs, C)
            rows.append(row)
            print(f"{mode:12s} linger={linger:4.1f}ms clients={clients:<3d} "
                  f"req/s={row['requests_per_s']:8.1f}  "
                  f"p50={row['p50_ms']:7.2f}ms  p99={row['p99_ms']:7.2f}ms  "
                  f"req/batch={row['requests_per_batch']:5.2f}  "
                  f"recompiles={row['recompiles_post_warmup']}", flush=True)
    # hot-swap under the heaviest batched load
    row = _run_cell("batched_swap", lingers[-1], max(clients_sweep), reqs, C,
                    swapper=True)
    rows.append(row)
    print(f"{'batched_swap':12s} swaps={row['n_swaps']:<4d} "
          f"versions={row['versions_observed']:<3d} "
          f"dropped={row['dropped']}  req/s={row['requests_per_s']:8.1f}",
          flush=True)
    return rows


def _acceptance(rows: list[dict]) -> dict:
    """The claims this artifact commits to (checked before writing)."""
    by_clients: dict[int, dict[str, float]] = {}
    for r in rows:
        if r["mode"] in ("per_request", "batched"):
            cell = by_clients.setdefault(r["clients"], {})
            key = r["mode"]
            cell[key] = max(cell.get(key, 0.0), r["requests_per_s"])
    heavy = max(by_clients)
    speedup = by_clients[heavy]["batched"] / by_clients[heavy]["per_request"]
    swap_row = next(r for r in rows if r["mode"] == "batched_swap")
    summary = {
        "heaviest_load_clients": heavy,
        "batched_vs_per_request_speedup": round(speedup, 2),
        "recompiles_post_warmup_total": sum(
            r["recompiles_post_warmup"] for r in rows),
        "swap_under_load": {
            "n_swaps": swap_row["n_swaps"],
            "versions_observed": swap_row["versions_observed"],
            "offered": swap_row["offered"],
            "dropped": swap_row["dropped"],
        },
    }
    problems = []
    if speedup <= 1.0:
        problems.append(
            f"batched ({by_clients[heavy]['batched']} req/s) did not beat "
            f"per-request ({by_clients[heavy]['per_request']} req/s)")
    if summary["recompiles_post_warmup_total"] != 0:
        problems.append("serving recompiled after bucket warmup")
    if swap_row["dropped"] != 0 or swap_row["errors"] != 0:
        problems.append("hot-swap under load dropped requests")
    if swap_row["versions_observed"] < 2:
        problems.append("hot-swap cell never observed a second version")
    summary["pass"] = not problems
    if problems:
        summary["problems"] = problems
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer clients/requests (CI smoke)")
    args = ap.parse_args()

    from repro.evalsuite import schema as bench_schema

    clients_sweep = (2, 8) if args.fast else (2, 8, 32)
    reqs = 40 if args.fast else 150
    lingers = (1.0,) if args.fast else (1.0, 5.0)

    rows = bench(clients_sweep, reqs, lingers)
    summary = _acceptance(rows)

    os.makedirs(os.path.join(REPO, "results"), exist_ok=True)
    csv_path = os.path.join(REPO, "results", "serve_latency.csv")
    fields = sorted({f for r in rows for f in r})
    with open(csv_path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields)
        w.writeheader()
        w.writerows(rows)

    json_path = bench_schema.write_bench(
        os.path.join(REPO, "BENCH_serve.json"),
        bench_schema.envelope(
            "serve_latency", rows,
            shape={"k": K, "n": N, "req_points": REQ_POINTS},
            protocol="closed-loop clients (offered load = clients); "
                     "latency = submit-to-completion incl. queueing/linger; "
                     "per_request mode caps max_batch at one request's "
                     "bucket so every launch carries exactly one request",
            summary=summary,
        ))
    print(f"# wrote {json_path} and {csv_path}")
    if not summary["pass"]:
        raise SystemExit(
            "serve_latency acceptance failed: " + "; ".join(summary["problems"]))


if __name__ == "__main__":
    main()
