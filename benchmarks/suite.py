"""CLI for the §5 reproduction suite (`repro.evalsuite`).

Runs the Big-means-vs-baselines quality/speed sweep over the dataset
registry and writes one schema-validated ``BENCH_suite.json`` (repo root)
plus ``results/suite_runs.csv``.  The regression gate diffs that artifact
against the committed ``results/BENCH_baseline.json``:

    PYTHONPATH=src python -m benchmarks.suite --quick
    PYTHONPATH=src python -m repro.evalsuite.gate \
        --baseline results/BENCH_baseline.json --fresh BENCH_suite.json

Refreshing the committed baseline after an intentional quality change:

    PYTHONPATH=src python -m benchmarks.suite --quick \
        --out results/BENCH_baseline.json
"""
from __future__ import annotations

import argparse
import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="the PR-gate tier: small datasets, 2 seeds "
                         "(default: the full nightly tier)")
    ap.add_argument("--seeds", type=int, default=None, metavar="N",
                    help="override the number of seeds (0..N-1)")
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_suite.json"))
    ap.add_argument("--csv",
                    default=os.path.join(REPO, "results", "suite_runs.csv"))
    ap.add_argument("--data-root", default=None,
                    help="where dataset memmaps materialize "
                         "(default: a per-user temp dir)")
    args = ap.parse_args(argv)

    from repro.evalsuite import suite

    tier = "quick" if args.quick else "full"
    seeds = tuple(range(args.seeds)) if args.seeds is not None else None
    doc = suite.run_suite(tier, seeds=seeds, data_root=args.data_root)
    suite.write_outputs(doc, args.out, args.csv)

    for cell in doc["cells"]:
        print(f"{cell['dataset']:14s} {cell['method']:22s} "
              f"eps_mean={cell['epsilon_mean']:+.4f}  "
              f"success={cell['success_rate']:.2f}  "
              f"wall={cell['wall_mean_s']:6.2f}s")
    print(f"wrote {args.out} and {args.csv}")
    bootstrap = [d["name"] for d in doc["datasets"]
                 if d.get("f_star_source") != "committed"]
    if bootstrap:
        print("NOTE: uncommitted f_star (run-best bootstrap) for: "
              + ", ".join(bootstrap))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
