"""Chaos tier: the quick reproduction suite under a seeded FaultPlan.

For each quick-tier dataset the harness runs the streaming strategy twice
over bitwise-identical chunk streams:

* a fault-free run — and, midway, a staged checkpointed prefix whose
  newest checkpoint is then *truncated* (a torn write);
* a chaos run resuming over that corrupted checkpoint directory, with a
  seeded :class:`repro.engine.faults.FaultPlan` injecting ~10% transient
  fetch faults (recovered by ``retries=2``) plus one NaN-poisoned chunk
  (quarantined).

The run must then prove the fault-tolerance contract end-to-end:

1. it completes, and chunk accounting reconciles exactly
   (``done + failed + dropped + quarantined == fetched``);
2. the incumbent objective stays finite and monotone non-increasing;
3. restore healed past the torn write (``ckpt_fallback`` in the trace);
4. quality holds: ``eps_chaos - eps_clean <= --eps-tol`` (the same
   tolerance the suite gate applies to baseline drift).

Exit status is non-zero on any violation, so CI can gate on it::

    PYTHONPATH=src python -m benchmarks.chaos --seed 0
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _trajectory_monotone(trace) -> bool:
    """The streaming runner's checkpoint entries carry f_best; with a fixed
    chunk size the raw incumbent must never rise."""
    fs = [t[1] for t in trace
          if len(t) == 3 and isinstance(t[0], (int, np.integer))]
    return all(b <= a * (1.0 + 1e-4) for a, b in zip(fs, fs[1:]))


def run_cell(spec, *, seed: int, data_root: str | None, eps_tol: float,
             retries: int = 2) -> dict:
    from repro.api import BigMeansConfig, evaluate, fit
    from repro.cluster import runner
    from repro.engine import faults
    from repro.evalsuite import datasets, metrics

    src = datasets.source(spec, data_root)
    provider = src.provider(spec.s, seed=seed)
    cfg = BigMeansConfig(k=spec.k, s=spec.s, n_chunks=spec.n_chunks,
                         prefetch=2, seed=seed,
                         retries=retries, retry_backoff_s=0.0,
                         validate_chunks=True)

    clean = fit(provider, cfg, method="streaming", n_features=src.n_features)
    _, f_clean = evaluate(clean, src)
    eps_clean = metrics.relative_error(f_clean, spec.f_star)

    # Stage a checkpointed prefix of the same stream, then tear its newest
    # checkpoint so the chaos run has to self-heal on resume.
    ckpt_dir = tempfile.mkdtemp(prefix=f"chaos-{spec.name}-")
    stage = cfg.replace(n_chunks=spec.n_chunks // 2,
                        ckpt_dir=ckpt_dir, ckpt_every=spec.n_chunks // 4)
    runner.run(provider, stage, n_features=src.n_features)
    faults.corrupt_checkpoint(ckpt_dir)

    # ~10% transient fetch faults everywhere + one poisoned chunk in the
    # post-resume tail (an earlier id would be skipped by the resume).
    plan = faults.FaultPlan(seed=seed + 0xC4A05, transient_rate=0.10,
                            transient_attempts=1,
                            nan_ids=(spec.n_chunks - 3,))
    wrapped = plan.wrap(provider)
    chaos = fit(wrapped, cfg.replace(ckpt_dir=ckpt_dir,
                                     ckpt_every=spec.n_chunks // 4),
                method="streaming", n_features=src.n_features)
    _, f_chaos = evaluate(chaos, src)
    eps_chaos = metrics.relative_error(f_chaos, spec.f_star)

    h = chaos.health or {}
    fetched = sum(wrapped.attempts.values())
    checks = {
        "completed_finite": bool(np.isfinite(chaos.objective)
                                 and np.isfinite(f_chaos)),
        "accounting_reconciles": (
            h.get("chunks_done", -1) + h.get("chunks_failed", 0)
            + h.get("chunks_dropped", 0) + h.get("chunks_quarantined", 0)
            == h.get("chunks_fetched")),
        "fetch_attempts_consistent": (
            h.get("chunks_fetched", -1) + sum(
                1 for cid in plan.transient_ids(spec.n_chunks)
                if wrapped.attempts[cid] > 1) == fetched),
        "transients_recovered": h.get("chunks_failed") == 0,
        "poison_quarantined": h.get("chunks_quarantined") == 1,
        "checkpoint_healed": h.get("ckpt_fallback") is not None,
        "f_best_monotone": _trajectory_monotone(chaos.trace),
        "eps_within_tol": eps_chaos - eps_clean <= eps_tol,
    }
    return {
        "dataset": spec.name,
        "seed": seed,
        "eps_clean": eps_clean,
        "eps_chaos": eps_chaos,
        "eps_tol": eps_tol,
        "health": h,
        "transient_ids": plan.transient_ids(spec.n_chunks),
        "checks": checks,
        "ok": all(checks.values()),
    }


def main(argv=None) -> int:
    from repro.evalsuite import datasets, gate

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--datasets", nargs="*", default=None,
                    help="registry names (default: the quick tier)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eps-tol", type=float, default=gate.DEFAULT_EPS_TOL,
                    help="max eps_chaos - eps_clean (default: the suite "
                         "gate's epsilon tolerance)")
    ap.add_argument("--data-root", default=None)
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_chaos.json"))
    args = ap.parse_args(argv)

    names = args.datasets or datasets.list_datasets("quick")
    cells = []
    for name in names:
        spec = datasets.get_dataset(name)
        cell = run_cell(spec, seed=args.seed, data_root=args.data_root,
                        eps_tol=args.eps_tol)
        cells.append(cell)
        status = "ok" if cell["ok"] else "FAIL"
        print(f"{name:14s} eps_clean={cell['eps_clean']:+.4f}  "
              f"eps_chaos={cell['eps_chaos']:+.4f}  "
              f"quarantined={cell['health'].get('chunks_quarantined')}  "
              f"ckpt_fallback={cell['health'].get('ckpt_fallback')}  "
              f"[{status}]")
        for check, passed in cell["checks"].items():
            if not passed:
                print(f"  FAILED check: {check}")

    doc = {"bench": "chaos", "seed": args.seed, "cells": cells,
           "ok": all(c["ok"] for c in cells)}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2, default=str)
    print(f"wrote {args.out}")
    return 0 if doc["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
