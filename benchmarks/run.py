"""Benchmark harness — one section per paper table/figure.

  table5_50   per-dataset quality/time/n_d summaries (paper Tables 5-50)
  table3_4    normalized score summary across datasets (paper Tables 3-4)
  fig1_4      distance-evaluation counts vs k (paper Figures 1-4)
  chunk_sweep chunk-size trade-off (paper §4.1 analysis)
  kernels     per-kernel microbenchmarks (us/call)

Run everything: ``PYTHONPATH=src python -m benchmarks.run``
Subset:         ``... -m benchmarks.run --only tables --fast``
Prints ``name,us_per_call,derived`` CSV rows; writes detailed CSVs to
results/.
"""
from __future__ import annotations

import argparse
import csv
import json
import os
import time

import jax
import numpy as np

from benchmarks import common

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")

_ROWS: list = []        # every _emit row, for the --smoke JSON artifact


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
    _ROWS.append({"name": name, "us_per_call": round(us, 1),
                  "derived": derived})


def bench_tables(rows, outdir):
    err = common.relative_errors(rows)
    path = os.path.join(outdir, "table5_50_quality.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["dataset", "k", "algo", "EA_min", "EA_mean", "EA_max",
                    "cpu_s", "n_d"])
        for (ds, k, algo), v in sorted(err.items()):
            w.writerow([ds, k, algo, f"{v['min']:.3f}", f"{v['mean']:.3f}",
                        f"{v['max']:.3f}", f"{v['cpu']:.3f}",
                        f"{v['n_d']:.3e}"])
    for (ds, k, algo), v in sorted(err.items()):
        if algo == "bigmeans":
            _emit(f"table5_50/{ds}/k{k}/bigmeans",
                  v["cpu"] * 1e6, f"EA_mean={v['mean']:.3f}%")
    sc = common.scores(rows)
    path = os.path.join(outdir, "table3_4_scores.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["algo", "accuracy_score", "cpu_score", "max_possible"])
        for a in sorted(sc["accuracy"]):
            w.writerow([a, f"{sc['accuracy'][a]:.3f}", f"{sc['cpu'][a]:.3f}",
                        sc["n_datasets"]])
    nds = sc["n_datasets"]
    for a in sorted(sc["accuracy"]):
        _emit(f"table3_4/{a}", 0.0,
              f"acc={sc['accuracy'][a]:.2f}/{nds};cpu={sc['cpu'][a]:.2f}/{nds}")
    # figures 1-4: n_d vs k per algo
    path = os.path.join(outdir, "fig1_4_distance_evals.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["dataset", "k", "algo", "n_d"])
        for (ds, k, algo), v in sorted(err.items()):
            w.writerow([ds, k, algo, f"{v['n_d']:.3e}"])
    return sc


def bench_chunk_sweep(outdir, fast=False):
    """Paper §4.1: chunk size controls approximation/variability balance."""
    from repro.core import big_means, full_objective
    from repro.data.synthetic import GMMSpec, gmm_dataset
    X = gmm_dataset(GMMSpec(m=40000, n=20, components=15, spread=4.0, seed=4))
    sizes = (250, 1000, 4000) if fast else (125, 250, 500, 1000, 2000, 4000,
                                            8000)
    path = os.path.join(outdir, "chunk_size_sweep.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["s", "f_mean", "f_std", "cpu_s"])
        for s in sizes:
            fs, t0 = [], time.monotonic()
            for e in range(3):
                st, _ = big_means(X, jax.random.PRNGKey(e), k=15, s=s,
                                  n_chunks=30)
                fs.append(float(full_objective(X, st.centroids)))
            cpu = (time.monotonic() - t0) / 3
            w.writerow([s, f"{np.mean(fs):.4e}", f"{np.std(fs):.4e}",
                        f"{cpu:.3f}"])
            _emit(f"chunk_sweep/s{s}", cpu * 1e6,
                  f"f_mean={np.mean(fs):.4e}")


def bench_kernels(outdir):
    """us/call for the hot kernels (jnp reference path on CPU; the Pallas
    kernels target TPU and are validated in interpret mode by tests)."""
    from repro.kernels import ops
    shapes = [(16384, 64, 25), (65536, 28, 25), (8192, 512, 25)]
    path = os.path.join(outdir, "kernel_bench.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["kernel", "m", "n", "k", "us_per_call", "gflops"])
        for m, n, k in shapes:
            x = jax.random.normal(jax.random.PRNGKey(0), (m, n))
            c = jax.random.normal(jax.random.PRNGKey(1), (k, n))
            ids, _ = ops.assign(x, c, impl="ref")
            for name, fn in (
                ("assign", lambda: ops.assign(x, c, impl="ref")[1]),
                ("update", lambda: ops.update(x, ids, k, impl="ref")[0]),
            ):
                fn().block_until_ready()
                t0 = time.monotonic()
                reps = 5
                for _ in range(reps):
                    fn().block_until_ready()
                us = (time.monotonic() - t0) / reps * 1e6
                flops = 2.0 * m * n * k if name == "assign" else 2.0 * m * n
                w.writerow([name, m, n, k, f"{us:.1f}",
                            f"{flops / (us * 1e-6) / 1e9:.2f}"])
                _emit(f"kernel/{name}/m{m}n{n}k{k}", us,
                      f"gflops={flops / (us * 1e-6) / 1e9:.2f}")


def bench_smoke(outdir):
    """CI smoke run: kernel microbenchmarks + a minimal batched-throughput
    probe, written to results/BENCH_smoke.json (uploaded as a CI artifact)."""
    from repro.core import big_means_batched
    from repro.data.synthetic import GMMSpec, gmm_dataset

    bench_kernels(outdir)
    X = gmm_dataset(GMMSpec(m=40000, n=20, components=15, seed=4))
    for batch in (1, 4):
        rounds = 8 // batch
        fn = lambda: big_means_batched(
            X, jax.random.PRNGKey(0), k=25, s=4096, batch=batch,
            rounds=rounds, impl="ref")[0].f_best.block_until_ready()
        fn()                                   # compile
        t0 = time.monotonic()
        fn()
        dt = time.monotonic() - t0
        _emit(f"smoke/batched/b{batch}", dt * 1e6 / 8,
              f"chunks_per_s={8 / dt:.2f}")
    path = os.path.join(outdir, "BENCH_smoke.json")
    with open(path, "w") as f:
        json.dump(_ROWS, f, indent=1)
    print(f"# wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["tables", "chunk_sweep", "kernels"])
    ap.add_argument("--fast", action="store_true",
                    help="reduced suite for smoke runs")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke subset; writes results/BENCH_smoke.json")
    args = ap.parse_args()
    os.makedirs(RESULTS, exist_ok=True)

    if args.smoke:
        bench_smoke(RESULTS)
        return
    if args.only in (None, "kernels"):
        bench_kernels(RESULTS)
    if args.only in (None, "chunk_sweep"):
        bench_chunk_sweep(RESULTS, fast=args.fast)
    if args.only in (None, "tables"):
        suite = common.SUITE[:3] if args.fast else common.SUITE
        kv = (2, 10) if args.fast else common.K_VALUES
        ne = 1 if args.fast else common.N_EXEC
        rows = common.full_sweep(suite=suite, k_values=kv, n_exec=ne)
        sc = bench_tables(rows, RESULTS)
        print("# scores:", {k: round(v, 2) for k, v in sc["accuracy"].items()})


if __name__ == "__main__":
    main()
