"""Engine composition benchmark: fixed-s vs competitive-s at equal budget.

The ``competitive_s`` scheduler (arXiv:2403.18766) races per-stream sample
sizes and reallocates streams toward the empirically winning ``s``.  This
benchmark gives every contender the SAME total chunk budget and compares
the full-data objective f(C, X):

* ``fixed_s`` rows — the uniform scheduler at each ladder size alone (what
  you get when you hand-pick that ``s``);
* ``competitive_s`` row — the racing scheduler over the whole ladder, plus
  which size won (its surviving allocation).

The point is robustness, not a guaranteed win: a hand-picked *good* ``s``
ties the race, but a hand-picked *bad* one loses to it — and the race never
needed the pick.  All runs go through ``repro.api.fit`` on the streaming
strategy (the engine's persistent-stream loop), ``impl='ref'``.

Writes BENCH_engine.json at the repo root (committed — the quality
trajectory future PRs regress against) and results/engine_compare.csv.

    PYTHONPATH=src python -m benchmarks.engine_compare [--fast]
"""
from __future__ import annotations

import argparse
import csv
import json
import os
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main(fast: bool = False, topology: str = "single"):
    from repro.api import BigMeansConfig, evaluate, fit
    from repro.data.synthetic import GMMSpec, gmm_dataset

    m = 20000 if fast else 40000
    k, n = 15, 20
    ladder = (256, 4096, 16384) if not fast else (128, 2048, 8192)
    s_mid = ladder[1]
    batch = 6
    n_chunks = 48 if fast else 96
    X = gmm_dataset(GMMSpec(m=m, n=n, components=k, spread=4.0, seed=11))

    rows = []

    def run(name, cfg):
        t0 = time.monotonic()
        r = fit(X, cfg, method="streaming")
        wall = time.monotonic() - t0
        _, f_full = evaluate(r, X)
        row = {
            "variant": name,
            "scheduler": cfg.scheduler,
            "s": cfg.s,
            "batch": cfg.batch,
            "n_chunks": n_chunks,
            "chunks_done": r.n_chunks,
            "f_full_per_point": round(f_full / m, 6),
            "n_accepted": r.n_accepted,
            "lloyd_iters": r.n_iterations,
            "wall_s": round(wall, 3),
        }
        if "competitive_s" in r.extras:
            info = r.extras["competitive_s"]
            row["ladder"] = list(info["ladder"])
            row["final_sizes"] = info["final_sizes"]
            row["windows"] = info["windows"]
        rows.append(row)
        print(f"{name:>22}: f/point={row['f_full_per_point']:.4f}  "
              f"chunks={r.n_chunks}  wall={wall:.2f}s")
        return row

    # fixed-s contenders: each ladder size alone, equal chunk budget
    for s in ladder:
        cfg = BigMeansConfig(k=k, s=s, n_chunks=n_chunks, batch=batch,
                             sync_every=2, impl="ref", seed=3,
                             log_every=0, topology=topology)
        run(f"fixed_s={s}", cfg)

    # the race over the same ladder, same budget
    cfg = BigMeansConfig(k=k, s=s_mid, n_chunks=n_chunks, batch=batch,
                         sync_every=2, scheduler="competitive_s",
                         competitive_ladder=ladder, impl="ref", seed=3,
                         log_every=0, topology=topology)
    run("competitive_s", cfg)

    best_fixed = min(r["f_full_per_point"] for r in rows[:-1])
    worst_fixed = max(r["f_full_per_point"] for r in rows[:-1])
    comp = rows[-1]["f_full_per_point"]
    summary = {
        "best_fixed_f_per_point": best_fixed,
        "worst_fixed_f_per_point": worst_fixed,
        "competitive_f_per_point": comp,
        "competitive_vs_best_fixed": round(comp / best_fixed, 4),
        "competitive_vs_worst_fixed": round(comp / worst_fixed, 4),
    }
    from repro.evalsuite import schema as bench_schema

    out = bench_schema.envelope(
        "engine_compare", rows,
        dataset={"m": m, "n": n, "components": k},
        k=k,
        ladder=list(ladder),
        equal_chunk_budget=n_chunks,
        impl="ref",
        summary=summary,
    )
    path = bench_schema.write_bench(
        os.path.join(REPO, "BENCH_engine.json"), out)
    os.makedirs(os.path.join(REPO, "results"), exist_ok=True)
    csv_path = os.path.join(REPO, "results", "engine_compare.csv")
    keys = ["variant", "scheduler", "s", "batch", "n_chunks", "chunks_done",
            "f_full_per_point", "n_accepted", "lloyd_iters", "wall_s"]
    with open(csv_path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(keys)
        for r in rows:
            w.writerow([r.get(c, "") for c in keys])
    print(f"summary: {json.dumps(summary)}")
    print(f"wrote {path} and {csv_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller dataset / budget (CI smoke)")
    ap.add_argument("--topology", default="single",
                    choices=["single", "stream_mesh", "host_mesh", "auto"],
                    help="declarative execution placement (BigMeansConfig"
                         ".topology); host_mesh expects the REPRO_* "
                         "bootstrap env vars")
    args = ap.parse_args()
    main(fast=args.fast, topology=args.topology)
