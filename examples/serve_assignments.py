"""Serving-style demo: a (tiny) assignment service over trained centroids.

The paper notes the final point-to-centroid assignment is itself a streaming
workload — clients submit batches of vectors, the service returns cluster ids
from the incumbent centroids (optionally refreshed from a checkpoint).

    PYTHONPATH=src python examples/serve_assignments.py
"""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import BigMeansConfig, fit, synthetic
from repro.cluster import checkpoint
from repro.core import bigmeans
from repro.kernels import ops

SPEC = synthetic.GMMSpec(m=1_000_000, n=12, components=10, seed=5)


def main():
    # "train": quick clustering run through the facade, checkpointed
    ckpt = os.path.join(tempfile.gettempdir(), "bigmeans_serve_ckpt")
    cfg = BigMeansConfig(k=10, s=4096, n_chunks=40, ckpt_dir=ckpt,
                         ckpt_every=20, seed=0, resume=False)
    result = fit(lambda cid: np.asarray(synthetic.gmm_chunk(SPEC, cid, 4096)),
                 cfg, method="streaming", n_features=SPEC.n)
    print(f"trained: {result.summary()}")

    # "serve": load centroids from the checkpoint, answer batched requests
    (restored, _key), step = checkpoint.restore(
        ckpt, (bigmeans.init_state(cfg.k, SPEC.n), jax.random.PRNGKey(0)))
    centroids = restored.centroids
    print(f"serving centroids from checkpoint step {step}")

    assign = jax.jit(lambda q: ops.assign(q, centroids, impl="ref")[0])
    latencies = []
    for req in range(20):
        batch = jnp.asarray(np.asarray(
            synthetic.gmm_chunk(SPEC, 50_000 + req, 256)))   # client batch
        t0 = time.monotonic()
        ids = assign(batch)
        ids.block_until_ready()
        latencies.append((time.monotonic() - t0) * 1e3)
    print(f"20 requests x 256 vectors: p50={np.percentile(latencies, 50):.2f}ms "
          f"p99={np.percentile(latencies, 99):.2f}ms")
    print("cluster histogram of last batch:",
          np.bincount(np.asarray(ids), minlength=10).tolist())


if __name__ == "__main__":
    main()
