"""Serving demo: train, register, serve concurrent clients, hot-swap.

The paper's end product is a centroid set; its value is realized at
assignment time, and point-to-centroid lookup is itself a streaming
workload.  This example runs the whole lifecycle through the public API:

1. **train** — a checkpointed streaming Big-means fit;
2. **serve** — register the result with ``repro.api.serve()``: concurrent
   client threads submit small point batches, the batching frontend
   coalesces them into padded power-of-two launches (zero recompiles
   after warmup);
3. **hot-swap** — a :class:`CheckpointWatcher` polls the checkpoint
   directory; training continues mid-traffic and the watcher atomically
   swaps the improved centroids in without dropping a single request.

    PYTHONPATH=src python examples/serve_assignments.py
    PYTHONPATH=src python examples/serve_assignments.py \
        --chunks 24 --clients 4 --requests 30        # CI-sized
"""
import argparse
import os
import tempfile
import threading

import numpy as np

from repro.api import BigMeansConfig, ServeConfig, fit, serve, synthetic

SPEC = synthetic.GMMSpec(m=1_000_000, n=12, components=10, seed=5)


def provider(chunk_id: int) -> np.ndarray:
    return np.asarray(synthetic.gmm_chunk(SPEC, chunk_id, 4096))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chunks", type=int, default=40,
                    help="chunks for the initial training stage")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests", type=int, default=60,
                    help="requests per client")
    args = ap.parse_args()

    # -- train: checkpointed streaming fit through the facade ---------------
    ckpt = os.path.join(tempfile.gettempdir(), "bigmeans_serve_ckpt")
    cfg = BigMeansConfig(k=10, s=4096, n_chunks=args.chunks, ckpt_dir=ckpt,
                         ckpt_every=max(1, args.chunks // 2), seed=0,
                         resume=False)
    result = fit(provider, cfg, method="streaming", n_features=SPEC.n)
    print(f"trained: {result.summary()}")

    # -- serve: concurrent clients against the registered model ------------
    serve_cfg = ServeConfig(min_bucket=64, max_batch=1024, max_linger_ms=2.0)
    rng = np.random.default_rng(0)
    done = []

    with serve({"gmm": result}, serve_cfg) as srv:
        watcher = srv.watch("gmm", ckpt, poll_interval_s=0.05)

        def client(cid: int) -> None:
            n_ok, versions = 0, set()
            for req in range(args.requests):
                batch = provider(50_000 + cid * args.requests + req)
                batch = batch[: int(rng.integers(32, 256))]
                resp = srv.assign("gmm", batch)
                versions.add(resp.version)
                n_ok += 1
            done.append((cid, n_ok, versions))

        threads = [threading.Thread(target=client, args=(cid,), daemon=True)
                   for cid in range(args.clients)]
        for t in threads:
            t.start()

        # -- hot-swap: training continues while traffic flows ---------------
        more = fit(provider, cfg, method="streaming", n_features=SPEC.n,
                   resume=True, n_chunks=args.chunks * 2)
        print(f"retrained: {more.summary()}")

        for t in threads:
            t.join()

        stats = srv.stats("gmm")
        print(f"served {stats['n_requests']} requests in "
              f"{stats['n_batches']} launches "
              f"({stats['requests_per_batch']:.2f} req/launch): "
              f"p50={stats.get('p50_ms', 0):.2f}ms "
              f"p99={stats.get('p99_ms', 0):.2f}ms")
        print(f"recompiles after warmup: "
              f"{stats['recompiles'] - len(serve_cfg.buckets())} "
              f"(buckets: {serve_cfg.buckets()})")
        print(f"hot-swaps applied: {watcher.n_swaps} "
              f"(serving step {stats['step']}); trace: {srv.trace}")

    total = sum(n for _, n, _ in done)
    versions = set().union(*(v for _, _, v in done))
    assert total == args.clients * args.requests, "dropped requests!"
    print(f"all {total} client requests completed; "
          f"centroid versions observed: {sorted(versions)}")


if __name__ == "__main__":
    main()
