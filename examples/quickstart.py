"""Quickstart: cluster a synthetic big-data stream through `repro.api`.

One config, one ``fit()``: the execution strategy is a knob, and the paper's
§5 competitors answer through the same interface.

    PYTHONPATH=src python examples/quickstart.py [--m 200000] [--chunks 40]
"""
import argparse

from repro.api import BigMeansConfig, evaluate, fit, synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=200_000, help="dataset rows")
    ap.add_argument("--chunks", type=int, default=40, help="chunk budget")
    args = ap.parse_args()

    # synthetic stream: args.m points, 16 features, 12 latent components
    X = synthetic.gmm_dataset(
        synthetic.GMMSpec(m=args.m, n=16, components=12, seed=0))
    cfg = BigMeansConfig(k=12, s=min(4000, args.m // 4), n_chunks=args.chunks)
    print(f"dataset: {X.shape},  k={cfg.k},  chunk size s={cfg.s}")

    result = fit(X, cfg)                     # 'auto' picks the strategy
    print(f"strategy: {result.strategy},  chunks: {result.n_chunks}, "
          f"accepted improvements: {result.n_accepted}")
    print(f"distance evaluations: {result.n_dist_evals:.3e} "
          f"(full K-means needs ~{2.0 * X.shape[0] * cfg.k * 20:.3e} per run)")

    _, f = evaluate(result, X)
    print(f"Big-means    f(C, X) = {f:.6e}")

    # reference: multi-start K-means++ on the FULL dataset, same fit() call
    ref = fit(X, cfg, method="kmeanspp", seed=1)
    print(f"K-means++    f(C, X) = {ref.objective:.6e} "
          f"({ref.n_iterations} Lloyd iterations over all {X.shape[0]} points)")


if __name__ == "__main__":
    main()
