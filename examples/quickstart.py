"""Quickstart: cluster a synthetic big-data stream with Big-means.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import big_means, full_assignment, full_objective, kmeanspp, lloyd
from repro.data.synthetic import GMMSpec, gmm_dataset


def main():
    # 200k points, 16 features, 12 latent components
    X = gmm_dataset(GMMSpec(m=200_000, n=16, components=12, seed=0))
    k, s = 12, 4000

    print(f"dataset: {X.shape},  k={k},  chunk size s={s}")
    state, infos = big_means(X, jax.random.PRNGKey(0), k=k, s=s, n_chunks=40)
    print(f"chunks processed: 40, accepted improvements: {int(state.n_accepted)}")
    print(f"distance evaluations: {float(state.n_dist_evals):.3e} "
          f"(full K-means needs ~{2.0 * X.shape[0] * k * 20:.3e} per run)")

    ids, f = full_assignment(X, state.centroids)
    print(f"Big-means   f(C, X) = {float(f):.6e}")

    # reference: K-means++ + Lloyd on the FULL dataset
    c0 = kmeanspp(X, jax.random.PRNGKey(1), k)
    res = lloyd(X, c0)
    print(f"full K-means f(C, X) = {float(res.objective):.6e} "
          f"({int(res.iterations)} Lloyd iterations over all {X.shape[0]} points)")


if __name__ == "__main__":
    main()
