"""End-to-end driver: out-of-core Big-means with checkpoints and restart.

Streams a virtual 8M x 28 dataset (HEPMASS-scale surrogate) through the
production runner for a few hundred chunks, checkpoints along the way,
simulates a crash + restart, and finishes with the full assignment pass.

    PYTHONPATH=src python examples/bigdata_clustering.py [--chunks 300]
"""
import argparse
import os
import tempfile

import jax
import numpy as np

from repro.cluster import runner
from repro.core import full_assignment
from repro.data.synthetic import GMMSpec, gmm_chunk

SPEC = GMMSpec(m=8_000_000, n=28, components=25, spread=4.0, seed=17)
S = 8192                     # chunk size


def provider(chunk_id: int) -> np.ndarray:
    """Fetch one uniform chunk of the virtual dataset (never materialized)."""
    return np.asarray(gmm_chunk(SPEC, chunk_id, S))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chunks", type=int, default=300)
    ap.add_argument("--k", type=int, default=25)
    args = ap.parse_args()

    ckpt = os.path.join(tempfile.gettempdir(), "bigmeans_demo_ckpt")
    cfg = runner.RunnerConfig(
        k=args.k, s=S, n_chunks=args.chunks,
        ckpt_dir=ckpt, ckpt_every=50, log_every=25, seed=0)

    print(f"phase 1: clustering {args.chunks // 2} chunks, then 'crashing'…")
    cfg1 = runner.RunnerConfig(**{**cfg.__dict__, "n_chunks": args.chunks // 2})
    state, m = runner.run(provider, cfg1, n_features=SPEC.n)
    print(f"  f_best={m.f_best:.5e}  accepted={m.accepted}  "
          f"wall={m.wall_time_s:.1f}s")

    print("phase 2: restart from checkpoint, finish the budget…")
    state, m = runner.run(provider, cfg, n_features=SPEC.n, resume=True)
    print(f"  f_best={m.f_best:.5e}  accepted={m.accepted}  "
          f"chunks_done={m.chunks_done} (resumed)  wall={m.wall_time_s:.1f}s")
    for cid, fb, fn in m.trace:
        print(f"    chunk {cid:4d}: incumbent {fb:.5e}  candidate {fn:.5e}")

    print("final pass: assigning a 1M-point sample to the centroids…")
    sample = np.concatenate([provider(10_000 + i) for i in range(128)])
    ids, f = full_assignment(jax.numpy.asarray(sample), state.centroids)
    sizes = np.bincount(np.asarray(ids), minlength=args.k)
    print(f"  f(C, sample)/point = {float(f) / len(sample):.4f}")
    print(f"  cluster sizes: min={sizes.min()} median={int(np.median(sizes))} "
          f"max={sizes.max()}")


if __name__ == "__main__":
    main()
