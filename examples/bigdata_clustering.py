"""End-to-end driver: out-of-core Big-means with checkpoints and restart,
entirely through `repro.api`.

Streams a virtual 8M x 28 dataset (HEPMASS-scale surrogate) through the
streaming strategy for a few hundred chunks, checkpoints along the way,
simulates a crash + restart, and finishes with the full assignment pass.

    PYTHONPATH=src python examples/bigdata_clustering.py [--chunks 300]
"""
import argparse
import os
import shutil
import tempfile

import numpy as np

from repro.api import BigMeansConfig, evaluate, fit, synthetic

SPEC = synthetic.GMMSpec(m=8_000_000, n=28, components=25, spread=4.0, seed=17)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chunks", type=int, default=300)
    ap.add_argument("--k", type=int, default=25)
    ap.add_argument("--s", type=int, default=8192, help="chunk size")
    ap.add_argument("--topology", default="auto",
                    choices=["auto", "single", "stream_mesh", "host_mesh"],
                    help="declarative placement spec; host_mesh reads the "
                         "REPRO_COORD/REPRO_NUM_HOSTS/REPRO_HOST_RANK env "
                         "vars set by the multi-process launcher")
    args = ap.parse_args()

    def provider(chunk_id: int) -> np.ndarray:
        """Fetch one chunk of the virtual dataset (never materialized)."""
        return np.asarray(synthetic.gmm_chunk(SPEC, chunk_id, args.s))

    ckpt = os.path.join(tempfile.gettempdir(), "bigmeans_demo_ckpt")
    shutil.rmtree(ckpt, ignore_errors=True)      # deterministic demo reruns
    cfg = BigMeansConfig(
        k=args.k, s=args.s, n_chunks=args.chunks, topology=args.topology,
        ckpt_dir=ckpt, ckpt_every=50, log_every=25, seed=0)

    print(f"phase 1: clustering {args.chunks // 2} chunks, then 'crashing'…")
    r1 = fit(provider, cfg.replace(n_chunks=args.chunks // 2, resume=False),
             method="streaming", n_features=SPEC.n)
    print(f"  f_best={r1.objective:.5e}  accepted={r1.n_accepted}  "
          f"wall={r1.wall_time_s:.1f}s")

    print("phase 2: restart from checkpoint, finish the budget…")
    r2 = fit(provider, cfg, method="streaming", n_features=SPEC.n)
    print(f"  f_best={r2.objective:.5e}  accepted={r2.n_accepted}  "
          f"chunks_done={r2.n_chunks} (resumed)  wall={r2.wall_time_s:.1f}s")
    for entry in r2.trace:
        if entry[0] == "fetch_error":
            print(f"    chunk {entry[1]:4d}: FETCH FAILED {entry[2]}")
        else:
            cid, fb, fn = entry
            print(f"    chunk {cid:4d}: incumbent {fb:.5e}  candidate {fn:.5e}")

    print("final pass: assigning a 1M-point sample to the centroids…")
    n_sample = max(1, 1_000_000 // args.s)
    sample = np.concatenate([provider(10_000 + i) for i in range(n_sample)])
    ids, f = evaluate(r2, sample)
    sizes = np.bincount(np.asarray(ids), minlength=args.k)
    print(f"  f(C, sample)/point = {float(f) / len(sample):.4f}")
    print(f"  cluster sizes: min={sizes.min()} median={int(np.median(sizes))} "
          f"max={sizes.max()}")


if __name__ == "__main__":
    main()
