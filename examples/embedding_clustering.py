"""Big-means x the LM zoo: build a vector-quantization codebook over hidden
states of any ``--arch`` model (reduced config on CPU).

This is the integration point described in DESIGN.md §5: the paper's
technique is data/representation-level, so it composes with every assigned
architecture rather than modifying its forward pass.

    PYTHONPATH=src python examples/embedding_clustering.py --arch hymba-1.5b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import evaluate, fit
from repro.models import transformer as T
from repro.models.registry import get_config, model_fns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--codebook", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    mod = model_fns(cfg)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)

    # harvest hidden states from a batch of synthetic sequences
    B, S = 16, 64
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (B, 16, cfg.frontend_dim))
        logits, _ = mod.forward(cfg, params, tokens, frames)
    elif cfg.family == "vlm":
        frames = jax.random.normal(key, (B, cfg.frontend_len, cfg.frontend_dim))
        logits, _ = mod.forward(cfg, params, tokens, frontend=frames)
    else:
        logits, _ = mod.forward(cfg, params, tokens)
    # cluster the softmax logit rows as "embeddings" (any activation works)
    H = logits.reshape(-1, logits.shape[-1]).astype(jnp.float32)
    H = H[:, :128] if H.shape[1] > 128 else H
    print(f"{args.arch}: clustering {H.shape[0]} activation vectors "
          f"({H.shape[1]}-d) into a {args.codebook}-entry codebook")

    result = fit(H, key=key, k=args.codebook,
                 s=min(512, H.shape[0]), n_chunks=25)
    _, f = evaluate(result, H)
    mse = f / H.size
    var = float(jnp.var(H))
    print(f"codebook quantization MSE/dim = {mse:.5f} "
          f"(activation variance {var:.5f}, "
          f"compression residual {mse / var:.1%})")


if __name__ == "__main__":
    main()
