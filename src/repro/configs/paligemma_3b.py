"""paligemma-3b [arXiv:2407.07726] — SigLIP stub + gemma backbone (MQA).

The vision tower is a STUB per the assignment: input_specs feeds precomputed
patch embeddings [B, 256, 1152] (SigLIP-So400m output width); the backbone
uses a prefix-LM mask (bidirectional over the image prefix).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216,
    mlp="geglu", scale_embedding=True, tie_embeddings=True,
    frontend="vision", frontend_dim=1152, frontend_len=256,
)
