"""hymba-1.5b [arXiv:2411.13676] — parallel attention + mamba heads.

Simplifications noted in DESIGN.md: mean fusion of the two paths, no meta
tokens / cross-layer KV sharing.  3 global-attention layers (first, middle,
last), the rest sliding-window — hence sub-quadratic / long_500k eligible.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", hybrid=True,
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    mlp="swiglu", layer_pattern="mostly_local", window=1024,
    n_global_layers=3,
    ssm=True, ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    tie_embeddings=True, sub_quadratic=True,
)
