"""deepseek-moe-16b [arXiv:2401.06066] — fine-grained MoE, 2 shared + 64 routed top-6.

Simplification noted in DESIGN.md: all 28 layers are MoE (the release keeps
layer 0 dense); the 2 shared experts supply the dense path in every layer.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe", moe=True,
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400,
    num_experts=64, top_k=6, num_shared_experts=2, moe_d_ff=1408,
    mlp="swiglu", tie_embeddings=False,
)
