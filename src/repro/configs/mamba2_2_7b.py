"""mamba2-2.7b [arXiv:2405.21060] — SSD (state-space duality), attention-free."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm", ssm=True,
    num_layers=64, d_model=2560, num_heads=1, num_kv_heads=1, head_dim=1,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    mlp="swiglu", tie_embeddings=True, sub_quadratic=True,
)
