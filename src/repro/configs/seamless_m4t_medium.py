"""seamless-m4t-medium [arXiv:2308.11596] — enc-dec, audio frontend stub.

12 encoder + 12 decoder layers (the released medium topology); input_specs
feeds precomputed audio frame embeddings [B, S_src, 1024].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206,
    mlp="swiglu", tie_embeddings=False,
    encoder_layers=12, cross_attention=True,
    frontend="audio", frontend_dim=1024, frontend_len=4096,
)
