"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-235B-A22B] — 128 experts top-8, qk-norm."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe", moe=True,
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936,
    num_experts=128, top_k=8, num_shared_experts=0, moe_d_ff=1536,
    rope_theta=1_000_000.0, qk_norm=True,
    mlp="swiglu", tie_embeddings=False,
)
