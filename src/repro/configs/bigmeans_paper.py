"""The paper's own workload as a dry-runnable config: Big-means on a
HEPMASS-scale stream (m=10.5M, n=27, k=25, s=64000 — the paper's largest
setting), two-level decomposition on the production mesh.

The algorithm knobs live in one place — an embedded
:class:`repro.api.BigMeansConfig` (``.algo``) — and are exposed as read-only
properties for the launch/dry-run tooling, so this file can no longer drift
from the facade's config.
"""
from __future__ import annotations

import dataclasses
import warnings

from repro.api.config import BigMeansConfig

_PAPER_ALGO = BigMeansConfig(
    k=25,
    s=64_000,
    n_chunks=4,          # chunks per worker in the sharded dry-run
    sync_every=2,
    batch=8,             # in-core chunk parallelism (batched driver)
    prefetch=2,          # host runner's prefetch queue depth
)


class BigMeansWorkload:
    """Dataset descriptor + algorithm config.

    Only the dataset shape (``m``, ``n_features``) and registry identity
    (``name``, ``family``) live here; every algorithm knob is a view onto
    ``.algo``.  The legacy constructor keywords (``k=``, ``s=``,
    ``chunks_per_worker=``, ...) still work for one release behind a
    DeprecationWarning.
    """

    _LEGACY_TO_ALGO = {
        "k": "k", "s": "s", "chunks_per_worker": "n_chunks",
        "sync_every": "sync_every", "max_iters": "max_iters", "tol": "tol",
        "candidates": "candidates", "batch": "batch", "prefetch": "prefetch",
    }

    def __init__(self, name: str = "bigmeans_paper", family: str = "cluster",
                 m: int = 10_500_000, n_features: int = 27,
                 algo: BigMeansConfig | None = None, **legacy):
        self.name = name
        self.family = family
        self.m = m
        self.n_features = n_features
        unknown = set(legacy) - set(self._LEGACY_TO_ALGO)
        if unknown:
            raise TypeError(
                f"unknown BigMeansWorkload fields {sorted(unknown)}")
        if legacy:
            warnings.warn(
                "passing algorithm knobs to BigMeansWorkload is deprecated; "
                "pass algo=repro.api.BigMeansConfig(...) instead",
                DeprecationWarning, stacklevel=2)
            algo = dataclasses.replace(
                algo or _PAPER_ALGO,
                **{self._LEGACY_TO_ALGO[k]: v for k, v in legacy.items()})
        self.algo = algo or _PAPER_ALGO

    # read-only views of the shared knob truth
    k = property(lambda self: self.algo.k)
    s = property(lambda self: self.algo.s)
    chunks_per_worker = property(lambda self: self.algo.n_chunks)
    sync_every = property(lambda self: self.algo.sync_every)
    max_iters = property(lambda self: self.algo.max_iters)
    tol = property(lambda self: self.algo.tol)
    candidates = property(lambda self: self.algo.candidates)
    batch = property(lambda self: self.algo.batch)
    prefetch = property(lambda self: self.algo.prefetch)

    def __repr__(self):
        return (f"BigMeansWorkload(name={self.name!r}, m={self.m}, "
                f"n_features={self.n_features}, algo={self.algo!r})")


CONFIG = BigMeansWorkload()
