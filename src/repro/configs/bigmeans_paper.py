"""The paper's own workload as a dry-runnable config: Big-means on a
HEPMASS-scale stream (m=10.5M, n=27, k=25, s=64000 — the paper's largest
setting), two-level decomposition on the production mesh."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class BigMeansWorkload:
    name: str = "bigmeans_paper"
    family: str = "cluster"
    m: int = 10_500_000
    n_features: int = 27
    k: int = 25
    s: int = 64_000
    chunks_per_worker: int = 4
    sync_every: int = 2
    max_iters: int = 300
    tol: float = 1e-4
    candidates: int = 3
    # In-core chunk parallelism (batched driver): B incumbent streams per
    # device, and the host runner's prefetch queue depth.
    batch: int = 8
    prefetch: int = 2


CONFIG = BigMeansWorkload()
