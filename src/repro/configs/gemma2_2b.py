"""gemma2-2b [arXiv:2408.00118] — local/global alternating, logit softcaps."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000,
    mlp="geglu", layer_pattern="local_global", window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    scale_embedding=True, sandwich_norm=True, tie_embeddings=True,
    # local layers bound the KV working set => eligible for long_500k decode
    sub_quadratic=True,
)
