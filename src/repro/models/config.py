"""Architecture configuration shared by the whole model zoo."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    rope_theta: float = 10_000.0
    window: int | None = None        # sliding-window size for 'local' layers
    layer_pattern: str = "full"      # full | local_global | mostly_local
    n_global_layers: int = 0         # for mostly_local (hymba)
    attn_softcap: float | None = None
    final_softcap: float | None = None
    qk_norm: bool = False            # qwen3
    scale_embedding: bool = False    # gemma family: embed * sqrt(D)
    sandwich_norm: bool = False      # gemma2 post-norms

    # --- mlp ---
    mlp: str = "swiglu"              # swiglu | geglu | relu2

    # --- MoE ---
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.0

    # --- SSM (mamba2 / hymba SSM path) ---
    ssm: bool = False
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (hymba: parallel attn + ssm heads) ---
    hybrid: bool = False

    # --- encoder-decoder (seamless) ---
    encoder_layers: int = 0
    cross_attention: bool = False

    # --- modality frontend stubs (paligemma / seamless) ---
    frontend: str | None = None      # vision | audio
    frontend_dim: int = 0            # raw embedding dim fed by the stub
    frontend_len: int = 256          # prefix length (patches / frames)

    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    sub_quadratic: bool = False      # eligible for long_500k

    # reduced smoke-test proportions
    def reduced(self) -> "ModelConfig":
        d_model = 64
        head_dim = 16
        heads = max(2, min(4, self.num_heads))
        kv = max(1, min(heads, self.num_kv_heads * heads // max(self.num_heads, 1)))
        return dataclasses.replace(
            self,
            num_layers=2,
            encoder_layers=2 if self.encoder_layers else 0,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=head_dim,
            d_ff=128,
            moe_d_ff=32 if self.moe else 0,
            num_experts=8 if self.moe else 0,
            top_k=min(2, self.top_k) if self.moe else 0,
            vocab_size=512,
            window=8 if self.window else None,
            ssm_state=8 if (self.ssm or self.hybrid) else 0,
            ssm_head_dim=16 if (self.ssm or self.hybrid) else 0,
            ssm_chunk=16,
            frontend_dim=32 if self.frontend else 0,
            frontend_len=4 if self.frontend else 0,
            n_global_layers=min(1, self.n_global_layers),
        )

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return max(self.d_inner // max(self.ssm_head_dim, 1), 1)

    def param_count(self) -> int:
        """Total parameters N (analytic; used for 6ND roofline checks)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        H, KV, hd = self.num_heads, self.num_kv_heads, self.head_dim
        total = V * D                                   # embedding
        if not self.tie_embeddings:
            total += V * D
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D

        def mlp_params(ff):
            gates = 2 if self.mlp in ("swiglu", "geglu") else 1
            return gates * D * ff + ff * D

        if self.family == "ssm":
            di, N_, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            g = 1                                        # n_groups
            zxbcdt = D * (2 * di + 2 * g * N_ + Hs)
            ssm = zxbcdt + di * D + self.ssm_conv * (di + 2 * g * N_) + 3 * Hs
            total += L * (ssm + D)                       # + norm
            total += D
            return total

        per_layer = attn + 2 * D                         # norms
        if self.sandwich_norm:
            per_layer += 2 * D
        if self.moe:
            E, Fe = self.num_experts, self.moe_d_ff
            per_layer += D * E + E * mlp_params(Fe)
            if self.num_shared_experts:
                per_layer += mlp_params(Fe * self.num_shared_experts)
        else:
            per_layer += mlp_params(F)
        if self.hybrid:
            di, N_, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer += D * (2 * di + 2 * N_ + Hs) + di * D \
                + self.ssm_conv * (di + 2 * N_) + 3 * Hs
        if self.cross_attention:
            per_layer += attn                            # decoder cross-attn
        total += L * per_layer
        total += self.encoder_layers * (attn + mlp_params(F) + 2 * D)
        if self.frontend:
            total += self.frontend_dim * D               # stub projection
        total += D                                       # final norm
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top_k + shared)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        E, Fe, D = self.num_experts, self.moe_d_ff, self.d_model
        gates = 2 if self.mlp in ("swiglu", "geglu") else 1
        per_exp = gates * D * Fe + Fe * D
        inactive = self.num_layers * (E - self.top_k) * per_exp
        return full - inactive
