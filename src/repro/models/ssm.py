"""Mamba2 (state-space duality) sequence mixer.

Implements the chunked SSD algorithm of the Mamba2 paper (arXiv:2405.21060):
the sequence is split into chunks of Q tokens; within a chunk the recurrence
is evaluated as a masked, decay-weighted attention-like contraction (MXU
work), while cross-chunk information flows through a small per-chunk state
recurrence ([B,H,P,N] carry, lax.scan).  Decode is the O(1) state update.

Used as the SSM path of Hymba's hybrid blocks (hymba-1.5b, small state
size).  n_groups = 1 (B/C shared across heads), as in the released Mamba2
models.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import flags

from repro.models.config import ModelConfig
from repro.models.layers import cast, rmsnorm
from repro.train.sharding import shard


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim
    return di, N, H, P


def init_ssm(key, cfg: ModelConfig, layers: int | None = None,
             dtype=jnp.float32):
    di, N, H, P = _dims(cfg)
    D = cfg.d_model
    conv_ch = di + 2 * N
    zxbcdt = 2 * di + 2 * N + H
    L = () if layers is None else (layers,)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": jax.random.normal(ks[0], L + (D, zxbcdt), dtype) * D ** -0.5,
        "conv_w": jax.random.normal(ks[1], L + (cfg.ssm_conv, conv_ch), dtype)
        * cfg.ssm_conv ** -0.5,
        "conv_b": jnp.zeros(L + (conv_ch,), dtype),
        "A_log": jnp.zeros(L + (H,), dtype),                 # A = -exp(A_log)
        "ssm_D": jnp.ones(L + (H,), dtype),
        "dt_bias": jnp.zeros(L + (H,), dtype),
        "gate_norm": {"scale": jnp.zeros(L + (di,), dtype)},
        "out_proj": jax.random.normal(ks[3], L + (di, D), dtype) * di ** -0.5,
    }


def _split_proj(cfg, p, x):
    di, N, H, P = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,dz->bsz", cast(x), cast(p["in_proj"]))
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1
    )
    return z, xs, Bc, Cc, dt


def _causal_conv_full(p, u):
    """Depthwise causal conv over [B,S,C] with width w."""
    w = p["conv_w"]                                          # [w, C]
    width = w.shape[0]
    up = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        up[:, i : i + u.shape[1], :] * cast(w[i])[None, None, :]
        for i in range(width)
    )
    return out + cast(p["conv_b"])[None, None, :]


def ssd_full(cfg: ModelConfig, p, x):
    """Full-sequence Mamba2 mixer.

    x [B,S,D] -> (y [B,S,D], cache {'conv': [B,w-1,C], 'state': [B,H,P,N]})
    where the cache is the decode-ready state after the last token.
    """
    di, N, H, P = _dims(cfg)
    B_, S, D = x.shape
    Q = min(cfg.ssm_chunk, S)

    z, xs, Bc, Cc, dt = _split_proj(cfg, p, x)
    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
    tail = max(cfg.ssm_conv - 1, 0)
    conv_tail = conv_in[:, S - tail:, :] if tail else conv_in[:, :0, :]
    conv_out = jax.nn.silu(_causal_conv_full(p, conv_in))
    xs, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    # Pad the sequence to a chunk multiple; padded steps get dt=0 (identity
    # state transition, zero input) so the returned state is exact.
    S_pad = -(-S // Q) * Q
    if S_pad != S:
        pad = ((0, 0), (0, S_pad - S), (0, 0))
        xs = jnp.pad(xs, pad)
        Bc = jnp.pad(Bc, pad)
        Cc = jnp.pad(Cc, pad)
        dt = jnp.pad(dt, pad)
    nc = S_pad // Q
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # [H]

    xh = xs.reshape(B_, nc, Q, H, P)
    dtc = dt.reshape(B_, nc, Q, H)
    Bch = Bc.reshape(B_, nc, Q, N).astype(jnp.float32)
    Cch = Cc.reshape(B_, nc, Q, N).astype(jnp.float32)

    dA = dtc * A[None, None, None, :]                        # [B,c,Q,H] (<=0)
    cum = jnp.cumsum(dA, axis=2)                             # within-chunk

    # ---- intra-chunk (attention-like, masked decay) ----
    # The [B,c,Q,Q(,H)] tensors below dominate the SSM cells' memory term;
    # flags.SSD_BF16 keeps the whole chain in bf16 (decay values are in
    # [0,1]; products accumulate in f32 inside the einsum).
    sdt = jnp.bfloat16 if flags.SSD_BF16 else jnp.float32
    CB = jnp.einsum("bcqn,bctn->bcqt", Cch, Bch,
                    preferred_element_type=jnp.float32).astype(sdt)
    diff = (cum[:, :, :, None, :] - cum[:, :, None, :, :]).astype(sdt)
    decay = jnp.exp(diff)                                    # [B,c,q,t,H]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    w_ = jnp.where(tri[None, None, :, :, None], decay, jnp.zeros((), sdt))
    scores = CB[..., None] * w_ * dtc[:, :, None, :, :].astype(sdt)
    y_intra = jnp.einsum(
        "bcqth,bcthp->bcqhp", scores.astype(jnp.bfloat16), cast(xh),
        preferred_element_type=jnp.float32,
    )

    # ---- chunk states + inter-chunk recurrence ----
    last = cum[:, :, -1:, :]                                 # [B,c,1,H]
    wS = jnp.exp(last - cum) * dtc                           # [B,c,Q,H]
    S_c = jnp.einsum(
        "bcth,bctn,bcthp->bchpn",
        wS.astype(jnp.bfloat16), Bch.astype(jnp.bfloat16), cast(xh),
        preferred_element_type=jnp.float32,
    )                                                        # [B,c,H,P,N]
    chunk_decay = jnp.exp(last[:, :, 0, :])                  # [B,c,H]

    def scanf(h, inp):
        s_c, dec = inp
        h_out = h                                            # state entering chunk
        h = h * dec[:, :, None, None] + s_c
        return h, h_out

    h0 = jnp.zeros((B_, H, P, N), jnp.float32)
    h_final, h_prev = jax.lax.scan(
        scanf,
        h0,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
        # cost-pass unroll capped: beyond 32 chunks the HLO would explode;
        # the residual undercount is the tiny O(B*H*P*N) state update.
        unroll=flags.scan_unroll(nc) if nc <= 32 else 1,
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)                      # [B,c,H,P,N]

    y_inter = jnp.einsum(
        "bcqn,bcqh,bchpn->bcqhp",
        Cch.astype(jnp.bfloat16),
        jnp.exp(cum).astype(jnp.bfloat16),
        h_prev.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )

    y = (y_intra + y_inter + p["ssm_D"].astype(jnp.float32)[None, None, None, :, None]
         * xh.astype(jnp.float32))
    y = y.reshape(B_, S_pad, di)[:, :S, :]
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)),
                p["gate_norm"]["scale"], cfg.norm_eps)
    out = jnp.einsum("bsd,dk->bsk", cast(y), cast(p["out_proj"]))
    cache = {"conv": conv_tail, "state": h_final}
    return shard(out, "batch", None, None), cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, N, H, P = _dims(cfg)
    conv_ch = di + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def ssd_decode(cfg: ModelConfig, p, x, cache):
    """One-token state update.  x [B,1,D] -> (y [B,1,D], new cache)."""
    di, N, H, P = _dims(cfg)
    z, xs, Bc, Cc, dt = _split_proj(cfg, p, x)

    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)         # [B,1,C]
    hist = jnp.concatenate([cache["conv"], conv_in], axis=1)  # [B,w,C]
    w = cast(p["conv_w"])                                    # [w,C]
    conv_out = jnp.einsum("bwc,wc->bc", cast(hist), w) + cast(p["conv_b"])
    conv_out = jax.nn.silu(conv_out)[:, None, :]
    new_conv = hist[:, 1:, :]
    xs, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[:, 0, :] * A[None, :])                   # [B,H]

    xh = xs.reshape(-1, H, P).astype(jnp.float32)
    Bv = Bc[:, 0, :].astype(jnp.float32)                     # [B,N]
    Cv = Cc[:, 0, :].astype(jnp.float32)
    dtv = dt[:, 0, :]                                        # [B,H]

    state = cache["state"] * dA[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dtv, xh, Bv
    )
    y = jnp.einsum("bhpn,bn->bhp", state, Cv)
    y = y + p["ssm_D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(-1, 1, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)),
                p["gate_norm"]["scale"], cfg.norm_eps)
    out = jnp.einsum("bsd,dk->bsk", cast(y), cast(p["out_proj"]))
    return out, {"conv": new_conv, "state": state}
