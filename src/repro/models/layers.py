"""Shared neural layers: norms, RoPE, GQA attention (windows / softcap /
prefix-LM / decode-cache), gated MLPs.  Pure functions over param pytrees;
compute in bf16, accumulation and softmax in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.train.sharding import seq_axis, shard, shard_kv_cache

COMPUTE_DTYPE = jnp.bfloat16
_NEG = -1e30


def cast(x):
    return x.astype(COMPUTE_DTYPE)


def rmsnorm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def rope(x, positions, theta: float):
    """x [..., S, H, hd], positions [..., S] -> same shape."""
    from repro.models import flags
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., S, half]
    cdt = COMPUTE_DTYPE if flags.ROPE_BF16 else jnp.float32
    cos = jnp.cos(ang)[..., None, :].astype(cdt)                # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :].astype(cdt)
    x1, x2 = jnp.split(x.astype(cdt), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def init_attn(key, cfg: ModelConfig, layers: int | None = None, dtype=jnp.float32):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    L = () if layers is None else (layers,)
    ks = jax.random.split(key, 4)
    sc = D ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], L + (D, H, hd), dtype) * sc,
        "wk": jax.random.normal(ks[1], L + (D, KV, hd), dtype) * sc,
        "wv": jax.random.normal(ks[2], L + (D, KV, hd), dtype) * sc,
        "wo": jax.random.normal(ks[3], L + (H, hd, D), dtype) * (H * hd) ** -0.5,
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.zeros(L + (hd,), dtype)}
        p["k_norm"] = {"scale": jnp.zeros(L + (hd,), dtype)}
    return p


def _attn_mask(q_pos, kv_pos, *, causal, window, prefix_len, kv_valid):
    """[..., Sq, Skv] boolean mask.  window/prefix_len may be traced scalars."""
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]
    if causal:
        mask = kp <= qp
    else:
        mask = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if window is not None:
        mask = jnp.logical_and(mask, qp - kp < window)
    if prefix_len is not None:
        bidir = jnp.logical_and(qp < prefix_len, kp < prefix_len)
        mask = jnp.logical_or(mask, bidir)
    if kv_valid is not None:
        mask = jnp.logical_and(mask, kv_valid[..., None, :])
    return mask


def attention_core_blockwise(cfg: ModelConfig, q, k, v, q_pos, kv_pos, *,
                             causal, window, prefix_len, block: int):
    """Flash-style attention: online-softmax scan over KV blocks.

    The [Sq, Skv] logit matrix never materializes — per-step working set is
    [.., Sq, block].  Differentiable (scan-of-scan backward); masks are
    rebuilt per block from positions.  This is the beyond-paper memory-term
    optimization measured in EXPERIMENTS.md §Perf.
    """
    from repro.models import flags  # avoid cycle at import time
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    if Skv % block:
        pad = block - Skv % block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-10**9)
        Skv += pad
    nb = Skv // block
    qg = cast(q.reshape(B, Sq, KV, G, hd))
    scale = hd ** -0.5

    kb = jnp.moveaxis(k.reshape(B, nb, block, KV, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nb, block, KV, hd), 1, 0)
    pb = jnp.moveaxis(kv_pos.reshape(-1, nb, block), 1, 0)

    def step(carry, xs):
        m, l, acc = carry
        k_j, v_j, p_j = xs
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, cast(k_j),
                            preferred_element_type=jnp.float32) * scale
        if cfg.attn_softcap:
            c = cfg.attn_softcap
            logits = c * jnp.tanh(logits / c)
        mask = _attn_mask(q_pos, p_j, causal=causal, window=window,
                          prefix_len=prefix_len, kv_valid=p_j >= 0)
        # mask [B?,Sq,block] -> [B,1,1,Sq,block]
        mask = mask[:, None, None] if mask.ndim == 3 else mask[None, None, None]
        logits = jnp.where(mask, logits, _NEG)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(COMPUTE_DTYPE), cast(v_j),
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, G, Sq), _NEG, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kb, vb, pb),
        unroll=flags.scan_unroll(nb) if nb <= 64 else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]         # [B,KV,G,Sq,hd]
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, hd)
    return out.astype(COMPUTE_DTYPE)


def attention_core(cfg: ModelConfig, q, k, v, mask):
    """q [B,Sq,H,hd]; k,v [B,Skv,KV,hd]; mask [B?,Sq,Skv] -> [B,Sq,H,hd]."""
    from repro.models import flags
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    if flags.ATTN_BF16_SOFTMAX:
        # scale folded into Q: one op over [Sq,hd] instead of [Sq,Skv];
        # the whole logits/softmax chain stays bf16 (row-max subtracted).
        qg = cast(qg) * jnp.asarray(hd ** -0.5, COMPUTE_DTYPE)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", cast(qg), cast(k),
                            preferred_element_type=COMPUTE_DTYPE)
        if cfg.attn_softcap:
            c = cfg.attn_softcap
            logits = (c * jnp.tanh(logits / c)).astype(COMPUTE_DTYPE)
        while mask.ndim < logits.ndim:
            mask = mask[:, None]
        neg = jnp.asarray(-3e38, COMPUTE_DTYPE)
        logits = jnp.where(mask, logits, neg)
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        p = jnp.exp(logits - m)
        w = p / jnp.sum(p, axis=-1, keepdims=True)
        out = jnp.einsum("bkgqs,bskd->bqkgd", w, cast(v),
                         preferred_element_type=jnp.float32)
        return out.reshape(B, Sq, H, hd).astype(COMPUTE_DTYPE)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", cast(qg), cast(k),
        preferred_element_type=jnp.float32,
    ) * (hd ** -0.5)
    if cfg.attn_softcap:
        c = cfg.attn_softcap
        logits = c * jnp.tanh(logits / c)
    while mask.ndim < logits.ndim:
        mask = mask[:, None]
    logits = jnp.where(mask, logits, _NEG)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bkgqs,bskd->bqkgd", cast(w), cast(v),
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, Sq, H, hd).astype(COMPUTE_DTYPE)


def _project_qkv(cfg, p, x):
    q = jnp.einsum("bsd,dhk->bshk", cast(x), cast(p["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", cast(x), cast(p["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", cast(x), cast(p["wv"]))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"]["scale"], cfg.norm_eps)
    return q, k, v


def self_attention(
    cfg: ModelConfig,
    p,
    x,
    positions,
    *,
    causal: bool = True,
    window=None,
    prefix_len=None,
):
    """Full-sequence self-attention (train / prefill)."""
    from repro.models import flags
    q, k, v = _project_qkv(cfg, p, x)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "model", None)
    k = shard(k, "batch", None, "model", None)
    if flags.BLOCKWISE_ATTN and q.shape[1] > flags.BLOCKWISE_ATTN:
        out = attention_core_blockwise(
            cfg, q, k, v, positions, positions,
            causal=causal, window=window, prefix_len=prefix_len,
            block=flags.BLOCKWISE_ATTN)
    else:
        mask = _attn_mask(positions, positions, causal=causal, window=window,
                          prefix_len=prefix_len, kv_valid=None)
        out = attention_core(cfg, q, k, v, mask)
    out = jnp.einsum("bshk,hkd->bsd", cast(out), cast(p["wo"]))
    return shard(out, "batch", seq_axis(), None), (k, v)


def self_attention_decode(cfg: ModelConfig, p, x, k_cache, v_cache, pos,
                          *, window=None):
    """Single-token decode vs a KV cache.

    x [B,1,D]; k_cache/v_cache [B,Smax,KV,hd]; pos scalar i32 (current index).
    Returns (out [B,1,D], new_k_cache, new_v_cache).
    """
    B, Smax = k_cache.shape[0], k_cache.shape[1]
    q, k_new, v_new = _project_qkv(cfg, p, x)
    posv = jnp.full((B, 1), pos, jnp.int32)
    q = rope(q, posv, cfg.rope_theta)
    k_new = rope(k_new, posv, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (0, pos, 0, 0))
    k_cache = shard_kv_cache(k_cache)
    v_cache = shard_kv_cache(v_cache)
    kv_pos = jnp.arange(Smax)[None, :]
    mask = _attn_mask(posv, kv_pos, causal=True, window=window,
                      prefix_len=None, kv_valid=kv_pos <= pos)
    out = attention_core(cfg, q, k_cache, v_cache, mask)
    out = jnp.einsum("bshk,hkd->bsd", cast(out), cast(p["wo"]))
    return out, k_cache, v_cache


def cross_attention(cfg: ModelConfig, p, x, k_enc, v_enc):
    """Decoder cross-attention to precomputed encoder K/V (no positions)."""
    q = jnp.einsum("bsd,dhk->bshk", cast(x), cast(p["wq"]))
    Skv = k_enc.shape[1]
    mask = jnp.ones((1, x.shape[1], Skv), bool)
    out = attention_core(cfg, q, k_enc, v_enc, mask)
    out = jnp.einsum("bshk,hkd->bsd", cast(out), cast(p["wo"]))
    return out


def encode_kv(cfg: ModelConfig, p, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", cast(enc_out), cast(p["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", cast(enc_out), cast(p["wv"]))
    return k, v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None,
             layers: int | None = None, dtype=jnp.float32):
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    L = () if layers is None else (layers,)
    ks = jax.random.split(key, 3)
    p = {
        "w_up": jax.random.normal(ks[0], L + (D, F), dtype) * D ** -0.5,
        "w_down": jax.random.normal(ks[1], L + (F, D), dtype) * F ** -0.5,
    }
    if cfg.mlp in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(ks[2], L + (D, F), dtype) * D ** -0.5
    return p


def mlp(cfg: ModelConfig, p, x):
    up = jnp.einsum("bsd,df->bsf", cast(x), cast(p["w_up"]))
    if cfg.mlp == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", cast(x), cast(p["w_gate"]))
        h = jax.nn.silu(gate) * up
    elif cfg.mlp == "geglu":
        gate = jnp.einsum("bsd,df->bsf", cast(x), cast(p["w_gate"]))
        h = jax.nn.gelu(gate, approximate=True) * up
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(up))
    else:
        raise ValueError(cfg.mlp)
    h = shard(h, "batch", None, "model")
    out = jnp.einsum("bsf,fd->bsd", h, cast(p["w_down"]))
    return shard(out, "batch", seq_axis(), None)
