"""Trace-time switches.

UNROLL_SCAN: XLA's HLO cost analysis visits a while-loop body once,
regardless of trip count, so the scanned layer stack under-reports
flops/bytes/collectives by ~L.  The dry-run cost pass flips this flag to
fully unroll every structural scan (layer stack, SSD chunk recurrence) so the
compiled module's cost analysis counts every layer.  The deliverable compile
(memory analysis, artifact) keeps the scanned form.
"""
UNROLL_SCAN = False


def scan_unroll(length: int) -> int:
    return length if UNROLL_SCAN else 1


# --- beyond-paper performance switches (EXPERIMENTS.md §Perf) --------------
# Blockwise (flash-style) attention: online-softmax scan over KV blocks of
# this size; the S x S logit matrix never exists in HBM.  None = baseline
# (materialized logits).
BLOCKWISE_ATTN: int | None = None

# Mixed-precision gradients: loss is differentiated against a bf16 copy of
# the params, so FSDP gradient reduce-scatters move half the bytes; the
# optimizer still applies fp32 master updates.
BF16_GRADS: bool = False

# Chunked cross-entropy: logits are produced and consumed in sequence chunks
# of this many tokens (rematerialized in backward) instead of one [B,S,V]
# fp32 tensor.  None = baseline.
CHUNKED_LOSS: int | None = None

# Serving MoE capacity factor: the baseline decode path uses capacity = T
# (zero drops, up to E/topk x overcompute).  Setting this to e.g. 2.0 sizes
# expert buffers at 2x the average load instead.  None = baseline.
SERVE_MOE_CAP: float | None = None

# bf16 attention softmax pipeline: logits, mask-select, exp and the
# weighted-value einsum all stay bf16 (row max still subtracted), and the
# 1/sqrt(hd) scale is folded into Q (one less op over the S x S tensor).
# Halves every S^2-sized HBM access.
ATTN_BF16_SOFTMAX: bool = False

# Rotary embedding arithmetic in bf16 (tables in fp32).
ROPE_BF16: bool = False

# Megatron-style sequence parallelism: the residual stream between TP blocks
# is sharded along S over the model axis, so norms/residuals/casts run on
# 1/TP-size tensors and the TP boundary becomes reduce-scatter + all-gather
# instead of a full all-reduce.
SEQ_PARALLEL: bool = False

# Decode: thread the KV/SSM cache through the layer scan as an aliased
# *carry* (in-place dynamic-update-slice on loop state) instead of xs/ys
# streams, eliminating the full-cache copies at the loop boundary.
DECODE_CACHE_CARRY: bool = False

# Remat policy: 'full' recomputes the whole layer in backward (minimum
# memory); 'dots' saves the outputs of weight matmuls (qkv/mlp projections,
# no-batch-dim dots) so backward skips their recompute — right trade for
# small models whose optimizer state is far below HBM capacity.
REMAT_POLICY: str = "full"

# Grouped MoE dispatch: tokens are routed within data-shard groups with
# per-group capacity, so the dispatch gather is shard-local and the
# group->expert resharding lowers to all-to-all instead of masked
# all-reduces.  -1 = auto (one group per batch shard of the active mesh —
# adopted default after §Perf: deepseek prefill bound −31.7%, qwen3 train
# bound −53%); 0 = off (paper-faithful naive dispatch); >0 = explicit.
MOE_GROUPED_DISPATCH: int = -1

# Cluster cell: stream the dataset/chunks in bf16 (fp32 accumulation).
CLUSTER_BF16: bool = False

# KV cache sharding fallback: when KV heads don't divide the model axis
# (GQA), shard the cache *sequence* dim over it (flash-decoding partial
# softmax) instead of replicating the cache TP-ways.  Default ON after the
# §Perf measurement (decode memory term −6..7x on llama/qwen3); the §Perf
# baselines were recorded with it off.
KV_SHARD_SEQ: bool = True

# SSD (mamba2/hymba): keep the [B, nc, Q, Q, H] intra-chunk decay/score
# tensors in bf16 (f32 einsum accumulation).  These 5-D tensors dominate
# the SSM cells' memory term.
SSD_BF16: bool = False
