"""Mixture-of-Experts FFN with capacity-based sorted dispatch.

TPU-friendly formulation (no megablocks-style ragged kernels): token→expert
assignments are ranked inside each expert by a stable argsort; tokens with
rank ≥ capacity are dropped (capacity_factor 1.0 ⇒ exact average load,
standard practice — drop fraction is returned as an aux metric).  Dispatch
and combine are gathers/scatter-adds on an [E, C] slot table — O(T·k·D)
memory, never the O(T·E·C) one-hot einsum.

Sharding: experts across the "model"/"expert" axis (expert parallelism),
tokens across the batch axes; GSPMD inserts the all-to-all-style collectives
at the gather/scatter boundary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import cast
from repro.train.sharding import shard


def init_moe(key, cfg: ModelConfig, layers: int | None = None,
             dtype=jnp.float32):
    D, E, Fe = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    L = () if layers is None else (layers,)
    ks = jax.random.split(key, 5)
    p = {
        "router": jax.random.normal(ks[0], L + (D, E), dtype) * D ** -0.5,
        "e_gate": jax.random.normal(ks[1], L + (E, D, Fe), dtype) * D ** -0.5,
        "e_up": jax.random.normal(ks[2], L + (E, D, Fe), dtype) * D ** -0.5,
        "e_down": jax.random.normal(ks[3], L + (E, Fe, D), dtype) * Fe ** -0.5,
    }
    if cfg.num_shared_experts:
        Fs = Fe * cfg.num_shared_experts
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": jax.random.normal(ks2[0], L + (D, Fs), dtype) * D ** -0.5,
            "w_up": jax.random.normal(ks2[1], L + (D, Fs), dtype) * D ** -0.5,
            "w_down": jax.random.normal(ks2[2], L + (Fs, D), dtype) * Fs ** -0.5,
        }
    return p


def _grouped_moe(cfg: ModelConfig, p, xt, top_p, top_e, factor: float, G: int):
    """Grouped dispatch (§Perf): tokens are slotted *within* G data-shard
    groups, so the dispatch gather/scatter is shard-local; only the expert
    contraction spans the model axis and the combine is a single TP
    all-reduce per layer (instead of masked cross-shard gathers).
    Per-group capacity trades a little extra drop for locality."""
    T, D = xt.shape
    E, K = cfg.num_experts, cfg.top_k
    Tg = T // G
    capg = max(int(factor * Tg * K / E + 0.5), 1)

    xg = xt.reshape(G, Tg, D)
    xg = shard(xg, "batch", None, None)
    eg = top_e.reshape(G, Tg * K)
    pg = top_p.reshape(G, Tg * K)

    order = jnp.argsort(eg, axis=1, stable=True)               # [G, Tg*K]
    sorted_e = jnp.take_along_axis(eg, order, axis=1)
    starts = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E)))(sorted_e)  # [G,E]
    rank = jnp.arange(Tg * K)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=1)
    keep = rank < capg

    e_idx = jnp.where(keep, sorted_e, E)
    c_idx = jnp.where(keep, rank, 0).astype(jnp.int32)
    tok_of = (order // K).astype(jnp.int32)                    # within-group
    gate_of = jnp.take_along_axis(pg, order, axis=1)

    def slot_one(e_i, c_i, t_o, g_o):
        st = jnp.full((E, capg), Tg, jnp.int32).at[e_i, c_i].set(
            t_o, mode="drop")
        sg = jnp.zeros((E, capg), jnp.float32).at[e_i, c_i].set(
            g_o, mode="drop")
        return st, sg

    slot_tok, slot_gate = jax.vmap(slot_one)(e_idx, c_idx, tok_of, gate_of)

    # local (per-group) gather, then slice the expert dim across "model"
    xe = jax.vmap(lambda xg_, st: jnp.take(
        xg_, jnp.minimum(st, Tg - 1), axis=0))(xg, slot_tok)   # [G,E,capg,D]
    valid = (slot_tok < Tg)[..., None]
    xe = jnp.where(valid, xe, 0)
    xe = shard(xe, "batch", "expert", None, None)

    act = jax.nn.gelu if cfg.mlp == "geglu" else jax.nn.silu
    gate = jnp.einsum("gecd,edf->gecf", cast(xe), cast(p["e_gate"]))
    up = jnp.einsum("gecd,edf->gecf", cast(xe), cast(p["e_up"]))
    ye = jnp.einsum("gecf,efd->gecd", act(gate) * up, cast(p["e_down"]))
    ye = ye * slot_gate[..., None].astype(ye.dtype)

    def combine_one(ye_g, st_g):
        y = jnp.zeros((Tg + 1, D), ye_g.dtype)
        return y.at[st_g.reshape(-1)].add(
            ye_g.reshape(E * capg, D))[:Tg]

    y = jax.vmap(combine_one)(ye, slot_tok)                    # [G,Tg,D]
    return shard(y.reshape(T, D), "batch", None)


def moe_ffn(cfg: ModelConfig, p, x, *, no_drop: bool = False,
            capacity_override: float | None = None):
    """x [B, S, D] -> [B, S, D].  Router in fp32, experts in bf16.

    ``no_drop=True`` sets capacity = T (single-token decode: a handful of
    tokens must never be dropped; the [E,T,D] buffer is tiny there).
    ``capacity_override`` replaces cfg.capacity_factor (serving tuning).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)

    logits = jnp.einsum(
        "td,de->te", xt.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                     # [T,K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)     # renormalize

    from repro.models import flags
    G = flags.MOE_GROUPED_DISPATCH
    if G < 0:
        # auto: one group per batch shard of the active mesh (1 off-mesh)
        from repro.train import sharding as _sh
        mesh = _sh._current_mesh()
        G = (_sh._axis_prod(mesh, _sh.physical_axes(mesh, "batch"))
             if mesh is not None else 1)
    if G > 1 and not no_drop and T % G == 0:
        factor = capacity_override or cfg.capacity_factor
        y = _grouped_moe(cfg, p, xt, top_p, top_e, factor, G)
        if cfg.num_shared_experts:
            sp = p["shared"]
            act = jax.nn.gelu if cfg.mlp == "geglu" else jax.nn.silu
            g_ = jnp.einsum("td,df->tf", cast(xt), cast(sp["w_gate"]))
            u_ = jnp.einsum("td,df->tf", cast(xt), cast(sp["w_up"]))
            y = y + jnp.einsum("tf,fd->td", act(g_) * u_, cast(sp["w_down"]))
        from repro.train.sharding import seq_axis
        return shard(y.reshape(B, S, D), "batch", seq_axis(), None)

    # --- capacity-based slotting ------------------------------------------
    if no_drop:
        cap = T
    else:
        factor = capacity_override or cfg.capacity_factor
        cap = max(int(factor * T * K / E + 0.5), 1)
        cap = min(cap, T)
    flat_e = top_e.reshape(-1)                                 # [T*K]
    order = jnp.argsort(flat_e, stable=True)                   # sort by expert
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))         # [E]
    rank = jnp.arange(T * K) - starts[sorted_e]                # within-expert
    keep = rank < cap

    slot_tok = jnp.full((E, cap), T, jnp.int32)                # T = "no token"
    e_idx = jnp.where(keep, sorted_e, E)
    c_idx = jnp.where(keep, rank, 0).astype(jnp.int32)
    tok_of = (order // K).astype(jnp.int32)
    slot_tok = slot_tok.at[e_idx, c_idx].set(tok_of, mode="drop")
    slot_gate = jnp.zeros((E, cap), jnp.float32).at[e_idx, c_idx].set(
        top_p.reshape(-1)[order], mode="drop")

    # --- dispatch, expert FFN, combine ------------------------------------
    xe = jnp.take(xt, jnp.minimum(slot_tok, T - 1), axis=0)    # [E,C,D]
    valid = (slot_tok < T)[..., None]
    xe = jnp.where(valid, xe, 0)
    xe = shard(xe, "expert", None, None)

    gate = jnp.einsum("ecd,edf->ecf", cast(xe), cast(p["e_gate"]))
    up = jnp.einsum("ecd,edf->ecf", cast(xe), cast(p["e_up"]))
    act = jax.nn.gelu if cfg.mlp == "geglu" else jax.nn.silu
    h = act(gate) * up
    ye = jnp.einsum("ecf,efd->ecd", h, cast(p["e_down"]))      # [E,C,D]
    ye = ye * slot_gate[..., None].astype(ye.dtype)

    y = jnp.zeros((T + 1, D), ye.dtype)
    y = y.at[slot_tok.reshape(-1)].add(ye.reshape(E * cap, D))
    y = y[:T]

    if cfg.num_shared_experts:
        sp = p["shared"]
        gate = jnp.einsum("td,df->tf", cast(xt), cast(sp["w_gate"]))
        up = jnp.einsum("td,df->tf", cast(xt), cast(sp["w_up"]))
        y = y + jnp.einsum("tf,fd->td", act(gate) * up, cast(sp["w_down"]))

    y = y.reshape(B, S, D)
    from repro.train.sharding import seq_axis
    return shard(y, "batch", seq_axis(), None)
