"""Architecture registry: ``--arch <id>`` resolution for every entry point."""
from __future__ import annotations

import importlib

_ARCH_MODULES = {
    "hymba-1.5b": "hymba_1_5b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "bigmeans_paper": "bigmeans_paper",
}

LM_ARCHS = [a for a in _ARCH_MODULES if a != "bigmeans_paper"]


def list_archs() -> list[str]:
    return list(_ARCH_MODULES)


def get_config(name: str):
    if name not in _ARCH_MODULES:
        # tolerate underscores / module-style ids
        inv = {v: k for k, v in _ARCH_MODULES.items()}
        if name in inv:
            name = inv[name]
        else:
            raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def model_fns(cfg):
    """Return the (loss_fn, forward, prefill, decode_step) family for a config."""
    from repro.models import encdec, transformer

    if cfg.family == "encdec":
        return encdec
    return transformer
