"""Encoder–decoder model (seamless-m4t backbone).

The audio frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings [B, S_src, frontend_dim]; a linear projection
maps them into the encoder width.  12 encoder layers (bidirectional self
attention) + 12 decoder layers (causal self-attention + cross-attention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.train.sharding import shard


def encode(cfg: ModelConfig, p, frames):
    """frames [B, S_src, frontend_dim] -> enc_out [B, S_src, D]."""
    x = jnp.einsum("bsr,rd->bsd", L.cast(frames), L.cast(p["frontend_proj"]))
    x = shard(x, "batch", None, None)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x, _ = T.run_stack(cfg, p["encoder"], x, positions,
                       n_layers=cfg.encoder_layers, causal=False)
    return L.rmsnorm(x, p["encoder_norm"]["scale"], cfg.norm_eps)


def forward(cfg: ModelConfig, p, tokens, frames, *, collect_cache=False):
    """Teacher-forced decoder pass.  Returns (logits [B,St,V], caches)."""
    enc_out = encode(cfg, p, frames)
    x = T.embed(cfg, p, tokens)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x, caches = T.run_stack(cfg, p["layers"], x, positions,
                            causal=True, enc_out=enc_out,
                            collect_cache=collect_cache)
    return T.unembed(cfg, p, x), caches


def loss_fn(cfg: ModelConfig, p, batch):
    logits, _ = forward(cfg, p, batch["tokens"], batch["frontend"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.sum(jnp.where(valid, nll, 0.0)) / jnp.maximum(jnp.sum(valid), 1)


def prefill(cfg: ModelConfig, p, tokens, frames, max_seq: int):
    logits, caches = forward(cfg, p, tokens, frames, collect_cache=True)
    B = tokens.shape[0]
    cache = T.init_cache(cfg, B, max_seq, enc_len=frames.shape[1])
    kpre = caches["k"].astype(cache["k"].dtype)
    vpre = caches["v"].astype(cache["v"].dtype)
    cache["k"] = jax.lax.dynamic_update_slice(cache["k"], kpre, (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(cache["v"], vpre, (0, 0, 0, 0, 0))
    cache["cross_k"] = caches["cross_k"].astype(cache["cross_k"].dtype)
    cache["cross_v"] = caches["cross_v"].astype(cache["cross_v"].dtype)
    return logits[:, -1, :], cache


def decode_step(cfg: ModelConfig, p, cache, token, pos):
    x = T.embed(cfg, p, token)
    x, new_cache = T.run_stack_decode(cfg, p["layers"], x, cache, pos)
    logits = T.unembed(cfg, p, x)[:, 0, :]
    return logits, new_cache
