"""Unified decoder stack for all assigned families.

One scan-over-layers implementation covers dense / MoE / SSM / hybrid / VLM;
the encoder-decoder (seamless) reuses the same blocks in ``encdec.py``.
Layer heterogeneity (gemma2 local/global alternation, hymba's 3 global
layers) is expressed as a per-layer *window vector* scanned alongside the
stacked parameters, keeping the stack homogeneous for ``lax.scan`` (compile
time stays O(1) in depth) and fully rematerialized (``jax.checkpoint``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import flags
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.train.sharding import shard

FULL_WINDOW = 1 << 30


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_layer_stack(key, cfg: ModelConfig, n_layers: int, *,
                     cross: bool = False, causal_family: str | None = None,
                     dtype=jnp.float32):
    fam = causal_family or cfg.family
    ks = iter(jax.random.split(key, 10))
    D = cfg.d_model
    p: dict = {"ln1": {"scale": jnp.zeros((n_layers, D), dtype)}}
    if fam == "ssm":
        p["ssm"] = ssm_mod.init_ssm(next(ks), cfg, layers=n_layers, dtype=dtype)
        return p

    p["attn"] = L.init_attn(next(ks), cfg, layers=n_layers, dtype=dtype)
    p["ln2"] = {"scale": jnp.zeros((n_layers, D), dtype)}
    if cfg.sandwich_norm:
        p["post_attn_ln"] = {"scale": jnp.zeros((n_layers, D), dtype)}
        p["post_mlp_ln"] = {"scale": jnp.zeros((n_layers, D), dtype)}
    if fam == "hybrid":
        p["ssm"] = ssm_mod.init_ssm(next(ks), cfg, layers=n_layers, dtype=dtype)
    if cfg.moe and fam in ("moe",):
        p["moe"] = moe_mod.init_moe(next(ks), cfg, layers=n_layers, dtype=dtype)
    else:
        p["mlp"] = L.init_mlp(next(ks), cfg, layers=n_layers, dtype=dtype)
    if cross:
        p["cross"] = L.init_attn(next(ks), cfg, layers=n_layers, dtype=dtype)
        p["ln_cross"] = {"scale": jnp.zeros((n_layers, D), dtype)}
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    ks = iter(jax.random.split(key, 8))
    D, V = cfg.d_model, cfg.vocab_size
    p = {
        "embedding": jax.random.normal(next(ks), (V, D), dtype) * D ** -0.5,
        "layers": init_layer_stack(
            next(ks), cfg, cfg.num_layers,
            cross=cfg.cross_attention, dtype=dtype),
        "final_norm": {"scale": jnp.zeros((D,), dtype)},
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(next(ks), (D, V), dtype) * D ** -0.5
    if cfg.frontend:
        p["frontend_proj"] = (
            jax.random.normal(next(ks), (cfg.frontend_dim, D), dtype)
            * cfg.frontend_dim ** -0.5)
    if cfg.encoder_layers:
        p["encoder"] = init_layer_stack(
            next(ks), cfg, cfg.encoder_layers, causal_family="dense",
            dtype=dtype)
        p["encoder_norm"] = {"scale": jnp.zeros((D,), dtype)}
    return p


def abstract_params(cfg: ModelConfig, dtype=jnp.float32):
    """ShapeDtypeStruct pytree (no allocation) — dry-run input."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Layer schedule
# ---------------------------------------------------------------------------
def window_schedule(cfg: ModelConfig, n_layers: int) -> jax.Array:
    idx = jnp.arange(n_layers)
    if cfg.layer_pattern == "local_global" and cfg.window:
        # gemma2: even layers local (sliding window), odd layers global
        return jnp.where(idx % 2 == 0, cfg.window, FULL_WINDOW)
    if cfg.layer_pattern == "mostly_local" and cfg.window:
        # hymba: first / middle / last layers global, rest sliding window
        glob = (idx == 0) | (idx == n_layers // 2) | (idx == n_layers - 1)
        return jnp.where(glob, FULL_WINDOW, cfg.window)
    return jnp.full((n_layers,), FULL_WINDOW)


# ---------------------------------------------------------------------------
# Blocks (full sequence)
# ---------------------------------------------------------------------------
def block_full(cfg: ModelConfig, lp, x, positions, window, *,
               causal=True, prefix_len=None, enc_out=None):
    """One decoder layer over the full sequence.  Returns (x, cache_entry)."""
    cache = {}
    if cfg.family == "ssm":
        h = L.rmsnorm(x, lp["ln1"]["scale"], cfg.norm_eps)
        out, sstate = ssm_mod.ssd_full(cfg, lp["ssm"], h)
        cache["ssm"] = sstate
        return x + out, cache

    h = L.rmsnorm(x, lp["ln1"]["scale"], cfg.norm_eps)
    attn_out, (k, v) = L.self_attention(
        cfg, lp["attn"], h, positions,
        causal=causal, window=window, prefix_len=prefix_len)
    cache["k"], cache["v"] = k, v
    if cfg.family == "hybrid":
        ssm_out, sstate = ssm_mod.ssd_full(cfg, lp["ssm"], h)
        cache["ssm"] = sstate
        attn_out = (attn_out + ssm_out) * 0.5      # hymba mean fusion
    if cfg.sandwich_norm:
        attn_out = L.rmsnorm(attn_out, lp["post_attn_ln"]["scale"], cfg.norm_eps)
    x = x + attn_out

    if enc_out is not None:
        h = L.rmsnorm(x, lp["ln_cross"]["scale"], cfg.norm_eps)
        k_enc, v_enc = L.encode_kv(cfg, lp["cross"], enc_out)
        cache["cross_k"], cache["cross_v"] = k_enc, v_enc
        x = x + L.cross_attention(cfg, lp["cross"], h, k_enc, v_enc)

    h = L.rmsnorm(x, lp["ln2"]["scale"], cfg.norm_eps)
    if cfg.moe and cfg.family == "moe":
        mlp_out = moe_mod.moe_ffn(cfg, lp["moe"], h)
    else:
        mlp_out = L.mlp(cfg, lp["mlp"], h)
    if cfg.sandwich_norm:
        mlp_out = L.rmsnorm(mlp_out, lp["post_mlp_ln"]["scale"], cfg.norm_eps)
    return x + mlp_out, cache


def run_stack(cfg: ModelConfig, p_layers, x, positions, *, n_layers=None,
              causal=True, prefix_len=None, enc_out=None,
              collect_cache=False):
    n_layers = n_layers or cfg.num_layers
    windows = window_schedule(cfg, n_layers)

    def layer(carry, xs):
        lp, w_l = xs
        out, cache = block_full(
            cfg, lp, carry, positions, w_l,
            causal=causal, prefix_len=prefix_len, enc_out=enc_out)
        return out, (cache if collect_cache else None)

    if flags.REMAT_POLICY == "dots":
        layer = jax.checkpoint(
            layer,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    else:
        layer = jax.checkpoint(layer)
    x, caches = jax.lax.scan(layer, x, (p_layers, windows),
                             unroll=flags.scan_unroll(n_layers))
    return x, caches


# ---------------------------------------------------------------------------
# Blocks (single-token decode vs cache)
# ---------------------------------------------------------------------------
def block_decode(cfg: ModelConfig, lp, x, cache, pos, window):
    new_cache = {}
    if cfg.family == "ssm":
        h = L.rmsnorm(x, lp["ln1"]["scale"], cfg.norm_eps)
        out, new_cache["ssm"] = ssm_mod.ssd_decode(cfg, lp["ssm"], h, cache["ssm"])
        return x + out, new_cache

    h = L.rmsnorm(x, lp["ln1"]["scale"], cfg.norm_eps)
    attn_out, k_c, v_c = L.self_attention_decode(
        cfg, lp["attn"], h, cache["k"], cache["v"], pos, window=window)
    new_cache["k"], new_cache["v"] = k_c, v_c
    if cfg.family == "hybrid":
        ssm_out, new_cache["ssm"] = ssm_mod.ssd_decode(
            cfg, lp["ssm"], h, cache["ssm"])
        attn_out = (attn_out + ssm_out) * 0.5
    if cfg.sandwich_norm:
        attn_out = L.rmsnorm(attn_out, lp["post_attn_ln"]["scale"], cfg.norm_eps)
    x = x + attn_out

    if "cross_k" in cache:
        h = L.rmsnorm(x, lp["ln_cross"]["scale"], cfg.norm_eps)
        x = x + L.cross_attention(
            cfg, lp["cross"], h, cache["cross_k"], cache["cross_v"])
        new_cache["cross_k"] = cache["cross_k"]
        new_cache["cross_v"] = cache["cross_v"]

    h = L.rmsnorm(x, lp["ln2"]["scale"], cfg.norm_eps)
    if cfg.moe and cfg.family == "moe":
        mlp_out = moe_mod.moe_ffn(
            cfg, lp["moe"], h,
            no_drop=flags.SERVE_MOE_CAP is None,
            capacity_override=flags.SERVE_MOE_CAP)
    else:
        mlp_out = L.mlp(cfg, lp["mlp"], h)
    if cfg.sandwich_norm:
        mlp_out = L.rmsnorm(mlp_out, lp["post_mlp_ln"]["scale"], cfg.norm_eps)
    return x + mlp_out, new_cache


def run_stack_decode(cfg: ModelConfig, p_layers, x, caches, pos, *,
                     n_layers=None):
    n_layers = n_layers or cfg.num_layers
    windows = window_schedule(cfg, n_layers)

    if flags.DECODE_CACHE_CARRY:
        # Cache as aliased scan *carry*: per-layer slices are read and
        # written in place inside the while-loop state, so the full cache
        # never round-trips the loop boundary (§Perf, decode cells).
        def layer(carry, xs):
            x, caches = carry
            lp, w_l, idx = xs
            cache_l = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0, False),
                caches)
            out, new_cache = block_decode(cfg, lp, x, cache_l, pos, w_l)
            caches = jax.tree.map(
                lambda a, u: jax.lax.dynamic_update_index_in_dim(
                    a, u.astype(a.dtype), idx, 0),
                caches, new_cache)
            return (out, caches), None

        (x, new_caches), _ = jax.lax.scan(
            layer, (x, caches),
            (p_layers, windows, jnp.arange(n_layers)),
            unroll=flags.scan_unroll(n_layers))
        return x, new_caches

    def layer(carry, xs):
        lp, w_l, cache_l = xs
        out, new_cache = block_decode(cfg, lp, carry, cache_l, pos, w_l)
        return out, new_cache

    x, new_caches = jax.lax.scan(layer, x, (p_layers, windows, caches),
                                 unroll=flags.scan_unroll(n_layers))
    return x, new_caches


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def embed(cfg: ModelConfig, p, tokens):
    e = jnp.take(p["embedding"], tokens, axis=0)
    if cfg.scale_embedding:
        e = e * jnp.sqrt(jnp.float32(cfg.d_model)).astype(e.dtype)
    from repro.train.sharding import seq_axis
    return shard(L.cast(e), "batch", seq_axis(), None)


def unembed(cfg: ModelConfig, p, h):
    h = L.rmsnorm(h, p["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", L.cast(h), L.cast(p["embedding"]),
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", L.cast(h), L.cast(p["lm_head"]),
                            preferred_element_type=jnp.float32)
    if cfg.final_softcap:
        c = cfg.final_softcap
        logits = c * jnp.tanh(logits / c)
    return shard(logits, "batch", None, "model")


def _prefix_inputs(cfg: ModelConfig, p, tokens, frontend):
    """VLM: project stub patch embeddings and prepend to token embeddings."""
    x_txt = embed(cfg, p, tokens)
    if frontend is None:
        return x_txt, None
    proj = jnp.einsum("bpr,rd->bpd", L.cast(frontend),
                      L.cast(p["frontend_proj"]))
    x = jnp.concatenate([proj, x_txt], axis=1)
    return shard(x, "batch", None, None), cfg.frontend_len


# ---------------------------------------------------------------------------
# Public model functions (decoder-only families)
# ---------------------------------------------------------------------------
def forward(cfg: ModelConfig, p, tokens, *, frontend=None,
            collect_cache=False):
    """Full-sequence forward.  tokens [B,St]; frontend [B,Lf,raw] for VLM.

    Returns (logits [B,S,V], caches or None).  For VLM, S = Lf + St.
    """
    x, prefix_len = _prefix_inputs(cfg, p, tokens, frontend)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x, caches = run_stack(
        cfg, p["layers"], x, positions,
        prefix_len=prefix_len, collect_cache=collect_cache)
    return unembed(cfg, p, x), caches


def _nll(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.sum(jnp.where(valid, nll, 0.0)), jnp.sum(valid)


def loss_fn(cfg: ModelConfig, p, batch):
    """Next-token cross-entropy; labels == -1 are masked (e.g. image prefix)."""
    labels = batch["labels"]
    if cfg.frontend and batch.get("frontend") is not None:
        pad = jnp.full((labels.shape[0], cfg.frontend_len), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)

    if flags.CHUNKED_LOSS:
        # never materialize the [B,S,V] fp32 logits: produce them per
        # sequence chunk, rematerialized in backward (§Perf optimization)
        x, prefix_len = _prefix_inputs(cfg, p, batch["tokens"],
                                       batch.get("frontend"))
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        h, _ = run_stack(cfg, p["layers"], x, positions,
                         prefix_len=prefix_len)
        c = flags.CHUNKED_LOSS
        pad_s = (-S) % c
        if pad_s:
            h = jnp.pad(h, ((0, 0), (0, pad_s), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad_s)),
                             constant_values=-1)
        nb = (S + pad_s) // c
        hb = jnp.moveaxis(h.reshape(B, nb, c, -1), 1, 0)
        lb = jnp.moveaxis(labels.reshape(B, nb, c), 1, 0)

        @jax.checkpoint
        def chunk(h_c, l_c):
            return _nll(unembed(cfg, p, h_c), l_c)

        def body(carry, xs):
            s, n = chunk(*xs)
            return (carry[0] + s, carry[1] + n), None

        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.float32(0.0), jnp.int32(0)), (hb, lb),
            unroll=flags.scan_unroll(nb) if nb <= 64 else 1)
        return tot / jnp.maximum(cnt, 1)

    logits, _ = forward(cfg, p, batch["tokens"],
                        frontend=batch.get("frontend"))
    tot, cnt = _nll(logits, labels)
    return tot / jnp.maximum(cnt, 1)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, *,
               enc_len: int | None = None, dtype=jnp.bfloat16):
    """Stacked-by-layer decode cache (ShapeDtype-compatible for dry-runs)."""
    Lc, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    cache: dict = {}
    if cfg.family != "ssm":
        cache["k"] = jnp.zeros((Lc, batch, max_seq, KV, hd), dtype)
        cache["v"] = jnp.zeros((Lc, batch, max_seq, KV, hd), dtype)
    if cfg.family in ("ssm", "hybrid"):
        one = ssm_mod.init_ssm_cache(cfg, batch, dtype=jnp.float32)
        cache["ssm"] = jax.tree.map(
            lambda a: jnp.zeros((Lc,) + a.shape, a.dtype), one)
    if cfg.cross_attention and enc_len:
        cache["cross_k"] = jnp.zeros((Lc, batch, enc_len, KV, hd), dtype)
        cache["cross_v"] = jnp.zeros((Lc, batch, enc_len, KV, hd), dtype)
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int, **kw):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_seq, **kw))


def decode_step(cfg: ModelConfig, p, cache, token, pos):
    """One serving step: token [B,1] i32, pos scalar i32.

    Returns (logits [B,V] f32, new cache)."""
    x = embed(cfg, p, token)
    x, new_cache = run_stack_decode(cfg, p["layers"], x, cache, pos)
    logits = unembed(cfg, p, x)[:, 0, :]
    return logits, new_cache


def prefill(cfg: ModelConfig, p, tokens, max_seq: int, *, frontend=None):
    """Process the prompt, build the decode cache padded to max_seq.

    Returns (last-position logits [B,V], cache)."""
    logits, caches = forward(cfg, p, tokens, frontend=frontend,
                             collect_cache=True)
    B = tokens.shape[0]
    cache = init_cache(cfg, B, max_seq)
    if "k" in cache:
        kpre = caches["k"].astype(cache["k"].dtype)  # [L,B,S,KV,hd]
        vpre = caches["v"].astype(cache["v"].dtype)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], kpre, (0, 0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], vpre, (0, 0, 0, 0, 0))
    if "ssm" in cache:
        cache["ssm"] = jax.tree.map(
            lambda z, c: c.astype(z.dtype), cache["ssm"], caches["ssm"])
    return logits[:, -1, :], cache
