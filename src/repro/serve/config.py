"""`ServeConfig` — every knob of the assignment-serving subsystem.

Serving has a different shape from training: many small concurrent
requests instead of a few huge chunks, so the knobs are about *coalescing*
(how long to wait, how much to pack into one launch) and *admission* (how
deep the queue may grow before clients are told to back off) rather than
chunk budgets.  One config drives every model the server hosts; precision
and kernel impl can still be overridden per model at registration time.
"""
from __future__ import annotations

import dataclasses

from repro.kernels import ops
from repro.kernels import precision as px

_DONATE_MODES = ("auto", "on", "off")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Validated configuration for one :class:`repro.serve.Server`.

    Batching frontend:

    * ``max_batch`` — most points one coalesced launch may carry; also the
      largest padded shape bucket.  Rounded up to a power of two.
    * ``min_bucket`` — smallest padded launch shape.  Requests are padded to
      the next power-of-two bucket in ``[min_bucket, max_batch]`` so the
      jitted assign call sees a small, fixed set of shapes and never
      recompiles per request size.
    * ``max_linger_ms`` — how long the batcher may hold the first request of
      a batch waiting for more to coalesce (the latency/throughput knob:
      0 launches immediately, a few ms packs concurrent clients together).
    * ``queue_depth`` — max requests pending per model; beyond it
      :meth:`Server.submit` raises :class:`repro.serve.QueueFull`
      immediately (graceful rejection, never a hang).

    Kernel dispatch (defaults for every model; overridable per model):

    * ``impl`` — kernel implementation (``'auto'`` resolves via
      :func:`repro.kernels.ops.resolve_impl`; the autotuned Pallas path on
      TPU backends, the jnp reference elsewhere).
    * ``precision`` — per-model precision policy routed through
      ``kernels/ops.assign`` (see :mod:`repro.kernels.precision`).
    * ``donate`` — donate the padded request buffer to the jitted assign
      call (``'auto'`` = on for accelerator backends, off on CPU where
      XLA cannot alias host buffers and would warn per launch).
    * ``warmup`` — at registration, eagerly run every shape bucket through
      the demotion-aware, autotune-consulting dispatch and compile the
      jitted call, so autotuning/demotion/compilation all happen off the
      request path (zero recompiles once traffic starts).

    Admission & resilience (see :mod:`repro.serve.resilience`):

    * ``default_deadline_ms`` — per-request deadline applied when a submit
      does not pass its own; ``None`` = requests never expire.  A request
      whose deadline lapses while queued is *shed* with
      :class:`repro.serve.DeadlineExceeded` before it can waste a launch
      slot.
    * ``validate_requests`` — reject non-finite payloads at submit time
      with :class:`repro.serve.InvalidRequest` (a client error) instead of
      letting a NaN poison a coalesced launch.  Per-submit ``validate=``
      overrides it for trusted clients.
    * ``tenant_quota`` — max *queued* requests per tenant id; beyond it
      :class:`repro.serve.QuotaExceeded` (one noisy tenant can no longer
      occupy the whole queue).  ``None`` = no per-tenant bound.
    * ``launch_retries`` — how many times a launch that failed with a
      *transient* fault is retried on the ref/demoted kernel path before
      the batch is bisected.
    * ``demote_after`` — consecutive primary-launch failures at one shape
      bucket before that bucket is demoted to the ref path for the rest of
      the process (recorded via ``kernels.ops.record_demotion``); 0 never
      demotes.
    * ``breaker_threshold`` — consecutive failed launches that trip the
      per-model circuit breaker (fast-fail
      :class:`repro.serve.ModelUnhealthy` until a half-open probe
      succeeds); 0 disables the breaker.
    * ``breaker_backoff_s`` / ``breaker_backoff_max_s`` — open → half-open
      probe backoff: doubles per consecutive trip, jittered by a PRNG
      seeded from ``(seed, trips)`` (deterministic replay).
    * ``seed`` — seeds the breaker's probe jitter.

    Hot-swap:

    * ``poll_interval_s`` — how often a :class:`repro.serve.CheckpointWatcher`
      polls its checkpoint directory for a newer intact step.
    * ``watcher_timeout_s`` — watchdog bound on one watcher poll (a hung
      checkpoint load is abandoned and counted as a stalled poll instead
      of freezing hot-swap forever); ``None`` = no watchdog.
    """

    max_batch: int = 4096
    min_bucket: int = 64
    max_linger_ms: float = 2.0
    queue_depth: int = 256
    impl: str = "auto"
    precision: str = "auto"
    donate: str = "auto"
    warmup: bool = True
    poll_interval_s: float = 0.2
    default_deadline_ms: float | None = None
    validate_requests: bool = True
    tenant_quota: int | None = None
    launch_retries: int = 1
    demote_after: int = 3
    breaker_threshold: int = 5
    breaker_backoff_s: float = 1.0
    breaker_backoff_max_s: float = 30.0
    seed: int = 0
    watcher_timeout_s: float | None = 30.0

    def __post_init__(self):
        def _positive(name, value):
            if not isinstance(value, int) or isinstance(value, bool) \
                    or value < 1:
                raise ValueError(
                    f"{name} must be a positive int, got {value!r}")

        _positive("max_batch", self.max_batch)
        _positive("min_bucket", self.min_bucket)
        _positive("queue_depth", self.queue_depth)
        if self.min_bucket > self.max_batch:
            raise ValueError(
                f"min_bucket={self.min_bucket} must be <= "
                f"max_batch={self.max_batch}")
        if self.max_linger_ms < 0:
            raise ValueError(
                f"max_linger_ms must be >= 0, got {self.max_linger_ms!r}")
        if self.poll_interval_s <= 0:
            raise ValueError(
                f"poll_interval_s must be positive, "
                f"got {self.poll_interval_s!r}")
        if self.default_deadline_ms is not None \
                and self.default_deadline_ms <= 0:
            raise ValueError(
                f"default_deadline_ms must be positive or None, "
                f"got {self.default_deadline_ms!r}")
        if not isinstance(self.validate_requests, bool):
            raise ValueError(
                f"validate_requests must be a bool, "
                f"got {self.validate_requests!r}")
        if self.tenant_quota is not None:
            _positive("tenant_quota", self.tenant_quota)
        for name in ("launch_retries", "demote_after", "breaker_threshold"):
            value = getattr(self, name)
            if not isinstance(value, int) or isinstance(value, bool) \
                    or value < 0:
                raise ValueError(
                    f"{name} must be a non-negative int, got {value!r}")
        if self.breaker_backoff_s <= 0 or self.breaker_backoff_max_s <= 0:
            raise ValueError("breaker backoffs must be positive")
        if not isinstance(self.seed, int) or isinstance(self.seed, bool):
            raise ValueError(f"seed must be an int, got {self.seed!r}")
        if self.watcher_timeout_s is not None and self.watcher_timeout_s <= 0:
            raise ValueError(
                f"watcher_timeout_s must be positive or None, "
                f"got {self.watcher_timeout_s!r}")
        if self.impl != "auto" and self.impl not in ops.IMPLS:
            raise ValueError(
                f"unknown impl {self.impl!r}; known: ('auto',) + {ops.IMPLS}")
        if self.precision != "auto":
            px.check(self.precision)
        if self.donate not in _DONATE_MODES:
            raise ValueError(
                f"donate must be one of {_DONATE_MODES}, got {self.donate!r}")
        if not isinstance(self.warmup, bool):
            raise ValueError(f"warmup must be a bool, got {self.warmup!r}")

    def replace(self, **overrides) -> "ServeConfig":
        """A copy with ``overrides`` applied (re-validated)."""
        return dataclasses.replace(self, **overrides)

    def buckets(self) -> tuple[int, ...]:
        """The padded power-of-two launch shapes, ascending.

        Every coalesced batch is padded up to the smallest bucket that
        holds it, so the jit cache holds exactly ``len(buckets())``
        entries per model and a new request size never triggers a
        recompile after warmup.
        """
        lo = _next_pow2(self.min_bucket)
        hi = _next_pow2(self.max_batch)
        out = []
        b = lo
        while b < hi:
            out.append(b)
            b *= 2
        out.append(hi)
        return tuple(out)


def _next_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p
