"""`repro.serve` — the batching assignment-serving subsystem.

The paper's end product is a centroid set whose value is realized at
assignment time; point-to-centroid lookup is itself a streaming big-data
workload.  This package productionizes it:

* :class:`Batcher` — coalesces concurrent client requests into one jitted
  assign launch: power-of-two padded shape buckets (zero recompiles after
  warmup), a bounded queue with a max-linger deadline, optional donated
  device buffers, per-request latency accounting.
* :class:`ModelRegistry` — multi-model tenancy: several (k, n) centroid
  sets resident at once, each with its own precision/impl policy routed
  through the autotuned ``kernels/ops.assign`` dispatch.
* :mod:`repro.serve.swap` — hot-swap: atomically replace a model's
  serving centroids (directly, or from the newest intact SHA-256-verified
  checkpoint) without dropping or re-queuing in-flight requests;
  :class:`CheckpointWatcher` automates it.
* :mod:`repro.serve.resilience` — the serving fault discipline: typed
  request failures (never a hang), per-model circuit breakers with seeded
  half-open probes, deadline shedding, per-tenant quotas, fault-isolated
  (classify → ref-retry → bisect) launches, and a supervised worker that
  fails pending futures and restarts on crashes.
* :class:`Server` / :func:`serve` — the assembled service, also exported
  from ``repro.api``; ``Server.health()`` aggregates breaker states,
  queue depths, worker/watcher liveness and swap ages.

See ``benchmarks/serve_latency.py`` for the p50/p99/throughput benchmark,
``benchmarks/serve_chaos.py`` for the multi-tenant fault-injection proof,
and the README "Serving" section for the architecture sketch.
"""
from repro.serve.batcher import AssignResponse, Batcher, BatcherStats
from repro.serve.config import ServeConfig
from repro.serve.registry import CentroidSnapshot, ModelEntry, ModelRegistry
from repro.serve.resilience import (
    CircuitBreaker,
    DeadlineExceeded,
    InvalidRequest,
    LaunchFault,
    ModelUnhealthy,
    QueueFull,
    QuotaExceeded,
    ServerClosed,
    WorkerCrashed,
)
from repro.serve.server import Server, serve
from repro.serve.swap import (
    CheckpointWatcher,
    load_centroids,
    swap_from_checkpoint,
)

__all__ = [
    "AssignResponse",
    "Batcher",
    "BatcherStats",
    "CentroidSnapshot",
    "CheckpointWatcher",
    "CircuitBreaker",
    "DeadlineExceeded",
    "InvalidRequest",
    "LaunchFault",
    "ModelEntry",
    "ModelRegistry",
    "ModelUnhealthy",
    "QueueFull",
    "QuotaExceeded",
    "ServeConfig",
    "Server",
    "ServerClosed",
    "WorkerCrashed",
    "load_centroids",
    "serve",
    "swap_from_checkpoint",
]
