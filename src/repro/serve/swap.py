"""Hot-swap: refresh serving centroids from training checkpoints.

The training stack writes SHA-256-digested checkpoints
(:mod:`repro.cluster.checkpoint`); this module is the serving-side
consumer.  :func:`load_centroids` restores the newest *intact* step
through the verified restore path (a torn or bit-rotted newest step falls
back, never serves garbage), understands both the engine's
``((state, key), vns_aux)`` payload and the legacy ``(state, key)`` one,
and reduces a batched incumbent state to its best stream.  A
:class:`CheckpointWatcher` polls a directory and swaps the registry
pointer whenever a newer intact step appears — traffic keeps flowing
through the swap (see :meth:`repro.serve.registry.ModelEntry.swap`).
"""
from __future__ import annotations

import threading
import time

import jax
import numpy as np

from repro.cluster import checkpoint
from repro.serve.registry import CentroidSnapshot, ModelRegistry


def _example_tree(k: int, n: int, n_leaves: int):
    """The restore skeleton matching a stored payload's leaf count.

    The streaming engine persists ``((BigMeansState, key), aux[3])``
    (7 leaves); pre-engine checkpoints stored ``(BigMeansState, key)``
    (6 leaves).  Leaf *shapes* in the example are irrelevant — restore
    fills in the stored arrays — only structure and count matter.
    """
    from repro.core import bigmeans

    legacy = (bigmeans.init_state(k, n), jax.random.PRNGKey(0))
    n_legacy = len(jax.tree.leaves(legacy))
    if n_leaves == n_legacy:
        return legacy, False
    if n_leaves == n_legacy + 1:
        return (legacy, np.zeros(3, np.int64)), True
    raise ValueError(
        f"unrecognized checkpoint payload: {n_leaves} leaves "
        f"(expected {n_legacy} or {n_legacy + 1})")


def load_centroids(ckpt_dir: str, *, step: int | None = None
                   ) -> tuple[np.ndarray, int]:
    """Load ``(centroids [k, n], step)`` from the newest intact checkpoint.

    Only steps passing the SHA-256 digest check are considered (PR-6
    self-healing semantics); a batched state's streams are reduced to the
    one with the best (finite, minimal) ``f_best``.
    """
    if step is None:
        step = checkpoint.latest_intact_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(
                f"no intact checkpoint under {ckpt_dir}")
    elif not checkpoint.verify_step(ckpt_dir, step):
        raise ValueError(
            f"checkpoint step {step} under {ckpt_dir} fails verification")
    n_leaves = checkpoint.n_leaves(ckpt_dir, step)
    example, engine_payload = _example_tree(1, 1, n_leaves)
    tree, got_step = checkpoint.restore(ckpt_dir, example, step=step)
    state = tree[0][0] if engine_payload else tree[0]
    centroids = np.asarray(state.centroids, dtype=np.float32)
    if centroids.ndim == 3:                      # batched incumbent streams
        f_best = np.asarray(state.f_best, dtype=np.float64).reshape(-1)
        f_best = np.where(np.isfinite(f_best), f_best, np.inf)
        centroids = centroids[int(np.argmin(f_best))]
    if centroids.ndim != 2:
        raise ValueError(
            f"checkpoint centroids have shape {centroids.shape}, "
            "expected [k, n] or [B, k, n]")
    return centroids, int(got_step)


def swap_from_checkpoint(registry: ModelRegistry, model_id: str,
                         ckpt_dir: str, *, step: int | None = None
                         ) -> CentroidSnapshot:
    """One-shot refresh: load the newest intact step and swap it in."""
    centroids, got_step = load_centroids(ckpt_dir, step=step)
    return registry.swap(model_id, centroids, step=got_step)


class CheckpointWatcher:
    """Supervised background thread: poll a checkpoint dir, swap new steps.

    The watcher only ever moves *forward* (a step newer than the last one
    it swapped in) and only through intact checkpoints, so a torn write
    mid-poll is skipped until the next complete save.  *Nothing* a poll
    does can kill the thread: every exception — including one from the
    directory scan itself — is recorded (``last_error`` / ``n_errors``)
    and retried next interval, and with ``poll_timeout_s`` each poll runs
    under a watchdog so a hung checkpoint load (NFS stall, torn mmap) is
    abandoned and counted in ``stalled_polls`` instead of freezing
    hot-swap forever.  Serving always continues on the current snapshot;
    ``describe()`` feeds ``Server.health()``.
    """

    def __init__(self, registry: ModelRegistry, model_id: str,
                 ckpt_dir: str, *, poll_interval_s: float = 0.2,
                 poll_timeout_s: float | None = 30.0):
        self.registry = registry
        self.model_id = model_id
        self.ckpt_dir = ckpt_dir
        self.poll_interval_s = poll_interval_s
        self.poll_timeout_s = poll_timeout_s
        self.n_swaps = 0
        self.n_errors = 0
        self.stalled_polls = 0
        self.last_step: int | None = None
        self.last_error: str | None = None
        self.last_poll_t: float | None = None    # monotonic, end of last poll
        self._stop = threading.Event()
        self._pending_done: threading.Event | None = None  # abandoned poll
        self._thread = threading.Thread(
            target=self._run, name=f"swap-{model_id}", daemon=True)

    def start(self) -> "CheckpointWatcher":
        # Seed the high-water mark with what is already serving, so a
        # watcher attached after a manual swap does not re-apply it.
        snap = self.registry.get(self.model_id).snapshot()
        if self.last_step is None:
            self.last_step = snap.step
        self._thread.start()
        return self

    def poll_once(self) -> bool:
        """One poll: swap if a newer intact step exists.  True on swap.
        Never raises — any failure lands in ``last_error``/``n_errors``."""
        try:
            step = checkpoint.latest_intact_step(self.ckpt_dir)
            if step is None or (self.last_step is not None
                                and step <= self.last_step):
                return False
            swap_from_checkpoint(self.registry, self.model_id,
                                 self.ckpt_dir, step=step)
        except Exception as exc:
            self.n_errors += 1
            self.last_error = f"{type(exc).__name__}: {exc}"
            return False
        self.last_step = step
        self.n_swaps += 1
        self.last_error = None
        return True

    def _poll_guarded(self) -> None:
        """One supervised poll cycle, with the hung-poll watchdog.

        An abandoned poll keeps running on its (daemon) thread; until it
        finishes we *skip* further polls rather than stacking a second
        load on top of a stalled filesystem.
        """
        if self._pending_done is not None:
            if not self._pending_done.is_set():
                return                            # previous poll still hung
            self._pending_done = None
        if self.poll_timeout_s is None:
            self.poll_once()
            self.last_poll_t = time.monotonic()
            return
        done = threading.Event()

        def _target():
            try:
                self.poll_once()
            finally:
                done.set()

        t = threading.Thread(target=_target,
                             name=f"swap-poll-{self.model_id}", daemon=True)
        t.start()
        if not done.wait(self.poll_timeout_s):
            self.stalled_polls += 1
            self.last_error = (
                f"poll stalled past {self.poll_timeout_s}s; abandoned")
            self._pending_done = done             # don't stack another poll
            self.registry.record(
                ("watcher_stall", self.model_id, self.poll_timeout_s))
        self.last_poll_t = time.monotonic()

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                self._poll_guarded()
            except Exception as exc:  # pragma: no cover — belt and braces
                self.n_errors += 1
                self.last_error = f"{type(exc).__name__}: {exc}"
            self._stop.wait(self.poll_interval_s)

    def alive(self) -> bool:
        return self._thread.is_alive()

    def describe(self) -> dict:
        """A JSON-safe snapshot for ``Server.health()``."""
        return {
            "model_id": self.model_id,
            "ckpt_dir": self.ckpt_dir,
            "alive": self.alive(),
            "n_swaps": self.n_swaps,
            "n_errors": self.n_errors,
            "stalled_polls": self.stalled_polls,
            "last_step": self.last_step,
            "last_error": self.last_error,
            "poll_age_s": (round(time.monotonic() - self.last_poll_t, 3)
                           if self.last_poll_t is not None else None),
        }

    def stop(self) -> None:
        self._stop.set()
        if self._thread.is_alive():
            self._thread.join(timeout=10.0)
