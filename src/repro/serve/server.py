"""`Server` — the assembled serving subsystem, and the `serve()` entry point.

Wiring: ``Server`` owns one :class:`~repro.serve.registry.ModelRegistry`
(tenancy + hot-swap) and one :class:`~repro.serve.batcher.Batcher` per
model (coalescing + admission), plus any :class:`CheckpointWatcher`
threads.  ``repro.api.serve()`` is the facade constructor::

    from repro.api import ServeConfig, fit, serve

    result = fit(X, k=25, s=8192, ckpt_dir="ckpt")
    with serve({"prod": result}, ServeConfig(max_linger_ms=2.0)) as srv:
        srv.watch("prod", "ckpt")                  # hot-swap on new ckpts
        resp = srv.assign("prod", queries)         # -> AssignResponse
"""
from __future__ import annotations

from concurrent.futures import Future

from repro.serve.batcher import AssignResponse, Batcher
from repro.serve.config import ServeConfig
from repro.serve.registry import CentroidSnapshot, ModelEntry, ModelRegistry
from repro.serve.swap import CheckpointWatcher, swap_from_checkpoint


class Server:
    """A running multi-model assignment service (in-process)."""

    def __init__(self, config: ServeConfig | None = None):
        self.config = config or ServeConfig()
        self.registry = ModelRegistry()
        self._batchers: dict[str, Batcher] = {}
        self._watchers: list[CheckpointWatcher] = []
        self._closed = False

    # -- tenancy ------------------------------------------------------------
    def register(self, model_id: str, centroids, *, impl: str | None = None,
                 precision: str | None = None,
                 warmup: bool | None = None) -> ModelEntry:
        """Make ``model_id`` servable.  ``centroids`` is a [k, n] array or
        anything with a ``.centroids`` field (e.g. a ``FitResult``).

        ``impl`` / ``precision`` default to the server config (so tenants
        can run different precision policies side by side); with ``warmup``
        every shape bucket is autotuned/demotion-probed and compiled now,
        off the request path.
        """
        import jax

        cfg = self.config
        donate = {"on": True, "off": False}.get(
            cfg.donate, jax.default_backend() not in ("cpu",))
        entry = self.registry.register(
            model_id, centroids,
            impl=cfg.impl if impl is None else impl,
            precision=cfg.precision if precision is None else precision,
            donate=donate)
        if cfg.warmup if warmup is None else warmup:
            entry.warmup(cfg.buckets())
        self._batchers[model_id] = Batcher(entry, cfg)
        return entry

    def unregister(self, model_id: str) -> None:
        batcher = self._batchers.pop(model_id, None)
        if batcher is not None:
            batcher.close()
        self.registry.unregister(model_id)

    def models(self) -> list[str]:
        return self.registry.list_models()

    # -- request path -------------------------------------------------------
    def submit(self, model_id: str, points) -> Future:
        """Enqueue a request; returns ``Future[AssignResponse]``.

        Raises :class:`repro.serve.QueueFull` immediately on a saturated
        queue (graceful rejection) and ``KeyError`` for unknown models.
        """
        try:
            batcher = self._batchers[model_id]
        except KeyError:
            raise KeyError(
                f"unknown model {model_id!r}; registered: "
                f"{self.models()}") from None
        return batcher.submit(points)

    def assign(self, model_id: str, points,
               timeout: float | None = 60.0) -> AssignResponse:
        """Synchronous convenience wrapper around :meth:`submit`."""
        return self.submit(model_id, points).result(timeout=timeout)

    # -- hot-swap -----------------------------------------------------------
    def swap(self, model_id: str, centroids, *,
             step: int | None = None) -> CentroidSnapshot:
        """Atomically replace ``model_id``'s serving centroids."""
        return self.registry.swap(model_id, centroids, step=step)

    def swap_from_checkpoint(self, model_id: str, ckpt_dir: str, *,
                             step: int | None = None) -> CentroidSnapshot:
        """Refresh from the newest intact (SHA-256-verified) checkpoint."""
        return swap_from_checkpoint(self.registry, model_id, ckpt_dir,
                                    step=step)

    def watch(self, model_id: str, ckpt_dir: str, *,
              poll_interval_s: float | None = None) -> CheckpointWatcher:
        """Start a background watcher hot-swapping ``model_id`` whenever a
        newer intact checkpoint appears under ``ckpt_dir``."""
        watcher = CheckpointWatcher(
            self.registry, model_id, ckpt_dir,
            poll_interval_s=poll_interval_s or self.config.poll_interval_s)
        self._watchers.append(watcher)
        return watcher.start()

    # -- telemetry ----------------------------------------------------------
    @property
    def trace(self) -> list:
        """Structured serving events (currently ``("swap", id, step)``)."""
        return self.registry.trace

    def stats(self, model_id: str | None = None) -> dict:
        """Per-model serving stats: latency percentiles, batch shapes,
        rejection and recompile counters."""
        def one(mid: str) -> dict:
            entry = self.registry.get(mid)
            out = self._batchers[mid].stats.to_dict()
            snap = entry.snapshot()
            out.update({
                "model_id": mid,
                "k": snap.k,
                "n_features": snap.n_features,
                "version": snap.version,
                "step": snap.step,
                "impl": entry.impl,
                "precision": entry.precision,
                "recompiles": entry.recompiles,
                "n_swaps": snap.version,
            })
            return out

        if model_id is not None:
            return one(model_id)
        return {mid: one(mid) for mid in self.models()}

    def recompiles(self, model_id: str) -> int:
        return self.registry.get(model_id).recompiles

    # -- lifecycle ----------------------------------------------------------
    def close(self, drain: bool = True) -> None:
        """Stop watchers, drain (or abort) queues, stop workers."""
        if self._closed:
            return
        self._closed = True
        for watcher in self._watchers:
            watcher.stop()
        for batcher in self._batchers.values():
            batcher.close(drain=drain)

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve(models: dict | None = None,
          config: ServeConfig | None = None, **overrides) -> Server:
    """Build and return a running :class:`Server`.

    * ``models`` — optional ``{model_id: centroids_or_FitResult}`` to
      register up front (each fully warmed before the call returns, so the
      first request never pays compilation).
    * ``config`` / ``overrides`` — a :class:`ServeConfig`, with field
      overrides applied on top (``serve(models, max_linger_ms=5.0)``).
    """
    cfg = config or ServeConfig()
    if overrides:
        cfg = cfg.replace(**overrides)
    server = Server(cfg)
    for model_id, centroids in (models or {}).items():
        server.register(model_id, centroids)
    return server
