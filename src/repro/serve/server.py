"""`Server` — the assembled serving subsystem, and the `serve()` entry point.

Wiring: ``Server`` owns one :class:`~repro.serve.registry.ModelRegistry`
(tenancy + hot-swap) and one :class:`~repro.serve.batcher.Batcher` per
model (coalescing + admission), plus any :class:`CheckpointWatcher`
threads.  ``repro.api.serve()`` is the facade constructor::

    from repro.api import ServeConfig, fit, serve

    result = fit(X, k=25, s=8192, ckpt_dir="ckpt")
    with serve({"prod": result}, ServeConfig(max_linger_ms=2.0)) as srv:
        srv.watch("prod", "ckpt")                  # hot-swap on new ckpts
        resp = srv.assign("prod", queries)         # -> AssignResponse
"""
from __future__ import annotations

import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FutureTimeoutError

from repro.serve.batcher import AssignResponse, Batcher
from repro.serve.resilience import CLOSED, DeadlineExceeded
from repro.serve.config import ServeConfig
from repro.serve.registry import CentroidSnapshot, ModelEntry, ModelRegistry
from repro.serve.swap import CheckpointWatcher, swap_from_checkpoint


class Server:
    """A running multi-model assignment service (in-process)."""

    def __init__(self, config: ServeConfig | None = None):
        self.config = config or ServeConfig()
        self.registry = ModelRegistry()
        self._batchers: dict[str, Batcher] = {}
        self._watchers: list[CheckpointWatcher] = []
        self._closed = False

    # -- tenancy ------------------------------------------------------------
    def register(self, model_id: str, centroids, *, impl: str | None = None,
                 precision: str | None = None,
                 warmup: bool | None = None) -> ModelEntry:
        """Make ``model_id`` servable.  ``centroids`` is a [k, n] array or
        anything with a ``.centroids`` field (e.g. a ``FitResult``).

        ``impl`` / ``precision`` default to the server config (so tenants
        can run different precision policies side by side); with ``warmup``
        every shape bucket is autotuned/demotion-probed and compiled now,
        off the request path.
        """
        import jax

        cfg = self.config
        donate = {"on": True, "off": False}.get(
            cfg.donate, jax.default_backend() not in ("cpu",))
        entry = self.registry.register(
            model_id, centroids,
            impl=cfg.impl if impl is None else impl,
            precision=cfg.precision if precision is None else precision,
            donate=donate)
        if cfg.warmup if warmup is None else warmup:
            entry.warmup(cfg.buckets())
        self._batchers[model_id] = Batcher(entry, cfg,
                                           trace=self.registry.record)
        return entry

    def unregister(self, model_id: str) -> None:
        batcher = self._batchers.pop(model_id, None)
        if batcher is not None:
            batcher.close()
        self.registry.unregister(model_id)

    def models(self) -> list[str]:
        return self.registry.list_models()

    # -- request path -------------------------------------------------------
    def _batcher(self, model_id: str) -> Batcher:
        try:
            return self._batchers[model_id]
        except KeyError:
            raise KeyError(
                f"unknown model {model_id!r}; registered: "
                f"{self.models()}") from None

    def submit(self, model_id: str, points, *,
               deadline_ms: float | None = None, tenant: str = "default",
               validate: bool | None = None) -> Future:
        """Enqueue a request; returns ``Future[AssignResponse]``.

        Admission is fail-fast and typed: :class:`repro.serve.QueueFull` on
        a saturated queue, :class:`repro.serve.QuotaExceeded` when
        ``tenant`` is over its quota, :class:`repro.serve.ModelUnhealthy`
        while the model's circuit breaker is open,
        :class:`repro.serve.InvalidRequest` for non-finite payloads, and
        ``KeyError`` for unknown models.  ``deadline_ms`` overrides
        ``config.default_deadline_ms`` for this request.
        """
        return self._batcher(model_id).submit(
            points, deadline_ms=deadline_ms, tenant=tenant,
            validate=validate)

    def assign(self, model_id: str, points,
               timeout: float | None = 60.0, *,
               deadline_ms: float | None = None, tenant: str = "default",
               validate: bool | None = None) -> AssignResponse:
        """Synchronous convenience wrapper around :meth:`submit`.

        On ``timeout`` the queued request is *cancelled* — it will not
        burn a launch slot later, and its latency never enters the
        percentiles a client didn't observe — and
        :class:`repro.serve.DeadlineExceeded` is raised.
        """
        batcher = self._batcher(model_id)
        fut = batcher.submit(points, deadline_ms=deadline_ms, tenant=tenant,
                             validate=validate)
        try:
            return fut.result(timeout=timeout)
        except FutureTimeoutError:
            batcher.cancel(fut)
            raise DeadlineExceeded(
                f"model {model_id!r}: assign() timed out after {timeout}s; "
                "request cancelled") from None

    # -- hot-swap -----------------------------------------------------------
    def swap(self, model_id: str, centroids, *,
             step: int | None = None) -> CentroidSnapshot:
        """Atomically replace ``model_id``'s serving centroids."""
        return self.registry.swap(model_id, centroids, step=step)

    def swap_from_checkpoint(self, model_id: str, ckpt_dir: str, *,
                             step: int | None = None) -> CentroidSnapshot:
        """Refresh from the newest intact (SHA-256-verified) checkpoint."""
        return swap_from_checkpoint(self.registry, model_id, ckpt_dir,
                                    step=step)

    def watch(self, model_id: str, ckpt_dir: str, *,
              poll_interval_s: float | None = None,
              poll_timeout_s: float | None = None) -> CheckpointWatcher:
        """Start a background watcher hot-swapping ``model_id`` whenever a
        newer intact checkpoint appears under ``ckpt_dir``.  Polls run
        under the ``config.watcher_timeout_s`` watchdog (overridable here)
        so a hung checkpoint load can never freeze hot-swap."""
        watcher = CheckpointWatcher(
            self.registry, model_id, ckpt_dir,
            poll_interval_s=poll_interval_s or self.config.poll_interval_s,
            poll_timeout_s=(self.config.watcher_timeout_s
                            if poll_timeout_s is None else poll_timeout_s))
        self._watchers.append(watcher)
        return watcher.start()

    # -- telemetry ----------------------------------------------------------
    @property
    def trace(self) -> list:
        """Structured serving events (currently ``("swap", id, step)``)."""
        return self.registry.trace

    def stats(self, model_id: str | None = None) -> dict:
        """Per-model serving stats: latency percentiles, batch shapes,
        rejection and recompile counters."""
        def one(mid: str) -> dict:
            entry = self.registry.get(mid)
            out = self._batchers[mid].stats.to_dict()
            snap = entry.snapshot()
            out.update({
                "model_id": mid,
                "k": snap.k,
                "n_features": snap.n_features,
                "version": snap.version,
                "step": snap.step,
                "impl": entry.impl,
                "precision": entry.precision,
                "recompiles": entry.recompiles,
                "n_swaps": snap.version,
            })
            return out

        if model_id is not None:
            return one(model_id)
        return {mid: one(mid) for mid in self.models()}

    def recompiles(self, model_id: str) -> int:
        return self.registry.get(model_id).recompiles

    def health(self) -> dict:
        """One aggregated liveness/readiness snapshot of the whole server.

        Per model: queue depth, circuit-breaker state, worker liveness and
        restart count, demoted buckets, and the age of the serving
        snapshot; plus every watcher's :meth:`CheckpointWatcher.describe`.
        ``ok`` is True iff every breaker is closed, every worker and
        watcher thread is alive, and no watcher poll is currently stalled.
        """
        now = time.monotonic()
        models = {}
        ok = not self._closed
        for mid in self.models():
            entry = self.registry.get(mid)
            batcher = self._batchers[mid]
            snap = entry.snapshot()
            breaker = batcher.breaker.describe()
            alive = batcher.worker_alive()
            models[mid] = {
                "queue_depth": batcher.queue_depth(),
                "breaker": breaker,
                "worker_alive": alive,
                "worker_restarts": batcher.stats.worker_restarts,
                "demoted_buckets": list(entry.demoted_buckets),
                "version": snap.version,
                "step": snap.step,
                "last_swap_age_s": round(now - snap.t_swapped, 3),
            }
            ok = ok and alive and breaker["state"] == CLOSED
        watchers = [w.describe() for w in self._watchers]
        for w in watchers:
            ok = ok and w["alive"] and not (
                w["last_error"] or "").startswith("poll stalled")
        return {"ok": ok, "models": models, "watchers": watchers}

    # -- lifecycle ----------------------------------------------------------
    def close(self, drain: bool = True) -> None:
        """Stop watchers, drain (or abort) queues, stop workers."""
        if self._closed:
            return
        self._closed = True
        for watcher in self._watchers:
            watcher.stop()
        for batcher in self._batchers.values():
            batcher.close(drain=drain)

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve(models: dict | None = None,
          config: ServeConfig | None = None, **overrides) -> Server:
    """Build and return a running :class:`Server`.

    * ``models`` — optional ``{model_id: centroids_or_FitResult}`` to
      register up front (each fully warmed before the call returns, so the
      first request never pays compilation).
    * ``config`` / ``overrides`` — a :class:`ServeConfig`, with field
      overrides applied on top (``serve(models, max_linger_ms=5.0)``).
    """
    cfg = config or ServeConfig()
    if overrides:
        cfg = cfg.replace(**overrides)
    server = Server(cfg)
    for model_id, centroids in (models or {}).items():
        server.register(model_id, centroids)
    return server
