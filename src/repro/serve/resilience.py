"""Serving-side resilience vocabulary: typed faults + the circuit breaker.

The serving layer speaks the same fault discipline as the streaming engine
(:mod:`repro.engine.faults`): every way a request can fail resolves its
future with a *typed* exception — never a hang — and the per-model circuit
breaker turns a dying model into fast, cheap rejections instead of a queue
of doomed launches.

Exceptions (all reachable from ``repro.serve``):

* :class:`DeadlineExceeded` — the request's deadline expired while it sat
  in the queue (shed before wasting a launch slot) or before submission.
* :class:`InvalidRequest` — the payload failed admission validation
  (non-finite values); a ``ValueError`` subclass, i.e. a *client* error.
* :class:`LaunchFault` — the launch carrying this request failed
  permanently (after transient retries and batch bisection isolated it).
* :class:`ModelUnhealthy` — the model's circuit breaker is open; retry
  after ``retry_in_s``.
* :class:`QuotaExceeded` — the per-tenant admission quota is full
  (a :class:`repro.serve.QueueFull` subclass: same backpressure contract).
* :class:`WorkerCrashed` — the batcher worker died with this request
  pending; the supervisor failed it and restarted the worker.

The breaker follows the classic three-state machine, with the same
seeded-determinism rule as the engine's :class:`RetryPolicy`: the open →
half-open backoff is jittered by a PRNG seeded from ``(seed, trips)``, so
a replayed chaos run probes at identical offsets.
"""
from __future__ import annotations

import threading
import time

import numpy as np

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class QueueFull(RuntimeError):
    """The model's request queue is at ``queue_depth``; retry later."""


class ServerClosed(RuntimeError):
    """The server (or this model's batcher) has been shut down."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline expired before a launch could serve it."""


class InvalidRequest(ValueError):
    """The request payload failed admission validation (non-finite values):
    a client error, rejected at submit time so it can never poison a
    coalesced launch."""


class LaunchFault(RuntimeError):
    """The launch carrying this request failed permanently.  Bisection has
    already isolated the failure: coalesced neighbors were re-launched and
    served; only the requests actually implicated carry this exception."""


class ModelUnhealthy(RuntimeError):
    """The model's circuit breaker is open: recent launches failed
    consecutively, so requests fast-fail instead of queueing for a doomed
    launch.  ``retry_in_s`` says when the next half-open probe is due."""

    def __init__(self, msg: str, retry_in_s: float = 0.0):
        super().__init__(msg)
        self.retry_in_s = retry_in_s


class QuotaExceeded(QueueFull):
    """This tenant's admission quota is full (other tenants still admit):
    per-tenant backpressure, same retry contract as :class:`QueueFull`."""


class WorkerCrashed(RuntimeError):
    """The batcher worker thread crashed while this request was pending.
    The supervisor failed every pending future with this exception and
    restarted the worker — clients see an error, never a hang."""


class CircuitBreaker:
    """Per-model three-state circuit breaker with seeded probe backoff.

    * **closed** — healthy; every launch outcome is recorded, and
      ``threshold`` *consecutive* failed launches trip the breaker.  A
      bisected batch records per-sub-launch, so one poisoned request among
      healthy traffic (fail, success, …) never accumulates to the
      threshold — only a model failing *everything* does.
    * **open** — submits fast-fail with :class:`ModelUnhealthy` until the
      backoff expires: ``min(backoff_s · 2^(trips−1), backoff_max_s)``
      jittered by a PRNG seeded from ``(seed, trips)`` (deterministic
      replay, no thundering probes).
    * **half_open** — the first ``allow()`` after the backoff admits one
      probe request; everyone else keeps fast-failing.  The probe's launch
      outcome closes the breaker (success) or re-opens it with a doubled
      backoff (failure).

    ``threshold=0`` disables the breaker (``allow()`` is always True and
    nothing ever trips).  ``on_event`` receives ``("breaker_open", ...)``
    / ``("breaker_probe", ...)`` / ``("breaker_close", ...)`` trace tuples.
    """

    def __init__(self, model_id: str, *, threshold: int = 5,
                 backoff_s: float = 1.0, backoff_max_s: float = 30.0,
                 seed: int = 0, clock=time.monotonic, on_event=None):
        self.model_id = model_id
        self.threshold = threshold
        self.backoff_s = backoff_s
        self.backoff_max_s = backoff_max_s
        self.seed = seed
        self._clock = clock
        self._on_event = on_event or (lambda event: None)
        self._lock = threading.Lock()
        self.state = CLOSED
        self.failures = 0          # consecutive failed launches
        self.trips = 0             # times the breaker has opened
        self._retry_at = 0.0

    # -- policy --------------------------------------------------------------
    def _probe_delay(self) -> float:
        base = min(self.backoff_s * (2.0 ** max(self.trips - 1, 0)),
                   self.backoff_max_s)
        rng = np.random.default_rng((self.seed, 0xB4EA, self.trips))
        return base * (0.5 + 0.5 * float(rng.random()))

    def allow(self) -> bool:
        """May a new request be admitted right now?  (Transitions open →
        half_open when the probe backoff has expired.)"""
        if self.threshold <= 0:
            return True
        with self._lock:
            if self.state == CLOSED:
                return True
            if self.state == OPEN and self._clock() >= self._retry_at:
                self.state = HALF_OPEN
                self._on_event(("breaker_probe", self.model_id, self.trips))
                return True                       # this caller is the probe
            return False                          # open, or probe in flight

    def retry_in_s(self) -> float:
        with self._lock:
            if self.state != OPEN:
                return 0.0
            return max(self._retry_at - self._clock(), 0.0)

    # -- launch outcomes -----------------------------------------------------
    def record_success(self) -> None:
        if self.threshold <= 0:
            return
        with self._lock:
            was = self.state
            self.state = CLOSED
            self.failures = 0
        if was != CLOSED:
            self._on_event(("breaker_close", self.model_id, self.trips))

    def record_failure(self, reason: str = "") -> None:
        if self.threshold <= 0:
            return
        with self._lock:
            self.failures += 1
            trip = (self.state == HALF_OPEN
                    or (self.state == CLOSED
                        and self.failures >= self.threshold))
            if not trip:
                return
            self.state = OPEN
            self.trips += 1
            self._retry_at = self._clock() + self._probe_delay()
        self._on_event(("breaker_open", self.model_id,
                        reason or f"{self.failures} consecutive failures"))

    # -- telemetry -----------------------------------------------------------
    def describe(self) -> dict:
        """A JSON-safe snapshot for ``Server.health()`` (no transitions)."""
        with self._lock:
            return {
                "state": self.state,
                "consecutive_failures": self.failures,
                "trips": self.trips,
                "retry_in_s": (round(max(self._retry_at - self._clock(), 0.0),
                                     3) if self.state == OPEN else 0.0),
            }
