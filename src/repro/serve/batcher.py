"""The batching frontend: coalesce concurrent requests into one launch.

Serving traffic is many small point batches arriving concurrently; the
kernel wants one large launch.  Each model gets one :class:`Batcher`: a
bounded queue plus a *supervised* worker thread that

1. blocks for the first pending request,
2. lingers up to ``max_linger_ms`` pulling whole requests while they fit
   under ``max_batch`` (a request is never split across launches — one
   response always comes from exactly one launch, hence exactly one
   centroid snapshot), shedding expired or cancelled requests from the
   queue before they can waste launch capacity,
3. pads the coalesced rows to the next power-of-two bucket (the jit cache
   therefore holds one executable per bucket and never recompiles per
   request size),
4. reads the model's centroid snapshot *once*, launches, and scatters the
   results back to each request's future with per-request latency
   accounting.

Admission is fail-fast: a full queue raises :class:`QueueFull`, a full
per-tenant quota :class:`QuotaExceeded`, an open circuit breaker
:class:`ModelUnhealthy`, a non-finite payload :class:`InvalidRequest` —
all at submit time, never by blocking the caller.

Failure is isolated, not amplified.  A launch that raises is classified
through :func:`repro.engine.faults.classify`: transients retry on the
ref/demoted kernel path; permanents *bisect* the batch so only the
requests actually implicated fail (their coalesced neighbors are
re-launched and served bitwise-identically to the healthy path).  The
worker itself runs under a supervisor: a crash fails every pending future
with :class:`WorkerCrashed` (never a stranded client), increments
``worker_restarts``, and restarts the serve loop.
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass

import jax
import numpy as np

from repro.engine import faults
from repro.serve import resilience
from repro.serve.config import ServeConfig, _next_pow2
from repro.serve.registry import ModelEntry
from repro.serve.resilience import (
    CircuitBreaker,
    DeadlineExceeded,
    InvalidRequest,
    LaunchFault,
    ModelUnhealthy,
    QueueFull,
    QuotaExceeded,
    ServerClosed,
    WorkerCrashed,
)

__all__ = [
    "AssignResponse",
    "Batcher",
    "BatcherStats",
    "QueueFull",
    "ServerClosed",
]


@dataclass
class AssignResponse:
    """One request's results plus its serving telemetry.

    ``version`` / ``step`` identify the exact centroid snapshot that
    served this response (one snapshot per response, by construction);
    ``batch_rows`` / ``n_coalesced`` describe the launch it rode in;
    ``latency_ms`` is submit-to-completion, queueing and linger included.
    """

    ids: np.ndarray         # [m] int32 cluster ids
    dists: np.ndarray       # [m] f32 squared distances
    model_id: str
    version: int
    step: int | None
    latency_ms: float
    batch_rows: int         # padded bucket rows of the launch
    n_coalesced: int        # requests coalesced into the launch


class _Request:
    __slots__ = ("points", "future", "t_submit", "deadline", "tenant")

    def __init__(self, points: np.ndarray, *, deadline: float | None = None,
                 tenant: str = "default"):
        self.points = points
        self.future: Future = Future()
        self.t_submit = time.monotonic()
        self.deadline = deadline           # absolute monotonic, or None
        self.tenant = tenant


class BatcherStats:
    """Mutable per-model serving counters (snapshot via ``to_dict``).

    Latency percentiles only ever see requests that completed with a
    result: cancelled, shed, rejected and failed requests are counted in
    their own counters and excluded — a client that gave up must not
    drag the percentiles it never observed.
    """

    def __init__(self, maxlen: int = 20000):
        self.lock = threading.Lock()
        self.latencies_ms = collections.deque(maxlen=maxlen)
        self.n_requests = 0
        self.n_rejected = 0          # QueueFull
        self.n_quota_rejected = 0    # QuotaExceeded (per-tenant)
        self.n_breaker_rejected = 0  # ModelUnhealthy fast-fails
        self.n_invalid = 0           # non-finite payloads (InvalidRequest)
        self.n_cancelled = 0         # client gave up (assign timeout)
        self.n_deadline_shed = 0     # expired in queue (DeadlineExceeded)
        self.n_launch_faults = 0     # launches that raised
        self.n_ref_retries = 0       # transient faults recovered on ref path
        self.n_failed = 0            # requests resolved with LaunchFault
        self.worker_restarts = 0     # supervisor restarts of the serve loop
        self.n_batches = 0
        self.n_points = 0
        self.n_padded_rows = 0

    def record_batch(self, reqs: list, bucket: int) -> None:
        with self.lock:
            self.n_batches += 1
            rows = sum(r.points.shape[0] for r in reqs)
            self.n_points += rows
            self.n_padded_rows += bucket - rows

    def record_latency(self, ms: float) -> None:
        with self.lock:
            self.latencies_ms.append(ms)

    def bump(self, counter: str, by: int = 1) -> None:
        with self.lock:
            setattr(self, counter, getattr(self, counter) + by)

    def to_dict(self) -> dict:
        with self.lock:
            lat = np.asarray(self.latencies_ms, dtype=np.float64)
            out = {
                "n_requests": self.n_requests,
                "n_rejected": self.n_rejected,
                "n_quota_rejected": self.n_quota_rejected,
                "n_breaker_rejected": self.n_breaker_rejected,
                "n_invalid": self.n_invalid,
                "n_cancelled": self.n_cancelled,
                "n_deadline_shed": self.n_deadline_shed,
                "n_launch_faults": self.n_launch_faults,
                "n_ref_retries": self.n_ref_retries,
                "n_failed": self.n_failed,
                "worker_restarts": self.worker_restarts,
                "n_batches": self.n_batches,
                "n_points": self.n_points,
                "n_padded_rows": self.n_padded_rows,
                "requests_per_batch": (
                    self.n_requests / self.n_batches if self.n_batches else 0.0),
            }
        if lat.size:
            out["p50_ms"] = float(np.percentile(lat, 50))
            out["p99_ms"] = float(np.percentile(lat, 99))
            out["mean_ms"] = float(lat.mean())
        return out


class Batcher:
    """One model's bounded queue + supervised coalescing worker thread."""

    def __init__(self, entry: ModelEntry, config: ServeConfig,
                 trace=None):
        self._entry = entry
        self._cfg = config
        self._buckets = config.buckets()
        self._queue: collections.deque[_Request] = collections.deque()
        self._cond = threading.Condition()
        self._closed = False
        self._tenant_pending: collections.Counter = collections.Counter()
        self._inflight: list[_Request] = []
        self._bucket_fail_streak: collections.Counter = collections.Counter()
        self.stats = BatcherStats()
        self._trace_cb = trace
        self.events: list = []
        self.breaker = CircuitBreaker(
            entry.model_id,
            threshold=config.breaker_threshold,
            backoff_s=config.breaker_backoff_s,
            backoff_max_s=config.breaker_backoff_max_s,
            seed=config.seed,
            on_event=self._emit)
        self._worker = threading.Thread(
            target=self._supervise, name=f"serve-{entry.model_id}",
            daemon=True)
        self._worker.start()

    def _emit(self, event: tuple) -> None:
        self.events.append(event)
        if self._trace_cb is not None:
            self._trace_cb(event)

    # -- client side --------------------------------------------------------
    def submit(self, points, *, deadline_ms: float | None = None,
               tenant: str = "default", validate: bool | None = None
               ) -> Future:
        """Enqueue one request; returns a Future[AssignResponse].

        Admission is checked immediately, never by blocking the caller:
        :class:`ServerClosed` after shutdown, :class:`ModelUnhealthy`
        while the circuit breaker is open, :class:`QueueFull` /
        :class:`QuotaExceeded` on a saturated queue or tenant quota, and
        :class:`InvalidRequest` for non-finite payloads (unless
        ``validate=False`` — a trusted-client fast path).
        ``deadline_ms`` overrides ``config.default_deadline_ms``; an
        expired request is shed from the queue with
        :class:`DeadlineExceeded` instead of wasting a launch slot.
        """
        pts = np.asarray(points, dtype=np.float32)
        if pts.ndim == 1:
            pts = pts[None, :]
        n = self._entry.snapshot().n_features
        if pts.ndim != 2 or pts.shape[1] != n:
            raise ValueError(
                f"request points must be [m, {n}], got {pts.shape}")
        if pts.shape[0] == 0:
            raise ValueError("empty request")
        if pts.shape[0] > self._cfg.max_batch:
            raise ValueError(
                f"request of {pts.shape[0]} points exceeds "
                f"max_batch={self._cfg.max_batch}; split it client-side")
        if deadline_ms is None:
            deadline_ms = self._cfg.default_deadline_ms
        elif deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms must be positive, got {deadline_ms!r}")
        if (self._cfg.validate_requests if validate is None else validate) \
                and not np.isfinite(pts).all():
            self.stats.bump("n_invalid")
            raise InvalidRequest(
                f"request for model {self._entry.model_id!r} contains "
                "non-finite values (NaN/Inf); rejected at admission so it "
                "cannot poison a coalesced launch")
        if not self.breaker.allow():
            self.stats.bump("n_breaker_rejected")
            retry_in = self.breaker.retry_in_s()
            raise ModelUnhealthy(
                f"model {self._entry.model_id!r} circuit breaker is "
                f"{self.breaker.state}; retry in {retry_in:.2f}s",
                retry_in_s=retry_in)
        req = _Request(
            pts,
            deadline=(time.monotonic() + deadline_ms / 1e3
                      if deadline_ms is not None else None),
            tenant=tenant)
        with self._cond:
            if self._closed:
                raise ServerClosed(
                    f"model {self._entry.model_id!r} is not serving")
            if len(self._queue) >= self._cfg.queue_depth:
                self.stats.bump("n_rejected")
                raise QueueFull(
                    f"model {self._entry.model_id!r}: {len(self._queue)} "
                    f"requests pending (queue_depth="
                    f"{self._cfg.queue_depth}); retry with backoff")
            quota = self._cfg.tenant_quota
            if quota is not None and self._tenant_pending[tenant] >= quota:
                self.stats.bump("n_quota_rejected")
                raise QuotaExceeded(
                    f"model {self._entry.model_id!r}: tenant {tenant!r} has "
                    f"{self._tenant_pending[tenant]} requests pending "
                    f"(tenant_quota={quota}); retry with backoff")
            self._queue.append(req)
            self._tenant_pending[tenant] += 1
            self.stats.bump("n_requests")
            self._cond.notify()
        return req.future

    def cancel(self, future: Future) -> bool:
        """Withdraw a queued request (``assign`` timeout path).

        Removes it from the queue so no launch slot is burned on a client
        that already gave up, and cancels the future so the worker skips
        it even if it was dequeued concurrently.  Returns True if the
        future will never be launched; a request already in a launch
        cannot be recalled (its result is simply dropped by the caller).
        """
        with self._cond:
            for i, r in enumerate(self._queue):
                if r.future is future:
                    del self._queue[i]
                    self._tenant_pending[r.tenant] -= 1
                    future.cancel()
                    self.stats.bump("n_cancelled")
                    return True
        # Not queued: either about to launch (cancel() wins the race only
        # if the worker has not marked it running yet) or already done.
        won = future.cancel()
        if won:
            self.stats.bump("n_cancelled")
        return won

    def queue_depth(self) -> int:
        with self._cond:
            return len(self._queue)

    def worker_alive(self) -> bool:
        return self._worker.is_alive()

    # -- worker side --------------------------------------------------------
    def _admit(self, req: _Request) -> bool:
        """Post-dequeue admission: skip cancelled, shed expired."""
        if not req.future.set_running_or_notify_cancel():
            return False                         # client cancelled in queue
        if req.deadline is not None:
            overdue = time.monotonic() - req.deadline
            if overdue > 0:
                self.stats.bump("n_deadline_shed")
                self._emit(("deadline_shed", self._entry.model_id,
                            round(overdue * 1e3, 3)))
                req.future.set_exception(DeadlineExceeded(
                    f"model {self._entry.model_id!r}: deadline exceeded by "
                    f"{overdue * 1e3:.1f}ms while queued; request shed "
                    "before launch"))
                return False
        return True

    def _dequeue_locked(self) -> _Request:
        req = self._queue.popleft()
        self._tenant_pending[req.tenant] -= 1
        self._inflight.append(req)
        return req

    def _take_batch(self) -> list[_Request] | None:
        """Block for the first admitted request, then linger to coalesce.

        Cancelled and deadline-expired requests are resolved and skipped
        here — before any launch capacity is reserved for them.  Returns
        None only when closed and drained.
        """
        first = None
        while first is None:
            with self._cond:
                while not self._queue and not self._closed:
                    self._cond.wait()
                if not self._queue:
                    return None                  # closed and drained
                req = self._dequeue_locked()
            if self._admit(req):
                first = req
        batch = [first]
        total = first.points.shape[0]
        deadline = first.t_submit + self._cfg.max_linger_ms / 1e3
        while total < self._cfg.max_batch:
            with self._cond:
                if self._queue:
                    m = self._queue[0].points.shape[0]
                    if total + m > self._cfg.max_batch:
                        break                    # next request rides later
                    req = self._dequeue_locked()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0 or self._closed:
                        break
                    self._cond.wait(remaining)
                    continue
            if self._admit(req):
                batch.append(req)
                total += req.points.shape[0]
        return batch

    def _bucket_for(self, rows: int) -> int:
        b = max(_next_pow2(rows), self._buckets[0])
        return min(b, self._buckets[-1])

    def _pack(self, batch: list[_Request], n_features: int
              ) -> tuple[np.ndarray, int]:
        rows = sum(r.points.shape[0] for r in batch)
        bucket = self._bucket_for(rows)
        buf = np.zeros((bucket, n_features), dtype=np.float32)
        off = 0
        for r in batch:
            m = r.points.shape[0]
            buf[off:off + m] = r.points
            off += m
        return buf, bucket

    def _scatter(self, batch, ids, dists, snap, bucket) -> None:
        t_done = time.monotonic()
        self.stats.record_batch(batch, bucket)
        off = 0
        for r in batch:
            m = r.points.shape[0]
            latency_ms = (t_done - r.t_submit) * 1e3
            self.stats.record_latency(latency_ms)
            r.future.set_result(AssignResponse(
                ids=ids[off:off + m].copy(),
                dists=dists[off:off + m].copy(),
                model_id=self._entry.model_id,
                version=snap.version,
                step=snap.step,
                latency_ms=latency_ms,
                batch_rows=bucket,
                n_coalesced=len(batch)))
            off += m

    # -- fault-isolated launch ----------------------------------------------
    def _launch_batch(self, batch: list[_Request]) -> None:
        """Launch ``batch``; classify, retry, bisect on failure.

        Transient faults retry the whole batch on the ref/demoted kernel
        path (``launch_retries`` attempts).  Permanent faults — and
        transients whose retries failed — bisect: each half re-launches at
        its own bucket, so a single poisoned request fails alone with
        :class:`LaunchFault` while its coalesced neighbors are served
        (bitwise-identically to a healthy launch, by the same padding
        invariance the buckets already rely on).  Every successful
        (sub-)launch feeds the circuit breaker a success, every
        single-request dead end a failure — only a model failing
        *everything* accumulates to the trip threshold.
        """
        snap = self._entry.snapshot()            # ONE snapshot per launch
        buf, bucket = self._pack(batch, snap.n_features)
        try:
            if self._entry.is_demoted(bucket):
                # Route around the failing primary at the batcher level,
                # so a wrapped/instrumented primary launch is not touched.
                ids, dists = self._entry.launch_fallback(
                    jax.numpy.asarray(buf), snap)
            else:
                ids, dists = self._entry.launch(jax.numpy.asarray(buf), snap)
        except Exception as exc:
            self._on_launch_fault(batch, buf, snap, bucket, exc)
            return
        self._bucket_fail_streak[bucket] = 0
        self.breaker.record_success()
        self._scatter(batch, ids, dists, snap, bucket)

    def _on_launch_fault(self, batch, buf, snap, bucket, exc) -> None:
        kind = faults.classify(exc)
        self.stats.bump("n_launch_faults")
        self._emit(("launch_fault", self._entry.model_id,
                    f"{kind}: {type(exc).__name__}: {exc}"))
        streak = self._bucket_fail_streak[bucket] + 1
        self._bucket_fail_streak[bucket] = streak
        if self._cfg.demote_after and streak == self._cfg.demote_after:
            # This bucket keeps failing on the primary path: pin it to the
            # ref fallback for the rest of the process.
            self._entry.demote_bucket(bucket, exc)
        if kind == faults.TRANSIENT:
            # The payload is not implicated: retry on the ref/demoted path
            # (rebuilt from the host buffer — the primary may have donated
            # the device array before failing).
            for _ in range(self._cfg.launch_retries):
                try:
                    ids, dists = self._entry.launch_fallback(
                        jax.numpy.asarray(buf), snap)
                except Exception as exc2:  # noqa: BLE001 — classified below
                    exc = exc2
                    self._emit(("launch_fault", self._entry.model_id,
                                f"ref retry: {type(exc).__name__}: {exc}"))
                    continue
                self.stats.bump("n_ref_retries")
                self.breaker.record_success()
                self._scatter(batch, ids, dists, snap, bucket)
                return
        if len(batch) == 1:
            # Fully isolated: this request is implicated; fail it alone.
            self.breaker.record_failure(f"{type(exc).__name__}: {exc}")
            self.stats.bump("n_failed")
            req = batch[0]
            req.future.set_exception(LaunchFault(
                f"model {self._entry.model_id!r}: launch failed "
                f"[{kind}] after isolation: {type(exc).__name__}: {exc}"))
            return
        # Permanent fault in a coalesced launch: bisect so only the
        # requests actually causing it fail.  Each half re-buckets and
        # re-launches; healthy halves return bitwise-identical results.
        mid = len(batch) // 2
        for half in (batch[:mid], batch[mid:]):
            self._launch_batch(half)

    # -- supervised serve loop ----------------------------------------------
    def _serve_loop(self) -> None:
        while True:
            batch = self._take_batch()
            if batch is None:
                return                           # clean shutdown
            if not batch:
                continue                         # everything shed/cancelled
            self._launch_batch(batch)
            self._inflight.clear()

    def _fail_request(self, req: _Request, exc: Exception) -> None:
        try:
            req.future.set_exception(exc)
        except Exception:  # noqa: BLE001 — already resolved/cancelled
            pass

    def _on_worker_crash(self, exc: BaseException) -> None:
        """Fail everything pending, loudly, then let the loop restart."""
        err = WorkerCrashed(
            f"serving worker for model {self._entry.model_id!r} crashed "
            f"({type(exc).__name__}: {exc}); pending requests failed and "
            "the worker restarted")
        with self._cond:
            pending = list(self._queue)
            self._queue.clear()
            self._tenant_pending.clear()
            inflight = list(self._inflight)
            self._inflight.clear()
        for r in inflight + pending:
            self._fail_request(r, err)
        self.stats.bump("worker_restarts")
        self._emit(("worker_restart", self._entry.model_id,
                    f"{type(exc).__name__}: {exc}"))

    def _supervise(self) -> None:
        """The worker thread: run the serve loop, restart it on crashes.

        ``_serve_loop`` returning means closed-and-drained; anything
        *raising* out of it is a worker crash — without supervision that
        thread death would strand every queued future while ``submit``
        kept accepting (the PR-6-era bug this loop exists to kill)."""
        while True:
            try:
                self._serve_loop()
                return
            except BaseException as exc:  # noqa: BLE001 — supervisor
                self._on_worker_crash(exc)
                with self._cond:
                    if self._closed:
                        return

    def close(self, drain: bool = True) -> None:
        """Stop accepting requests; finish (or fail) what is queued."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            pending = [] if drain else list(self._queue)
            if not drain:
                self._queue.clear()
                self._tenant_pending.clear()
            self._cond.notify_all()
        for r in pending:
            self._fail_request(r, ServerClosed(
                f"model {self._entry.model_id!r} shut down"))
        self._worker.join(timeout=10.0)
