"""The batching frontend: coalesce concurrent requests into one launch.

Serving traffic is many small point batches arriving concurrently; the
kernel wants one large launch.  Each model gets one :class:`Batcher`: a
bounded queue plus a worker thread that

1. blocks for the first pending request,
2. lingers up to ``max_linger_ms`` pulling whole requests while they fit
   under ``max_batch`` (a request is never split across launches — one
   response always comes from exactly one launch, hence exactly one
   centroid snapshot),
3. pads the coalesced rows to the next power-of-two bucket (the jit cache
   therefore holds one executable per bucket and never recompiles per
   request size),
4. reads the model's centroid snapshot *once*, launches, and scatters the
   results back to each request's future with per-request latency
   accounting.

Admission is fail-fast: a full queue raises :class:`QueueFull` at submit
time — clients get backpressure immediately instead of a hang.
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass

import jax
import numpy as np

from repro.serve.config import ServeConfig, _next_pow2
from repro.serve.registry import ModelEntry


class QueueFull(RuntimeError):
    """The model's request queue is at ``queue_depth``; retry later."""


class ServerClosed(RuntimeError):
    """The server (or this model's batcher) has been shut down."""


@dataclass
class AssignResponse:
    """One request's results plus its serving telemetry.

    ``version`` / ``step`` identify the exact centroid snapshot that
    served this response (one snapshot per response, by construction);
    ``batch_rows`` / ``n_coalesced`` describe the launch it rode in;
    ``latency_ms`` is submit-to-completion, queueing and linger included.
    """

    ids: np.ndarray         # [m] int32 cluster ids
    dists: np.ndarray       # [m] f32 squared distances
    model_id: str
    version: int
    step: int | None
    latency_ms: float
    batch_rows: int         # padded bucket rows of the launch
    n_coalesced: int        # requests coalesced into the launch


class _Request:
    __slots__ = ("points", "future", "t_submit")

    def __init__(self, points: np.ndarray):
        self.points = points
        self.future: Future = Future()
        self.t_submit = time.monotonic()


class BatcherStats:
    """Mutable per-model serving counters (snapshot via ``to_dict``)."""

    def __init__(self, maxlen: int = 20000):
        self.lock = threading.Lock()
        self.latencies_ms = collections.deque(maxlen=maxlen)
        self.n_requests = 0
        self.n_rejected = 0
        self.n_batches = 0
        self.n_points = 0
        self.n_padded_rows = 0

    def record_batch(self, reqs: list, bucket: int) -> None:
        with self.lock:
            self.n_batches += 1
            rows = sum(r.points.shape[0] for r in reqs)
            self.n_points += rows
            self.n_padded_rows += bucket - rows

    def record_latency(self, ms: float) -> None:
        with self.lock:
            self.latencies_ms.append(ms)

    def to_dict(self) -> dict:
        with self.lock:
            lat = np.asarray(self.latencies_ms, dtype=np.float64)
            out = {
                "n_requests": self.n_requests,
                "n_rejected": self.n_rejected,
                "n_batches": self.n_batches,
                "n_points": self.n_points,
                "n_padded_rows": self.n_padded_rows,
                "requests_per_batch": (
                    self.n_requests / self.n_batches if self.n_batches else 0.0),
            }
        if lat.size:
            out["p50_ms"] = float(np.percentile(lat, 50))
            out["p99_ms"] = float(np.percentile(lat, 99))
            out["mean_ms"] = float(lat.mean())
        return out


class Batcher:
    """One model's bounded queue + coalescing worker thread."""

    def __init__(self, entry: ModelEntry, config: ServeConfig):
        self._entry = entry
        self._cfg = config
        self._buckets = config.buckets()
        self._queue: collections.deque[_Request] = collections.deque()
        self._cond = threading.Condition()
        self._closed = False
        self.stats = BatcherStats()
        self._worker = threading.Thread(
            target=self._run, name=f"serve-{entry.model_id}", daemon=True)
        self._worker.start()

    # -- client side --------------------------------------------------------
    def submit(self, points) -> Future:
        """Enqueue one request; returns a Future[AssignResponse].

        Raises :class:`QueueFull` when ``queue_depth`` requests are already
        pending and :class:`ServerClosed` after shutdown — both immediately,
        never by blocking the caller.
        """
        pts = np.asarray(points, dtype=np.float32)
        if pts.ndim == 1:
            pts = pts[None, :]
        n = self._entry.snapshot().n_features
        if pts.ndim != 2 or pts.shape[1] != n:
            raise ValueError(
                f"request points must be [m, {n}], got {pts.shape}")
        if pts.shape[0] == 0:
            raise ValueError("empty request")
        if pts.shape[0] > self._cfg.max_batch:
            raise ValueError(
                f"request of {pts.shape[0]} points exceeds "
                f"max_batch={self._cfg.max_batch}; split it client-side")
        req = _Request(pts)
        with self._cond:
            if self._closed:
                raise ServerClosed(
                    f"model {self._entry.model_id!r} is not serving")
            if len(self._queue) >= self._cfg.queue_depth:
                with self.stats.lock:
                    self.stats.n_rejected += 1
                raise QueueFull(
                    f"model {self._entry.model_id!r}: {len(self._queue)} "
                    f"requests pending (queue_depth="
                    f"{self._cfg.queue_depth}); retry with backoff")
            self._queue.append(req)
            with self.stats.lock:
                self.stats.n_requests += 1
            self._cond.notify()
        return req.future

    # -- worker side --------------------------------------------------------
    def _take_batch(self) -> list[_Request] | None:
        """Block for the first request, then linger to coalesce more."""
        with self._cond:
            while not self._queue and not self._closed:
                self._cond.wait()
            if not self._queue:
                return None                      # closed and drained
            batch = [self._queue.popleft()]
        total = batch[0].points.shape[0]
        deadline = batch[0].t_submit + self._cfg.max_linger_ms / 1e3
        while total < self._cfg.max_batch:
            with self._cond:
                if self._queue:
                    m = self._queue[0].points.shape[0]
                    if total + m > self._cfg.max_batch:
                        break                    # next request rides later
                    batch.append(self._queue.popleft())
                    total += m
                    continue
                remaining = deadline - time.monotonic()
                if remaining <= 0 or self._closed:
                    break
                self._cond.wait(remaining)
        return batch

    def _bucket_for(self, rows: int) -> int:
        b = max(_next_pow2(rows), self._buckets[0])
        return min(b, self._buckets[-1])

    def _launch(self, batch: list[_Request]) -> None:
        rows = sum(r.points.shape[0] for r in batch)
        bucket = self._bucket_for(rows)
        snap = self._entry.snapshot()            # ONE snapshot per launch
        buf = np.zeros((bucket, snap.n_features), dtype=np.float32)
        off = 0
        for r in batch:
            m = r.points.shape[0]
            buf[off:off + m] = r.points
            off += m
        ids, dists = self._entry.launch(jax.numpy.asarray(buf), snap)
        t_done = time.monotonic()
        self.stats.record_batch(batch, bucket)
        off = 0
        for r in batch:
            m = r.points.shape[0]
            latency_ms = (t_done - r.t_submit) * 1e3
            self.stats.record_latency(latency_ms)
            r.future.set_result(AssignResponse(
                ids=ids[off:off + m].copy(),
                dists=dists[off:off + m].copy(),
                model_id=self._entry.model_id,
                version=snap.version,
                step=snap.step,
                latency_ms=latency_ms,
                batch_rows=bucket,
                n_coalesced=len(batch)))
            off += m

    def _run(self) -> None:
        while True:
            batch = self._take_batch()
            if batch is None:
                return
            try:
                self._launch(batch)
            except Exception as exc:            # pragma: no cover - safety
                for r in batch:
                    if not r.future.done():
                        r.future.set_exception(exc)

    def close(self, drain: bool = True) -> None:
        """Stop accepting requests; finish (or fail) what is queued."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            pending = [] if drain else list(self._queue)
            if not drain:
                self._queue.clear()
            self._cond.notify_all()
        for r in pending:
            r.future.set_exception(
                ServerClosed(f"model {self._entry.model_id!r} shut down"))
        self._worker.join(timeout=10.0)
