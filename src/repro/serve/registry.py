"""Multi-model tenancy: several centroid sets resident and servable at once.

A :class:`ModelRegistry` maps model ids to :class:`ModelEntry` objects.
Each entry owns

* an immutable :class:`CentroidSnapshot` behind an atomic pointer — the
  unit of hot-swap.  A batch launch reads the pointer exactly once, so a
  swap lands between launches and old/new centroids are never mixed within
  one response;
* its own kernel policy (``impl`` resolved once at registration,
  ``precision`` routed through ``kernels/ops.assign`` — the autotuned,
  demotion-aware dispatch, not a hardcoded reference path);
* one jitted assign callable whose Python body doubles as a *recompile
  counter*: the body only executes when jax traces a new shape, so after
  bucket warmup the counter must stay flat (asserted by tests and the
  latency benchmark).

Swaps append a ``("swap", model_id, step)`` event to the registry trace,
the serving twin of the engine's trace-event vocabulary.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

from repro.kernels import ops
from repro.kernels import precision as px


@dataclass(frozen=True)
class CentroidSnapshot:
    """One immutable, device-resident centroid set.

    ``version`` increments on every swap; ``step`` is the checkpoint step
    the snapshot came from (None for directly registered arrays).  Every
    :class:`repro.serve.AssignResponse` records the (version, step) that
    served it, so clients and tests can attribute results to exactly one
    centroid generation.  ``t_swapped`` (monotonic seconds) is when this
    generation went live — ``Server.health()`` reports its age.
    """

    centroids: Any          # [k, n] jax array
    version: int
    step: int | None
    t_swapped: float = field(default_factory=time.monotonic, compare=False)

    @property
    def k(self) -> int:
        return self.centroids.shape[0]

    @property
    def n_features(self) -> int:
        return self.centroids.shape[1]


def _as_centroids(obj) -> jax.Array:
    """Accept a raw [k, n] array or anything with a ``.centroids`` field
    (e.g. a :class:`repro.api.FitResult`)."""
    arr = getattr(obj, "centroids", obj)
    arr = jax.numpy.asarray(arr, dtype=jax.numpy.float32)
    if arr.ndim != 2:
        raise ValueError(
            f"centroids must be [k, n], got shape {arr.shape}")
    if not bool(jax.numpy.isfinite(arr).all()):
        raise ValueError("centroids contain non-finite values")
    return arr


class ModelEntry:
    """One resident model: a swappable snapshot + its compiled assign."""

    def __init__(self, model_id: str, centroids, *, impl: str = "auto",
                 precision: str = "auto", donate: bool = False):
        arr = _as_centroids(centroids)
        self.model_id = model_id
        self.impl = ops.resolve_impl(impl)
        self.precision = px.resolve(precision, arr.dtype)
        self._lock = threading.Lock()
        self._snapshot = CentroidSnapshot(arr, version=0, step=None)
        self._recompiles = 0
        self._donate = donate
        self._assign = self._build_assign()
        self._fallback_assign = None             # built lazily / at warmup
        self._demoted_buckets: set[int] = set()

    # -- kernel dispatch ----------------------------------------------------
    def _build_assign(self):
        def _assign(q, c):
            # Executes only while jax traces a new (bucket, k, n) shape —
            # a free, exact recompile counter for the serving hot path.
            self._recompiles += 1
            return ops.assign(q, c, impl=self.impl, precision=self.precision)

        donate = (0,) if self._donate else ()
        return jax.jit(_assign, donate_argnums=donate)

    def _fallback(self):
        # Ref-path launch for transient-fault retries and demoted buckets.
        # Its own jit (never donated: a retry must be able to rebuild the
        # buffer), its own trace counter — warming it never perturbs the
        # primary zero-recompile contract.
        with self._lock:
            if self._fallback_assign is None:
                self._fallback_assign = jax.jit(
                    lambda q, c: ops.assign(
                        q, c, impl="ref", precision=self.precision))
            return self._fallback_assign

    def launch(self, q: jax.Array,
               snapshot: CentroidSnapshot) -> tuple[np.ndarray, np.ndarray]:
        """Run one coalesced assignment launch against ``snapshot``.

        The batcher calls this with the padded request buffer; it is a
        method (not an inlined jit call) so tests can wrap it to simulate
        slow kernels without touching the queueing logic.  A bucket the
        batcher demoted (repeated primary failures) routes straight to the
        ref fallback.
        """
        if int(q.shape[0]) in self._demoted_buckets:
            return self.launch_fallback(q, snapshot)
        ids, d = self._assign(q, snapshot.centroids)
        return np.asarray(ids), np.asarray(d)

    def launch_fallback(self, q: jax.Array,
                        snapshot: CentroidSnapshot
                        ) -> tuple[np.ndarray, np.ndarray]:
        """The ref-path launch: where transient launch faults retry."""
        ids, d = self._fallback()(q, snapshot.centroids)
        return np.asarray(ids), np.asarray(d)

    def demote_bucket(self, bucket: int, exc: Exception) -> None:
        """Pin ``bucket`` to the ref path for this entry's lifetime, and
        record the failure in the process-wide kernel demotion table (so
        eager dispatches at this shape skip the Pallas path too)."""
        self._demoted_buckets.add(int(bucket))
        if self.impl in ("pallas", "pallas_interpret"):
            snap = self.snapshot()
            ops.record_demotion(
                "assign", self.impl, (1, int(bucket), snap.k, snap.n_features),
                self.precision, exc)

    def is_demoted(self, bucket: int) -> bool:
        return int(bucket) in self._demoted_buckets

    @property
    def demoted_buckets(self) -> tuple[int, ...]:
        return tuple(sorted(self._demoted_buckets))

    def warmup(self, buckets: tuple[int, ...]) -> None:
        """Pre-pay every per-bucket cost off the request path.

        For each padded shape bucket this (1) runs the *eager*
        demotion-aware dispatch via :func:`repro.kernels.ops.warm_assign`,
        so the autotune cache is consulted/populated and a failing Pallas
        build demotes this exact serving shape to the ref path now — the
        same way ``fit()`` pre-tunes ``fused_step`` — and (2) compiles the
        jitted serving call, so traffic never waits on a trace.
        """
        snap = self.snapshot()
        n = snap.n_features
        for b in buckets:
            ops.warm_assign(b, snap.k, n, impl=self.impl,
                            precision=self.precision)
            q = jax.numpy.zeros((b, n), jax.numpy.float32)
            jax.block_until_ready(self._assign(q, snap.centroids))
            # Compile the ref fallback too: a transient launch fault must
            # retry immediately, not pay a trace on the request path.
            jax.block_until_ready(self._fallback()(q, snap.centroids))

    # -- snapshot management ------------------------------------------------
    def snapshot(self) -> CentroidSnapshot:
        """The current centroid generation (atomic read)."""
        with self._lock:
            return self._snapshot

    def swap(self, centroids, *, step: int | None = None) -> CentroidSnapshot:
        """Atomically replace the serving centroids.

        The new set must match the resident (k, n) — same shape means the
        compiled per-bucket executables are reused as-is, so a swap costs
        one pointer write and zero recompiles, and in-flight requests are
        neither dropped nor re-queued: launches already in progress finish
        on the old snapshot, the next launch reads the new one.
        """
        arr = _as_centroids(centroids)
        with self._lock:
            old = self._snapshot
            if arr.shape != old.centroids.shape:
                raise ValueError(
                    f"swap shape mismatch for {self.model_id!r}: resident "
                    f"{tuple(old.centroids.shape)}, new {tuple(arr.shape)}")
            new = CentroidSnapshot(arr, version=old.version + 1, step=step)
            self._snapshot = new
        return new

    @property
    def recompiles(self) -> int:
        """How many times the serving assign has been traced (one per
        warmed bucket; must not grow under steady traffic)."""
        return self._recompiles


class ModelRegistry:
    """Thread-safe id -> :class:`ModelEntry` map with a swap trace."""

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: dict[str, ModelEntry] = {}
        self.trace: list = []

    def register(self, model_id: str, centroids, *, impl: str = "auto",
                 precision: str = "auto", donate: bool = False) -> ModelEntry:
        entry = ModelEntry(model_id, centroids, impl=impl,
                           precision=precision, donate=donate)
        with self._lock:
            if model_id in self._entries:
                raise ValueError(
                    f"model {model_id!r} already registered; use swap() to "
                    "replace its centroids")
            self._entries[model_id] = entry
        return entry

    def get(self, model_id: str) -> ModelEntry:
        with self._lock:
            try:
                return self._entries[model_id]
            except KeyError:
                raise KeyError(
                    f"unknown model {model_id!r}; registered: "
                    f"{sorted(self._entries)}") from None

    def unregister(self, model_id: str) -> None:
        with self._lock:
            self._entries.pop(model_id, None)

    def list_models(self) -> list[str]:
        with self._lock:
            return sorted(self._entries)

    def record(self, event: tuple) -> None:
        """Append a structured serving event to the trace (thread-safe).
        The batcher and circuit breaker route their ``launch_fault`` /
        ``deadline_shed`` / ``breaker_*`` / ``worker_restart`` events here."""
        with self._lock:
            self.trace.append(event)

    def swap(self, model_id: str, centroids, *,
             step: int | None = None) -> CentroidSnapshot:
        """Hot-swap ``model_id``'s centroids; logs ``("swap", id, step)``."""
        snap = self.get(model_id).swap(centroids, step=step)
        self.record(("swap", model_id, step))
        return snap
