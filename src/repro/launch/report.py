"""Render results/dryrun.jsonl into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report results/dryrun.jsonl
"""
from __future__ import annotations

import json
import sys
from collections import OrderedDict


def load(path):
    recs = OrderedDict()
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r   # last write wins
    return list(recs.values())


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | status | compile s | args/dev | temps/dev | collectives (count) |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP — "
                        f"{r['reason'][:60]}… | | | | |")
            continue
        if r["status"] == "error":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR | | | | |")
            continue
        mem = r.get("memory_analysis", {})
        nd = r["devices"]
        coll = r.get("collective_raw", r.get("collective", {}))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['compile_s']:.1f} | "
            f"{fmt_bytes(mem.get('argument_bytes', 0) / nd)} | "
            f"{fmt_bytes(mem.get('temp_bytes', 0))} | "
            f"{coll.get('count', 0)} |")
    return "\n".join(rows)


def roofline_table(recs):
    rows = ["| arch | shape | compute s | memory s | collective s | dominant | "
            "roofline frac | 6ND/HLO | what would move the dominant term |",
            "|---|---|---|---|---|---|---|---|---|"]
    hints = {
        ("compute",): "higher arithmetic intensity (larger per-chip tiles), "
                      "drop remat recompute on cheap ops",
        ("memory",): "blockwise attention (no S^2 logits in HBM), bf16/int8 "
                     "weight streaming, fused softmax",
        ("collective",): "reduce-scatter instead of all-reduce, bf16 grads, "
                         "overlap collectives with per-layer compute",
    }
    for r in recs:
        if r["mesh"] != "16x16":
            continue
        if r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — "
                        f"| — | {r['reason'][:70]} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | "
                        f"— | — | |")
            continue
        rl = r["roofline"]
        ratio = r.get("useful_flops_ratio", float("nan"))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4f} | "
            f"{rl['memory_s']:.4f} | {rl['collective_s']:.4f} | "
            f"{rl['dominant']} | {rl['roofline_fraction']:.3f} | "
            f"{ratio:.3f} | {hints[(rl['dominant'],)]} |")
    return "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl"
    recs = load(path)
    print("## Dry-run matrix\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16, per device)\n")
    print(roofline_table(recs))
    ok = sum(r["status"] == "ok" for r in recs)
    skip = sum(r["status"] == "skip" for r in recs)
    err = sum(r["status"] == "error" for r in recs)
    print(f"\ncells: {ok} ok / {skip} skip / {err} error")


if __name__ == "__main__":
    main()
