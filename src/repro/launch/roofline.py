"""Three-term roofline from a compiled dry-run artifact.

TPU v5e constants (per chip):
    peak bf16 compute  197 TFLOP/s
    HBM bandwidth      819 GB/s
    ICI                ~50 GB/s per link

Terms (per device, from the post-SPMD per-device module):
    compute    = HLO_FLOPs_device / peak
    memory     = HLO_bytes_device / hbm_bw
    collective = collective_operand_bytes_device / ici_bw

Ring/tree constant factors are deliberately folded out — terms are compared
*across cells and iterations*, not against wall clocks (CPU-only container).
"""
from __future__ import annotations

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def roofline_terms(flops_dev: float, bytes_dev: float, coll_bytes_dev: float):
    compute = flops_dev / PEAK_FLOPS
    memory = bytes_dev / HBM_BW
    collective = coll_bytes_dev / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # "roofline fraction": useful compute time / achievable step time if the
    # dominant term fully overlaps the others (ideal async schedule).
    frac = compute / bound if bound > 0 else 0.0
    return {**terms, "dominant": dominant.replace("_s", ""),
            "bound_s": bound, "roofline_fraction": frac}


# ---------------------------------------------------------------------------
# Fused-chunk traffic model: FLOPs / streamed bytes per Big-means chunk
# ---------------------------------------------------------------------------

# Storage bytes per chunk element (int8 adds one f32 scale row per chunk,
# accounted separately in chunk_bytes).
_ITEMSIZE = {"f32": 4, "bf16": 2, "bf16x3": 4, "int8": 1}


def chunk_bytes(s: int, n: int, precision: str) -> int:
    """Bytes to stream one ``[s, n]`` chunk once under ``precision``.

    int8 ships the quantized payload (int8 codes + one f32 per-feature
    scale row — what the prefetcher actually transfers); the float
    policies ship the raw array.
    """
    b = s * n * _ITEMSIZE[precision]
    if precision == "int8":
        b += 4 * n
    return b


def chunk_traffic(s: int, n: int, k: int, precision: str,
                  passes: float) -> dict:
    """FLOPs and streamed bytes for one chunk's fused Lloyd loop.

    ``passes`` = lloyd_iters + 2 (the fused loop re-reads the chunk every
    iteration; the acceptance epilogue adds an assign + update pass).
    Per pass: the distance contraction (2*s*k*n), the norm/argmin
    assembly (~3*s*k) and the one-hot update contraction (2*s*k*n) —
    ~4*s*k*n FLOPs; bytes are the chunk stream plus the (small) centroid
    read and sums/counts write-back, all f32 regardless of policy.
    """
    flops_pass = 4.0 * s * k * n + 3.0 * s * k
    bytes_pass = chunk_bytes(s, n, precision) + 2 * (4 * k * n) + 4 * k
    return {
        "flops": flops_pass * passes,
        "bytes": bytes_pass * passes,
        "bytes_per_chunk": chunk_bytes(s, n, precision),
    }


def precision_roofline(row: dict) -> dict:
    """Roofline terms + achieved-vs-peak bandwidth for one
    BENCH_precision.json row (see benchmarks/batched_throughput.py)."""
    s, n, k = row["s"], row["n"], row["k"]
    passes = row.get("lloyd_iters_per_chunk", 0.0) + 2
    traffic = chunk_traffic(s, n, k, row["precision"], passes)
    terms = roofline_terms(traffic["flops"], traffic["bytes"], 0.0)
    # Achieved streamed bytes/s on the *measuring* host (from chunks/s) vs
    # the accelerator peak the roofline is drawn against.  On the CPU
    # container the fraction is tiny — the committed signal is the
    # per-precision bytes term shrinking while chunks/s holds.
    achieved = row["chunks_per_s"] * traffic["bytes"]
    return {
        "precision": row["precision"],
        "batch": row["batch"],
        "k": k, "n": n, "s": s,
        "passes": round(passes, 2),
        "model_flops_per_chunk": traffic["flops"],
        "model_bytes_per_chunk": traffic["bytes"],
        "bytes_per_chunk_stream": traffic["bytes_per_chunk"],
        "chunks_per_s": row["chunks_per_s"],
        "achieved_bytes_per_s": round(achieved, 1),
        "peak_bytes_per_s": HBM_BW,
        "achieved_frac_of_peak": round(achieved / HBM_BW, 8),
        "arithmetic_intensity": round(
            traffic["flops"] / traffic["bytes"], 3),
        **terms,
    }


def main(argv=None) -> None:
    """Project BENCH_precision.json onto the v5e roofline.

    Reads the committed precision matrix and writes BENCH_roofline.json
    (repro.bench/1 envelope): per (precision, batch) row the modeled
    FLOPs/bytes of the fused chunk loop, its roofline terms, and the
    achieved vs peak streamed bandwidth.  The cross-precision story —
    int8 moving ~0.25x of the f32 bytes at the same chunk rate — is the
    committed, hardware-independent record of the kernel-depth work.
    """
    import argparse
    import json
    import os

    from repro.evalsuite import schema as bench_schema

    repo = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=os.path.join(
        repo, "BENCH_precision.json"))
    ap.add_argument("--out", default=os.path.join(
        repo, "BENCH_roofline.json"))
    args = ap.parse_args(argv)

    with open(args.bench) as f:
        bench = json.load(f)
    rows = [precision_roofline(r) for r in bench["rows"]]
    f32 = {r["batch"]: r for r in rows if r["precision"] == "f32"}
    for r in rows:
        twin = f32.get(r["batch"])
        if twin:
            r["bytes_ratio_vs_f32"] = round(
                r["model_bytes_per_chunk"] / twin["model_bytes_per_chunk"],
                4)
    out = bench_schema.write_bench(
        args.out,
        bench_schema.envelope(
            "precision_roofline", rows,
            source=os.path.basename(args.bench),
            peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW, ici_bw=ICI_BW,
            traffic_model="per pass: 4*s*k*n + 3*s*k FLOPs; "
                          "chunk_bytes(precision) + 2*4*k*n + 4*k bytes; "
                          "passes = lloyd_iters_per_chunk + 2",
        ))
    for r in rows:
        print(f"prec={r['precision']:6s} batch={r['batch']:<3d} "
              f"AI={r['arithmetic_intensity']:6.2f} flop/byte  "
              f"dominant={r['dominant']:7s} "
              f"bytes/chunk={r['model_bytes_per_chunk']:.3e}  "
              f"achieved/peak={r['achieved_frac_of_peak']:.2e}")
    print(f"# wrote {out}")


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


if __name__ == "__main__":
    main()
