"""Three-term roofline from a compiled dry-run artifact.

TPU v5e constants (per chip):
    peak bf16 compute  197 TFLOP/s
    HBM bandwidth      819 GB/s
    ICI                ~50 GB/s per link

Terms (per device, from the post-SPMD per-device module):
    compute    = HLO_FLOPs_device / peak
    memory     = HLO_bytes_device / hbm_bw
    collective = collective_operand_bytes_device / ici_bw

Ring/tree constant factors are deliberately folded out — terms are compared
*across cells and iterations*, not against wall clocks (CPU-only container).
"""
from __future__ import annotations

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def roofline_terms(flops_dev: float, bytes_dev: float, coll_bytes_dev: float):
    compute = flops_dev / PEAK_FLOPS
    memory = bytes_dev / HBM_BW
    collective = coll_bytes_dev / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # "roofline fraction": useful compute time / achievable step time if the
    # dominant term fully overlaps the others (ideal async schedule).
    frac = compute / bound if bound > 0 else 0.0
    return {**terms, "dominant": dominant.replace("_s", ""),
            "bound_s": bound, "roofline_fraction": frac}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
