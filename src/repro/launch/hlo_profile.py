"""Per-instruction byte/flop attribution from optimized HLO text — the
"profiler" of the dry-run world (DESIGN.md §6b).  Groups operand+result
bytes by opcode and reports the top single instructions, so §Perf iterations
aim at the真 dominant traffic instead of folklore."""
from __future__ import annotations

import re
from collections import defaultdict

from repro.launch.hlo_analysis import _DEF_RE, _SHAPE_RE, _shape_bytes


def profile(hlo_text: str, top: int = 25):
    defs: dict[str, int] = {}
    rows = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        op_m = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
        result_part = rhs[: op_m.start()] if op_m else rhs
        out_bytes = sum(_shape_bytes(d, dims)
                        for d, dims in _SHAPE_RE.findall(result_part))
        defs[name] = out_bytes
        if not op_m:
            continue
        op = op_m.group(1)
        args_part = rhs[op_m.end():]
        depth, end = 1, len(args_part)
        for i, ch in enumerate(args_part):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_names = re.findall(r"%([\w.\-]+)", args_part[:end])
        in_bytes = sum(defs.get(o, 0) for o in operand_names)
        rows.append((op, name, in_bytes + out_bytes,
                     line.split("metadata", 1)[-1][:120]))
    by_op = defaultdict(lambda: [0, 0])
    for op, name, b, _ in rows:
        by_op[op][0] += b
        by_op[op][1] += 1
    summary = sorted(by_op.items(), key=lambda kv: -kv[1][0])
    top_rows = sorted(rows, key=lambda r: -r[2])[:top]
    return summary, top_rows


if __name__ == "__main__":
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import argparse
    import dataclasses

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--set", default="")
    args = ap.parse_args()

    from repro.launch.perf import apply_flags
    settings = dict(kv.split("=") for kv in filter(None, args.set.split(",")))
    apply_flags(settings)

    from repro.configs.shapes import SHAPES
    from repro.launch.dryrun import _compile_and_cost
    from repro.launch.mesh import make_production_mesh
    from repro.models import flags
    from repro.models.registry import get_config

    cfg = get_config(args.arch)
    cfg = dataclasses.replace(
        cfg, num_layers=args.layers,
        encoder_layers=args.layers if cfg.encoder_layers else 0)
    flags.UNROLL_SCAN = True
    mesh = make_production_mesh()
    compiled, cost = _compile_and_cost(cfg, SHAPES[args.shape], mesh)
    summary, top_rows = profile(compiled.as_text())
    total = sum(v[0] for _, v in summary)
    print(f"total attributed bytes/device: {total:.3e} "
          f"(cost_analysis: {cost['bytes']:.3e})")
    print("\n-- by opcode --")
    for op, (b, c) in summary[:18]:
        print(f"{op:24s} {b:.3e}  x{c}")
    print("\n-- top instructions --")
    for op, name, b, meta in top_rows:
        print(f"{b: .3e}  {op:18s} {name:28s} {meta[:90]}")
