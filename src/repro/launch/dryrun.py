import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init.  512 placeholder host devices back the production
# meshes (16x16 single-pod, 2x16x16 multi-pod) for lower()+compile() only —
# nothing is executed.

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.shapes import SHAPES  # noqa: E402
from repro.launch import hlo_analysis, roofline, specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.registry import LM_ARCHS, get_config  # noqa: E402
from repro.train import sharding as sh  # noqa: E402
from repro.train.optimizer import adamw, warmup_cosine  # noqa: E402
from repro.train.train_step import (  # noqa: E402
    make_prefill_step, make_serve_step, make_train_step)


def opt_state_shardings(mesh, p_sh):
    from repro.train.optimizer import AdamWState
    return AdamWState(
        step=NamedSharding(mesh, P()),
        mu=jax.tree.map(lambda s: s, p_sh),
        nu=jax.tree.map(lambda s: s, p_sh),
    )


def build_lowerable(cfg, shape, mesh):
    """Return (fn, args, in_shardings, out_shardings, donate_argnums)."""
    sp = specs.input_specs(cfg, shape)
    in_sh = specs.input_shardings(mesh, cfg, shape, sp)

    if shape.kind == "train":
        params = T.abstract_params(cfg, jnp.float32)
        p_sh = sh.param_shardings(mesh, params)
        opt = adamw(warmup_cosine(3e-4, 2000, 100_000))
        opt_state = jax.eval_shape(opt.init, params)
        o_sh = opt_state_shardings(mesh, p_sh)
        fn = make_train_step(cfg, opt)
        rep = NamedSharding(mesh, P())
        return (fn, (params, opt_state, sp), (p_sh, o_sh, in_sh),
                (p_sh, o_sh, {"loss": rep}), (0, 1))

    params = T.abstract_params(cfg, jnp.bfloat16)   # serving: bf16 weights
    p_sh = sh.param_shardings(mesh, params)
    rep = NamedSharding(mesh, P())

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, max_seq=shape.seq_len)
        cache_spec = T.abstract_cache(
            cfg, shape.global_batch, shape.seq_len,
            enc_len=cfg.frontend_len if cfg.cross_attention else None)
        cache_sh = specs.cache_shardings(mesh, cache_spec)
        logits_sh = NamedSharding(
            mesh, sh.spec(mesh, "batch", "model",
                          shape=(shape.global_batch, cfg.vocab_size)))
        args = [params, sp["tokens"]]
        arg_sh = [p_sh, in_sh["tokens"]]
        if cfg.frontend:
            args.append(sp["frontend"])
            arg_sh.append(in_sh["frontend"])
        return (fn, tuple(args), tuple(arg_sh), (logits_sh, cache_sh), ())

    # decode
    fn = make_serve_step(cfg)
    cache_sh = in_sh["cache"]
    tok_sh = NamedSharding(
        mesh, sh.spec(mesh, "batch", None, shape=(shape.global_batch, 1)))
    logits_sh = NamedSharding(
        mesh, sh.spec(mesh, "batch", "model",
                      shape=(shape.global_batch, cfg.vocab_size)))
    next_sh = NamedSharding(
        mesh, sh.spec(mesh, "batch", shape=(shape.global_batch,)))
    return (
        fn,
        (params, sp["cache"], sp["token"], sp["pos"]),
        (p_sh, cache_sh, tok_sh, NamedSharding(mesh, P())),
        (next_sh, logits_sh, cache_sh),
        (1,),
    )


def build_bigmeans(cfg, mesh):
    """The paper's own workload on the production mesh (2-level decomposition)."""
    from repro.core.bigmeans import big_means_sharded

    from repro.models import flags as _flags
    axes = tuple(mesh.axis_names)
    n_workers = mesh.devices.size
    m = -(-cfg.m // n_workers) * n_workers           # pad rows to worker grid
    xdtype = jnp.bfloat16 if _flags.CLUSTER_BF16 else jnp.float32
    X = jax.ShapeDtypeStruct((m, cfg.n_features), xdtype)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def fn(X, key):
        return big_means_sharded(
            X, key, mesh=mesh, k=cfg.k, s=cfg.s,
            chunks_per_worker=cfg.chunks_per_worker,
            sync_every=cfg.sync_every, axes=axes,
            max_iters=8,          # bounded per-chunk budget (stragglers)
            impl="ref")

    x_sh = NamedSharding(mesh, P(axes))
    k_sh = NamedSharding(mesh, P())
    return fn, (X, key), (x_sh, k_sh), None, ()


def _compile_and_cost(cfg, shape, mesh):
    """Lower+compile one cell variant; return (compiled, cost dict)."""
    with sh.use_mesh(mesh):
        if getattr(cfg, "family", None) == "cluster":
            fn, args, in_sh, out_sh, donate = build_bigmeans(cfg, mesh)
        else:
            fn, args, in_sh, out_sh, donate = build_lowerable(cfg, shape, mesh)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    return compiled, {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll["total"]),
        "coll_detail": coll,
    }


def _unrolled_costs(cfg, shape, mesh):
    """XLA cost analysis visits a while/scan body ONCE regardless of trip
    count, so the scanned stack under-reports per-layer costs by ~L.

    Fix: recompile with every structural scan fully unrolled
    (flags.UNROLL_SCAN) so cost analysis counts each layer.  Deep stacks
    (L > 12) would compile for tens of minutes, so there we compile two
    *unrolled reduced depths* (L=2, L=4 — both fully counted) and
    extrapolate linearly; per-layer cost is depth-independent in this zoo
    (layer patterns change masks, not op shapes) and the embed/head/loss
    base is captured by the intercept.  The scanned compile remains the
    deliverable artifact (memory analysis)."""
    from repro.models import flags
    flags.UNROLL_SCAN = True
    try:
        L = cfg.num_layers
        if L <= 12:
            _, c = _compile_and_cost(cfg, shape, mesh)
            return c
        l1, l2 = 2, 4

        def variant(n):
            return dataclasses.replace(
                cfg, num_layers=n,
                encoder_layers=n if cfg.encoder_layers else 0)

        _, c1 = _compile_and_cost(variant(l1), shape, mesh)
        _, c2 = _compile_and_cost(variant(l2), shape, mesh)
        out = {}
        for k in ("flops", "bytes", "coll"):
            per = (c2[k] - c1[k]) / (l2 - l1)
            out[k] = c1[k] + (L - l1) * per
        by_op = {}
        ops_seen = set(c1["coll_detail"]["by_op"]) | set(c2["coll_detail"]["by_op"])
        for op in ops_seen:
            a = c1["coll_detail"]["by_op"].get(op, 0)
            b = c2["coll_detail"]["by_op"].get(op, 0)
            by_op[op] = int(a + (L - l1) * (b - a) / (l2 - l1))
        out["coll_detail"] = {
            "total": int(out["coll"]),
            "count": c2["coll_detail"]["count"],
            "by_op": by_op,
            "extrapolated_from_depths": [l1, l2],
        }
        return out
    finally:
        flags.UNROLL_SCAN = False


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             skip_correction: bool = False) -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": int(n_dev), "status": "ok",
    }

    if cfg.family == "cluster":
        shape = None
    else:
        shape = SHAPES[shape_name]
        if shape.name == "long_500k" and not cfg.sub_quadratic:
            record["status"] = "skip"
            record["reason"] = ("pure full-attention arch: 500k decode needs "
                                "a quadratic-cost prefill to build its state")
            return record

    t0 = time.time()
    compiled, raw = _compile_and_cost(cfg, shape, mesh)
    t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        record["memory_analysis"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:                            # pragma: no cover
        record["memory_analysis"] = {"error": str(e)}

    record.update({
        "compile_s": round(t_compile, 2),
        "raw_flops_per_device": raw["flops"],
        "raw_bytes_per_device": raw["bytes"],
        "collective_raw": raw["coll_detail"],
    })

    if cfg.family == "cluster" or skip_correction:
        flops_dev, bytes_dev, coll_dev = raw["flops"], raw["bytes"], raw["coll"]
    else:
        t0 = time.time()
        corr = _unrolled_costs(cfg, shape, mesh)
        record["unrolled_compile_s"] = round(time.time() - t0, 2)
        record["collective"] = corr["coll_detail"]
        flops_dev, bytes_dev, coll_dev = corr["flops"], corr["bytes"], corr["coll"]

    rl = roofline.roofline_terms(flops_dev, bytes_dev, coll_dev)
    record.update({
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "roofline": rl,
    })
    if cfg.family != "cluster":
        mf = roofline.model_flops(cfg, shape)
        record["model_flops_global"] = mf
        total_hlo = flops_dev * n_dev
        record["useful_flops_ratio"] = mf / total_hlo if total_hlo else 0.0
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="arch id (default: all LM archs + bigmeans_paper)")
    ap.add_argument("--shape", default=None,
                    help="shape id (default: all four)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--json", default=None, help="append records to this file")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else LM_ARCHS + ["bigmeans_paper"]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    records = []
    for arch in archs:
        cfg = get_config(arch)
        if cfg.family == "cluster":
            shapes = ["cluster"]
        else:
            shapes = [args.shape] if args.shape else list(SHAPES)
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
                try:
                    # roofline table is single-pod only: multi-pod cells skip
                    # the (expensive) unrolled cost recompile.
                    rec = run_cell(arch, shape_name, mp, skip_correction=mp)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-2000:]}
                records.append(rec)
                status = rec["status"]
                extra = ""
                if status == "ok" and "roofline" in rec:
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" frac={r['roofline_fraction']:.3f}"
                             f" compile={rec['compile_s']:.1f}s")
                print(f"[dryrun] {tag}: {status}{extra}", flush=True)
                if args.json:
                    with open(args.json, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    ok = sum(r["status"] == "ok" for r in records)
    skip = sum(r["status"] == "skip" for r in records)
    err = sum(r["status"] == "error" for r in records)
    print(f"[dryrun] done: {ok} ok, {skip} skip, {err} error")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
