"""Collective-traffic extraction from optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` has no collective-bytes entry, so we parse the
per-device HLO module: every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute instruction contributes the byte size of its
operands (per the roofline spec).  Async pairs (-start/-done) are counted
once, at the -start.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _shapes_bytes(text: str) -> int:
    return sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(text))


def collective_bytes(hlo_text: str) -> dict:
    """Return {'total': int, 'count': int, 'by_op': {op: bytes}, ...}."""
    defs: dict[str, int] = {}
    pending = []            # (op, operand_names, inline_bytes, result_bytes)

    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # result type = everything before the opcode token
        op_m = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
        result_part = rhs[: op_m.start()] if op_m else rhs
        defs[name] = _shapes_bytes(result_part)
        if not op_m:
            continue
        op = op_m.group(1)
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if base not in _COLLECTIVES:
            continue
        args_part = rhs[op_m.end():]
        # strip trailing attributes (replica_groups=...) conservatively:
        depth, end = 1, len(args_part)
        for i, ch in enumerate(args_part):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        args = args_part[:end]
        operand_names = re.findall(r"%([\w.\-]+)", args)
        inline = _shapes_bytes(args)
        pending.append((base, operand_names, inline, defs[name]))

    by_op: dict[str, int] = defaultdict(int)
    by_op_count: dict[str, int] = defaultdict(int)
    total = 0
    for base, operands, inline, result in pending:
        looked_up = sum(defs.get(o, 0) for o in operands)
        nbytes = inline or looked_up or result
        by_op[base] += nbytes
        by_op_count[base] += 1
        total += nbytes
    return {
        "total": int(total),
        "count": int(sum(by_op_count.values())),
        "by_op": {k: int(v) for k, v in sorted(by_op.items())},
        "by_op_count": {k: int(v) for k, v in sorted(by_op_count.items())},
    }
