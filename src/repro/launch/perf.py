import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (must precede any jax import — see dryrun.py)

"""Perf-iteration runner (EXPERIMENTS.md §Perf).

Compiles one (arch x shape) cell on the single-pod mesh with a named set of
optimization flags and appends the roofline record to results/perf.jsonl:

    PYTHONPATH=src python -m repro.launch.perf --arch hymba-1.5b \
        --shape train_4k --variant blockwise --set blockwise_attn=1024

Variants compare against the paper-faithful/naive `base` variant; each run
records the flag dictionary so the EXPERIMENTS log can show
hypothesis -> change -> before -> after.
"""

import argparse   # noqa: E402
import json       # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402
from repro.models import flags            # noqa: E402


def apply_flags(settings: dict):
    if "blockwise_attn" in settings:
        flags.BLOCKWISE_ATTN = int(settings["blockwise_attn"])
    if "bf16_grads" in settings:
        flags.BF16_GRADS = bool(int(settings["bf16_grads"]))
    if "chunked_loss" in settings:
        flags.CHUNKED_LOSS = int(settings["chunked_loss"])
    if "serve_moe_cap" in settings:
        flags.SERVE_MOE_CAP = float(settings["serve_moe_cap"])
    if "attn_bf16_softmax" in settings:
        flags.ATTN_BF16_SOFTMAX = bool(int(settings["attn_bf16_softmax"]))
    if "rope_bf16" in settings:
        flags.ROPE_BF16 = bool(int(settings["rope_bf16"]))
    if "seq_parallel" in settings:
        flags.SEQ_PARALLEL = bool(int(settings["seq_parallel"]))
    if "cache_carry" in settings:
        flags.DECODE_CACHE_CARRY = bool(int(settings["cache_carry"]))
    if "remat" in settings:
        flags.REMAT_POLICY = settings["remat"]
    if "cluster_bf16" in settings:
        flags.CLUSTER_BF16 = bool(int(settings["cluster_bf16"]))
    if "kv_seq" in settings:
        flags.KV_SHARD_SEQ = bool(int(settings["kv_seq"]))
    if "ssd_bf16" in settings:
        flags.SSD_BF16 = bool(int(settings["ssd_bf16"]))
    if "moe_groups" in settings:
        flags.MOE_GROUPED_DISPATCH = int(settings["moe_groups"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="base")
    ap.add_argument("--set", default="",
                    help="comma list k=v: blockwise_attn, bf16_grads, "
                         "chunked_loss, serve_moe_cap")
    ap.add_argument("--json", default="results/perf.jsonl")
    args = ap.parse_args()

    settings = {}
    for kv in filter(None, args.set.split(",")):
        k, v = kv.split("=")
        settings[k.strip()] = v.strip()
    apply_flags(settings)

    rec = run_cell(args.arch, args.shape, multi_pod=False)
    rec["variant"] = args.variant
    rec["flags"] = settings
    with open(args.json, "a") as f:
        f.write(json.dumps(rec) + "\n")
    r = rec.get("roofline", {})
    print(f"[perf] {args.arch} x {args.shape} [{args.variant}] "
          f"compute={r.get('compute_s', 0):.4f}s "
          f"memory={r.get('memory_s', 0):.4f}s "
          f"collective={r.get('collective_s', 0):.4f}s "
          f"dominant={r.get('dominant')} frac={r.get('roofline_fraction', 0):.4f}")


if __name__ == "__main__":
    main()
