"""Clustering launcher (the paper's workload is training-like).

    PYTHONPATH=src python -m repro.launch.train --arch bigmeans_paper \
        --chunks 200 --scale 0.02 --ckpt /tmp/bigmeans_run

Runs the host-streaming Big-means driver on a synthetic surrogate of the
configured stream.  Placement is declarative: ``--topology`` names the
spec (``single`` / ``stream_mesh`` / ``host_mesh``), and for ``host_mesh``
the ``--hosts/--coordinator/--rank`` flags (or the ``REPRO_*`` env vars of
``repro.engine.hostmesh.launch_local``) describe the process group — launch
one copy of this command per rank.  For LM training smoke runs see
``examples/`` and the dry-run launcher.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.api import BigMeansConfig, TopologySpec, fit
from repro.data.synthetic import GMMSpec, gmm_chunk
from repro.models.registry import get_config


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bigmeans_paper")
    ap.add_argument("--chunks", type=int, default=200)
    ap.add_argument("--scale", type=float, default=0.02,
                    help="scale factor on the configured stream size")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--time-budget", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--topology", default="auto",
                    choices=["auto", "single", "stream_mesh", "host_mesh"],
                    help="declarative placement (BigMeansConfig.topology)")
    ap.add_argument("--hosts", type=int, default=None,
                    help="host_mesh: process-group size (else REPRO_NUM_HOSTS)")
    ap.add_argument("--coordinator", default=None,
                    help="host_mesh: coordinator host:port (else REPRO_COORD)")
    ap.add_argument("--rank", type=int, default=None,
                    help="host_mesh: this process's rank (else "
                         "REPRO_HOST_RANK)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    assert cfg.family == "cluster", "use dryrun.py / examples for LM archs"
    m = max(int(cfg.m * args.scale), cfg.s * 2)
    spec = GMMSpec(m=m, n=cfg.n_features, components=cfg.k, spread=4.0,
                   seed=args.seed)

    if args.topology == "host_mesh":
        topology = TopologySpec(kind="host_mesh", hosts=args.hosts,
                                coordinator=args.coordinator, rank=args.rank)
    else:
        topology = args.topology
    rcfg = BigMeansConfig.from_workload(
        cfg, n_chunks=args.chunks, time_budget_s=args.time_budget,
        ckpt_dir=args.ckpt, seed=args.seed, topology=topology)

    print(f"[train] {args.arch}: m={m} n={cfg.n_features} k={rcfg.k} "
          f"s={rcfg.s} chunks={args.chunks} batch={rcfg.batch} "
          f"topology={rcfg.topology.kind}")
    result = fit(
        lambda cid: np.asarray(gmm_chunk(spec, cid, rcfg.s)), rcfg,
        method="streaming", n_features=cfg.n_features)
    failed = result.extras.get("chunks_failed", 0)
    print(f"[train] done: f_best={result.objective:.6e} "
          f"accepted={result.n_accepted}/{result.n_chunks} "
          f"failed={failed} wall={result.wall_time_s:.1f}s "
          f"n_d={result.n_dist_evals:.3e}")


if __name__ == "__main__":
    main()
