"""Clustering launcher (the paper's workload is training-like).

    PYTHONPATH=src python -m repro.launch.train --arch bigmeans_paper \
        --chunks 200 --scale 0.02 --ckpt /tmp/bigmeans_run

Runs the host-streaming Big-means driver on a synthetic surrogate of the
configured stream; ``--workers N`` switches to the sharded in-core driver
over N forced host devices (spawn with XLA_FLAGS yourself in that case).
For LM training smoke runs see ``examples/`` and the dry-run launcher.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.api import BigMeansConfig, fit
from repro.data.synthetic import GMMSpec, gmm_chunk
from repro.models.registry import get_config


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bigmeans_paper")
    ap.add_argument("--chunks", type=int, default=200)
    ap.add_argument("--scale", type=float, default=0.02,
                    help="scale factor on the configured stream size")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--time-budget", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    assert cfg.family == "cluster", "use dryrun.py / examples for LM archs"
    m = max(int(cfg.m * args.scale), cfg.s * 2)
    spec = GMMSpec(m=m, n=cfg.n_features, components=cfg.k, spread=4.0,
                   seed=args.seed)

    rcfg = BigMeansConfig.from_workload(
        cfg, n_chunks=args.chunks, time_budget_s=args.time_budget,
        ckpt_dir=args.ckpt, seed=args.seed)

    print(f"[train] {args.arch}: m={m} n={cfg.n_features} k={rcfg.k} "
          f"s={rcfg.s} chunks={args.chunks} batch={rcfg.batch}")
    result = fit(
        lambda cid: np.asarray(gmm_chunk(spec, cid, rcfg.s)), rcfg,
        method="streaming", n_features=cfg.n_features)
    failed = result.extras.get("chunks_failed", 0)
    print(f"[train] done: f_best={result.objective:.6e} "
          f"accepted={result.n_accepted}/{result.n_chunks} "
          f"failed={failed} wall={result.wall_time_s:.1f}s "
          f"n_d={result.n_dist_evals:.3e}")


if __name__ == "__main__":
    main()
