"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (TPU v5e pod
slice); multi-pod: 2 pods x 256 = 512 chips with a leading "pod" axis.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType

    def _axis_kwargs(n_axes: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n_axes}
except ImportError:     # jax < 0.6: axes are implicitly Auto

    def _axis_kwargs(n_axes: int) -> dict:
        return {}


def make_mesh(shape, axes, devices=None):
    """`jax.make_mesh` with Auto axis types where the API supports them —
    the portable entry point for tests and benchmark subprocesses."""
    kwargs = _axis_kwargs(len(axes))
    if devices is not None:
        kwargs["devices"] = devices
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (dryrun.py does this)."
        )
    return make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
    return make_mesh(shape, axes)
