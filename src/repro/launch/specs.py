"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs`` returns abstract model inputs (weak-type-correct, no device
allocation); ``*_shardings`` map them (and params / optimizer state / caches)
onto the production mesh.  Modality frontends are stubs: precomputed
patch/frame embeddings appear directly as inputs, per the assignment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.models import transformer as T
from repro.train import sharding as sh


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def text_len(cfg, seq_len: int) -> int:
    """VLM cells split the assigned seq_len into image prefix + text."""
    if cfg.family == "vlm":
        return seq_len - cfg.frontend_len
    return seq_len


def input_specs(cfg, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    St = text_len(cfg, S)
    if shape.kind == "train":
        specs = {
            "tokens": _sds((B, St), jnp.int32),
            "labels": _sds((B, St), jnp.int32),
        }
        if cfg.frontend:
            flen = cfg.frontend_len
            specs["frontend"] = _sds((B, flen, cfg.frontend_dim), jnp.bfloat16)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": _sds((B, St), jnp.int32)}
        if cfg.frontend:
            specs["frontend"] = _sds(
                (B, cfg.frontend_len, cfg.frontend_dim), jnp.bfloat16)
        return specs
    if shape.kind == "decode":
        cache = T.abstract_cache(
            cfg, B, S,
            enc_len=cfg.frontend_len if cfg.cross_attention else None)
        return {
            "cache": cache,
            "token": _sds((B, 1), jnp.int32),
            "pos": _sds((), jnp.int32),
        }
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------
def batch_sharding(mesh: Mesh, spec_tree):
    """Shard dim 0 (global batch) over the batch axes where divisible."""

    def leaf(x):
        logical = ("batch",) + (None,) * (len(x.shape) - 1)
        return NamedSharding(mesh, sh.spec(mesh, *logical, shape=x.shape))

    return jax.tree.map(leaf, spec_tree)


def cache_shardings(mesh: Mesh, cache_spec):
    """KV/SSM cache: batch over data axes; if batch is unshardable (B=1,
    long-context), shard the *sequence* dim instead (flash-decoding style);
    heads/channels over the model axis where divisible."""

    def leaf(path, x):
        name = None
        for part in reversed(path):
            k = getattr(part, "key", None)
            if isinstance(k, str):
                name = k
                break
        shp = x.shape
        if name in ("k", "v", "cross_k", "cross_v"):
            # [L, B, S, KV, hd]
            logical = list(sh.kv_cache_logical(mesh, shp))
        elif name == "conv":
            # [L, B, w-1, C]
            logical = [None, "batch", None, "model"]
        elif name == "state":
            # [L, B, H, P, N]
            logical = [None, "batch", "model", None, None]
        else:
            logical = [None] * len(shp)
        return NamedSharding(mesh, sh.spec(mesh, *logical, shape=shp))

    return jax.tree_util.tree_map_with_path(leaf, cache_spec)


def input_shardings(mesh: Mesh, cfg, shape: ShapeSpec, specs: dict) -> dict:
    out = {}
    for k, v in specs.items():
        if k == "cache":
            out[k] = cache_shardings(mesh, v)
        elif k == "pos":
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = batch_sharding(mesh, v)
    return out
