"""Host-streaming Big-means driver: out-of-core data, checkpoints, failures.

This is the production entry point for datasets that do not fit device (or
host) memory.  Chunks are *fetched* by a user-supplied provider — a memmap
slice, a shard of a distributed file system, or the synthetic generator — and
fed to the jitted ``chunk_step``.  Design properties (DESIGN.md §6):

* **fault tolerance** — global state is (C, degenerate, f_best, step, key):
  kilobytes.  Checkpoint every ``ckpt_every`` chunks; on restart, resume from
  the latest checkpoint.  A lost/failed chunk is simply skipped: chunks are
  i.i.d. uniform samples, so dropping one changes nothing statistically (the
  algorithm is natively fault-tolerant).
* **straggler mitigation** — the Lloyd iteration budget is a compile-time
  bound, and a wall-clock budget (the paper's cpu_max stop condition) caps
  the whole run; a straggling provider fetch can be skipped after
  ``fetch_timeout`` without violating correctness (same argument as above).
* **elasticity** — the state carries no topology; rescaling workers between
  restarts only changes how many chunk streams advance per wall-clock second.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator

import jax
import numpy as np

from repro.cluster import checkpoint
from repro.core import bigmeans

ChunkProvider = Callable[[int], np.ndarray]


@dataclasses.dataclass
class RunnerConfig:
    k: int
    s: int
    n_chunks: int = 1_000_000         # effectively "until budget"
    max_iters: int = 300
    tol: float = 1e-4
    candidates: int = 3
    impl: str = "auto"
    time_budget_s: float | None = None   # paper's cpu_max
    ckpt_dir: str | None = None
    ckpt_every: int = 100
    log_every: int = 50
    seed: int = 0
    # --- VNS extension (paper §6 future work): when the incumbent stalls
    # for `vns_patience` chunks, move to the next chunk size in the ladder
    # (stronger shaking on smaller chunks, finer approximation on larger);
    # an acceptance resets to the base size.  Empty ladder = paper baseline.
    vns_ladder: tuple = ()
    vns_patience: int = 10


@dataclasses.dataclass
class RunnerMetrics:
    chunks_done: int = 0
    chunks_failed: int = 0
    accepted: int = 0
    wall_time_s: float = 0.0
    f_best: float = float("inf")
    trace: list = dataclasses.field(default_factory=list)


def run(
    provider: ChunkProvider,
    cfg: RunnerConfig,
    *,
    n_features: int,
    resume: bool = True,
    fault_injector: Callable[[int], None] | None = None,
) -> tuple[bigmeans.BigMeansState, RunnerMetrics]:
    """Stream chunks through Big-means until the chunk count or time budget."""
    state = bigmeans.init_state(cfg.k, n_features)
    start_chunk = 0
    key = jax.random.PRNGKey(cfg.seed)

    if resume and cfg.ckpt_dir and checkpoint.latest_step(cfg.ckpt_dir) is not None:
        (state, key), start_chunk = checkpoint.restore(
            cfg.ckpt_dir, (state, key)
        )

    metrics = RunnerMetrics(f_best=float(state.f_best))
    t0 = time.monotonic()

    ladder = (cfg.s,) + tuple(cfg.vns_ladder)
    rung, stall = 0, 0
    last_s = cfg.s

    for chunk_id in range(start_chunk, cfg.n_chunks):
        if cfg.time_budget_s is not None:
            if time.monotonic() - t0 > cfg.time_budget_s:
                break
        # Per-chunk keys are folded from (seed, chunk_id): restarts and
        # worker-count changes replay the identical sample stream.
        ck = jax.random.fold_in(key, chunk_id)
        try:
            if fault_injector is not None:
                fault_injector(chunk_id)
            chunk = np.asarray(provider(chunk_id), dtype=np.float32)
        except Exception:
            metrics.chunks_failed += 1
            continue        # skip: uniform chunks are interchangeable
        s_now = ladder[rung]
        if chunk.shape[0] > s_now:
            chunk = chunk[:s_now]       # VNS: shrink the neighbourhood
        if chunk.shape[0] != last_s and np.isfinite(float(state.f_best)):
            # objectives are sums over s points: rescale the incumbent's
            # objective so acceptance compares per-point quality
            state = state._replace(
                f_best=state.f_best * (chunk.shape[0] / last_s))
        last_s = chunk.shape[0]
        state, info = bigmeans.chunk_step(
            jax.numpy.asarray(chunk), state, ck,
            max_iters=cfg.max_iters, tol=cfg.tol,
            candidates=cfg.candidates, impl=cfg.impl,
        )
        metrics.chunks_done += 1
        if bool(info.accepted):
            metrics.accepted += 1
            rung, stall = 0, 0          # VNS: success -> base neighbourhood
        elif cfg.vns_ladder:
            stall += 1
            if stall >= cfg.vns_patience:
                rung = min(rung + 1, len(ladder) - 1)
                stall = 0
        if cfg.log_every and metrics.chunks_done % cfg.log_every == 0:
            metrics.trace.append(
                (chunk_id, float(state.f_best), float(info.f_new))
            )
        if cfg.ckpt_dir and (chunk_id + 1) % cfg.ckpt_every == 0:
            checkpoint.save(cfg.ckpt_dir, chunk_id + 1, (state, key))

    if cfg.ckpt_dir:
        checkpoint.save(cfg.ckpt_dir, metrics.chunks_done + start_chunk,
                        (state, key))
    metrics.wall_time_s = time.monotonic() - t0
    metrics.f_best = float(state.f_best)
    return state, metrics
