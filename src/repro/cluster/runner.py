"""Host-streaming Big-means driver: out-of-core data, checkpoints, failures.

This is the production entry point for datasets that do not fit device (or
host) memory.  Chunks are *fetched* by a user-supplied provider — a memmap
slice, a shard of a distributed file system, or the synthetic generator — and
fed to the jitted ``chunk_step``.  Design properties (DESIGN.md §6):

* **fault tolerance** — global state is (C, degenerate, f_best, step, key):
  kilobytes.  Checkpoint every ``ckpt_every`` chunks; on restart, resume from
  the latest checkpoint.  A lost/failed chunk is simply skipped: chunks are
  i.i.d. uniform samples, so dropping one changes nothing statistically (the
  algorithm is natively fault-tolerant).
* **straggler mitigation** — the Lloyd iteration budget is a compile-time
  bound, and a wall-clock budget (the paper's cpu_max stop condition) caps
  the whole run; a straggling provider fetch can be skipped after
  ``fetch_timeout`` without violating correctness (same argument as above).
* **elasticity** — the state carries no topology; rescaling workers between
  restarts only changes how many chunk streams advance per wall-clock second.
* **pipelining** — a background thread prefetches up to ``prefetch`` chunks
  into a bounded queue and stages them on device (``jax.device_put``), so
  provider fetch and host→device transfer overlap device compute instead of
  blocking it.  Under ``cfg.precision='bf16'`` the prefetch thread casts
  chunks to bf16 *on the host* before ``device_put``, halving the
  host→device bytes as well as the device-side HBM traffic.  ``batch`` > 1 feeds B chunks at a time to the batched
  driver (``chunk_step_batched``): B Lloyd searches advance concurrently
  against the incumbent and the best result is kept — the single-device
  analogue of the sharded driver's worker streams.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
import warnings
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster import checkpoint
from repro.core import bigmeans

ChunkProvider = Callable[[int], np.ndarray]


class EndOfStream(Exception):
    """Raised by a provider to end the run cleanly before ``n_chunks``
    (e.g. a finite chunk iterator ran dry).  Not counted as a failure."""


def RunnerConfig(**kwargs):
    """Deprecated shim: the knob truth moved to `repro.api.BigMeansConfig`.

    Accepts the historical ``RunnerConfig`` keywords (a strict subset of
    ``BigMeansConfig``'s fields) and preserves the old ``n_chunks`` default
    of "effectively until budget".  Remove after one release.
    """
    warnings.warn(
        "repro.cluster.runner.RunnerConfig is deprecated; use "
        "repro.api.BigMeansConfig",
        DeprecationWarning, stacklevel=2)
    from repro.api.config import BigMeansConfig

    kwargs.setdefault("n_chunks", 1_000_000)
    return BigMeansConfig(**kwargs)


@dataclasses.dataclass
class RunnerMetrics:
    """``trace`` holds ``(chunk_id, f_best, f_new)`` progress entries and
    ``("fetch_error", chunk_id, "ExcType: message")`` entries for failed
    fetches, so streaming failures are debuggable from the result."""
    chunks_done: int = 0
    chunks_failed: int = 0
    accepted: int = 0
    wall_time_s: float = 0.0
    f_best: float = float("inf")
    trace: list = dataclasses.field(default_factory=list)


class _FetchFailure:
    """A failed chunk fetch: carries the provider's exception type+message."""

    __slots__ = ("error",)

    def __init__(self, exc: BaseException):
        self.error = f"{type(exc).__name__}: {exc}"


class _Prefetcher:
    """Background chunk fetcher: provider call + np conversion + device_put
    run off the main thread, double-buffered through a bounded queue.

    Yields ``(chunk_id, chunk-or-_FetchFailure)`` in id order; a
    ``_FetchFailure`` marks a failed fetch (the provider raised) so the
    consumer can account for it and record the cause.
    """

    _DONE = object()

    def __init__(self, provider, ids, depth,
                 fault_injector=None, dtype=np.float32):
        self._provider = provider
        self._ids = ids
        self._dtype = dtype
        self._fault_injector = fault_injector
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _fetch(self, cid):
        try:
            if self._fault_injector is not None:
                self._fault_injector(cid)
            arr = np.asarray(self._provider(cid), dtype=self._dtype)
            return jax.device_put(arr)
        except EndOfStream:
            return self._DONE
        except Exception as exc:
            return _FetchFailure(exc)

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _work(self):
        for cid in self._ids:
            if self._stop.is_set():
                return
            item = self._fetch(cid)
            if item is self._DONE:          # provider signalled end-of-stream
                break
            if not self._put((cid, item)):
                return
        self._put(self._DONE)

    def __iter__(self) -> Iterator:
        while True:
            item = self._q.get()
            if item is self._DONE:
                return
            yield item

    def close(self):
        self._stop.set()
        # Drain so a blocked producer can observe the stop flag and exit.
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)


def _sync_chunks(provider, ids, fault_injector, dtype=np.float32):
    """prefetch=0 fallback: fetch in the main thread (debug / determinism)."""
    for cid in ids:
        try:
            if fault_injector is not None:
                fault_injector(cid)
            arr = np.asarray(provider(cid), dtype=dtype)
            yield cid, jax.device_put(arr)
        except EndOfStream:
            return
        except Exception as exc:
            yield cid, _FetchFailure(exc)


def run(
    provider: ChunkProvider,
    cfg,
    *,
    n_features: int,
    resume: bool = True,
    fault_injector: Callable[[int], None] | None = None,
    key: jax.Array | None = None,
) -> tuple[bigmeans.BigMeansState, RunnerMetrics]:
    """Stream chunks through Big-means until the chunk count or time budget.

    ``cfg`` is a `repro.api.BigMeansConfig` (or anything with the same
    fields; the deprecated :func:`RunnerConfig` shim builds one).
    """
    state = bigmeans.init_state(cfg.k, n_features)
    start_chunk = 0
    if key is None:
        key = jax.random.PRNGKey(cfg.seed)

    if resume and cfg.ckpt_dir and checkpoint.latest_step(cfg.ckpt_dir) is not None:
        (state, key), start_chunk = checkpoint.restore(
            cfg.ckpt_dir, (state, key)
        )

    metrics = RunnerMetrics(f_best=float(state.f_best))
    t0 = time.monotonic()

    ladder = (cfg.s,) + tuple(cfg.vns_ladder)
    rung, stall = 0, 0
    last_s = cfg.s

    from repro.kernels import precision as px

    precision = getattr(cfg, "precision", "auto")
    host_dtype = px.host_dtype(precision) or np.float32
    ids = range(start_chunk, cfg.n_chunks)
    source = (
        _Prefetcher(provider, ids, cfg.prefetch, fault_injector, host_dtype)
        if cfg.prefetch > 0
        else _sync_chunks(provider, ids, fault_injector, host_dtype)
    )

    def step_batch(state, pending):
        """Advance the incumbent by len(pending) concurrent chunk streams."""
        cids = [cid for cid, _ in pending]
        # Per-chunk keys are folded from (seed, chunk_id): restarts, batch
        # sizes and worker-count changes replay the identical sample stream.
        cks = [jax.random.fold_in(key, cid) for cid in cids]
        if len(pending) == 1:
            return bigmeans.chunk_step(
                pending[0][1], state, cks[0],
                max_iters=cfg.max_iters, tol=cfg.tol,
                candidates=cfg.candidates, impl=cfg.impl,
                precision=precision,
            )
        chunks = jnp.stack([c for _, c in pending])
        states = bigmeans.broadcast_state(state, len(pending))
        states, info = bigmeans.chunk_step_batched(
            chunks, states, jnp.stack(cks),
            max_iters=cfg.max_iters, tol=cfg.tol,
            candidates=cfg.candidates, impl=cfg.impl,
            precision=precision,
        )
        return bigmeans.reduce_state(states, base=state), info

    def consume_info(info):
        nonlocal rung, stall
        n_acc = int(np.sum(np.asarray(info.accepted)))
        metrics.accepted += n_acc
        if n_acc:
            rung, stall = 0, 0          # VNS: success -> base neighbourhood
        elif cfg.vns_ladder:
            stall += int(np.size(np.asarray(info.accepted)))
            if stall >= cfg.vns_patience:
                rung = min(rung + 1, len(ladder) - 1)
                stall = 0

    pending: list = []
    last_cid = start_chunk - 1
    try:
        for chunk_id, chunk in source:
            if cfg.time_budget_s is not None:
                if time.monotonic() - t0 > cfg.time_budget_s:
                    break
            if chunk is None or isinstance(chunk, _FetchFailure):
                metrics.chunks_failed += 1
                if isinstance(chunk, _FetchFailure):
                    metrics.trace.append(("fetch_error", chunk_id, chunk.error))
                continue
            s_now = ladder[rung]
            if chunk.shape[0] > s_now:
                chunk = chunk[:s_now]       # VNS: shrink the neighbourhood
            if pending and chunk.shape != pending[0][1].shape:
                # ragged chunk (short tail / VNS rung change mid-batch):
                # flush the homogeneous batch first, then start a new one
                state, info = step_batch(state, pending)
                metrics.chunks_done += len(pending)
                last_cid = pending[-1][0]
                pending = []
                consume_info(info)
            if chunk.shape[0] != last_s and np.isfinite(float(state.f_best)):
                # objectives are sums over s points: rescale the incumbent's
                # objective so acceptance compares per-point quality
                state = state._replace(
                    f_best=state.f_best * (chunk.shape[0] / last_s))
            last_s = chunk.shape[0]
            pending.append((chunk_id, chunk))
            if len(pending) < cfg.batch:
                continue

            state, info = step_batch(state, pending)
            metrics.chunks_done += len(pending)
            last_cid = pending[-1][0]
            pending = []
            consume_info(info)
            if cfg.log_every and metrics.chunks_done % cfg.log_every < cfg.batch:
                metrics.trace.append(
                    (last_cid, float(state.f_best),
                     float(np.min(np.asarray(info.f_new))))
                )
            if cfg.ckpt_dir and (last_cid + 1) % cfg.ckpt_every < cfg.batch:
                checkpoint.save(cfg.ckpt_dir, last_cid + 1, (state, key))
            if cfg.time_budget_s is not None:
                if time.monotonic() - t0 > cfg.time_budget_s:
                    break
        else:
            if pending:                     # final partial batch
                state, info = step_batch(state, pending)
                metrics.chunks_done += len(pending)
                last_cid = pending[-1][0]
                pending = []
                consume_info(info)
    finally:
        if isinstance(source, _Prefetcher):
            source.close()

    if cfg.ckpt_dir:
        checkpoint.save(cfg.ckpt_dir, metrics.chunks_done + start_chunk,
                        (state, key))
    metrics.wall_time_s = time.monotonic() - t0
    metrics.f_best = float(state.f_best)
    return state, metrics
