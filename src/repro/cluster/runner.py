"""Host-streaming Big-means driver — a thin assembly of engine pieces.

The out-of-core accept loop (prefetch pipeline, fault tolerance, VNS,
checkpoints, time budget) lives in :mod:`repro.engine.stream`; this module
keeps the historical entry point: :func:`run` builds the config-derived
middleware stack / topology / scheduler / sync policy and delegates.  The
names ``RunnerMetrics``, ``EndOfStream`` and the prefetcher classes are
re-exported for backwards compatibility.
"""
from __future__ import annotations

import warnings
from typing import Callable

import jax

from repro.core import bigmeans
from repro.engine.stream import (  # noqa: F401  (compat re-exports)
    ChunkProvider,
    EndOfStream,
    RunnerMetrics,
    _FetchFailure,
    _Prefetcher,
    _sync_chunks,
    run_stream,
)


def RunnerConfig(**kwargs):
    """Deprecated shim: the knob truth moved to `repro.api.BigMeansConfig`.

    Accepts the historical ``RunnerConfig`` keywords (a strict subset of
    ``BigMeansConfig``'s fields) and preserves the old ``n_chunks`` default
    of "effectively until budget".  Remove after one release.
    """
    warnings.warn(
        "repro.cluster.runner.RunnerConfig is deprecated; use "
        "repro.api.BigMeansConfig",
        DeprecationWarning, stacklevel=2)
    from repro.api.config import BigMeansConfig

    kwargs.setdefault("n_chunks", 1_000_000)
    return BigMeansConfig(**kwargs)


def run(
    provider: ChunkProvider,
    cfg,
    *,
    n_features: int,
    resume: bool = True,
    fault_injector: Callable[[int], None] | None = None,
    key: jax.Array | None = None,
) -> tuple[bigmeans.BigMeansState, RunnerMetrics]:
    """Stream chunks through Big-means until the chunk count or time budget.

    ``cfg`` is a `repro.api.BigMeansConfig` (or anything with the same
    fields; the deprecated :func:`RunnerConfig` shim builds one).  The
    scheduler (``cfg.scheduler``), topology (``cfg.mesh`` shards the stream
    axis) and sync policy (``cfg.sync`` / ``cfg.sync_every``) all come from
    the config; middleware (checkpoint, VNS, budget, tracing, fetch skip,
    chunk sanitizer + invariant guard) is the default stack, and the
    fault-tolerance knobs (``cfg.retries`` / ``cfg.fetch_timeout_s`` /
    ``cfg.validate_chunks`` — see :mod:`repro.engine.faults`) govern the
    fetch pipeline.  ``fault_injector(cid)`` (raises to fail a fetch) is
    the legacy injection hook; :class:`repro.engine.faults.FaultPlan` is
    the generalized harness.
    """
    return run_stream(
        provider, cfg, n_features=n_features, resume=resume,
        fault_injector=fault_injector, key=key)
