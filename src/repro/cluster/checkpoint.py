"""Minimal, dependency-free checkpointing (orbax is not available offline).

* atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` to ``step_<n>``;
* bounded: keeps the last ``keep`` checkpoints;
* elastic: arrays are stored as full logical values; ``restore`` re-shards
  with whatever sharding the caller passes — restarting on a different
  worker count / mesh shape needs no conversion step.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree, *, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:012d}")
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = _flatten(tree)
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(leaves),
                   "treedef": str(treedef)}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)

    ckpts = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for stale in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, stale))
    return final


def n_leaves(directory: str, step: int | None = None) -> int | None:
    """Leaf count of a stored checkpoint (from its metadata, without loading
    the arrays) — lets callers distinguish payload formats (e.g. the engine's
    ``((state, key), vns_aux)`` vs the legacy ``(state, key)``) before
    choosing an example tree for :func:`restore`."""
    if step is None:
        step = latest_step(directory)
    if step is None:
        return None
    path = os.path.join(directory, f"step_{step:012d}", "meta.json")
    with open(path) as f:
        return int(json.load(f)["n_leaves"])


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    if not ckpts:
        return None
    return int(ckpts[-1].split("_")[1])


def restore(directory: str, example_tree, *, step: int | None = None,
            shardings=None):
    """Load into the structure of ``example_tree``; optionally device_put with
    ``shardings`` (same pytree structure or a single sharding)."""
    if step is None:
        step = latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:012d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(example_tree)
    assert len(leaves) == len(data.files), (len(leaves), len(data.files))
    new_leaves = [data[f"a{i}"] for i in range(len(leaves))]
    tree = jax.tree.unflatten(treedef, new_leaves)
    if shardings is not None:
        if not isinstance(shardings, (list, dict, tuple)) and not hasattr(
            shardings, "keys"
        ):
            tree = jax.tree.map(lambda a: jax.device_put(a, shardings), tree)
        else:
            tree = jax.tree.map(jax.device_put, tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, step
