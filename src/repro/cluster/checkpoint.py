"""Minimal, dependency-free checkpointing (orbax is not available offline).

* atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` to ``step_<n>``;
  stale ``tmp.*`` leftovers from a crashed save are cleaned on the next
  :func:`save` and never considered by restore;
* bounded: keeps the last ``keep`` checkpoints;
* self-healing: ``meta.json`` records a SHA-256 digest per data file;
  :func:`restore` verifies the newest checkpoint and falls back to the
  newest *intact* ``step_*`` when it is corrupt (truncated write, bit rot)
  instead of crashing the run or silently loading garbage.  Legacy
  checkpoints without digests are verified by a read-back load instead;
* elastic: arrays are stored as full logical values; ``restore`` re-shards
  with whatever sharding the caller passes — restarting on a different
  worker count / mesh shape needs no conversion step.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _clean_tmp(directory: str) -> None:
    """Remove ``tmp.*`` leftovers from crashed saves: they are partial by
    definition and must never shadow or outlive real ``step_*`` dirs."""
    for entry in os.listdir(directory):
        if entry.startswith("tmp."):
            shutil.rmtree(os.path.join(directory, entry),
                          ignore_errors=True)


def save(directory: str, step: int, tree, *, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    _clean_tmp(directory)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:012d}")
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = _flatten(tree)
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(leaves)}
    arrays_path = os.path.join(tmp, "arrays.npz")
    np.savez(arrays_path, **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(leaves),
                   "treedef": str(treedef),
                   "digests": {"arrays.npz": _sha256(arrays_path)}}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)

    ckpts = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for stale in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, stale))
    return final


def steps(directory: str) -> list[int]:
    """All stored checkpoint steps, ascending (``tmp.*`` never included)."""
    if not os.path.isdir(directory):
        return []
    return sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                  if d.startswith("step_"))


def verify_step(directory: str, step: int) -> bool:
    """True iff the checkpoint at ``step`` is intact.

    Digest-bearing checkpoints are verified against their recorded
    SHA-256s; legacy checkpoints (no ``digests`` in ``meta.json``) fall
    back to actually loading ``arrays.npz`` — slower, but a truncated file
    still fails closed.
    """
    path = os.path.join(directory, f"step_{step:012d}")
    try:
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        digests = meta.get("digests")
        if digests is not None:
            return all(
                _sha256(os.path.join(path, name)) == want
                for name, want in digests.items())
        with np.load(os.path.join(path, "arrays.npz")) as data:
            return len(data.files) == int(meta["n_leaves"])
    except Exception:
        return False


def n_leaves(directory: str, step: int | None = None) -> int | None:
    """Leaf count of a stored checkpoint (from its metadata, without loading
    the arrays) — lets callers distinguish payload formats (e.g. the engine's
    ``((state, key), vns_aux)`` vs the legacy ``(state, key)``) before
    choosing an example tree for :func:`restore`."""
    if step is None:
        step = latest_step(directory)
    if step is None:
        return None
    path = os.path.join(directory, f"step_{step:012d}", "meta.json")
    with open(path) as f:
        return int(json.load(f)["n_leaves"])


def latest_step(directory: str) -> int | None:
    all_steps = steps(directory)
    return all_steps[-1] if all_steps else None


def latest_intact_step(directory: str) -> int | None:
    """The newest step that passes :func:`verify_step` (None when every
    stored checkpoint is corrupt or none exist)."""
    for step in reversed(steps(directory)):
        if verify_step(directory, step):
            return step
    return None


def restore(directory: str, example_tree, *, step: int | None = None,
            shardings=None, verify: bool = True):
    """Load into the structure of ``example_tree``; optionally device_put with
    ``shardings`` (same pytree structure or a single sharding).

    With ``step=None`` and ``verify=True`` (the default), the newest
    *intact* checkpoint is loaded — a corrupt newest step is skipped, not
    served.  An explicit ``step`` is loaded as-is (debugging raw access).
    """
    if step is None:
        step = latest_intact_step(directory) if verify \
            else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no intact checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:012d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(example_tree)
    assert len(leaves) == len(data.files), (len(leaves), len(data.files))
    new_leaves = [data[f"a{i}"] for i in range(len(leaves))]
    tree = jax.tree.unflatten(treedef, new_leaves)
    if shardings is not None:
        if not isinstance(shardings, (list, dict, tuple)) and not hasattr(
            shardings, "keys"
        ):
            tree = jax.tree.map(lambda a: jax.device_put(a, shardings), tree)
        else:
            tree = jax.tree.map(jax.device_put, tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, step
