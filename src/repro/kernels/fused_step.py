"""Fused Lloyd-iteration kernel: assignment + update + objective in ONE pass.

The two-kernel formulation streams the chunk from HBM twice per iteration
(assign reads X, update reads X again).  This kernel computes, per point
tile resident in VMEM:

    scores  = ||c||^2 - 2 x @ c^T          (MXU)
    idx     = argmin(scores)               (VPU)
    sums   += onehot(idx)^T @ x            (MXU, same resident tile)
    counts += colsum(onehot)
    obj    += sum(min_dist)

halving the dominant HBM traffic of Big-means' inner loop.

k and n are tiled *inside* the kernel: k is processed in ``block_k`` lane
tiles with a running (min, argmin) pair carried across tiles — the full
s x k distance block is never materialized — and the distance matmul
contracts n in ``block_n`` tiles.  That lifts the historical single-chunk
wall (k <= 128, n <= 1024) to the VMEM-working-set envelope :func:`fits`
(k <= 1024, n <= 4096, k_pad * n_pad <= 1M elements) for the single and
batched variants alike.

Mixed precision (``precision='bf16'``): the chunk and centroids are stored
and streamed bf16 — halving the remaining HBM bytes again — and both MXU
contractions take bf16 operands.  Everything that decides or accumulates is
f32: the score accumulator (``preferred_element_type``), ``||c||^2`` /
``||x||^2`` (computed from the full-width view before the storage cast),
sums, counts and the objective.  ``'bf16x3'`` keeps f32 storage and runs
each contraction as three compensated bf16 products (near-f32 numerics at
bf16 MXU rates; no bandwidth change).

``'int8'`` streams the chunk as int8 codes + per-feature scales (a quarter
of the f32 bytes; see :mod:`repro.kernels.precision`): centroids are
re-quantized per iteration into the chunk's scaled feature space with
per-row scales ``t`` so the distance contraction is int8 x int8 -> int32
(exact) with ``t`` factoring out per score column; the one-hot update
contraction is 0/1 x int8 -> int32 (exact), scaled to data space after the
kernel; and the correction terms — full-width ``||c||^2``, dequantized
``||x||^2`` — plus the running argmin, counts and objective stay f32.

Pipelines (single-chunk kernel):

* ``pipeline='blocks'`` — the classic Pallas grid: one program per point
  tile, the BlockSpec machinery streams x tiles HBM->VMEM.
* ``pipeline='dma'``    — double-buffered chunk DMA: x stays in HBM/ANY and
  one program walks the point tiles with explicit ``make_async_copy`` into
  a two-slot VMEM scratch, starting the copy of tile i+1 before computing
  on tile i, so HBM streaming overlaps MXU compute.  Same math, same
  results; registered as an autotune candidate so the tuner picks whichever
  wins on the backend.

``ops.fused_step`` / ``ops.fused_step_batched`` fall back to the two-pass
path outside the envelope or when point weights are used.  Block sizes
default to the module constants; ``ops`` overrides them with autotuned
tilings (``repro.kernels.autotune``) — tile/pipeline choice is perf-only
and never changes results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import precision as px

_BIG = 1e30

# VMEM-working-set envelope: k and n are tiled inside the kernel, so the
# wall is the resident c + sums blocks, not the lane width.
MAX_K = 1024
MAX_N = 4096
_MAX_KN_ELEMS = 1 << 20        # k_pad * n_pad <= 1M f32 (4 MB per block)

# Historical single-chunk envelope (pre-tiling), kept for tests/docs: shapes
# beyond it used to fall back to the two-pass ref path.
LEGACY_MAX_K = 128
LEGACY_MAX_N = 1024

_BLOCK_K = 128                 # lane tile for the running argmin
_BLOCK_N = 512                 # contraction tile for the distance matmul

PIPELINES = ("blocks", "dma")


def _pad_to(a, size, axis, value=0.0):
    pad = size - a.shape[axis]
    if pad <= 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def _batched_tiles(k: int, n: int, block_k: int | None = None,
                   block_n: int | None = None) -> tuple[int, int, int, int]:
    """(k_pad, n_pad, block_k, block_n) used by the fused kernels."""
    block_k = _BLOCK_K if block_k is None else block_k
    k_pad = -(-k // block_k) * block_k
    n_pad = -(-n // 128) * 128
    if block_n is None:
        block_n = n_pad if n_pad <= _BLOCK_N else _BLOCK_N
    n_pad = -(-n_pad // block_n) * block_n
    return k_pad, n_pad, block_k, block_n


def fits(k: int, n: int) -> bool:
    k_pad, n_pad, _, _ = _batched_tiles(k, n)
    return k <= MAX_K and n <= MAX_N and k_pad * n_pad <= _MAX_KN_ELEMS


# Single and batched kernels share one envelope since the k/n tiling moved
# into both bodies.
fits_batched = fits

MAX_K_BATCHED = MAX_K
MAX_N_BATCHED = MAX_N


def _tile_argmin(x, c, csq, *, block_k: int, block_n: int, precision: str,
                 t=None, scale=None):
    """Running (min, argmin) across k lane tiles for one resident point tile.

    ``x`` [bm, n_pad], ``c`` [k_pad, n_pad], ``csq`` [1, k_pad]; under int8
    ``t`` [1, k_pad] are the per-row centroid scales and ``scale`` [1, n_pad]
    the per-feature chunk scales.  Returns (bidx int32 [bm], best f32 [bm],
    xsq f32 [bm]).  Both tile loops are unrolled at trace time.
    """
    bm, n_pad = x.shape
    k_pad = c.shape[0]
    nk, nn = k_pad // block_k, n_pad // block_n
    int8 = precision == "int8"

    best = jnp.full((bm,), _BIG, jnp.float32)
    bidx = jnp.zeros((bm,), jnp.int32)
    for j in range(nk):
        ct = c[j * block_k:(j + 1) * block_k]                # [bk, n_pad]
        if int8:
            idots = jnp.zeros((bm, block_k), jnp.int32)
            for u in range(nn):
                sl = slice(u * block_n, (u + 1) * block_n)
                idots += px.intdot(x[:, sl], ct[:, sl],
                                   (((1,), (1,)), ((), ())))
            dots = (idots.astype(jnp.float32)
                    * t[0:1, j * block_k:(j + 1) * block_k])
        else:
            dots = jnp.zeros((bm, block_k), jnp.float32)
            for u in range(nn):
                sl = slice(u * block_n, (u + 1) * block_n)
                dots += px.dot(x[:, sl], ct[:, sl], (((1,), (1,)), ((), ())),
                               precision)
        sc = csq[0:1, j * block_k:(j + 1) * block_k] - 2.0 * dots
        tmin = jnp.min(sc, axis=1)
        targ = jnp.argmin(sc, axis=1).astype(jnp.int32) + j * block_k
        take = tmin < best
        best = jnp.where(take, tmin, best)
        bidx = jnp.where(take, targ, bidx)

    if int8:
        deq = x.astype(jnp.float32) * scale                  # [bm, n_pad]
        xsq = jnp.sum(deq * deq, axis=1)
    else:
        xsq = px.sqnorm(x, axis=1)
    return bidx, best, xsq


def _unpack_fused_refs(args, precision: str):
    """(x, c, csq, t, scale, sums, counts, obj, rest) from positional refs."""
    if precision == "int8":
        x_ref, c_ref, csq_ref, t_ref, scale_ref = args[:5]
        rest = args[5:]
    else:
        x_ref, c_ref, csq_ref = args[:3]
        t_ref = scale_ref = None
        rest = args[3:]
    sums_ref, counts_ref, obj_ref = rest[:3]
    return x_ref, c_ref, csq_ref, t_ref, scale_ref, sums_ref, counts_ref, \
        obj_ref, rest[3:]


def _fused_tile_accumulate(i, x, c, csq, t, scale, sums_ref, counts_ref,
                           obj_ref, *, m: int, block_m: int, block_k: int,
                           block_n: int, precision: str, batched: bool):
    """Process one resident point tile and accumulate into the output refs.

    ``i`` is the point-tile index (python int or tracer); ``batched`` says
    whether the output refs carry a leading [1] batch axis.
    """
    bm = x.shape[0]
    k_pad = c.shape[0]
    nk = k_pad // block_k
    int8 = precision == "int8"

    bidx, best, xsq = _tile_argmin(x, c, csq, block_k=block_k,
                                   block_n=block_n, precision=precision,
                                   t=t, scale=scale)
    mind = jnp.maximum(best + xsq, 0.0)
    rows = i * block_m + jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
    validb = rows < m                                        # [bm, 1] bool
    valid = validb.astype(jnp.float32)

    for j in range(nk):
        lanes = (jax.lax.broadcasted_iota(jnp.int32, (bm, block_k), 1)
                 + j * block_k)
        hit = (bidx[:, None] == lanes) & validb              # [bm, bk]
        ksl = slice(j * block_k, (j + 1) * block_k)
        if int8:
            part = px.intdot(hit.astype(jnp.int8), x,
                             (((0,), (0,)), ((), ())))       # [bk, n_pad] i32
        else:
            part = px.dot(hit.astype(jnp.float32), x,
                          (((0,), (0,)), ((), ())), precision)
        if batched:
            sums_ref[0, ksl, :] += part
            counts_ref[0, :, ksl] += jnp.sum(
                hit.astype(jnp.float32), axis=0, keepdims=True)
        else:
            sums_ref[ksl, :] += part
            counts_ref[:, ksl] += jnp.sum(
                hit.astype(jnp.float32), axis=0, keepdims=True)
    contrib = jnp.sum(mind[:, None] * valid, keepdims=True)[0:1, 0:1]
    if batched:
        obj_ref[...] += contrib.reshape(1, 1, 1)
    else:
        obj_ref[...] += contrib


def _fused_kernel(*args, m: int, block_m: int, block_k: int, block_n: int,
                  precision: str):
    (x_ref, c_ref, csq_ref, t_ref, scale_ref, sums_ref, counts_ref, obj_ref,
     _) = _unpack_fused_refs(args, precision)
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _zero():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        obj_ref[...] = jnp.zeros_like(obj_ref)

    _fused_tile_accumulate(
        i, x_ref[...], c_ref[...], csq_ref[...],
        None if t_ref is None else t_ref[...],
        None if scale_ref is None else scale_ref[...],
        sums_ref, counts_ref, obj_ref, m=m, block_m=block_m, block_k=block_k,
        block_n=block_n, precision=precision, batched=False)


def _fused_dma_kernel(*args, m: int, block_m: int, block_k: int,
                      block_n: int, precision: str, num_tiles: int):
    """Double-buffered variant: x lives in HBM/ANY; explicit async copies
    stream point tiles into a two-slot VMEM scratch so the DMA of tile i+1
    overlaps compute on tile i."""
    (x_hbm, c_ref, csq_ref, t_ref, scale_ref, sums_ref, counts_ref, obj_ref,
     rest) = _unpack_fused_refs(args, precision)
    scratch, sem = rest

    sums_ref[...] = jnp.zeros_like(sums_ref)
    counts_ref[...] = jnp.zeros_like(counts_ref)
    obj_ref[...] = jnp.zeros_like(obj_ref)

    c = c_ref[...]
    csq = csq_ref[...]
    t = None if t_ref is None else t_ref[...]
    scale = None if scale_ref is None else scale_ref[...]

    def dma(slot, i):
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(i * block_m, block_m)], scratch.at[slot],
            sem.at[slot])

    dma(0, 0).start()

    def body(i, carry):
        slot = jax.lax.rem(i, 2)

        @pl.when(i + 1 < num_tiles)
        def _prefetch_next():
            dma(jax.lax.rem(i + 1, 2), i + 1).start()

        dma(slot, i).wait()
        _fused_tile_accumulate(
            i, scratch[slot], c, csq, t, scale, sums_ref, counts_ref,
            obj_ref, m=m, block_m=block_m, block_k=block_k, block_n=block_n,
            precision=precision, batched=False)
        return carry

    jax.lax.fori_loop(0, num_tiles, body, 0)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_n", "pipeline", "precision",
                     "interpret"),
)
def fused_step_pallas(
    x,
    c: jax.Array,
    *,
    block_m: int = 256,
    block_k: int | None = None,
    block_n: int | None = None,
    pipeline: str = "blocks",
    precision: str = "f32",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x [m,n], c [k,n] -> (sums f32 [k,n], counts f32 [k], obj f32 scalar).

    ``x`` may be a plain array or (under ``'int8'``) a pre-quantized
    :class:`~repro.kernels.precision.QuantizedChunk`.
    """
    px.check(precision)
    if pipeline not in PIPELINES:
        raise ValueError(f"unknown pipeline {pipeline!r}; known: {PIPELINES}")
    int8 = precision == "int8" or isinstance(x, px.QuantizedChunk)

    if int8:
        qx = px.as_quantized(x)
        m, n = qx.q.shape
        k = c.shape[0]
        assert fits(k, n), (k, n)
        csq = px.sqnorm(c)                  # full-width correction term
        cq, t = px.quantize_centroids(c, qx.scale)
        xs, cs = qx.q, cq
    else:
        m, n = x.shape
        k = c.shape[0]
        assert fits(k, n), (k, n)
        csq = px.sqnorm(c)                  # f32, from the full-width view
        store = px.storage_dtype(precision)
        xs, cs = x.astype(store), c.astype(store)

    block_m = min(block_m, max(8, m))
    bm = -(-m // block_m) * block_m
    k_pad, n_pad, block_k, block_n = _batched_tiles(k, n, block_k, block_n)

    xp = _pad_to(_pad_to(xs, bm, 0), n_pad, 1)
    cp = _pad_to(_pad_to(cs, k_pad, 0), n_pad, 1)
    csqp = _pad_to(csq[None, :], k_pad, 1, value=_BIG)
    inputs = [xp, cp, csqp]
    if int8:
        inputs += [_pad_to(t[None, :], k_pad, 1),
                   _pad_to(qx.scale[None, :], n_pad, 1)]

    sums_dtype = jnp.int32 if int8 else jnp.float32
    out_shape = [
        jax.ShapeDtypeStruct((k_pad, n_pad), sums_dtype),
        jax.ShapeDtypeStruct((1, k_pad), jnp.float32),
        jax.ShapeDtypeStruct((1, 1), jnp.float32),
    ]
    kw = dict(m=m, block_m=block_m, block_k=block_k, block_n=block_n,
              precision="int8" if int8 else precision)

    if pipeline == "dma":
        num_tiles = bm // block_m
        x_spec = [pl.BlockSpec(memory_space=pltpu.ANY)]
        aux_specs = [pl.BlockSpec((k_pad, n_pad), lambda: (0, 0)),
                     pl.BlockSpec((1, k_pad), lambda: (0, 0))]
        if int8:
            aux_specs += [pl.BlockSpec((1, k_pad), lambda: (0, 0)),
                          pl.BlockSpec((1, n_pad), lambda: (0, 0))]
        sums, counts, obj = pl.pallas_call(
            functools.partial(_fused_dma_kernel, num_tiles=num_tiles, **kw),
            in_specs=x_spec + aux_specs,
            out_specs=[
                pl.BlockSpec((k_pad, n_pad), lambda: (0, 0)),
                pl.BlockSpec((1, k_pad), lambda: (0, 0)),
                pl.BlockSpec((1, 1), lambda: (0, 0)),
            ],
            out_shape=out_shape,
            scratch_shapes=[
                pltpu.VMEM((2, block_m, n_pad), xp.dtype),
                pltpu.SemaphoreType.DMA((2,)),
            ],
            interpret=interpret,
        )(*inputs)
    else:
        in_specs = [
            pl.BlockSpec((block_m, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((k_pad, n_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
        ]
        if int8:
            in_specs += [pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
                         pl.BlockSpec((1, n_pad), lambda i: (0, 0))]
        sums, counts, obj = pl.pallas_call(
            functools.partial(_fused_kernel, **kw),
            grid=(bm // block_m,),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((k_pad, n_pad), lambda i: (0, 0)),
                pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
                pl.BlockSpec((1, 1), lambda i: (0, 0)),
            ],
            out_shape=out_shape,
            interpret=interpret,
        )(*inputs)

    if int8:
        # Exact int32 sums in the scaled space -> f32 sums in data space.
        sums_f = sums[:k, :n].astype(jnp.float32) * qx.scale[None, :]
        return sums_f, counts[0, :k], obj[0, 0]
    return sums[:k, :n], counts[0, :k], obj[0, 0]


def _fused_batched_kernel(*args, m: int, block_m: int, block_k: int,
                          block_n: int, precision: str):
    """One (batch, point-tile) grid cell of the batched fused step."""
    (x_ref, c_ref, csq_ref, t_ref, scale_ref, sums_ref, counts_ref, obj_ref,
     _) = _unpack_fused_refs(args, precision)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _zero():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        obj_ref[...] = jnp.zeros_like(obj_ref)

    _fused_tile_accumulate(
        i, x_ref[0], c_ref[0], csq_ref[0],
        None if t_ref is None else t_ref[0],
        None if scale_ref is None else scale_ref[0],
        sums_ref, counts_ref, obj_ref, m=m, block_m=block_m, block_k=block_k,
        block_n=block_n, precision=precision, batched=True)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_n", "precision",
                     "interpret"),
)
def fused_step_batched_pallas(
    x,
    c: jax.Array,
    *,
    block_m: int = 256,
    block_k: int | None = None,
    block_n: int | None = None,
    precision: str = "f32",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x [B,m,n], c [B,k,n] -> (sums [B,k,n], counts [B,k], obj [B]).

    One ``pallas_call`` computes the per-chunk Lloyd statistics of all B
    streams: grid (B, m-tiles), with the batch as the outer grid dimension
    so each stream's accumulators are zeroed once and revisited in order.
    """
    px.check(precision)
    int8 = precision == "int8" or isinstance(x, px.QuantizedChunk)

    if int8:
        qx = px.as_quantized(x)
        batch, m, n = qx.q.shape
        k = c.shape[1]
        assert fits_batched(k, n), (k, n)
        csq = px.sqnorm(c)                  # [B, k] full-width
        cq, t = jax.vmap(px.quantize_centroids)(c, qx.scale)
        xs, cs = qx.q, cq
    else:
        batch, m, n = x.shape
        k = c.shape[1]
        assert fits_batched(k, n), (k, n)
        csq = px.sqnorm(c)                  # [B, k] f32, pre-cast view
        store = px.storage_dtype(precision)
        xs, cs = x.astype(store), c.astype(store)

    block_m = min(block_m, max(8, m))
    bm = -(-m // block_m) * block_m
    k_pad, n_pad, block_k, block_n = _batched_tiles(k, n, block_k, block_n)

    xp = _pad_to(_pad_to(xs, bm, 1), n_pad, 2)
    cp = _pad_to(_pad_to(cs, k_pad, 1), n_pad, 2)
    csqp = _pad_to(csq[:, None, :], k_pad, 2, value=_BIG)
    inputs = [xp, cp, csqp]
    in_specs = [
        pl.BlockSpec((1, block_m, n_pad), lambda b, i: (b, i, 0)),
        pl.BlockSpec((1, k_pad, n_pad), lambda b, i: (b, 0, 0)),
        pl.BlockSpec((1, 1, k_pad), lambda b, i: (b, 0, 0)),
    ]
    if int8:
        inputs += [_pad_to(t[:, None, :], k_pad, 2),
                   _pad_to(qx.scale[:, None, :], n_pad, 2)]
        in_specs += [pl.BlockSpec((1, 1, k_pad), lambda b, i: (b, 0, 0)),
                     pl.BlockSpec((1, 1, n_pad), lambda b, i: (b, 0, 0))]

    sums_dtype = jnp.int32 if int8 else jnp.float32
    sums, counts, obj = pl.pallas_call(
        functools.partial(_fused_batched_kernel, m=m, block_m=block_m,
                          block_k=block_k, block_n=block_n,
                          precision="int8" if int8 else precision),
        grid=(batch, bm // block_m),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, k_pad, n_pad), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, 1, k_pad), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, i: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, k_pad, n_pad), sums_dtype),
            jax.ShapeDtypeStruct((batch, 1, k_pad), jnp.float32),
            jax.ShapeDtypeStruct((batch, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs)

    if int8:
        sums_f = (sums[:, :k, :n].astype(jnp.float32)
                  * qx.scale[:, None, :])
        return sums_f, counts[:, 0, :k], obj[:, 0, 0]
    return sums[:, :k, :n], counts[:, 0, :k], obj[:, 0, 0]
