"""Fused Lloyd-iteration kernel: assignment + update + objective in ONE pass.

The two-kernel formulation streams the chunk from HBM twice per iteration
(assign reads X, update reads X again).  This kernel computes, per point
tile resident in VMEM:

    scores  = ||c||^2 - 2 x @ c^T          (MXU)
    idx     = argmin(scores)               (VPU)
    sums   += onehot(idx)^T @ x            (MXU, same resident tile)
    counts += colsum(onehot)
    obj    += sum(min_dist)

halving the dominant HBM traffic of Big-means' inner loop.  Constraints
(paper regime): k <= 128 (one lane tile), n <= 1024 (feature block fits
VMEM).  ``ops.fused_step`` falls back to the two-pass path outside that
envelope or when point weights are used.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = 1e30

MAX_K = 128
MAX_N = 1024


def _fused_kernel(x_ref, c_ref, csq_ref, sums_ref, counts_ref, obj_ref, *,
                  m: int, block_m: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _zero():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        obj_ref[...] = jnp.zeros_like(obj_ref)

    x = x_ref[...]                                           # [bm, n_pad]
    c = c_ref[...]                                           # [k_pad, n_pad]
    scores = csq_ref[...] - 2.0 * jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                  # [bm, k_pad]
    idx = jnp.argmin(scores, axis=1).astype(jnp.int32)       # [bm]
    xsq = jnp.sum(x * x, axis=1)                             # [bm]
    mind = jnp.maximum(jnp.min(scores, axis=1) + xsq, 0.0)

    rows = i * block_m + jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], 1), 0)
    valid = (rows < m).astype(jnp.float32)                   # [bm, 1]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], c.shape[0]), 1)
    onehot = (idx[:, None] == lanes).astype(jnp.float32) * valid

    sums_ref[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # [k_pad, n_pad]
    counts_ref[...] += jnp.sum(onehot, axis=0, keepdims=True)
    obj_ref[...] += jnp.sum(mind[:, None] * valid, keepdims=True)[0:1, 0:1]


def _pad_to(a, size, axis, value=0.0):
    pad = size - a.shape[axis]
    if pad <= 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def fits(k: int, n: int) -> bool:
    return k <= MAX_K and n <= MAX_N


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def fused_step_pallas(
    x: jax.Array,
    c: jax.Array,
    *,
    block_m: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x [m,n], c [k,n] -> (sums f32 [k,n], counts f32 [k], obj f32 scalar)."""
    m, n = x.shape
    k = c.shape[0]
    assert fits(k, n), (k, n)
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)

    block_m = min(block_m, max(8, m))
    bm = -(-m // block_m) * block_m
    n_pad = -(-n // 128) * 128
    k_pad = MAX_K

    xp = _pad_to(_pad_to(x, bm, 0), n_pad, 1)
    cp = _pad_to(_pad_to(c, k_pad, 0), n_pad, 1)
    csq = _pad_to(jnp.sum(c * c, axis=-1)[None, :], k_pad, 1, value=_BIG)

    sums, counts, obj = pl.pallas_call(
        functools.partial(_fused_kernel, m=m, block_m=block_m),
        grid=(bm // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, n_pad), lambda i: (0, 0) if False else (i, 0)),
            pl.BlockSpec((k_pad, n_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k_pad, n_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k_pad, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, k_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp, cp, csq)
    return sums[:k, :n], counts[0, :k], obj[0, 0]
