"""Fused Lloyd-iteration kernel: assignment + update + objective in ONE pass.

The two-kernel formulation streams the chunk from HBM twice per iteration
(assign reads X, update reads X again).  This kernel computes, per point
tile resident in VMEM:

    scores  = ||c||^2 - 2 x @ c^T          (MXU)
    idx     = argmin(scores)               (VPU)
    sums   += onehot(idx)^T @ x            (MXU, same resident tile)
    counts += colsum(onehot)
    obj    += sum(min_dist)

halving the dominant HBM traffic of Big-means' inner loop.

Mixed precision (``precision='bf16'``): the chunk and centroids are stored
and streamed bf16 — halving the remaining HBM bytes again — and both MXU
contractions take bf16 operands.  Everything that decides or accumulates is
f32: the score accumulator (``preferred_element_type``), ``||c||^2`` /
``||x||^2`` (computed from the full-width view before the storage cast),
sums, counts and the objective.  ``'bf16x3'`` keeps f32 storage and runs
each contraction as three compensated bf16 products (near-f32 numerics at
bf16 MXU rates; no bandwidth change).

Two variants:

* :func:`fused_step_pallas` — single chunk, paper-regime envelope
  (k <= 128: one lane tile; n <= 1024: feature block fits VMEM).
* :func:`fused_step_batched_pallas` — a leading batch-grid dimension runs B
  independent chunk streams in one launch, and the kernel tiles k (lane
  tiles of ``block_k`` with a running argmin across tiles) and n
  (contraction tiles) internally, widening the envelope to
  :func:`fits_batched`.

``ops.fused_step`` / ``ops.fused_step_batched`` fall back to the two-pass
path outside the envelope or when point weights are used.  Block sizes
default to the module constants; ``ops`` overrides them with autotuned
tilings (``repro.kernels.autotune``) — tile choice is perf-only and never
changes results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import precision as px

_BIG = 1e30

MAX_K = 128
MAX_N = 1024

# Batched-kernel envelope: k and n are tiled inside the kernel, so the wall
# is VMEM working set (c + sums blocks), not the lane width.
MAX_K_BATCHED = 1024
MAX_N_BATCHED = 4096
_MAX_KN_ELEMS = 1 << 20        # k_pad * n_pad <= 1M f32 (4 MB per block)

_BLOCK_K = 128                 # lane tile for the running argmin
_BLOCK_N = 512                 # contraction tile for the distance matmul


def _fused_kernel(x_ref, c_ref, csq_ref, sums_ref, counts_ref, obj_ref, *,
                  m: int, block_m: int, precision: str):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _zero():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        obj_ref[...] = jnp.zeros_like(obj_ref)

    x = x_ref[...]                                           # [bm, n_pad]
    c = c_ref[...]                                           # [k_pad, n_pad]
    scores = csq_ref[...] - 2.0 * px.dot(
        x, c, (((1,), (1,)), ((), ())), precision)           # [bm, k_pad] f32
    idx = jnp.argmin(scores, axis=1).astype(jnp.int32)       # [bm]
    xsq = px.sqnorm(x, axis=1)                               # [bm] f32
    mind = jnp.maximum(jnp.min(scores, axis=1) + xsq, 0.0)

    rows = i * block_m + jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], 1), 0)
    valid = (rows < m).astype(jnp.float32)                   # [bm, 1]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], c.shape[0]), 1)
    onehot = (idx[:, None] == lanes).astype(jnp.float32) * valid

    sums_ref[...] += px.dot(
        onehot, x, (((0,), (0,)), ((), ())), precision)      # [k_pad, n_pad]
    counts_ref[...] += jnp.sum(onehot, axis=0, keepdims=True)
    obj_ref[...] += jnp.sum(mind[:, None] * valid, keepdims=True)[0:1, 0:1]


def _pad_to(a, size, axis, value=0.0):
    pad = size - a.shape[axis]
    if pad <= 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def fits(k: int, n: int) -> bool:
    return k <= MAX_K and n <= MAX_N


def _batched_tiles(k: int, n: int, block_k: int | None = None,
                   block_n: int | None = None) -> tuple[int, int, int, int]:
    """(k_pad, n_pad, block_k, block_n) used by the batched kernel."""
    block_k = _BLOCK_K if block_k is None else block_k
    k_pad = -(-k // block_k) * block_k
    n_pad = -(-n // 128) * 128
    if block_n is None:
        block_n = n_pad if n_pad <= _BLOCK_N else _BLOCK_N
    n_pad = -(-n_pad // block_n) * block_n
    return k_pad, n_pad, block_k, block_n


def fits_batched(k: int, n: int) -> bool:
    k_pad, n_pad, _, _ = _batched_tiles(k, n)
    return (k <= MAX_K_BATCHED and n <= MAX_N_BATCHED
            and k_pad * n_pad <= _MAX_KN_ELEMS)


@functools.partial(
    jax.jit, static_argnames=("block_m", "precision", "interpret"))
def fused_step_pallas(
    x: jax.Array,
    c: jax.Array,
    *,
    block_m: int = 256,
    precision: str = "f32",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x [m,n], c [k,n] -> (sums f32 [k,n], counts f32 [k], obj f32 scalar)."""
    m, n = x.shape
    k = c.shape[0]
    assert fits(k, n), (k, n)
    px.check(precision)
    csq = px.sqnorm(c)                      # f32, from the full-width view
    store = px.storage_dtype(precision)
    x = x.astype(store)
    c = c.astype(store)

    block_m = min(block_m, max(8, m))
    bm = -(-m // block_m) * block_m
    n_pad = -(-n // 128) * 128
    k_pad = MAX_K

    xp = _pad_to(_pad_to(x, bm, 0), n_pad, 1)
    cp = _pad_to(_pad_to(c, k_pad, 0), n_pad, 1)
    csqp = _pad_to(csq[None, :], k_pad, 1, value=_BIG)

    sums, counts, obj = pl.pallas_call(
        functools.partial(_fused_kernel, m=m, block_m=block_m,
                          precision=precision),
        grid=(bm // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((k_pad, n_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k_pad, n_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k_pad, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, k_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp, cp, csqp)
    return sums[:k, :n], counts[0, :k], obj[0, 0]


def _fused_batched_kernel(x_ref, c_ref, csq_ref, sums_ref, counts_ref,
                          obj_ref, *, m: int, block_m: int, block_k: int,
                          block_n: int, precision: str):
    """One (batch, point-tile) grid cell of the batched fused step.

    k is processed in ``block_k`` lane tiles with a running (min, argmin)
    carried across tiles; the distance matmul contracts n in ``block_n``
    tiles.  Both loops are unrolled at trace time (tile counts are static).
    """
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _zero():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        obj_ref[...] = jnp.zeros_like(obj_ref)

    x = x_ref[0]                                             # [bm, n_pad]
    c = c_ref[0]                                             # [k_pad, n_pad]
    csq = csq_ref[0]                                         # [1, k_pad]
    bm, n_pad = x.shape
    k_pad = c.shape[0]
    nk, nn = k_pad // block_k, n_pad // block_n

    best = jnp.full((bm,), _BIG, jnp.float32)
    bidx = jnp.zeros((bm,), jnp.int32)
    for j in range(nk):
        ct = c[j * block_k:(j + 1) * block_k]                # [bk, n_pad]
        dots = jnp.zeros((bm, block_k), jnp.float32)
        for t in range(nn):
            sl = slice(t * block_n, (t + 1) * block_n)
            dots += px.dot(x[:, sl], ct[:, sl], (((1,), (1,)), ((), ())),
                           precision)
        sc = csq[0:1, j * block_k:(j + 1) * block_k] - 2.0 * dots
        tmin = jnp.min(sc, axis=1)
        targ = jnp.argmin(sc, axis=1).astype(jnp.int32) + j * block_k
        take = tmin < best
        best = jnp.where(take, tmin, best)
        bidx = jnp.where(take, targ, bidx)

    xsq = px.sqnorm(x, axis=1)
    mind = jnp.maximum(best + xsq, 0.0)
    rows = i * block_m + jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
    valid = (rows < m).astype(jnp.float32)                   # [bm, 1]

    for j in range(nk):
        lanes = (jax.lax.broadcasted_iota(jnp.int32, (bm, block_k), 1)
                 + j * block_k)
        onehot = (bidx[:, None] == lanes).astype(jnp.float32) * valid
        sums_ref[0, j * block_k:(j + 1) * block_k, :] += px.dot(
            onehot, x, (((0,), (0,)), ((), ())), precision)
        counts_ref[0, :, j * block_k:(j + 1) * block_k] += jnp.sum(
            onehot, axis=0, keepdims=True)
    obj_ref[...] += jnp.sum(
        mind[:, None] * valid, keepdims=True)[0:1, 0:1].reshape(1, 1, 1)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_n", "precision",
                     "interpret"),
)
def fused_step_batched_pallas(
    x: jax.Array,
    c: jax.Array,
    *,
    block_m: int = 256,
    block_k: int | None = None,
    block_n: int | None = None,
    precision: str = "f32",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x [B,m,n], c [B,k,n] -> (sums [B,k,n], counts [B,k], obj [B]).

    One ``pallas_call`` computes the per-chunk Lloyd statistics of all B
    streams: grid (B, m-tiles), with the batch as the outer grid dimension
    so each stream's accumulators are zeroed once and revisited in order.
    """
    batch, m, n = x.shape
    k = c.shape[1]
    assert fits_batched(k, n), (k, n)
    px.check(precision)
    csq = px.sqnorm(c)                      # [B, k] f32, pre-cast view
    store = px.storage_dtype(precision)
    x = x.astype(store)
    c = c.astype(store)

    block_m = min(block_m, max(8, m))
    bm = -(-m // block_m) * block_m
    k_pad, n_pad, block_k, block_n = _batched_tiles(k, n, block_k, block_n)

    xp = _pad_to(_pad_to(x, bm, 1), n_pad, 2)
    cp = _pad_to(_pad_to(c, k_pad, 1), n_pad, 2)
    csqp = _pad_to(csq[:, None, :], k_pad, 2, value=_BIG)

    sums, counts, obj = pl.pallas_call(
        functools.partial(_fused_batched_kernel, m=m, block_m=block_m,
                          block_k=block_k, block_n=block_n,
                          precision=precision),
        grid=(batch, bm // block_m),
        in_specs=[
            pl.BlockSpec((1, block_m, n_pad), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, k_pad, n_pad), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, 1, k_pad), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k_pad, n_pad), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, 1, k_pad), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, i: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, k_pad, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((batch, 1, k_pad), jnp.float32),
            jax.ShapeDtypeStruct((batch, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp, cp, csqp)
    return sums[:, :k, :n], counts[:, 0, :k], obj[:, 0, 0]
