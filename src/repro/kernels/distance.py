"""Fused nearest-centroid assignment kernel (the K-means hot spot).

TPU-native formulation: ``argmin_k ||x - c_k||^2`` is decomposed as
``argmin_k (||c_k||^2 - 2 x.c_k)`` so the dominant term is a matmul that runs
on the MXU; ``||x||^2`` is a per-point constant that is added back only for
the reported distance value.  The kernel tiles (points x centroids x
features) into VMEM blocks and keeps a running (min, argmin) accumulator in
VMEM scratch across centroid tiles, accumulating the dot product across
feature tiles.

Mixed precision (``precision='bf16'``): x and c are streamed bf16 — half the
HBM bytes of the bandwidth-bound hot loop — and the MXU contracts bf16
operands; the dot accumulator, ``||x||^2`` / ``||c||^2`` and the reported
distances stay f32 (``preferred_element_type``), so near-tie argmins are
decided on f32 scores.  ``'bf16x3'`` keeps f32 storage and splits each
operand into hi/lo bf16 halves for three compensated MXU products.

``'int8'`` streams the chunk as int8 codes + per-feature scales (a quarter
of the f32 bytes), re-quantizes centroids into the chunk's scaled feature
space with per-row scales ``t`` (so ``x.c_j ~= intdot(xq, cq_j) * t_j``),
contracts int8 x int8 -> int32 exactly, and assembles the score with the f32
correction terms (full-width ``||c||^2``, dequantized ``||x||^2``) — argmins
are still decided on f32 scores.

Grid: (point_tiles, centroid_tiles, feature_tiles), features innermost.
Block sizes default to the module constants; ``repro.kernels.ops`` overrides
them with autotuned tilings (``repro.kernels.autotune``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import precision as px

_NEG_INIT = 1e30  # large finite sentinel (avoids inf-inf traps in padding)


def _assign_kernel(
    x_ref,       # [bm, bf] storage dtype (f32 or bf16)
    c_ref,       # [bk, bf] storage dtype
    csq_ref,     # [1, bk]  f32 (padded centroids hold _NEG_INIT)
    id_ref,      # out [bm, 1] int32
    d_ref,       # out [bm, 1] f32
    acc_ref,     # scratch [bm, bk] f32: running -? dot accumulator
    xsq_ref,     # scratch [bm, 1] f32: running ||x||^2
    min_ref,     # scratch [bm, 1] f32
    arg_ref,     # scratch [bm, 1] int32
    *,
    block_k: int,
    precision: str,
):
    j = pl.program_id(1)
    l = pl.program_id(2)
    num_k = pl.num_programs(1)
    num_f = pl.num_programs(2)

    @pl.when(jnp.logical_and(j == 0, l == 0))
    def _init_point_tile():
        xsq_ref[...] = jnp.zeros_like(xsq_ref)
        min_ref[...] = jnp.full_like(min_ref, _NEG_INIT)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    @pl.when(l == 0)
    def _init_k_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    c = c_ref[...]
    acc_ref[...] += px.dot(x, c, (((1,), (1,)), ((), ())), precision)

    @pl.when(j == 0)
    def _accum_xsq():
        xsq_ref[...] += px.sqnorm(x, axis=1, keepdims=True)

    @pl.when(l == num_f - 1)
    def _reduce_k_tile():
        # score = ||c||^2 - 2 x.c  (constant ||x||^2 dropped for the argmin)
        score = csq_ref[...] - 2.0 * acc_ref[...]          # [bm, bk]
        tile_min = jnp.min(score, axis=1, keepdims=True)   # [bm, 1]
        tile_arg = jnp.argmin(score, axis=1).astype(jnp.int32)[:, None]
        better = tile_min < min_ref[...]
        arg_ref[...] = jnp.where(better, j * block_k + tile_arg, arg_ref[...])
        min_ref[...] = jnp.where(better, tile_min, min_ref[...])

        @pl.when(j == num_k - 1)
        def _finalize():
            id_ref[...] = arg_ref[...]
            d_ref[...] = jnp.maximum(min_ref[...] + xsq_ref[...], 0.0)


def _assign_kernel_q(
    x_ref,       # [bm, bf] int8 chunk codes
    c_ref,       # [bk, bf] int8 centroid codes (scaled feature space)
    csq_ref,     # [1, bk]  f32 full-width ||c||^2 (padded centroids: _NEG_INIT)
    t_ref,       # [1, bk]  f32 per-row centroid scales (padded: 0)
    scale_ref,   # [1, bf]  f32 per-feature chunk scales (padded: 0)
    id_ref,      # out [bm, 1] int32
    d_ref,       # out [bm, 1] f32
    acc_ref,     # scratch [bm, bk] int32: running integer dot (exact)
    xsq_ref,     # scratch [bm, 1] f32: running dequantized ||x||^2
    min_ref,     # scratch [bm, 1] f32
    arg_ref,     # scratch [bm, 1] int32
    *,
    block_k: int,
):
    j = pl.program_id(1)
    l = pl.program_id(2)
    num_k = pl.num_programs(1)
    num_f = pl.num_programs(2)

    @pl.when(jnp.logical_and(j == 0, l == 0))
    def _init_point_tile():
        xsq_ref[...] = jnp.zeros_like(xsq_ref)
        min_ref[...] = jnp.full_like(min_ref, _NEG_INIT)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    @pl.when(l == 0)
    def _init_k_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xq = x_ref[...]
    acc_ref[...] += px.intdot(xq, c_ref[...], (((1,), (1,)), ((), ())))

    @pl.when(j == 0)
    def _accum_xsq():
        deq = xq.astype(jnp.float32) * scale_ref[...]
        xsq_ref[...] += jnp.sum(deq * deq, axis=1, keepdims=True)

    @pl.when(l == num_f - 1)
    def _reduce_k_tile():
        # score = ||c||^2 - 2 x.c with the int32 dot scaled per column by t
        dots = acc_ref[...].astype(jnp.float32) * t_ref[...]
        score = csq_ref[...] - 2.0 * dots                  # [bm, bk]
        tile_min = jnp.min(score, axis=1, keepdims=True)   # [bm, 1]
        tile_arg = jnp.argmin(score, axis=1).astype(jnp.int32)[:, None]
        better = tile_min < min_ref[...]
        arg_ref[...] = jnp.where(better, j * block_k + tile_arg, arg_ref[...])
        min_ref[...] = jnp.where(better, tile_min, min_ref[...])

        @pl.when(j == num_k - 1)
        def _finalize():
            id_ref[...] = arg_ref[...]
            d_ref[...] = jnp.maximum(min_ref[...] + xsq_ref[...], 0.0)


def _pad_to(a: jax.Array, size: int, axis: int, value=0.0) -> jax.Array:
    pad = size - a.shape[axis]
    if pad <= 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_f", "precision", "interpret"),
)
def assign_pallas(
    x,
    c: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 128,
    block_f: int = 256,
    precision: str = "f32",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Pallas nearest-centroid assignment.  x [m,n], c [k,n] -> (ids, sqdist).

    ``x`` may be a plain array or (for ``precision='int8'``) a pre-quantized
    :class:`~repro.kernels.precision.QuantizedChunk`; plain arrays are
    quantized here with the canonical per-feature scheme.
    """
    px.check(precision)
    if precision == "int8" or isinstance(x, px.QuantizedChunk):
        return _assign_pallas_q(x, c, block_m=block_m, block_k=block_k,
                                block_f=block_f, interpret=interpret)
    m, n = x.shape
    k, n2 = c.shape
    assert n == n2, (x.shape, c.shape)
    # ||c||^2 in f32 from the full-width view, *before* any storage cast.
    csq = px.sqnorm(c)
    store = px.storage_dtype(precision)
    x = x.astype(store)
    c = c.astype(store)

    block_m = min(block_m, max(8, m))
    bm = -(-m // block_m) * block_m
    bk = -(-k // block_k) * block_k
    bf = -(-n // block_f) * block_f

    xp = _pad_to(_pad_to(x, bm, 0), bf, 1)
    cp = _pad_to(_pad_to(c, bk, 0), bf, 1)
    csqp = _pad_to(csq[None, :], bk, 1, value=_NEG_INIT)   # padded c never wins

    grid = (bm // block_m, bk // block_k, bf // block_f)
    ids, d = pl.pallas_call(
        functools.partial(_assign_kernel, block_k=block_k,
                          precision=precision),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_f), lambda i, j, l: (i, l)),
            pl.BlockSpec((block_k, block_f), lambda i, j, l: (j, l)),
            pl.BlockSpec((1, block_k), lambda i, j, l: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i, j, l: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j, l: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bm, 1), jnp.int32),
            jax.ShapeDtypeStruct((bm, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_m, block_k), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.int32),
        ],
        interpret=interpret,
    )(xp, cp, csqp)
    return ids[:m, 0], d[:m, 0]


def _assign_pallas_q(
    x,
    c: jax.Array,
    *,
    block_m: int,
    block_k: int,
    block_f: int,
    interpret: bool,
) -> tuple[jax.Array, jax.Array]:
    """int8 variant of :func:`assign_pallas` (traced inline under its jit)."""
    qx = px.as_quantized(x)
    m, n = qx.q.shape
    k, n2 = c.shape
    assert n == n2, (qx.q.shape, c.shape)
    csq = px.sqnorm(c)                       # full-width correction term
    cq, t = px.quantize_centroids(c, qx.scale)

    block_m = min(block_m, max(8, m))
    bm = -(-m // block_m) * block_m
    bk = -(-k // block_k) * block_k
    bf = -(-n // block_f) * block_f

    xp = _pad_to(_pad_to(qx.q, bm, 0), bf, 1)
    cp = _pad_to(_pad_to(cq, bk, 0), bf, 1)
    csqp = _pad_to(csq[None, :], bk, 1, value=_NEG_INIT)   # padded c never wins
    tp = _pad_to(t[None, :], bk, 1)
    scalep = _pad_to(qx.scale[None, :], bf, 1)

    grid = (bm // block_m, bk // block_k, bf // block_f)
    ids, d = pl.pallas_call(
        functools.partial(_assign_kernel_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_f), lambda i, j, l: (i, l)),
            pl.BlockSpec((block_k, block_f), lambda i, j, l: (j, l)),
            pl.BlockSpec((1, block_k), lambda i, j, l: (0, j)),
            pl.BlockSpec((1, block_k), lambda i, j, l: (0, j)),
            pl.BlockSpec((1, block_f), lambda i, j, l: (0, l)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i, j, l: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j, l: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bm, 1), jnp.int32),
            jax.ShapeDtypeStruct((bm, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_m, block_k), jnp.int32),
            pltpu.VMEM((block_m, 1), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.int32),
        ],
        interpret=interpret,
    )(xp, cp, csqp, tp, scalep)
    return ids[:m, 0], d[:m, 0]
