"""Block-size autotuner for the Pallas Lloyd-hot-path kernels.

The kernels historically ran with hardcoded tilings (``block_m=256``,
``_BLOCK_K=128``, ``_BLOCK_N=512``) — guesses that cannot be right for every
``(backend, batch, m, k, n, precision)`` point.  This module times a small
candidate set of tilings ONCE per shape key and caches the winner:

* **in-process** — a dict keyed by ``(kind, backend, B, m, k, n, precision)``;
* **on disk (optional)** — a JSON cache (``REPRO_AUTOTUNE_CACHE=/path.json``
  or :func:`set_cache_path`), so the one-time timing cost survives restarts
  and winners can be pinned/shipped per host type.

Tile choice is strictly perf-only: every candidate computes identical
(sums, counts, obj) — the accumulators are f32 and padding is masked — so
the tuner can never change results (asserted by tests/test_precision.py).

``repro.kernels.ops`` consults :func:`get_blocks` instead of the module
constants.  When tuning is disabled (the default — enable with
``REPRO_AUTOTUNE=1``, :func:`enable`, or ``BigMeansConfig(autotune=True)``)
the lookup falls through to cached winners if present, else the historical
defaults, without ever timing anything.

Caveat — tuning vs jit caches: block sizes are read at *trace* time and are
not part of any jit cache key, so winners only reach launches whose
enclosing jit entry point (``lloyd``, the drivers) is traced *after* the
cache is populated.  ``repro.api.fit(autotune=True)`` pre-tunes before its
strategy compiles, which covers the normal path; a shape that was already
compiled untuned earlier in the process keeps its existing (default-tiled)
executable until the trace cache is invalidated.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Callable

_DEFAULTS: dict[str, dict] = {
    "assign": {"block_m": 256, "block_k": 128, "block_f": 256},
    # None -> the kernel's shape-derived tile (see fused_step._batched_tiles)
    "fused": {"block_m": 256, "block_k": None, "block_n": None,
              "pipeline": "blocks"},
    "fused_batched": {"block_m": 256, "block_k": None, "block_n": None},
}

_lock = threading.RLock()
_cache: dict[str, dict] = {}          # key -> winning blocks
_loaded_paths: set[str] = set()
_enabled: bool = os.environ.get("REPRO_AUTOTUNE", "") not in ("", "0")
_cache_path: str | None = os.environ.get("REPRO_AUTOTUNE_CACHE") or None

_WARMUP, _REPS = 1, 3

# Observability: cache files that failed to load (corrupt JSON, stale or
# unknown schema) are *ignored*, never fatal — but each ignore is recorded
# here so drivers can surface it as a trace event instead of the cache
# silently reverting to defaults.
_events: list[tuple] = []


def events() -> list[tuple]:
    """Every cache-load anomaly this process has recorded, in order.

    Entries are ``("autotune_cache_ignored", path, reason)`` for whole-file
    rejects and ``("autotune_cache_entry_ignored", path, key)`` for
    malformed individual entries.  ``repro.api.fit`` drains new entries into
    the run trace.
    """
    return list(_events)


def _record_event(kind: str, *info) -> None:
    _events.append((kind,) + info)


def enable(on: bool = True) -> None:
    """Turn timing-based tuning on/off process-wide (lookups always work)."""
    global _enabled
    _enabled = bool(on)


def enabled() -> bool:
    return _enabled


def set_cache_path(path: str | os.PathLike | None) -> None:
    """Point the on-disk JSON cache at ``path`` (``None`` disables disk)."""
    global _cache_path
    _cache_path = None if path is None else os.fspath(path)


def cache_path() -> str | None:
    return _cache_path


def clear(disk: bool = False) -> None:
    """Drop every cached winner (and the disk cache file when ``disk``)."""
    with _lock:
        _cache.clear()
        _loaded_paths.clear()
        if disk and _cache_path and os.path.exists(_cache_path):
            os.remove(_cache_path)


def cache_key(kind: str, *, backend: str, b: int, m: int, k: int, n: int,
              precision: str) -> str:
    return f"{kind}|{backend}|b{b}|m{m}|k{k}|n{n}|{precision}"


def _valid_entry(blocks) -> bool:
    """A disk-cache entry ops can splat into a kernel call as kwargs."""
    if not isinstance(blocks, dict):
        return False
    return all(
        isinstance(name, str)
        and (val is None or isinstance(val, (int, str))
             and not isinstance(val, bool))
        for name, val in blocks.items())


def _load_disk() -> None:
    if not _cache_path or _cache_path in _loaded_paths:
        return
    _loaded_paths.add(_cache_path)
    try:
        with open(_cache_path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return                          # no cache yet: the normal first run
    except (OSError, ValueError) as exc:
        _record_event("autotune_cache_ignored", _cache_path,
                      f"unreadable: {type(exc).__name__}: {exc}")
        return
    if not isinstance(data, dict) or not isinstance(data.get("entries"), dict):
        _record_event("autotune_cache_ignored", _cache_path,
                      "not a cache object")
        return
    if data.get("version") != 1:
        _record_event("autotune_cache_ignored", _cache_path,
                      f"stale schema version {data.get('version')!r}")
        return
    for key, blocks in data["entries"].items():
        if not _valid_entry(blocks):
            _record_event("autotune_cache_entry_ignored", _cache_path, key)
            continue
        _cache.setdefault(key, blocks)


def _save_disk() -> None:
    if not _cache_path:
        return
    # Merge-on-write: re-read the file so concurrent processes sharing one
    # cache path keep each other's entries (this process's winners take
    # precedence); os.replace keeps each write atomic.
    merged: dict[str, dict] = {}
    try:
        with open(_cache_path) as f:
            merged.update(json.load(f).get("entries", {}))
    except (OSError, ValueError):
        pass
    merged.update(_cache)
    tmp = f"{_cache_path}.tmp.{os.getpid()}"
    payload = {"version": 1, "entries": dict(sorted(merged.items()))}
    d = os.path.dirname(_cache_path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, _cache_path)


def candidates(kind: str, *, b: int, m: int, k: int, n: int,
               precision: str) -> list[dict]:
    """The handful of tilings worth timing for this kernel kind + shape."""
    from repro.kernels import fused_step as fused

    out: list[dict] = []
    if kind == "fused":
        # Shape-derived default tiling first (see fused_batched below), then
        # lane/contraction tile variants x the two pipelines: 'blocks' (grid
        # streaming) vs 'dma' (double-buffered explicit copies) — the tuner
        # decides per backend whether compute/DMA overlap pays.
        _, _, bk0, bn0 = fused._batched_tiles(k, n)
        out.append({"block_m": 256, "block_k": bk0, "block_n": bn0,
                    "pipeline": "blocks"})
        for pipe in ("blocks", "dma"):
            for bm in (128, 256, 512):
                for bk, bn in ((bk0, bn0), (128, 256), (256, 512)):
                    cand = {"block_m": bm, "block_k": bk, "block_n": bn,
                            "pipeline": pipe}
                    if cand in out:
                        continue
                    k_pad, n_pad, _, _ = fused._batched_tiles(k, n, bk, bn)
                    if k_pad * n_pad > fused._MAX_KN_ELEMS:
                        continue
                    out.append(cand)
    elif kind == "fused_batched":
        # The shape-derived default tiling is candidate #0, so tuning can
        # never cache something slower than not tuning at all.
        _, _, bk0, bn0 = fused._batched_tiles(k, n)
        out.append({"block_m": 256, "block_k": bk0, "block_n": bn0})
        for bm in (128, 256, 512):
            for bk in (128, 256):
                for bn in (256, 512):
                    cand = {"block_m": bm, "block_k": bk, "block_n": bn}
                    if cand in out:
                        continue
                    k_pad, n_pad, _, _ = fused._batched_tiles(k, n, bk, bn)
                    if k_pad * n_pad > fused._MAX_KN_ELEMS:
                        continue
                    out.append(cand)
    elif kind == "assign":
        # Serving-shaped calls (small m, large k) need different tilings
        # from the training hot path: block_m candidates above the actual
        # point count collapse to one launch shape (assign_pallas clamps
        # to max(8, m), so they are deduped here), and once k exceeds one
        # centroid tile the [bm, bk] reduce amortizes over wider block_k.
        bms = sorted({min(bm, max(8, m)) for bm in (128, 256, 512)})
        bks = [bk for bk in (128, 256, 512) if bk == 128 or k > bk // 2]
        for bm in bms:
            for bk in bks:
                for bf in (256, 512):
                    out.append({"block_m": bm, "block_k": bk,
                                "block_f": bf})
    else:
        raise ValueError(f"unknown autotune kind {kind!r}")
    # Defaults first, so ties keep historic behaviour.  For the fused kinds
    # the "default" that must be timed first is the shape-derived tiling
    # prepended above (the _DEFAULTS entry holds unresolved Nones).
    head = (out[0],) if kind in ("fused", "fused_batched") \
        else (_DEFAULTS[kind],)
    out.sort(key=lambda blk: blk not in head)
    return out


def _time(run: Callable[[], object]) -> float:
    for _ in range(_WARMUP):
        run()                                  # compile + warm caches
    best = float("inf")
    for _ in range(_REPS):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    return best


def get_blocks(
    kind: str,
    bench_factory: Callable[[dict], Callable[[], object]] | None = None,
    *,
    backend: str,
    b: int,
    m: int,
    k: int,
    n: int,
    precision: str,
) -> dict:
    """The tiling ``ops`` should launch with for this kernel kind + shape.

    Resolution order: in-process cache -> on-disk cache -> (when tuning is
    enabled and a ``bench_factory`` is given) time the candidates once and
    cache the winner -> the historical defaults.  ``bench_factory(blocks)``
    must return a zero-arg callable that runs the kernel to completion
    (``jax.block_until_ready``); a candidate whose build or run raises is
    skipped, so an over-aggressive tiling can never take down the fit.
    """
    key = cache_key(kind, backend=backend, b=b, m=m, k=k, n=n,
                    precision=precision)
    with _lock:
        _load_disk()
        hit = _cache.get(key)
    if hit is not None:
        return dict(hit)
    if not _enabled or bench_factory is None:
        return dict(_DEFAULTS[kind])

    best_blocks, best_t = dict(_DEFAULTS[kind]), float("inf")
    for blocks in candidates(kind, b=b, m=m, k=k, n=n, precision=precision):
        try:
            t = _time(bench_factory(blocks))
        except Exception:
            continue
        if t < best_t:
            best_blocks, best_t = blocks, t
    with _lock:
        _cache[key] = dict(best_blocks)
        _save_disk()
    return dict(best_blocks)
