"""Centroid-update kernel: per-cluster sums and counts.

GPU codes use scatter-add for this step; TPUs execute scatters poorly.  The
TPU-native adaptation builds a one-hot membership tile in VMEM and contracts
it against the point tile on the MXU:

    sums[k_tile, f_tile] += onehot(ids_tile).T @ x_tile
    counts[k_tile]       += onehot(ids_tile).sum(axis=0)

Grid: (centroid_tiles, feature_tiles, point_tiles), points innermost, so the
output block stays resident in VMEM while the point stream flows through.

Mixed precision (``precision='bf16'``): the point stream is read as bf16
(half the HBM bytes) and the membership contraction runs bf16 on the MXU —
one-hot entries are 0/1, exactly representable — while sums and counts
accumulate f32.

``'int8'``: the point stream is int8 codes (a quarter of the f32 bytes) and
the one-hot — 0/1, int8-exact — contracts against the codes in int32, which
is *exact*; the int32 sums are scaled by the per-feature chunk scales after
the kernel.  Counts accumulate f32 as usual.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import precision as px


def _update_kernel(
    x_ref,        # [bm, bf] storage dtype (f32 or bf16)
    ids_ref,      # [bm, 1] int32 (padding rows hold -1)
    sums_ref,     # out [bk, bf] f32 (accumulated across point tiles)
    counts_ref,   # out [1, bk] f32
    *,
    block_k: int,
    precision: str,
):
    j = pl.program_id(0)   # centroid tile
    l = pl.program_id(1)   # feature tile
    i = pl.program_id(2)   # point tile

    @pl.when(i == 0)
    def _zero_out():
        sums_ref[...] = jnp.zeros_like(sums_ref)

        @pl.when(l == 0)
        def _zero_counts():
            counts_ref[...] = jnp.zeros_like(counts_ref)

    ids = ids_ref[...]                                       # [bm, 1]
    lane = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], block_k), 1)
    onehot = (ids == j * block_k + lane).astype(jnp.float32)  # [bm, bk]

    x = x_ref[...]
    sums_ref[...] += px.dot(onehot, x, (((0,), (0,)), ((), ())), precision)

    @pl.when(l == 0)
    def _accum_counts():
        counts_ref[...] += jnp.sum(onehot, axis=0, keepdims=True)


def _update_kernel_q(
    x_ref,        # [bm, bf] int8 chunk codes
    ids_ref,      # [bm, 1] int32 (padding rows hold -1)
    sums_ref,     # out [bk, bf] int32 (exact; scaled to f32 by the wrapper)
    counts_ref,   # out [1, bk] f32
    *,
    block_k: int,
):
    j = pl.program_id(0)   # centroid tile
    l = pl.program_id(1)   # feature tile
    i = pl.program_id(2)   # point tile

    @pl.when(i == 0)
    def _zero_out():
        sums_ref[...] = jnp.zeros_like(sums_ref)

        @pl.when(l == 0)
        def _zero_counts():
            counts_ref[...] = jnp.zeros_like(counts_ref)

    ids = ids_ref[...]                                       # [bm, 1]
    lane = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], block_k), 1)
    hit = ids == j * block_k + lane                          # [bm, bk]
    onehot = hit.astype(jnp.int8)

    sums_ref[...] += px.intdot(onehot, x_ref[...], (((0,), (0,)), ((), ())))

    @pl.when(l == 0)
    def _accum_counts():
        counts_ref[...] += jnp.sum(
            hit.astype(jnp.float32), axis=0, keepdims=True)


def _pad_to(a, size, axis, value=0):
    pad = size - a.shape[axis]
    if pad <= 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("k", "block_m", "block_k", "block_f", "precision",
                     "interpret"),
)
def update_pallas(
    x,
    ids: jax.Array,
    k: int,
    *,
    block_m: int = 256,
    block_k: int = 128,
    block_f: int = 256,
    precision: str = "f32",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """x [m,n], ids [m] int32 -> (sums f32 [k,n], counts f32 [k])."""
    px.check(precision)
    if precision == "int8" or isinstance(x, px.QuantizedChunk):
        return _update_pallas_q(x, ids, k, block_m=block_m, block_k=block_k,
                                block_f=block_f, interpret=interpret)
    m, n = x.shape
    x = x.astype(px.storage_dtype(precision))
    ids = ids.astype(jnp.int32)

    block_m = min(block_m, max(8, m))
    bm = -(-m // block_m) * block_m
    bk = -(-k // block_k) * block_k
    bf = -(-n // block_f) * block_f

    xp = _pad_to(_pad_to(x, bm, 0), bf, 1)
    idsp = _pad_to(ids[:, None], bm, 0, value=-1)            # padding never hits

    grid = (bk // block_k, bf // block_f, bm // block_m)
    sums, counts = pl.pallas_call(
        functools.partial(_update_kernel, block_k=block_k,
                          precision=precision),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_f), lambda j, l, i: (i, l)),
            pl.BlockSpec((block_m, 1), lambda j, l, i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_k, block_f), lambda j, l, i: (j, l)),
            pl.BlockSpec((1, block_k), lambda j, l, i: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bk, bf), jnp.float32),
            jax.ShapeDtypeStruct((1, bk), jnp.float32),
        ],
        interpret=interpret,
    )(xp, idsp)
    return sums[:k, :n], counts[0, :k]


def _update_pallas_q(
    x,
    ids: jax.Array,
    k: int,
    *,
    block_m: int,
    block_k: int,
    block_f: int,
    interpret: bool,
) -> tuple[jax.Array, jax.Array]:
    """int8 variant of :func:`update_pallas` (traced inline under its jit)."""
    qx = px.as_quantized(x)
    m, n = qx.q.shape
    ids = ids.astype(jnp.int32)

    block_m = min(block_m, max(8, m))
    bm = -(-m // block_m) * block_m
    bk = -(-k // block_k) * block_k
    bf = -(-n // block_f) * block_f

    xp = _pad_to(_pad_to(qx.q, bm, 0), bf, 1)
    idsp = _pad_to(ids[:, None], bm, 0, value=-1)            # padding never hits

    grid = (bk // block_k, bf // block_f, bm // block_m)
    sums, counts = pl.pallas_call(
        functools.partial(_update_kernel_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_f), lambda j, l, i: (i, l)),
            pl.BlockSpec((block_m, 1), lambda j, l, i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_k, block_f), lambda j, l, i: (j, l)),
            pl.BlockSpec((1, block_k), lambda j, l, i: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bk, bf), jnp.int32),
            jax.ShapeDtypeStruct((1, bk), jnp.float32),
        ],
        interpret=interpret,
    )(xp, idsp)
    # Exact int32 sums in the scaled space -> f32 sums in data space.
    sums_f = sums[:k, :n].astype(jnp.float32) * qx.scale[None, :]
    return sums_f, counts[0, :k]
