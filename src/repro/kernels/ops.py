"""Jit'd public wrappers around the K-means kernels.

Dispatch policy
---------------
``impl='auto'`` resolves to the compiled Pallas kernel on TPU backends and to
the pure-jnp reference elsewhere (this container is CPU-only; Pallas runs
there in interpret mode, which we reserve for tests).  Every wrapper accepts
``impl`` overrides:

* ``'pallas'``            — compiled Pallas (TPU target)
* ``'pallas_interpret'``  — Pallas interpret mode (CPU correctness testing)
* ``'ref'``               — single-shot jnp oracle
* ``'ref_chunked'``       — jnp oracle, lax.map over point blocks (bounds the
                            [m,k] distance-matrix working set for big m)

Every wrapper also takes ``precision`` (``'auto'`` | ``'f32'`` | ``'bf16'``
| ``'bf16x3'`` | ``'int8'``, see :mod:`repro.kernels.precision`): the
storage/MXU element type of the point stream (``'auto'`` follows the data
dtype).  Accumulators, norms and the objective are always f32, so the knob
trades bytes/FLOP precision without touching acceptance semantics.  Under
``'int8'`` the chunk argument may be a pre-quantized
:class:`~repro.kernels.precision.QuantizedChunk` (int8 codes + per-feature
scales — what the streaming engine ships); plain arrays are quantized at
kernel entry with the same deterministic scheme.

Pallas launches consult :mod:`repro.kernels.autotune` for their tile sizes
(keyed by backend, batch, shape and precision) instead of hardcoded module
constants; with tuning disabled this returns the historical defaults.

Graceful degradation: a Pallas dispatch that raises demotes that
``(op, impl, shape, precision)`` to the ref path once per process (recorded
in :func:`kernel_demotions`, surfaced as a ``RuntimeWarning`` and as
``("kernel_fallback", ...)`` trace events by ``repro.api.fit``) — a kernel
bug degrades a long run instead of killing it.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ref
from repro.kernels import precision as px
from repro.kernels.distance import assign_pallas
from repro.kernels.update import update_pallas

IMPLS = ("pallas", "pallas_interpret", "ref", "ref_chunked")
PRECISIONS = px.PRECISIONS

_DEFAULT_IMPL: str | None = None    # explicit override; None = auto-detect

# Graceful degradation: a Pallas dispatch that raises (lowering bug, tiling
# miss, backend quirk) demotes that (op, impl, shape, precision) to the ref
# path for the rest of the process — the run degrades instead of dying, and
# it happens ONCE per key, not once per chunk.  `kernel_demotions()` is the
# run-health surface (`repro.api.fit` turns new entries into
# ("kernel_fallback", ...) trace events).
_DEMOTIONS: dict[tuple, dict] = {}


def kernel_demotions() -> list[dict]:
    """Every Pallas→ref demotion this process has taken, in order."""
    return list(_DEMOTIONS.values())


def reset_kernel_demotions() -> None:
    """Forget recorded demotions (tests; a fixed backend mid-process)."""
    _DEMOTIONS.clear()


def record_demotion(op: str, impl: str, shape: tuple, precision: str,
                    exc: Exception) -> None:
    """Record a kernel failure observed *outside* the eager dispatch.

    Callers that run an op under their own ``jax.jit`` (the serving
    batcher) see Pallas failures escape at the outer compile, past the
    dispatch's try/except, so nothing demotes automatically.  When such a
    caller has classified the failure itself (e.g. repeated launch faults
    at one serving bucket), this records the same demotion the eager path
    would have taken: future eager dispatches at this key skip the Pallas
    path, and :func:`kernel_demotions` reflects it for run health.
    Idempotent per ``(op, impl, shape, precision)``.
    """
    if impl not in ("pallas", "pallas_interpret"):
        return
    key = (op, impl, tuple(shape), precision)
    if not _demoted(key):
        _demote(key, exc)


def _demoted(key: tuple) -> bool:
    return key in _DEMOTIONS


def _demote(key: tuple, exc: Exception) -> None:
    op = key[0]
    _DEMOTIONS[key] = {
        "op": op,
        "impl": key[1],
        "shape": key[2],
        "precision": key[3],
        "error": f"{type(exc).__name__}: {exc}",
    }
    warnings.warn(
        f"pallas {op} dispatch failed for shape {key[2]} "
        f"({key[1]}, {key[3]}); demoting to the ref path for this process: "
        f"{exc}", RuntimeWarning, stacklevel=3)


def default_impl() -> str:
    """The impl ``'auto'`` resolves to: the explicit override if one was set
    via :func:`set_default_impl`, else a fresh backend probe (never cached,
    so backend changes between calls are picked up)."""
    if _DEFAULT_IMPL is not None:
        return _DEFAULT_IMPL
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def set_default_impl(impl: str | None) -> None:
    """Override what ``'auto'`` resolves to; ``None`` restores auto-detection."""
    if impl is not None and impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; known: {IMPLS}")
    global _DEFAULT_IMPL
    _DEFAULT_IMPL = impl


def resolve_impl(impl: str | None = "auto") -> str:
    """Resolve an ``impl`` knob to a concrete kernel implementation.

    This is the one resolver every dispatch site (and the ``repro.api``
    facade) routes through: ``'auto'``/``None`` resolve via
    :func:`default_impl`, concrete names are validated and passed through.
    """
    if impl is None or impl == "auto":
        return default_impl()
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; known: ('auto',) + {IMPLS}")
    return impl


def _tune_backend(impl: str) -> str:
    """Autotune cache partition: interpret timings never leak into compiled
    entries (and vice versa)."""
    return "interpret" if impl == "pallas_interpret" else jax.default_backend()


def _bench(x, factory):
    """The autotune bench factory, or None inside a jit trace.

    Most call sites sit under ``jax.jit`` (lloyd, the drivers), where the
    operands are tracers: timing there would measure trace time and block
    on abstract values.  The tuner then falls back to cached winners /
    defaults; eager warm-up (``repro.api.fit`` pre-tunes with concrete
    arrays) is what populates the cache.
    """
    arr = x.q if isinstance(x, px.QuantizedChunk) else x
    return None if isinstance(arr, jax.core.Tracer) else factory


def assign(
    x: jax.Array,
    c: jax.Array,
    *,
    impl: str = "auto",
    precision: str = "auto",
    chunk: int = 65536,
) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment.  x [m,n], c [k,n] -> (ids i32 [m], d f32 [m])."""
    impl = resolve_impl(impl)
    precision = px.resolve(precision, x.dtype)
    if impl in ("pallas", "pallas_interpret"):
        dkey = ("assign", impl, (1, x.shape[0], c.shape[0], x.shape[1]),
                precision)
        if not _demoted(dkey):
            try:
                interp = impl == "pallas_interpret"
                blocks = autotune.get_blocks(
                    "assign",
                    _bench(x, lambda blk: lambda: jax.block_until_ready(
                        assign_pallas(x, c, precision=precision,
                                      interpret=interp, **blk))),
                    backend=_tune_backend(impl), b=1, m=x.shape[0],
                    k=c.shape[0], n=x.shape[1], precision=precision)
                return assign_pallas(x, c, precision=precision,
                                     interpret=interp, **blocks)
            except Exception as exc:
                _demote(dkey, exc)
        impl = "ref"                    # demoted shape: ref path below
    if impl == "ref":
        return ref.assign_ref(x, c, precision=precision)
    if impl == "ref_chunked":
        return _assign_chunked(x, c, chunk=chunk, precision=precision)
    raise ValueError(f"unknown impl {impl!r}")


@functools.partial(jax.jit, static_argnames=("chunk", "precision"))
def _assign_chunked(x, c, *, chunk, precision="f32"):
    m = x.shape[0]
    if m <= chunk:
        return ref.assign_ref(x, c, precision=precision)
    nblk = -(-m // chunk)
    pad = nblk * chunk - m
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    xb = xp.reshape(nblk, chunk, x.shape[1])
    ids, d = jax.lax.map(
        lambda xi: ref.assign_ref(xi, c, precision=precision), xb)
    return ids.reshape(-1)[:m], d.reshape(-1)[:m]


def warm_assign(
    m: int,
    k: int,
    n: int,
    *,
    impl: str = "auto",
    precision: str = "auto",
    dtype=jnp.float32,
) -> str:
    """Eagerly exercise the :func:`assign` dispatch at a concrete shape.

    Callers that run ``assign`` under their own ``jax.jit`` (the serving
    batcher, ``lloyd``'s epilogue) never hit the eager machinery: under a
    trace the autotune bench cannot time (``_bench`` returns None) and a
    Pallas *compile* failure surfaces at the outer jit's compile time —
    outside :func:`assign`'s try/except, so nothing demotes and the caller
    crashes.  ``fit()`` solves this for ``fused_step`` by pre-tuning with
    concrete arrays; this is the same move packaged for bare ``assign``:
    one cheap eager call at ``(m, k, n)`` consults/populates the autotune
    cache and, if the Pallas build fails, demotes exactly this
    serving-shaped key to the ref path — off the request path, once.

    Returns the impl the shape will actually run after warmup
    (``'ref'`` when the Pallas path demoted).
    """
    impl = resolve_impl(impl)
    x = jnp.zeros((m, n), dtype)
    c = jnp.zeros((k, n), dtype)
    prec = px.resolve(precision, x.dtype)
    jax.block_until_ready(assign(x, c, impl=impl, precision=prec))
    if impl in ("pallas", "pallas_interpret") and _demoted(
            ("assign", impl, (1, m, k, n), prec)):
        return "ref"
    return impl


def update(
    x: jax.Array,
    ids: jax.Array,
    k: int,
    *,
    weights: jax.Array | None = None,
    impl: str = "auto",
    precision: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Cluster sums/counts.  x [m,n], ids [m] -> (sums [k,n], counts [k])."""
    impl = resolve_impl(impl)
    precision = px.resolve(precision, x.dtype)
    if weights is not None:
        # Weighted path stays on the jnp oracle (cold path: coresets, K-means||).
        return ref.update_ref(x, ids, k, weights, precision=precision)
    if impl in ("pallas", "pallas_interpret"):
        dkey = ("update", impl, (1, x.shape[0], k, x.shape[1]), precision)
        if not _demoted(dkey):
            try:
                return update_pallas(x, ids, k, precision=precision,
                                     interpret=impl == "pallas_interpret")
            except Exception as exc:
                _demote(dkey, exc)
        impl = "ref"                    # demoted shape: ref path below
    if impl in ("ref", "ref_chunked"):
        return ref.update_ref(x, ids, k, precision=precision)
    raise ValueError(f"unknown impl {impl!r}")


def assign_and_update(
    x: jax.Array,
    c: jax.Array,
    *,
    weights: jax.Array | None = None,
    impl: str = "auto",
    precision: str = "auto",
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One fused Lloyd step's statistics: (ids, d, sums, counts)."""
    ids, d = assign(x, c, impl=impl, precision=precision)
    sums, counts = update(x, ids, c.shape[0], weights=weights, impl=impl,
                          precision=precision)
    return ids, d, sums, counts


def fused_step(
    x: jax.Array,
    c: jax.Array,
    *,
    weights: jax.Array | None = None,
    impl: str = "auto",
    precision: str = "auto",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One Lloyd iteration's (sums, counts, objective) — single-HBM-pass
    Pallas kernel when the (k, n) envelope fits, two-pass fallback
    otherwise."""
    from repro.kernels import fused_step as fused

    impl = resolve_impl(impl)
    precision = px.resolve(precision, x.dtype)
    k, n = c.shape[0], c.shape[1]
    if weights is None and fused.fits(k, n):
        if impl in ("pallas", "pallas_interpret"):
            dkey = ("fused", impl, (1, x.shape[0], k, n), precision)
            if not _demoted(dkey):
                try:
                    interp = impl == "pallas_interpret"
                    blocks = autotune.get_blocks(
                        "fused",
                        _bench(x, lambda blk: lambda: jax.block_until_ready(
                            fused.fused_step_pallas(
                                x, c, precision=precision, interpret=interp,
                                **blk))),
                        backend=_tune_backend(impl), b=1, m=x.shape[0], k=k,
                        n=n, precision=precision)
                    return fused.fused_step_pallas(
                        x, c, precision=precision, interpret=interp, **blocks)
                except Exception as exc:
                    _demote(dkey, exc)
            # demoted shape: the two-pass ref fallback below
    # Two-pass fallback (non-fused impls, weighted steps, or an envelope
    # miss).  Explicit ref impls are honored as-is — in particular
    # 'ref_chunked' keeps its bounded [chunk, k] distance working set for
    # big m — while the Pallas impls fall back to the plain oracle.
    fallback = impl if impl.startswith("ref") else "ref"
    ids, d = assign(x, c, impl=fallback, precision=precision)
    sums, counts = update(x, ids, k, weights=weights, impl=fallback,
                          precision=precision)
    obj = jnp.sum(d * weights) if weights is not None else jnp.sum(d)
    return sums, counts, obj


@functools.partial(jax.jit, static_argnames=("precision",))
def _fused_step_batched_ref(x, c, *, precision="f32"):
    """Batched two-pass oracle.

    ``lax.map`` over streams, not ``vmap``: the math per stream is
    identical (streams are independent), but mapping keeps each stream's
    [m, k] distance working set cache-resident on CPU, where the vmapped
    [B, m, k] intermediates are ~2.5x slower at paper-scale chunks.  The
    Pallas path gets its batch parallelism from the kernel grid instead.
    """

    def one(xc):
        xb, cb = xc
        ids, d = ref.assign_ref(xb, cb, precision=precision)
        sums, counts = ref.update_ref(xb, ids, cb.shape[0],
                                      precision=precision)
        return sums, counts, jnp.sum(d)

    return jax.lax.map(one, (x, c))


def fused_step_batched(
    x: jax.Array,
    c: jax.Array,
    *,
    impl: str = "auto",
    precision: str = "auto",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """B concurrent Lloyd iterations in one launch.

    x [B,m,n], c [B,k,n] -> (sums [B,k,n], counts [B,k], obj [B]).  Routes
    to the batched fused Pallas kernel inside its (wider, k/n-tiled)
    envelope; falls back to :func:`_fused_step_batched_ref` elsewhere — a
    ``lax.map`` (not ``vmap``) over the two-pass jnp oracle, which keeps
    each stream's [m, k] distance working set cache-resident on CPU (the
    vmapped [B, m, k] intermediates measured ~2.5x slower at paper-scale
    chunks; see its docstring).
    """
    from repro.kernels import fused_step as fused

    impl = resolve_impl(impl)
    precision = px.resolve(precision, x.dtype)
    batch, m = x.shape[0], x.shape[1]
    k, n = c.shape[1], c.shape[2]
    if fused.fits_batched(k, n):
        if impl in ("pallas", "pallas_interpret"):
            dkey = ("fused_batched", impl, (batch, m, k, n), precision)
            if not _demoted(dkey):
                try:
                    interp = impl == "pallas_interpret"
                    blocks = autotune.get_blocks(
                        "fused_batched",
                        _bench(x, lambda blk: lambda: jax.block_until_ready(
                            fused.fused_step_batched_pallas(
                                x, c, precision=precision, interpret=interp,
                                **blk))),
                        backend=_tune_backend(impl), b=batch, m=m, k=k, n=n,
                        precision=precision)
                    return fused.fused_step_batched_pallas(
                        x, c, precision=precision, interpret=interp, **blocks)
                except Exception as exc:
                    _demote(dkey, exc)
                # demoted shape: the batched two-pass oracle below
    return _fused_step_batched_ref(x, c, precision=precision)
