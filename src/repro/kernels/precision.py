"""Mixed-precision policy for the K-means kernel stack.

One ``precision`` knob is threaded through every kernel, oracle and driver:

* ``'f32'``    — everything float32 (the historical behaviour).
* ``'bf16'``   — inputs are *stored and streamed* as bfloat16 (half the HBM /
  host->device bytes of the bandwidth-bound chunk loop) and the distance /
  update contractions run bf16 x bf16 on the MXU.  Everything that decides
  or compares — accumulators, ``||c||^2`` / ``||x||^2`` norms, the objective,
  centroid updates, ``f_best`` acceptance — stays float32 via
  ``preferred_element_type``.
* ``'bf16x3'`` — compensated compute: operands stay f32 in storage and every
  contraction is decomposed into three bf16 products
  (``a.b ~= hi_a.hi_b + hi_a.lo_b + lo_a.hi_b`` with ``hi = bf16(a)``,
  ``lo = bf16(a - hi)``), recovering near-f32 accuracy at bf16 MXU rates.
  No bandwidth saving — it is a compute-precision option, used e.g. for the
  objective epilogue when bf16 rounding of f(C, X) itself is the concern.
* ``'int8'``   — chunk data is quantized once per chunk to int8 with
  per-feature scales (``s[f] = max_m |x[m,f]| / 127``) and streamed as a
  :class:`QuantizedChunk` at a quarter of the f32 bytes.  Centroids are
  re-quantized per Lloyd iteration *in the scaled feature space* with
  per-row scales ``t[j]`` so the distance contraction is a pure
  int8 x int8 -> int32 MXU matmul whose scale factors out per output
  column: ``x.c_j ~= (sum_f xq cq) * t[j]``.  The norm terms ``||c||^2``
  (full-width) and ``||x||^2`` (from the dequantized representation) stay
  f32 — the *correction term* that keeps distances honest.  As with bf16,
  the ``f_best`` acceptance objective is never evaluated through the
  quantized contraction: drivers keep a full-width copy for the epilogue
  (the bf16 f_best lesson, below).

The bf16 f_best lesson: ``||x||^2 - 2 x.c + ||c||^2`` cancels
catastrophically near the optimum, and the 0-clamp turns rounding noise
into a one-sided bias, so acceptance comparisons evaluated through reduced
contractions drift (~2.4% observed for bf16).  Every reduced-precision
policy therefore evaluates the accepting objective with f32 contractions;
a <1% drift test enforces it per policy.

The helpers here are pure jnp/lax so they are usable both from the jnp
oracles and *inside* Pallas kernel bodies.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

PRECISIONS = ("f32", "bf16", "bf16x3", "int8")

INT8_MAX = 127.0

# Smallest admissible quantization scale: guards the x/s division against
# all-zero features (warm-up zeros, constant columns) without perturbing any
# real scale (float32 tiny is ~1e-38).
_SCALE_FLOOR = 1e-30


def check(precision: str) -> str:
    """Validate and return a *concrete* ``precision``."""
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; known: {PRECISIONS}")
    return precision


def from_dtype(dtype) -> str:
    """The precision a raw array dtype implies (dtype-driven ``'auto'``)."""
    if dtype == jnp.bfloat16:
        return "bf16"
    if dtype == jnp.int8:
        return "int8"
    return "f32"


def resolve(precision: str | None, dtype) -> str:
    """Resolve a precision knob against the data dtype.

    ``'auto'`` / ``None`` follow the data (bf16 arrays contract in bf16, the
    historical behaviour; everything else is f32); concrete values are
    authoritative — ``'f32'`` up-casts bf16 data to full width, ``'bf16'``
    down-casts f32 storage.
    """
    if precision is None or precision == "auto":
        return from_dtype(dtype)
    return check(precision)


def storage_dtype(precision: str):
    """The dtype chunk data is stored/streamed in under a concrete policy.

    For ``'int8'`` the payload is a :class:`QuantizedChunk` (int8 codes +
    f32 per-feature scales); this returns the code dtype.
    """
    check(precision)
    if precision == "bf16":
        return jnp.bfloat16
    if precision == "int8":
        return jnp.int8
    return jnp.float32


def cast_storage(x, precision: str | None):
    """Cast data to its storage form under ``precision`` (auto-aware).

    Returns a plain array for the float policies and a
    :class:`QuantizedChunk` for ``'int8'`` (already-quantized input passes
    through unchanged).
    """
    if isinstance(x, QuantizedChunk):
        return x
    if resolve(precision, x.dtype) == "int8":
        return quantize_chunk(x)
    return x.astype(storage_dtype(resolve(precision, x.dtype)))


def host_dtype(precision: str | None):
    """The NumPy dtype a host-side chunk cast should request, or ``None``.

    ``'bf16'`` asks for ``ml_dtypes.bfloat16`` (a jax dependency;
    ``jax.device_put`` of such an array yields a device bf16 buffer with no
    further conversion) so the cast happens on the host and host->device
    transfers move half the bytes.  Every other policy returns ``None`` —
    "no explicit request", letting each data source serve its native
    dtype.
    """
    if precision == "bf16":
        import ml_dtypes
        import numpy as np

        return np.dtype(ml_dtypes.bfloat16)
    return None


def _split_bf16(a: jax.Array) -> tuple[jax.Array, jax.Array]:
    hi = a.astype(jnp.bfloat16)
    lo = (a - hi.astype(jnp.float32)).astype(jnp.bfloat16)
    return hi, lo


def dot(a: jax.Array, b: jax.Array, dimension_numbers, precision: str):
    """``lax.dot_general`` under the mixed-precision policy.

    Always accumulates and returns float32 (``preferred_element_type``); the
    knob only controls the operand element type fed to the MXU.  Under
    ``'bf16x3'``, operands that arrive as bf16 carry no low bits, so the
    compensation degrades gracefully to the plain bf16 product.
    """
    check(precision)
    if precision == "int8":
        raise ValueError(
            "px.dot has no generic int8 path: the per-feature/per-row scale "
            "algebra is contraction-specific. Use quantize_chunk / "
            "quantize_centroids / intdot explicitly (see ref.py oracles).")
    dg = lambda x, y: jax.lax.dot_general(  # noqa: E731
        x, y, dimension_numbers, preferred_element_type=jnp.float32)
    if precision == "f32":
        return dg(a.astype(jnp.float32), b.astype(jnp.float32))
    if precision == "bf16":
        return dg(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16))
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    ah, al = _split_bf16(a)
    bh, bl = _split_bf16(b)
    return dg(ah, bh) + dg(ah, bl) + dg(al, bh)


def sqnorm(a: jax.Array, axis=-1, keepdims: bool = False) -> jax.Array:
    """``sum(a*a)`` in f32 regardless of storage dtype (norms never bf16)."""
    a = a.astype(jnp.float32)
    return jnp.sum(a * a, axis=axis, keepdims=keepdims)


# ---------------------------------------------------------------------------
# int8 quantization scheme
# ---------------------------------------------------------------------------
#
# Chunk side (once per chunk, on host or at Lloyd entry):
#   s[f]  = max_m |x[m, f]| / 127          (per-feature, clamped away from 0)
#   xq    = round(x / s) in [-127, 127]    (int8 codes)
# Centroid side (per Lloyd iteration, cheap: k rows):
#   cs    = c * s                          (centroids in the scaled space)
#   t[j]  = max_f |cs[j, f]| / 127         (per-row, clamped)
#   cq    = round(cs / t) in [-127, 127]
# Then the distance contraction factors exactly per output column:
#   x . c_j  ~=  (sum_f xq[m,f] cq[j,f]) * t[j]        (int8 matmul -> int32)
# and ||x||^2 / ||c||^2 stay f32 (the correction term): ||c||^2 from the
# full-width centroids, ||x||^2 from the dequantized codes (the values the
# contraction actually sees), so the assembled distance is the honest
# distance of the quantized representation — bitwise reproducible between
# the jnp oracle and the Pallas kernel on integer data.


class QuantizedChunk(NamedTuple):
    """An int8-quantized chunk: codes plus per-feature scales.

    ``q`` is int8 ``[..., m, n]``; ``scale`` is f32 ``[..., n]`` (one scale
    per feature, broadcast over points; batched chunks carry one scale row
    per stream).  NamedTuples are jax pytrees, so a QuantizedChunk passes
    through ``jit`` / ``lax.map`` / ``device_put`` like an array pair.
    """

    q: jax.Array
    scale: jax.Array

    @property
    def shape(self):
        return self.q.shape

    @property
    def dtype(self):
        return self.q.dtype

    @property
    def ndim(self):
        return self.q.ndim


def feature_scales(x: jax.Array, axis: int = -2) -> jax.Array:
    """Per-feature quantization scales ``max|x|/127`` over the points axis."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis)
    return jnp.maximum(absmax / INT8_MAX, _SCALE_FLOOR)


def quantize_chunk(x: jax.Array) -> "QuantizedChunk":
    """Quantize a chunk ``[..., m, n]`` to int8 codes + per-feature scales."""
    x = x.astype(jnp.float32)
    scale = feature_scales(x)                                 # [..., n]
    q = jnp.clip(jnp.round(x / scale[..., None, :]), -INT8_MAX, INT8_MAX)
    return QuantizedChunk(q.astype(jnp.int8), scale)


def as_quantized(x) -> "QuantizedChunk":
    """Coerce a chunk to its quantized form (idempotent)."""
    return x if isinstance(x, QuantizedChunk) else quantize_chunk(x)


def dequantize(qx: "QuantizedChunk") -> jax.Array:
    """Reconstruct the f32 values the int8 contraction actually sees."""
    return qx.q.astype(jnp.float32) * qx.scale[..., None, :]


def quantize_centroids(c: jax.Array,
                       scale: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Quantize centroids ``[k, n]`` into the chunk's scaled feature space.

    Returns ``(cq int8 [k, n], t f32 [k])`` with
    ``c[j] . x[m] ~= (cq[j] . xq[m]) * t[j]`` — the per-row scale ``t``
    factors out of the int8 contraction per output column.
    """
    cs = c.astype(jnp.float32) * scale[None, :]               # scaled space
    t = jnp.maximum(jnp.max(jnp.abs(cs), axis=-1) / INT8_MAX, _SCALE_FLOOR)
    cq = jnp.clip(jnp.round(cs / t[:, None]), -INT8_MAX, INT8_MAX)
    return cq.astype(jnp.int8), t


def intdot(a: jax.Array, b: jax.Array, dimension_numbers) -> jax.Array:
    """int8 x int8 ``dot_general`` accumulating in int32 (exact).

    With ``|q| <= 127`` a product is at most 16129, so contractions up to
    ~133k elements fit int32 — far beyond any feature width here.
    """
    return jax.lax.dot_general(
        a.astype(jnp.int8), b.astype(jnp.int8), dimension_numbers,
        preferred_element_type=jnp.int32)


def host_quantize(arr) -> tuple:
    """NumPy twin of :func:`quantize_chunk` for the host prefetch thread.

    Returns ``(q int8 [m, n], scale f32 [n])`` computed with the same
    round-half-to-even semantics, so host-quantized and device-quantized
    chunks are bitwise identical.  Shipping int8 codes + one f32 scale row
    moves ~a quarter of the f32 host->device bytes.
    """
    import numpy as np

    arr = np.asarray(arr, dtype=np.float32)
    scale = np.maximum(np.abs(arr).max(axis=-2) / INT8_MAX, _SCALE_FLOOR)
    scale = scale.astype(np.float32)
    q = np.clip(np.round(arr / scale[..., None, :]), -INT8_MAX, INT8_MAX)
    return q.astype(np.int8), scale
