"""Mixed-precision policy for the K-means kernel stack.

One ``precision`` knob is threaded through every kernel, oracle and driver:

* ``'f32'``    — everything float32 (the historical behaviour).
* ``'bf16'``   — inputs are *stored and streamed* as bfloat16 (half the HBM /
  host->device bytes of the bandwidth-bound chunk loop) and the distance /
  update contractions run bf16 x bf16 on the MXU.  Everything that decides
  or compares — accumulators, ``||c||^2`` / ``||x||^2`` norms, the objective,
  centroid updates, ``f_best`` acceptance — stays float32 via
  ``preferred_element_type``.
* ``'bf16x3'`` — compensated compute: operands stay f32 in storage and every
  contraction is decomposed into three bf16 products
  (``a.b ~= hi_a.hi_b + hi_a.lo_b + lo_a.hi_b`` with ``hi = bf16(a)``,
  ``lo = bf16(a - hi)``), recovering near-f32 accuracy at bf16 MXU rates.
  No bandwidth saving — it is a compute-precision option, used e.g. for the
  objective epilogue when bf16 rounding of f(C, X) itself is the concern.

The helpers here are pure jnp/lax so they are usable both from the jnp
oracles and *inside* Pallas kernel bodies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

PRECISIONS = ("f32", "bf16", "bf16x3")


def check(precision: str) -> str:
    """Validate and return a *concrete* ``precision``."""
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; known: {PRECISIONS}")
    return precision


def from_dtype(dtype) -> str:
    """The precision a raw array dtype implies (dtype-driven ``'auto'``)."""
    return "bf16" if dtype == jnp.bfloat16 else "f32"


def resolve(precision: str | None, dtype) -> str:
    """Resolve a precision knob against the data dtype.

    ``'auto'`` / ``None`` follow the data (bf16 arrays contract in bf16, the
    historical behaviour; everything else is f32); concrete values are
    authoritative — ``'f32'`` up-casts bf16 data to full width, ``'bf16'``
    down-casts f32 storage.
    """
    if precision is None or precision == "auto":
        return from_dtype(dtype)
    return check(precision)


def storage_dtype(precision: str):
    """The dtype chunk data is stored/streamed in under a concrete policy."""
    check(precision)
    return jnp.bfloat16 if precision == "bf16" else jnp.float32


def cast_storage(x: jax.Array, precision: str | None) -> jax.Array:
    """Cast an array to its storage dtype under ``precision`` (auto-aware)."""
    return x.astype(storage_dtype(resolve(precision, x.dtype)))


def host_dtype(precision: str | None):
    """The NumPy dtype a host-side chunk cast should request, or ``None``.

    ``'bf16'`` asks for ``ml_dtypes.bfloat16`` (a jax dependency;
    ``jax.device_put`` of such an array yields a device bf16 buffer with no
    further conversion) so the cast happens on the host and host->device
    transfers move half the bytes.  Every other policy returns ``None`` —
    "no explicit request", letting each data source serve its native
    dtype.
    """
    if precision == "bf16":
        import ml_dtypes
        import numpy as np

        return np.dtype(ml_dtypes.bfloat16)
    return None


def _split_bf16(a: jax.Array) -> tuple[jax.Array, jax.Array]:
    hi = a.astype(jnp.bfloat16)
    lo = (a - hi.astype(jnp.float32)).astype(jnp.bfloat16)
    return hi, lo


def dot(a: jax.Array, b: jax.Array, dimension_numbers, precision: str):
    """``lax.dot_general`` under the mixed-precision policy.

    Always accumulates and returns float32 (``preferred_element_type``); the
    knob only controls the operand element type fed to the MXU.  Under
    ``'bf16x3'``, operands that arrive as bf16 carry no low bits, so the
    compensation degrades gracefully to the plain bf16 product.
    """
    check(precision)
    dg = lambda x, y: jax.lax.dot_general(  # noqa: E731
        x, y, dimension_numbers, preferred_element_type=jnp.float32)
    if precision == "f32":
        return dg(a.astype(jnp.float32), b.astype(jnp.float32))
    if precision == "bf16":
        return dg(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16))
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    ah, al = _split_bf16(a)
    bh, bl = _split_bf16(b)
    return dg(ah, bh) + dg(ah, bl) + dg(al, bh)


def sqnorm(a: jax.Array, axis=-1, keepdims: bool = False) -> jax.Array:
    """``sum(a*a)`` in f32 regardless of storage dtype (norms never bf16)."""
    a = a.astype(jnp.float32)
    return jnp.sum(a * a, axis=axis, keepdims=keepdims)
