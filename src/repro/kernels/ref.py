"""Pure-jnp oracles for the Pallas kernels.

These are the semantic ground truth: every Pallas kernel in this package has
an ``*_ref`` twin here and tests assert allclose between the two across shape
and dtype sweeps. They are also the production path on non-TPU backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_sqdist_ref(x: jax.Array, c: jax.Array,
                        x2: jax.Array | None = None) -> jax.Array:
    """Squared euclidean distances between rows of x [m,n] and c [k,n] -> [m,k].

    Accumulation is always fp32; if the *data* arrives in bf16 the dominant
    matmul reads it at half the bytes (mixed-precision streaming — §Perf
    cluster cell).  ``x2`` (optional [m,1]) lets callers hoist the point
    norms out of loops that probe many candidate centroid sets (K-means++
    seeding reads the chunk once per slot instead of twice)."""
    if x.dtype == jnp.bfloat16:
        xd, cd = x, c.astype(jnp.bfloat16)
    else:
        xd, cd = x.astype(jnp.float32), c.astype(jnp.float32)
    if x2 is None:
        x2 = jnp.sum(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    c2 = jnp.sum(jnp.square(c.astype(jnp.float32)), axis=-1)[None, :]
    dots = jax.lax.dot_general(
        xd, cd, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d = x2 - 2.0 * dots + c2
    return jnp.maximum(d, 0.0)


def assign_ref(x: jax.Array, c: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment.

    Returns (ids int32 [m], sq_dist f32 [m]).
    """
    d = pairwise_sqdist_ref(x, c)
    ids = jnp.argmin(d, axis=1).astype(jnp.int32)
    mind = jnp.min(d, axis=1)
    return ids, mind


def update_ref(
    x: jax.Array,
    ids: jax.Array,
    k: int,
    weights: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Centroid-update statistics: per-cluster feature sums and counts.

    Returns (sums f32 [k,n], counts f32 [k]).  ``ids`` entries outside
    [0, k) contribute nothing (used for padding).  bf16 data is read at
    half bytes; accumulation stays fp32.
    """
    xd = x if x.dtype == jnp.bfloat16 else x.astype(jnp.float32)
    onehot = jax.nn.one_hot(ids, k, dtype=xd.dtype)        # [m,k]; oob -> 0s
    if weights is not None:
        onehot = onehot * weights.astype(onehot.dtype)[:, None]
    sums = jax.lax.dot_general(
        onehot, xd, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # [k,n]
    counts = jnp.sum(onehot.astype(jnp.float32), axis=0)   # [k]
    return sums, counts


def min_update_ref(d: jax.Array, x: jax.Array, c_new: jax.Array) -> jax.Array:
    """K-means++ distance relaxation: d <- min(d, ||x - c_new||^2).

    d [m], x [m,n], c_new [n] -> [m].
    """
    x = x.astype(jnp.float32)
    c_new = c_new.astype(jnp.float32)
    diff = x - c_new[None, :]
    d_new = jnp.sum(diff * diff, axis=-1)
    return jnp.minimum(d, d_new)
