"""Pure-jnp oracles for the Pallas kernels.

These are the semantic ground truth: every Pallas kernel in this package has
an ``*_ref`` twin here and tests assert allclose between the two across shape
and dtype sweeps. They are also the production path on non-TPU backends.

All oracles take a ``precision`` knob (see :mod:`repro.kernels.precision`):
``None`` infers it from the data dtype (bf16 arrays contract in bf16, the
historical behaviour), a concrete value forces the policy.  Accumulation —
norms, sums, counts, objective — is always float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import precision as px


def pairwise_sqdist_ref(x, c: jax.Array,
                        x2: jax.Array | None = None,
                        *, precision: str | None = None) -> jax.Array:
    """Squared euclidean distances between rows of x [m,n] and c [k,n] -> [m,k].

    The dominant matmul runs under the ``precision`` policy (bf16 data at
    half the bytes, optional bf16x3 compensation); ``||x||^2`` / ``||c||^2``
    are always f32.  ``x2`` (optional [m,1]) lets callers hoist the point
    norms out of loops that probe many candidate centroid sets (K-means++
    seeding reads the chunk once per slot instead of twice).

    Under ``'int8'`` (or when ``x`` arrives as a
    :class:`~repro.kernels.precision.QuantizedChunk`) the contraction is the
    int8 x int8 -> int32 scheme of :mod:`repro.kernels.precision`: per-feature
    chunk scales, centroids re-quantized in the scaled space with per-row
    scales, and the f32 norm correction term (``||c||^2`` full-width,
    ``||x||^2`` from the dequantized codes)."""
    prec = px.from_dtype(x.dtype) if precision is None else px.check(precision)
    if prec == "int8":
        qx = px.as_quantized(x)
        cq, t = px.quantize_centroids(c, qx.scale)
        if x2 is None:
            x2 = px.sqnorm(px.dequantize(qx), keepdims=True)
        c2 = px.sqnorm(c)[None, :]
        idots = px.intdot(qx.q, cq, (((1,), (1,)), ((), ())))   # [m,k] i32
        dots = idots.astype(jnp.float32) * t[None, :]
        # Associate as (c2 - 2 dots) + x2: the order the Pallas kernels use
        # (score assembled per k-tile, ||x||^2 added at the end), so oracle
        # and kernel agree bitwise, not just to rounding.
        return jnp.maximum((c2 - 2.0 * dots) + x2, 0.0)
    if x2 is None:
        x2 = px.sqnorm(x, keepdims=True)
    c2 = px.sqnorm(c)[None, :]
    dots = px.dot(x, c, (((1,), (1,)), ((), ())), prec)
    d = x2 - 2.0 * dots + c2
    return jnp.maximum(d, 0.0)


def assign_ref(x, c: jax.Array,
               *, precision: str | None = None) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment.

    Returns (ids int32 [m], sq_dist f32 [m]).
    """
    d = pairwise_sqdist_ref(x, c, precision=precision)
    ids = jnp.argmin(d, axis=1).astype(jnp.int32)
    mind = jnp.min(d, axis=1)
    return ids, mind


def update_ref(
    x,
    ids: jax.Array,
    k: int,
    weights: jax.Array | None = None,
    *,
    precision: str | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Centroid-update statistics: per-cluster feature sums and counts.

    Returns (sums f32 [k,n], counts f32 [k]).  ``ids`` entries outside
    [0, k) contribute nothing (used for padding).  bf16 data is read at
    half bytes; accumulation stays fp32 (one-hot entries are 0/1, exactly
    representable in bf16, so the membership operand loses nothing).

    Under ``'int8'`` the unweighted one-hot is 0/1 — int8-exact — so the
    sums contraction is onehot x codes in int32 (exact), scaled by the
    per-feature chunk scales afterwards.  A weighted update has non-integer
    membership and falls back to f32 math on the dequantized codes (cold
    path: only baselines weight updates).
    """
    prec = px.from_dtype(x.dtype) if precision is None else px.check(precision)
    if prec == "int8":
        qx = px.as_quantized(x)
        if weights is not None:
            return update_ref(px.dequantize(qx), ids, k, weights,
                              precision="f32")
        onehot = jax.nn.one_hot(ids, k, dtype=jnp.int8)       # [m,k]; 0/1
        isums = px.intdot(onehot, qx.q, (((0,), (0,)), ((), ())))  # [k,n] i32
        sums = isums.astype(jnp.float32) * qx.scale[None, :]
        counts = jnp.sum(onehot.astype(jnp.float32), axis=0)
        return sums, counts
    onehot = jax.nn.one_hot(ids, k, dtype=jnp.float32)     # [m,k]; oob -> 0s
    if weights is not None:
        onehot = onehot * weights.astype(jnp.float32)[:, None]
    sums = px.dot(onehot, x, (((0,), (0,)), ((), ())), prec)  # [k,n] f32
    counts = jnp.sum(onehot, axis=0)                          # [k]
    return sums, counts


def min_update_ref(d: jax.Array, x: jax.Array, c_new: jax.Array) -> jax.Array:
    """K-means++ distance relaxation: d <- min(d, ||x - c_new||^2).

    d [m], x [m,n], c_new [n] -> [m].
    """
    x = x.astype(jnp.float32)
    c_new = c_new.astype(jnp.float32)
    diff = x - c_new[None, :]
    d_new = jnp.sum(diff * diff, axis=-1)
    return jnp.minimum(d, d_new)
