"""K-means++ candidate-probe kernel: relaxed distances + potentials, fused.

Each greedy K-means++ step evaluates L candidate seeds: for every point,
``d_new = min(d, ||x - cand_l||^2)`` and the per-candidate potential
``sum_x d_new``.  The jnp path materializes the [m, L] candidate-distance
matrix and re-reads it for the min and the sum; this kernel streams the
chunk once per step, computing the distance tile, the relaxed minimum and
the potential column-sums in VMEM.

Grid: (point_tiles,).  Candidates padded to the 128-lane tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = 1e30
MAX_L = 128
MAX_N = 1024


def _kpp_kernel(x_ref, c_ref, csq_ref, d_ref, newd_ref, pot_ref, *,
                m: int, block_m: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _zero():
        pot_ref[...] = jnp.zeros_like(pot_ref)

    x = x_ref[...]                                           # [bm, n_pad]
    c = c_ref[...]                                           # [L_pad, n_pad]
    d = d_ref[...]                                           # [bm, 1]
    xsq = jnp.sum(x * x, axis=1, keepdims=True)              # [bm, 1]
    dc = csq_ref[...] - 2.0 * jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) + xsq            # [bm, L_pad]
    dc = jnp.maximum(dc, 0.0)
    newd = jnp.minimum(d, dc)                                # relaxed dists

    rows = i * block_m + jax.lax.broadcasted_iota(
        jnp.int32, (x.shape[0], 1), 0)
    valid = (rows < m).astype(jnp.float32)
    newd_ref[...] = newd
    pot_ref[...] += jnp.sum(newd * valid, axis=0, keepdims=True)


def _pad_to(a, size, axis, value=0.0):
    pad = size - a.shape[axis]
    if pad <= 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def fits(l: int, n: int) -> bool:
    return l <= MAX_L and n <= MAX_N


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def kpp_probe_pallas(
    x: jax.Array,
    cands: jax.Array,
    d: jax.Array,
    *,
    block_m: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """x [m,n], cands [L,n], d f32 [m] -> (newd f32 [m,L], potentials f32 [L])."""
    m, n = x.shape
    L = cands.shape[0]
    assert fits(L, n), (L, n)
    x = x.astype(jnp.float32)
    cands = cands.astype(jnp.float32)

    block_m = min(block_m, max(8, m))
    bm = -(-m // block_m) * block_m
    n_pad = -(-n // 128) * 128
    L_pad = MAX_L

    xp = _pad_to(_pad_to(x, bm, 0), n_pad, 1)
    cp = _pad_to(_pad_to(cands, L_pad, 0), n_pad, 1)
    csq = _pad_to(jnp.sum(cands * cands, axis=-1)[None, :], L_pad, 1,
                  value=_BIG)
    dp = _pad_to(d.astype(jnp.float32)[:, None], bm, 0)

    newd, pot = pl.pallas_call(
        functools.partial(_kpp_kernel, m=m, block_m=block_m),
        grid=(bm // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((L_pad, n_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, L_pad), lambda i: (0, 0)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, L_pad), lambda i: (i, 0)),
            pl.BlockSpec((1, L_pad), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bm, L_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, L_pad), jnp.float32),
        ],
        interpret=interpret,
    )(xp, cp, csq, dp)
    return newd[:m, :L], pot[0, :L]
