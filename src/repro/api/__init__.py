"""`repro.api` — the single public entry point for Big-means clustering.

One config (:class:`BigMeansConfig`), one :func:`fit`, pluggable data
sources and driver strategies::

    from repro.api import fit

    result = fit(X, k=25, s=16384, n_chunks=100)          # auto strategy
    result = fit(X, cfg, method="batched")                # explicit strategy
    result = fit("data.npy", cfg, method="streaming")     # out-of-core
    result = fit(X, cfg, method="kmeanspp")               # §5 baseline

Every call returns a :class:`FitResult` — Big-means strategies and §5
baselines alike — so algorithms are compared through one interface.  The
low-level drivers (``repro.core.bigmeans``, ``repro.cluster.runner``) stay
importable, but documented usage goes through this facade.
"""
from __future__ import annotations

import time

import jax

from repro.api import baselines as baselines
from repro.api import sources as sources
from repro.api import strategies as strategies
from repro.api.baselines import get_baseline, list_baselines, register_baseline
from repro.api.config import BigMeansConfig
from repro.api.result import FitResult
from repro.api.sources import (
    ArraySource,
    DataSource,
    IteratorSource,
    MemmapSource,
    ProviderSource,
    as_source,
)
from repro.api.strategies import (
    get_strategy,
    list_strategies,
    register_strategy,
    resolve_auto,
)
from repro.cluster.runner import EndOfStream

# The declarative execution-placement spec (BigMeansConfig.topology) is part
# of the public fitting surface.
from repro.engine.topology import TopologySpec

# Synthetic-data helpers re-exported so examples and smoke tests can run off
# `repro.api` imports alone.
from repro.data import synthetic as synthetic

# The assignment-serving subsystem (see repro.serve): training produces the
# centroids, serve() is how their value is realized at assignment time.
from repro.serve import ServeConfig, Server, serve

__all__ = [
    "ArraySource",
    "BigMeansConfig",
    "DataSource",
    "EndOfStream",
    "FitResult",
    "IteratorSource",
    "MemmapSource",
    "ProviderSource",
    "as_source",
    "baselines",
    "evaluate",
    "fit",
    "get_baseline",
    "get_strategy",
    "list_baselines",
    "list_methods",
    "list_strategies",
    "register_baseline",
    "register_strategy",
    "resolve_auto",
    "serve",
    "ServeConfig",
    "Server",
    "TopologySpec",
    "sources",
    "strategies",
    "synthetic",
]


def _pretune(cfg: BigMeansConfig, source) -> None:
    """Populate the autotune cache eagerly, off the jit path.

    The drivers call the kernels from inside ``jax.jit``, where operands
    are tracers and timing is impossible — so tuning happens here, once,
    with concrete arrays at the exact hot-path shapes this fit will launch
    (single fused step at [s, n], batched step at [batch, s, n], and the
    epilogue assignment).  Compiled-Pallas only: interpret mode is a CPU
    correctness harness whose timings would be meaningless.
    """
    from repro.kernels import ops
    from repro.kernels import precision as px

    impl = cfg.resolved_impl()
    if impl != "pallas":
        return
    import jax.numpy as jnp

    # Resolve 'auto' against the data dtype when the source exposes one
    # (in-core arrays/memmaps); streamed chunks arrive f32 unless bf16 is
    # explicitly requested, so f32 is the right fallback.
    data_dtype = getattr(getattr(source, "X", None), "dtype", None) \
        or getattr(getattr(source, "mm", None), "dtype", None) or jnp.float32
    prec = px.resolve(cfg.precision, data_dtype)
    kx, kc = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (cfg.s, source.n_features), jnp.float32)
    c = jax.random.normal(kc, (cfg.k, source.n_features), jnp.float32)
    x_full = x
    x = px.cast_storage(x, prec)
    ops.fused_step(x, c, impl=impl, precision=prec)
    ops.assign(x, c, impl=impl, precision=prec)
    if prec in ("bf16", "int8"):
        # lloyd's objective epilogue assigns with f32 contractions on the
        # full-width view (see core/kmeans.py) — tune that key too, or it
        # runs untuned defaults.
        ops.assign(x_full, c, impl=impl, precision="f32")
    if cfg.batch > 1:
        if isinstance(x, px.QuantizedChunk):
            xb = px.QuantizedChunk(
                q=jnp.broadcast_to(x.q, (cfg.batch,) + x.q.shape),
                scale=jnp.broadcast_to(x.scale, (cfg.batch,) + x.scale.shape))
        else:
            xb = jnp.broadcast_to(x, (cfg.batch,) + x.shape)
        cb = jnp.broadcast_to(c, (cfg.batch,) + c.shape)
        ops.fused_step_batched(xb, cb, impl=impl, precision=prec)


def list_methods() -> list[str]:
    """Everything :func:`fit` accepts as ``method``."""
    return ["auto"] + list_strategies() + list_baselines()


def _resolve_method(method: str):
    if method == "auto" or method in list_strategies():
        return get_strategy(method)
    if method in list_baselines():
        return get_baseline(method)
    raise KeyError(f"unknown method {method!r}; known: {list_methods()}")


def fit(
    data,
    config: BigMeansConfig | None = None,
    *,
    method: str = "auto",
    key: jax.Array | None = None,
    n_features: int | None = None,
    **overrides,
) -> FitResult:
    """Cluster ``data`` and return a :class:`FitResult`.

    * ``data`` — anything :func:`as_source` accepts: a 2-D array, an
      ``.npy`` path, a ``provider(chunk_id)`` callable, a chunk iterator,
      or a :class:`DataSource`.
    * ``config`` — a :class:`BigMeansConfig`; ``overrides`` are applied on
      top (or, with no config, must include at least ``k`` and ``s``).
    * ``method`` — a strategy (``auto`` / ``sequential`` / ``batched`` /
      ``sharded`` / ``streaming``) or a §5 baseline (see
      :func:`list_methods`).
    * ``key`` — PRNG key; defaults to ``PRNGKey(config.seed)``.
    * ``n_features`` — feature count, only needed for provider/iterator
      data whose first chunk should not be probed eagerly.

    ``wall_time_s`` on the result covers the whole call, compile included.
    """
    if config is None:
        missing = {"k", "s"} - set(overrides)
        if missing:
            raise TypeError(
                f"fit() without a config needs {sorted(missing)} "
                "(e.g. fit(X, k=25, s=16384))")
        cfg = BigMeansConfig(**overrides)
    else:
        cfg = config.replace(**overrides) if overrides else config

    from repro.engine import topology as topo_lib

    if topo_lib.requested_kind(cfg) == "host_mesh":
        # jax.distributed.initialize() must run before the first JAX
        # computation in the process (the PRNG key below already is one),
        # so multi-host configs bootstrap the process group here.
        # Idempotent: resolve() reuses an already-initialized group.
        topo_lib.resolve(cfg.topology)

    source = as_source(data, n_features=n_features)
    prev_tuning = None
    from repro.kernels import autotune as _autotune

    # Snapshot before any kernel work: the disk cache loads lazily on the
    # first get_blocks lookup, which may happen inside _pretune below.
    n_tune_events = len(_autotune.events())
    try:
        if cfg.autotune:
            # Scoped to this call (exception paths included): the tuner
            # times candidate kernel tilings for this fit's shapes eagerly
            # (off the jit path) and caches the winners (see
            # repro.kernels.autotune); results are unaffected.  The
            # previous enable state is restored afterwards so a later fit
            # with autotune=False never pays surprise timing sweeps.
            from repro.kernels import autotune

            prev_tuning = autotune.enabled()
            autotune.enable(True)
            _pretune(cfg, source)
        fn = _resolve_method(method)
        if key is None:
            key = jax.random.PRNGKey(cfg.seed)

        from repro.kernels import ops as _ops

        n_demotions = len(_ops.kernel_demotions())
        t0 = time.monotonic()
        result = fn(cfg, source, key)
        jax.block_until_ready(result.centroids)
        result.wall_time_s = time.monotonic() - t0
        # Graceful kernel degradation taken during this call surfaces on
        # the result: trace events + the run-health summary.
        fallbacks = _ops.kernel_demotions()[n_demotions:]
        for d in fallbacks:
            result.trace.append(("kernel_fallback", d["op"], d["error"]))
        # Likewise for autotune-cache files that were ignored (corrupt or
        # stale schema): never fatal, but never silent either.
        for ev in _autotune.events()[n_tune_events:]:
            result.trace.append(ev)
        if fallbacks:
            result.extras.setdefault("health", {})["kernel_fallbacks"] = \
                fallbacks
        # Suite hook: how this fit was actually dispatched, in one
        # JSON-safe record (evalsuite and benchmarks read it off
        # `FitResult.to_row()` instead of re-deriving resolution logic).
        result.extras["fit"] = {
            "method": method,
            "impl": cfg.resolved_impl(),
            "precision": cfg.precision,
            "autotune": cfg.autotune,
            "seed": int(cfg.seed),
            "source": type(source).__name__,
        }
    finally:
        if prev_tuning is not None:
            from repro.kernels import autotune

            autotune.enable(prev_tuning)
    return result


def evaluate(result_or_centroids, data) -> tuple[jax.Array, float]:
    """Full-data evaluation: ``(assignments [m], objective f(C, X))``.

    The like-for-like comparison across methods whose native ``objective``
    fields have different scopes (chunk, coreset, full data).
    """
    from repro.core.objective import full_assignment

    centroids = getattr(result_or_centroids, "centroids", result_or_centroids)
    X = as_source(data).as_array()
    ids, f = full_assignment(jax.numpy.asarray(X, dtype=jax.numpy.float32),
                             jax.numpy.asarray(centroids))
    return ids, float(f)
