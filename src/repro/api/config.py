"""`BigMeansConfig` — the single source of truth for every algorithm knob.

Historically the knobs were scattered across three surfaces that silently
drifted apart: the ``big_means*`` driver kwargs, the host runner's
``RunnerConfig``, and the dry-runnable ``BigMeansWorkload`` in
``configs/bigmeans_paper.py``.  This dataclass unifies them; the old
constructors survive as deprecation shims that build one of these.

A config is *strategy-agnostic*: the same instance drives the sequential,
batched, sharded and streaming strategies (each strategy reads the fields it
needs and validates the combinations it cares about — e.g. only the batched
strategy requires ``batch`` to divide ``n_chunks``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.kernels import ops
from repro.kernels import precision as px


@dataclasses.dataclass(frozen=True)
class BigMeansConfig:
    """Validated configuration for one Big-means fit.

    Core algorithm (paper Algorithm 3):

    * ``k`` — number of clusters.
    * ``s`` — chunk (sample) size; must be >= ``k``.
    * ``n_chunks`` — total chunk budget across all streams/workers.
    * ``max_iters`` / ``tol`` — per-chunk Lloyd stop condition (§5.7 rule).
    * ``candidates`` — K-means++ candidates per degenerate slot.
    * ``impl`` — kernel implementation ('auto' resolves via
      :func:`repro.kernels.ops.resolve_impl`).
    * ``precision`` — kernel-stack precision (``'auto'`` | ``'f32'`` |
      ``'bf16'`` | ``'bf16x3'`` | ``'int8'``): bf16 stores/streams chunks
      at half the bytes and feeds bf16 operands to the MXU; int8 quantizes
      each chunk once (per-feature scales, quantized on the host by the
      prefetch pipeline) and contracts int8 x int8 -> int32 at a quarter of
      the f32 bytes, with f32 norm-correction terms; accumulators, norms,
      the objective and every ``f_best`` comparison stay f32 (see
      :mod:`repro.kernels.precision`).  ``'auto'`` follows the data dtype
      (bf16 arrays keep bf16 compute, everything else f32).
    * ``autotune`` — time candidate kernel tilings once per shape and cache
      the winner (:mod:`repro.kernels.autotune`); perf-only, never changes
      results.
    * ``with_replacement`` — chunk sampling scheme.

    Parallel execution:

    * ``batch`` — concurrent incumbent streams per device (batched driver /
      batched host runner).
    * ``sync_every`` — rounds between incumbent exchanges (1 = collective,
      ``n_chunks`` = competitive).
    * ``sync`` — the engine sync policy by name (``'auto'`` | ``'collective'``
      | ``'periodic'`` | ``'competitive'``); ``'auto'``/``'periodic'`` read
      the period from ``sync_every``, ``'competitive'`` never exchanges
      until the final argmin-reduce (see :mod:`repro.engine.sync`).
    * ``scheduler`` — the engine chunk scheduler (``'uniform'`` |
      ``'competitive_s'``): ``competitive_s`` races per-stream sample sizes
      and reallocates streams toward the winning ``s``
      (arXiv:2403.18766; see :mod:`repro.engine.scheduler`).
    * ``competitive_ladder`` — the sample sizes ``competitive_s`` races;
      empty = a geometric ladder around ``s``.
    * ``topology`` — the declarative execution-placement spec: a kind name
      (``'auto'`` | ``'single'`` | ``'stream_mesh'`` | ``'worker_mesh'`` |
      ``'host_mesh'``) or a full :class:`repro.engine.topology.TopologySpec`
      (device counts/shapes, axis names, multi-host fields).  This is the
      ONE way placement is requested; :func:`repro.engine.topology.resolve`
      is the one place meshes get constructed from it.
    * ``mesh`` / ``mesh_axes`` / ``stream_axis`` — **deprecated** raw-mesh
      plumbing, kept as a shim: a constructed ``mesh`` is wrapped into the
      equivalent topology descriptor (bit-identical results) with a
      ``DeprecationWarning``.  Pass ``topology=`` instead; setting both is
      an error.

    Streaming runner (out-of-core data):

    * ``prefetch`` — chunk-queue depth (0 = synchronous fetch).
    * ``time_budget_s`` — the paper's cpu_max wall-clock stop.
    * ``ckpt_dir`` / ``ckpt_every`` / ``resume`` — checkpointing.
    * ``log_every`` — trace granularity.
    * ``vns_ladder`` / ``vns_patience`` — chunk-size VNS extension (§6).

    Fault tolerance (streaming; see :mod:`repro.engine.faults`):

    * ``retries`` — re-attempts per chunk fetch for *transient* errors
      (timeouts, lost nodes), with exponential backoff and deterministic
      jitter; permanent errors (malformed data, contract violations) fail
      immediately.  0 = the legacy drop-the-chunk behaviour, bit-for-bit.
    * ``retry_backoff_s`` — base backoff delay (doubles per attempt,
      capped at 2s).
    * ``fetch_timeout_s`` — watchdog bound per provider call; a hung fetch
      becomes a retryable fault and the prefetch worker is always
      reclaimable.  None = no watchdog.
    * ``validate_chunks`` — sanitize chunks (finiteness, shape) before
      acceptance, quarantining bad ones (``("quarantine", cid, reason)``
      trace events + ``chunks_quarantined``), and enforce the post-accept
      invariant that ``f_best`` stays finite and monotone non-increasing.
    """

    k: int
    s: int
    n_chunks: int = 100
    max_iters: int = 300
    tol: float = 1e-4
    candidates: int = 3
    impl: str = "auto"
    precision: str = "auto"
    autotune: bool = False
    with_replacement: bool = True
    # --- parallel execution
    batch: int = 1
    sync_every: int = 1
    sync: str = "auto"
    scheduler: str = "uniform"
    competitive_ladder: tuple = ()
    topology: Any = "auto"     # kind name or engine.topology.TopologySpec
    mesh: Any = None           # deprecated: use topology=
    mesh_axes: tuple = ("data",)
    stream_axis: str = "streams"
    # --- streaming runner
    prefetch: int = 2
    time_budget_s: float | None = None
    ckpt_dir: str | None = None
    ckpt_every: int = 100
    resume: bool = True
    log_every: int = 50
    seed: int = 0
    vns_ladder: tuple = ()
    vns_patience: int = 10
    # --- fault tolerance (see repro.engine.faults)
    retries: int = 0
    retry_backoff_s: float = 0.05
    fetch_timeout_s: float | None = None
    validate_chunks: bool = True

    def __post_init__(self):
        def _positive(name, value):
            if not isinstance(value, int) or isinstance(value, bool) or value < 1:
                raise ValueError(f"{name} must be a positive int, got {value!r}")

        _positive("k", self.k)
        _positive("s", self.s)
        _positive("n_chunks", self.n_chunks)
        _positive("max_iters", self.max_iters)
        _positive("candidates", self.candidates)
        _positive("batch", self.batch)
        _positive("sync_every", self.sync_every)
        _positive("ckpt_every", self.ckpt_every)
        _positive("vns_patience", self.vns_patience)
        if self.s < self.k:
            raise ValueError(
                f"chunk size s={self.s} must be >= k={self.k}: K-means++ "
                "cannot seed k centers from fewer than k points")
        if self.tol < 0:
            raise ValueError(f"tol must be >= 0, got {self.tol!r}")
        if self.prefetch < 0:
            raise ValueError(f"prefetch must be >= 0, got {self.prefetch!r}")
        if self.log_every < 0:
            raise ValueError(f"log_every must be >= 0, got {self.log_every!r}")
        if self.time_budget_s is not None and self.time_budget_s <= 0:
            raise ValueError(
                f"time_budget_s must be positive, got {self.time_budget_s!r}")
        if not isinstance(self.retries, int) or isinstance(self.retries, bool) \
                or self.retries < 0:
            raise ValueError(
                f"retries must be an int >= 0, got {self.retries!r}")
        if self.retry_backoff_s < 0:
            raise ValueError(
                f"retry_backoff_s must be >= 0, got {self.retry_backoff_s!r}")
        if self.fetch_timeout_s is not None and self.fetch_timeout_s <= 0:
            raise ValueError(
                f"fetch_timeout_s must be positive, got "
                f"{self.fetch_timeout_s!r}")
        if not isinstance(self.validate_chunks, bool):
            raise ValueError(
                f"validate_chunks must be a bool, got "
                f"{self.validate_chunks!r}")
        if self.impl != "auto" and self.impl not in ops.IMPLS:
            raise ValueError(
                f"unknown impl {self.impl!r}; known: ('auto',) + {ops.IMPLS}")
        if self.precision != "auto":
            px.check(self.precision)
        if not isinstance(self.autotune, bool):
            raise ValueError(
                f"autotune must be a bool, got {self.autotune!r}")
        for rung in self.vns_ladder:
            if not isinstance(rung, int) or rung < self.k:
                raise ValueError(
                    f"vns_ladder entries must be ints >= k, got {rung!r}")
        if self.sync not in ("auto", "collective", "periodic", "competitive"):
            raise ValueError(
                f"unknown sync mode {self.sync!r}; known: auto, collective, "
                "periodic, competitive")
        from repro.engine.scheduler import list_schedulers

        if self.scheduler not in list_schedulers():
            raise ValueError(
                f"unknown scheduler {self.scheduler!r}; known: "
                f"{list_schedulers()}")
        for rung in self.competitive_ladder:
            if not isinstance(rung, int) or rung < self.k:
                raise ValueError(
                    f"competitive_ladder entries must be ints >= k, "
                    f"got {rung!r}")
        if self.scheduler == "competitive_s" and self.batch < 2:
            raise ValueError(
                "scheduler='competitive_s' races streams against each "
                f"other; it needs batch >= 2, got batch={self.batch}")
        from repro.engine import topology as topo_lib

        # normalize to a frozen TopologySpec (validates kind/fields once,
        # here, so every strategy downstream can trust the spec)
        object.__setattr__(self, "topology", topo_lib.as_spec(self.topology))
        if self.mesh is not None:
            if self.topology.kind != "auto":
                raise ValueError(
                    "cfg.mesh (deprecated) and cfg.topology are mutually "
                    "exclusive; drop the raw mesh and describe it with "
                    f"topology= (got topology={self.topology.kind!r})")
            import warnings

            warnings.warn(
                "BigMeansConfig(mesh=...) is deprecated: pass a declarative "
                "topology= spec (e.g. topology='stream_mesh' or "
                "TopologySpec(kind='worker_mesh', devices=4)); the raw mesh "
                "is wrapped into the equivalent topology for now",
                DeprecationWarning, stacklevel=3)

    def replace(self, **overrides) -> "BigMeansConfig":
        """A copy with ``overrides`` applied (re-validated)."""
        return dataclasses.replace(self, **overrides)

    def resolved_impl(self) -> str:
        """The concrete kernel implementation this config will run."""
        return ops.resolve_impl(self.impl)

    @classmethod
    def from_workload(cls, workload, **overrides) -> "BigMeansConfig":
        """Derive a config from a workload descriptor.

        New-style workloads (``configs/bigmeans_paper.BigMeansWorkload``)
        carry their knobs as an embedded ``.algo`` BigMeansConfig, which is
        returned (with ``overrides`` applied).  Legacy duck-typed workloads
        are read field-by-field (``chunks_per_worker`` maps to ``n_chunks``).
        """
        algo = getattr(workload, "algo", None)
        if isinstance(algo, cls):
            return algo.replace(**overrides) if overrides else algo
        fields = dict(
            k=workload.k,
            s=workload.s,
            n_chunks=getattr(workload, "chunks_per_worker", 100),
            sync_every=getattr(workload, "sync_every", 1),
            max_iters=getattr(workload, "max_iters", 300),
            tol=getattr(workload, "tol", 1e-4),
            candidates=getattr(workload, "candidates", 3),
            batch=getattr(workload, "batch", 1),
            prefetch=getattr(workload, "prefetch", 2),
            precision=getattr(workload, "precision", "auto"),
        )
        fields.update(overrides)
        return cls(**fields)
