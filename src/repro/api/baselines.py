"""Baseline registry: the paper's §5 competitors behind the same `fit()`.

Each entry is a ``fn(config, source, key) -> FitResult`` wrapper over the
implementations in ``repro.core.baselines``, so ``benchmarks/`` and
``examples/`` compare Big-means against its competitors through one
interface instead of six calling conventions.

Baselines are full-data (in-core) algorithms; their ``objective`` is
f(C, X) over the data they actually clustered (the coreset baseline reports
the weighted coreset objective — evaluate on X for a like-for-like number).
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import numpy as np

from repro.api.config import BigMeansConfig
from repro.api.result import FitResult
from repro.api.sources import DataSource

BaselineFn = Callable[[BigMeansConfig, DataSource, jax.Array], FitResult]

_BASELINES: dict[str, BaselineFn] = {}


def register_baseline(name: str):
    """Decorator: register ``fn(config, source, key) -> FitResult``."""
    def deco(fn: BaselineFn) -> BaselineFn:
        _BASELINES[name] = fn
        return fn
    return deco


def get_baseline(name: str) -> BaselineFn:
    try:
        return _BASELINES[name]
    except KeyError:
        raise KeyError(
            f"unknown baseline {name!r}; known: {list_baselines()}") from None


def list_baselines() -> list[str]:
    return sorted(_BASELINES)


def _array(source: DataSource, name: str):
    if not source.in_core:
        raise TypeError(
            f"baseline {name!r} is a full-data algorithm and needs in-core "
            f"data; {type(source).__name__} cannot be materialized")
    return source.as_array()


def _from_kmeans_result(res, name: str, cfg: BigMeansConfig) -> FitResult:
    return FitResult(
        centroids=res.centroids,
        objective=float(res.objective),
        algorithm=name,
        strategy=None,
        n_chunks=0,
        n_accepted=0,
        n_iterations=int(np.asarray(res.iterations).sum()),
        n_dist_evals=math.nan,
        config=cfg,
        extras={"counts": np.asarray(res.counts)},
    )


@register_baseline("forgy")
def _fit_forgy(cfg, source, key):
    from repro.core.baselines import forgy_kmeans

    X = _array(source, "forgy")
    res = forgy_kmeans(X, key, k=cfg.k, max_iters=cfg.max_iters, tol=cfg.tol,
                       impl=cfg.impl)
    return _from_kmeans_result(res, "forgy", cfg)


@register_baseline("kmeanspp")
def _fit_kmeanspp(cfg, source, key):
    """Multi-start K-means++ (the paper's "K-means++" competitor column)."""
    from repro.core.baselines import multistart_kmeans

    X = _array(source, "kmeanspp")
    res = multistart_kmeans(
        X, key, k=cfg.k, n_init=3, init="kmeans++",
        candidates=cfg.candidates, max_iters=cfg.max_iters, tol=cfg.tol,
        impl=cfg.impl)
    return _from_kmeans_result(res, "kmeanspp", cfg)


@register_baseline("kmeans_parallel")
def _fit_kmeans_parallel(cfg, source, key):
    from repro.core.baselines import kmeans_parallel

    X = _array(source, "kmeans_parallel")
    res = kmeans_parallel(X, key, k=cfg.k, max_iters=cfg.max_iters,
                          tol=cfg.tol, impl=cfg.impl)
    return _from_kmeans_result(res, "kmeans_parallel", cfg)


@register_baseline("coreset")
def _fit_coreset(cfg, source, key):
    from repro.core.baselines import lightweight_coreset_kmeans

    X = _array(source, "coreset")
    res = lightweight_coreset_kmeans(
        X, key, k=cfg.k, s=cfg.s, candidates=cfg.candidates,
        max_iters=cfg.max_iters, tol=cfg.tol, impl=cfg.impl)
    out = _from_kmeans_result(res, "coreset", cfg)
    out.extras["objective_scope"] = "weighted coreset"
    return out


@register_baseline("da_mssc")
def _fit_da_mssc(cfg, source, key):
    from repro.core.baselines import da_mssc

    X = _array(source, "da_mssc")
    m = X.shape[0]
    q = max(1, min(cfg.n_chunks, m // cfg.s))
    res = da_mssc(X, key, k=cfg.k, s=cfg.s, q=q, candidates=cfg.candidates,
                  max_iters=cfg.max_iters, tol=cfg.tol, impl=cfg.impl)
    out = _from_kmeans_result(res, "da_mssc", cfg)
    out.n_chunks = q
    return out


@register_baseline("ward")
def _fit_ward(cfg, source, key):
    from repro.core.baselines import ward
    from repro.core.objective import full_objective

    X = _array(source, "ward")
    centroids, labels = ward(np.asarray(X), cfg.k)
    centroids = np.asarray(centroids, dtype=np.float32)
    f = float(full_objective(jax.numpy.asarray(X, dtype=jax.numpy.float32),
                             jax.numpy.asarray(centroids)))
    return FitResult(
        centroids=centroids,
        objective=f,
        algorithm="ward",
        strategy=None,
        n_dist_evals=math.nan,
        config=cfg,
        extras={"labels": np.asarray(labels)},
    )
