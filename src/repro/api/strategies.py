"""Driver strategy registry: how a Big-means fit executes.

Every strategy wraps one of the existing drivers behind the common
``fit(config, source, key) -> FitResult`` contract:

* ``sequential`` — the paper's Algorithm 3 (``core.bigmeans.big_means``).
* ``batched``    — B incumbent streams per device
  (``big_means_batched``; with ``config.mesh`` the stream axis is sharded).
* ``sharded``    — multi-worker chunk streams with periodic incumbent
  exchange (``big_means_sharded``).
* ``streaming``  — the out-of-core host runner (``cluster.runner.run``):
  prefetch pipeline, checkpoints, time budget, VNS ladder.
* ``auto``       — picks one of the above from the config + data source +
  hardware topology.

Strategies are registered by name so follow-up work (competitive sample-size
optimization, stream fusion — arXiv:2403.18766 / 2410.14548) plugs in as new
entries instead of new entry points.
"""
from __future__ import annotations

from typing import Callable

import jax
import numpy as np

from repro.api.config import BigMeansConfig
from repro.api.result import FitResult
from repro.api.sources import DataSource

StrategyFn = Callable[[BigMeansConfig, DataSource, jax.Array], FitResult]

_STRATEGIES: dict[str, StrategyFn] = {}


def register_strategy(name: str):
    """Decorator: register ``fn(config, source, key) -> FitResult``."""
    def deco(fn: StrategyFn) -> StrategyFn:
        _STRATEGIES[name] = fn
        return fn
    return deco


def get_strategy(name: str) -> StrategyFn:
    if name == "auto":
        return _fit_auto
    try:
        return _STRATEGIES[name]
    except KeyError:
        raise KeyError(
            f"unknown strategy {name!r}; known: "
            f"{['auto'] + list_strategies()}") from None


def list_strategies() -> list[str]:
    return sorted(_STRATEGIES)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _require_array(source: DataSource, strategy: str):
    if not source.in_core:
        raise TypeError(
            f"strategy {strategy!r} needs in-core data but the source "
            f"({type(source).__name__}) cannot be materialized; use "
            "strategy='streaming' (or 'auto')")
    return source.as_array()


def _trace_from_infos(infos) -> list:
    f_new = np.asarray(infos.f_new, dtype=np.float64)
    accepted = np.asarray(infos.accepted)
    return [(int(i), float(f), bool(a))
            for i, (f, a) in enumerate(zip(f_new, accepted))]


def _result_from_state(state, infos, cfg, strategy, **extras) -> FitResult:
    return FitResult(
        centroids=state.centroids,
        objective=float(state.f_best),
        algorithm="big_means",
        strategy=strategy,
        n_chunks=int(np.asarray(infos.f_new).size),
        n_accepted=int(state.n_accepted),
        n_iterations=int(np.sum(np.asarray(infos.lloyd_iters))),
        n_dist_evals=float(state.n_dist_evals),
        trace=_trace_from_infos(infos),
        checkpoint_dir=None,
        config=cfg,
        extras=extras,
    )


def _mesh_size(mesh) -> int:
    return int(mesh.devices.size)


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------


@register_strategy("sequential")
def _fit_sequential(cfg: BigMeansConfig, source: DataSource,
                    key: jax.Array) -> FitResult:
    from repro.core import bigmeans

    X = _require_array(source, "sequential")
    state, infos = bigmeans.big_means(
        X, key, k=cfg.k, s=cfg.s, n_chunks=cfg.n_chunks,
        max_iters=cfg.max_iters, tol=cfg.tol, candidates=cfg.candidates,
        impl=cfg.impl, with_replacement=cfg.with_replacement,
        precision=cfg.precision)
    return _result_from_state(state, infos, cfg, "sequential")


@register_strategy("batched")
def _fit_batched(cfg: BigMeansConfig, source: DataSource,
                 key: jax.Array) -> FitResult:
    from repro.core import bigmeans

    if cfg.n_chunks % cfg.batch:
        raise ValueError(
            f"strategy 'batched' needs batch ({cfg.batch}) to divide "
            f"n_chunks ({cfg.n_chunks})")
    rounds = cfg.n_chunks // cfg.batch
    if rounds % cfg.sync_every:
        raise ValueError(
            f"strategy 'batched' needs sync_every ({cfg.sync_every}) to "
            f"divide the round count ({rounds} = n_chunks / batch)")
    if cfg.mesh is not None and cfg.batch % _mesh_size(cfg.mesh):
        raise ValueError(
            f"stream mesh has {_mesh_size(cfg.mesh)} devices, which must "
            f"divide batch ({cfg.batch})")

    X = _require_array(source, "batched")
    state, infos = bigmeans.big_means_batched(
        X, key, k=cfg.k, s=cfg.s, batch=cfg.batch, rounds=rounds,
        sync_every=cfg.sync_every, max_iters=cfg.max_iters, tol=cfg.tol,
        candidates=cfg.candidates, impl=cfg.impl,
        with_replacement=cfg.with_replacement, precision=cfg.precision,
        mesh=cfg.mesh, stream_axis=cfg.stream_axis)
    return _result_from_state(
        state, infos, cfg, "batched", batch=cfg.batch, rounds=rounds)


@register_strategy("sharded")
def _fit_sharded(cfg: BigMeansConfig, source: DataSource,
                 key: jax.Array) -> FitResult:
    from repro.core import bigmeans
    from repro.launch.mesh import make_mesh

    mesh = cfg.mesh
    if mesh is None:
        ndev = len(jax.devices())
        mesh = make_mesh((ndev,), cfg.mesh_axes[:1])
    workers = _mesh_size(mesh)
    if cfg.n_chunks % workers:
        raise ValueError(
            f"strategy 'sharded' needs the worker count ({workers}) to "
            f"divide n_chunks ({cfg.n_chunks})")
    chunks_per_worker = cfg.n_chunks // workers
    if chunks_per_worker % cfg.sync_every:
        raise ValueError(
            f"strategy 'sharded' needs sync_every ({cfg.sync_every}) to "
            f"divide chunks_per_worker ({chunks_per_worker} = "
            f"n_chunks / workers)")

    X = _require_array(source, "sharded")
    state, infos = bigmeans.big_means_sharded(
        X, key, mesh=mesh, k=cfg.k, s=cfg.s,
        chunks_per_worker=chunks_per_worker, sync_every=cfg.sync_every,
        axes=tuple(mesh.axis_names), max_iters=cfg.max_iters, tol=cfg.tol,
        candidates=cfg.candidates, impl=cfg.impl,
        with_replacement=cfg.with_replacement, precision=cfg.precision)
    return _result_from_state(
        state, infos, cfg, "sharded",
        workers=workers, chunks_per_worker=chunks_per_worker)


@register_strategy("streaming")
def _fit_streaming(cfg: BigMeansConfig, source: DataSource,
                   key: jax.Array) -> FitResult:
    from repro.cluster import runner
    from repro.kernels import precision as px

    # bf16 precision: chunks are cast on the host (prefetch thread) so
    # host->device transfers move half the bytes, not just HBM reads.
    # host_dtype is None otherwise: the source serves its native default.
    provider = source.provider(
        cfg.s, seed=cfg.seed, with_replacement=cfg.with_replacement,
        dtype=px.host_dtype(cfg.precision))
    state, metrics = runner.run(
        provider, cfg, n_features=source.n_features, resume=cfg.resume,
        key=key)
    return FitResult(
        centroids=state.centroids,
        objective=float(state.f_best),
        algorithm="big_means",
        strategy="streaming",
        n_chunks=metrics.chunks_done,
        n_accepted=metrics.accepted,
        n_iterations=0,          # the runner does not surface Lloyd iters
        n_dist_evals=float(state.n_dist_evals),
        wall_time_s=metrics.wall_time_s,
        trace=list(metrics.trace),
        checkpoint_dir=cfg.ckpt_dir,
        config=cfg,
        extras={"chunks_failed": metrics.chunks_failed},
    )


def resolve_auto(cfg: BigMeansConfig, source: DataSource) -> str:
    """Pick a concrete strategy from config + data source + topology.

    Out-of-core / stream-shaped sources and runner-only features (ckpt,
    time budget, VNS) go to ``streaming``; ``batch > 1`` goes to
    ``batched``; a mesh or a multi-device host goes to ``sharded``;
    otherwise the paper's ``sequential``.
    """
    wants_runner = (cfg.ckpt_dir is not None or cfg.time_budget_s is not None
                    or bool(cfg.vns_ladder))
    if not source.in_core or source.prefers_streaming or wants_runner:
        return "streaming"
    if cfg.batch > 1:
        return "batched"
    if cfg.mesh is not None or len(jax.devices()) > 1:
        # only if the topology meets the sharded driver's preconditions —
        # auto must never pick a strategy that rejects this config
        workers = (_mesh_size(cfg.mesh) if cfg.mesh is not None
                   else len(jax.devices()))
        if (cfg.n_chunks % workers == 0
                and (cfg.n_chunks // workers) % cfg.sync_every == 0):
            return "sharded"
    return "sequential"


def _fit_auto(cfg: BigMeansConfig, source: DataSource,
              key: jax.Array) -> FitResult:
    name = resolve_auto(cfg, source)
    result = _STRATEGIES[name](cfg, source, key)
    result.extras["auto"] = True
    return result
