"""Driver strategy registry: how a Big-means fit executes.

Every strategy is an *engine configuration* — an assembly of the
scheduler / topology / sync-policy / middleware pieces from
:mod:`repro.engine` — behind the common ``fit(config, source, key) ->
FitResult`` contract:

* ``sequential`` — the paper's Algorithm 3: single device, scalar stream
  (``engine.incore.sequential``).
* ``batched``    — B incumbent streams per device
  (``engine.incore.batched_local``; with ``topology='stream_mesh'`` the
  stream axis is sharded, ``batched_stream_mesh``).
* ``sharded``    — multi-worker chunk streams with periodic incumbent
  exchange (``engine.incore.worker_sharded``); with checkpointing or a time
  budget the same windows run host-orchestrated
  (``worker_sharded_rounds``) so the middleware stack composes.
* ``streaming``  — the out-of-core host loop (``engine.stream.run_stream``):
  prefetch pipeline, checkpoints, time budget, VNS ladder — on one device,
  with the stream axis sharded (``topology='stream_mesh'``), or scaled out
  over processes (``topology='host_mesh'`` →
  ``engine.hostmesh.run_host_stream``).
* ``auto``       — picks one of the above from the config + data source +
  hardware topology.

Placement is declarative: strategies consume ``cfg.topology`` (a
:class:`repro.engine.topology.TopologySpec`) through
``engine.topology.from_config`` and never hand-build meshes; the deprecated
raw ``cfg.mesh`` rides the same path via the shim, bit-identically.

Strategies are registered by name so follow-up work (competitive sample-size
optimization, stream fusion — arXiv:2403.18766 / 2410.14548) plugs in as
engine configurations instead of new entry points (``competitive_s`` is the
first: set ``config.scheduler='competitive_s'`` on the streaming strategy).
"""
from __future__ import annotations

from typing import Callable

import jax
import numpy as np

from repro.api.config import BigMeansConfig
from repro.api.result import FitResult
from repro.api.sources import DataSource

StrategyFn = Callable[[BigMeansConfig, DataSource, jax.Array], FitResult]

_STRATEGIES: dict[str, StrategyFn] = {}


def register_strategy(name: str):
    """Decorator: register ``fn(config, source, key) -> FitResult``."""
    def deco(fn: StrategyFn) -> StrategyFn:
        _STRATEGIES[name] = fn
        return fn
    return deco


def get_strategy(name: str) -> StrategyFn:
    if name == "auto":
        return _fit_auto
    try:
        return _STRATEGIES[name]
    except KeyError:
        raise KeyError(
            f"unknown strategy {name!r}; known: "
            f"{['auto'] + list_strategies()}") from None


def list_strategies() -> list[str]:
    return sorted(_STRATEGIES)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _require_array(source: DataSource, strategy: str):
    if not source.in_core:
        raise TypeError(
            f"strategy {strategy!r} needs in-core data but the source "
            f"({type(source).__name__}) cannot be materialized; use "
            "strategy='streaming' (or 'auto')")
    return source.as_array()


def _trace_from_infos(infos) -> list:
    f_new = np.asarray(infos.f_new, dtype=np.float64)
    accepted = np.asarray(infos.accepted)
    return [(int(i), float(f), bool(a))
            for i, (f, a) in enumerate(zip(f_new, accepted))]


def _result_from_state(state, infos, cfg, strategy, **extras) -> FitResult:
    return FitResult(
        centroids=state.centroids,
        objective=float(state.f_best),
        algorithm="big_means",
        strategy=strategy,
        n_chunks=int(np.asarray(infos.f_new).size),
        n_accepted=int(state.n_accepted),
        n_iterations=int(np.sum(np.asarray(infos.lloyd_iters))),
        n_dist_evals=float(state.n_dist_evals),
        trace=_trace_from_infos(infos),
        checkpoint_dir=None,
        config=cfg,
        extras=extras,
    )


def _resolve_sync_every(cfg: BigMeansConfig, rounds: int) -> int:
    """Concrete exchange period from the sync-policy knob (``'competitive'``
    resolves to a single final exchange)."""
    from repro.engine import sync as sync_lib

    return sync_lib.from_config(cfg).resolve(rounds)


def _largest_divisor_le(n: int, cap: int) -> int:
    for d in range(min(cap, n), 0, -1):
        if n % d == 0:
            return d
    return 1


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------


@register_strategy("sequential")
def _fit_sequential(cfg: BigMeansConfig, source: DataSource,
                    key: jax.Array) -> FitResult:
    from repro.core import bigmeans

    X = _require_array(source, "sequential")
    state, infos = bigmeans.big_means(
        X, key, k=cfg.k, s=cfg.s, n_chunks=cfg.n_chunks,
        max_iters=cfg.max_iters, tol=cfg.tol, candidates=cfg.candidates,
        impl=cfg.impl, with_replacement=cfg.with_replacement,
        precision=cfg.precision)
    return _result_from_state(state, infos, cfg, "sequential")


@register_strategy("batched")
def _fit_batched(cfg: BigMeansConfig, source: DataSource,
                 key: jax.Array) -> FitResult:
    from repro.core import bigmeans

    from repro.engine import topology as topo_lib

    if cfg.n_chunks % cfg.batch:
        raise ValueError(
            f"strategy 'batched' needs batch ({cfg.batch}) to divide "
            f"n_chunks ({cfg.n_chunks})")
    rounds = cfg.n_chunks // cfg.batch
    sync_every = _resolve_sync_every(cfg, rounds)
    if rounds % sync_every:
        raise ValueError(
            f"strategy 'batched' needs sync_every ({sync_every}) to "
            f"divide the round count ({rounds} = n_chunks / batch)")
    topo = topo_lib.for_streams(cfg)
    if not isinstance(topo, (topo_lib.SingleDevice, topo_lib.StreamMesh)):
        raise ValueError(
            f"strategy 'batched' runs on 'single' or 'stream_mesh' "
            f"topologies, got {topo.name!r}")
    mesh = topo.mesh if isinstance(topo, topo_lib.StreamMesh) else None
    stream_axis = topo.axis if mesh is not None else cfg.stream_axis
    if mesh is not None and cfg.batch % topo.devices:
        raise ValueError(
            f"stream mesh has {topo.devices} devices, which must "
            f"divide batch ({cfg.batch})")

    X = _require_array(source, "batched")
    state, infos = bigmeans.big_means_batched(
        X, key, k=cfg.k, s=cfg.s, batch=cfg.batch, rounds=rounds,
        sync_every=sync_every, max_iters=cfg.max_iters, tol=cfg.tol,
        candidates=cfg.candidates, impl=cfg.impl,
        with_replacement=cfg.with_replacement, precision=cfg.precision,
        mesh=mesh, stream_axis=stream_axis)
    return _result_from_state(
        state, infos, cfg, "batched", batch=cfg.batch, rounds=rounds)


@register_strategy("sharded")
def _fit_sharded(cfg: BigMeansConfig, source: DataSource,
                 key: jax.Array) -> FitResult:
    from repro.engine import incore, middleware as mw
    from repro.engine import topology as topo_lib

    spec = cfg.topology
    if cfg.mesh is None and spec.kind == "auto" \
            and tuple(cfg.mesh_axes[:1]) != ("data",):
        # legacy axis-name knob without a mesh: honour it through the spec
        spec = topo_lib.TopologySpec(kind="worker_mesh",
                                     axes=tuple(cfg.mesh_axes[:1]))
        topo = topo_lib.resolve(spec, role="worker")
    else:
        topo = topo_lib.for_workers(cfg)
    mesh, workers = topo.mesh, topo.devices
    if cfg.n_chunks % workers:
        raise ValueError(
            f"strategy 'sharded' needs the worker count ({workers}) to "
            f"divide n_chunks ({cfg.n_chunks})")
    chunks_per_worker = cfg.n_chunks // workers
    sync_every = _resolve_sync_every(cfg, chunks_per_worker)
    if chunks_per_worker % sync_every:
        raise ValueError(
            f"strategy 'sharded' needs sync_every ({sync_every}) to "
            f"divide chunks_per_worker ({chunks_per_worker} = "
            f"n_chunks / workers)")

    X = _require_array(source, "sharded")
    kwargs = dict(
        mesh=mesh, k=cfg.k, s=cfg.s, chunks_per_worker=chunks_per_worker,
        sync_every=sync_every, axes=topo.axes,
        max_iters=cfg.max_iters, tol=cfg.tol, candidates=cfg.candidates,
        impl=cfg.impl, with_replacement=cfg.with_replacement,
        precision=cfg.precision)
    extras = dict(workers=workers, chunks_per_worker=chunks_per_worker)
    if cfg.ckpt_dir is not None or cfg.time_budget_s is not None:
        # middleware composition (checkpoint/resume, time budget): run the
        # same sync windows host-orchestrated, one jitted segment per window
        mws: list = []
        if cfg.ckpt_dir:
            mws.append(mw.Checkpoint(cfg.ckpt_dir, cfg.ckpt_every,
                                     sync_every, step_from="step"))
        if cfg.time_budget_s is not None:
            mws.append(mw.TimeBudget(cfg.time_budget_s))
        state, infos, ctx = incore.worker_sharded_rounds(
            X, key, cfg=cfg, middlewares=mws, resume=cfg.resume, **kwargs)
        result = _result_from_state(
            state, infos, cfg, "sharded",
            rounds_done=ctx.step, **extras)
        result.checkpoint_dir = cfg.ckpt_dir
        return result
    state, infos = incore.worker_sharded(X, key, **kwargs)
    return _result_from_state(state, infos, cfg, "sharded", **extras)


@register_strategy("streaming")
def _fit_streaming(cfg: BigMeansConfig, source: DataSource,
                   key: jax.Array) -> FitResult:
    from repro.engine import hostmesh
    from repro.engine import scheduler as sched_lib
    from repro.engine import stream as engine_stream
    from repro.engine import topology as topo_lib
    from repro.kernels import precision as px

    topology = topo_lib.for_streams(cfg)
    scheduler = sched_lib.get_scheduler(cfg.scheduler, cfg)
    fetch_s = getattr(scheduler, "fetch_s", cfg.s) or cfg.s
    # bf16 precision: chunks are cast on the host (prefetch thread) so
    # host->device transfers move half the bytes, not just HBM reads.
    # host_dtype is None otherwise: the source serves its native default.
    provider = source.provider(
        fetch_s, seed=cfg.seed, with_replacement=cfg.with_replacement,
        dtype=px.host_dtype(cfg.precision))
    if isinstance(topology, topo_lib.HostMesh):
        # multi-host scale-out: this process runs its chunk-id shard and
        # exchanges incumbents at sync windows (run_host_stream builds the
        # rank-local scheduler, so the config-level one is discarded)
        state, metrics = hostmesh.run_host_stream(
            provider, cfg, topology=topology, n_features=source.n_features,
            resume=cfg.resume, key=key)
    else:
        state, metrics = engine_stream.run_stream(
            provider, cfg, n_features=source.n_features, resume=cfg.resume,
            key=key, scheduler=scheduler, topology=topology)
    extras = {"chunks_failed": metrics.chunks_failed,
              "chunks_dropped": metrics.chunks_dropped,
              "chunks_quarantined": metrics.chunks_quarantined}
    # Run-health summary: the reconciliation contract in one record —
    # done + failed + dropped + quarantined == chunks fetched.
    extras["health"] = {
        "chunks_done": metrics.chunks_done,
        "chunks_failed": metrics.chunks_failed,
        "chunks_dropped": metrics.chunks_dropped,
        "chunks_quarantined": metrics.chunks_quarantined,
        "chunks_fetched": (metrics.chunks_done + metrics.chunks_failed
                           + metrics.chunks_dropped
                           + metrics.chunks_quarantined),
        "ckpt_fallback": next(
            (t[1] for t in metrics.trace if t[0] == "ckpt_fallback"), None),
        "quarantine_reasons": [
            (t[1], t[2]) for t in metrics.trace if t[0] == "quarantine"],
    }
    if metrics.host is not None:
        # the final cross-host gather: every rank's reconciliation record
        extras["health"]["ranks"] = metrics.host["per_rank"]
        extras["host"] = {k: metrics.host[k]
                          for k in ("rank", "processes", "winner_rank")}
    if metrics.host is None and isinstance(scheduler, sched_lib.CompetitiveS):
        extras["competitive_s"] = {
            "ladder": scheduler.ladder,
            "final_sizes": list(scheduler.s_of),
            "windows": len(scheduler.history),
        }
    return FitResult(
        centroids=state.centroids,
        objective=float(state.f_best),
        algorithm="big_means",
        strategy="streaming",
        n_chunks=metrics.chunks_done,
        n_accepted=metrics.accepted,
        n_iterations=metrics.lloyd_iters,
        n_dist_evals=float(state.n_dist_evals),
        wall_time_s=metrics.wall_time_s,
        trace=list(metrics.trace),
        checkpoint_dir=cfg.ckpt_dir,
        config=cfg,
        extras=extras,
    )


def resolve_auto(cfg: BigMeansConfig, source: DataSource) -> str:
    """Pick a concrete strategy from config + data source + topology.

    Out-of-core / stream-shaped sources and stream-loop-only features
    (VNS, ``competitive_s``) go to ``streaming``; ``batch > 1`` goes to
    ``batched``; a mesh or a multi-device host goes to ``sharded``
    (deriving a compatible ``sync_every`` when the requested one does not
    divide the per-worker chunk count — see :func:`_fit_auto`); otherwise
    the paper's ``sequential``.
    """
    from repro.engine import topology as topo_lib

    kind = topo_lib.requested_kind(cfg)
    if kind == "host_mesh":
        return "streaming"          # host_mesh is a streaming-only topology
    worker_kind = kind in ("legacy_mesh", "worker_mesh")
    wants_runner = (cfg.ckpt_dir is not None or cfg.time_budget_s is not None
                    or bool(cfg.vns_ladder)
                    or cfg.scheduler == "competitive_s")
    if not source.in_core or source.prefers_streaming or wants_runner:
        if cfg.ckpt_dir is not None and source.in_core \
                and not source.prefers_streaming and cfg.batch == 1 \
                and not cfg.vns_ladder and cfg.scheduler == "uniform" \
                and worker_kind \
                and cfg.n_chunks % topo_lib.worker_count(cfg) == 0:
            return "sharded"        # in-core mesh + checkpoints: now possible
        return "streaming"
    if cfg.batch > 1:
        return "batched"
    if worker_kind or (kind == "auto" and len(jax.devices()) > 1):
        if cfg.n_chunks % topo_lib.worker_count(cfg) == 0:
            return "sharded"
    return "sequential"


def _fit_auto(cfg: BigMeansConfig, source: DataSource,
              key: jax.Array) -> FitResult:
    from repro.engine import topology as topo_lib

    name = resolve_auto(cfg, source)
    extras = {}
    if name == "sharded":
        workers = topo_lib.worker_count(cfg)
        chunks_per_worker = cfg.n_chunks // workers
        if chunks_per_worker % cfg.sync_every:
            # auto never downgrades a multi-device host to sequential over
            # an incompatible sync_every: derive the largest compatible one
            used = _largest_divisor_le(chunks_per_worker, cfg.sync_every)
            extras["sync_every_adjusted"] = {
                "requested": cfg.sync_every, "used": used}
            cfg = cfg.replace(sync_every=used)
    result = _STRATEGIES[name](cfg, source, key)
    result.extras["auto"] = True
    result.extras.update(extras)
    return result
