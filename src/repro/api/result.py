"""`FitResult` — the one result type every strategy and baseline returns.

Whatever produced it — a Big-means driver, the streaming runner or a §5
competitor — the caller reads the same fields: centroids, the algorithm's
native objective, the acceptance / Lloyd-iteration / distance-evaluation
telemetry (the paper's ``n_d``), a trace and an optional checkpoint path.
``benchmarks/`` and ``examples/`` compare algorithms only through this.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any


@dataclasses.dataclass
class FitResult:
    """Unified result of one :func:`repro.api.fit` call.

    * ``centroids`` — [k, n] float32 cluster centers.
    * ``objective`` — the algorithm's *native* incumbent objective: for
      Big-means strategies f(C, P) on the winning chunk (a sum over ``s``
      points), for full-data baselines f(C, X).  Use
      :func:`repro.api.evaluate` for a like-for-like full-data comparison.
    * ``algorithm`` — "big_means" or the baseline registry name.
    * ``strategy`` — execution strategy that ran ("sequential", "batched",
      "sharded", "streaming"); None for baselines.
    * ``n_chunks`` — chunks processed (0 for full-data baselines).
    * ``n_accepted`` — incumbent improvements (Big-means keep-the-best).
    * ``n_iterations`` — total Lloyd iterations.
    * ``n_dist_evals`` — the paper's analytic n_d counter (NaN where the
      algorithm does not track it).
    * ``trace`` — list of trace entries; Big-means strategies log
      ``(chunk_idx, f_new, accepted)`` triples, the streaming runner logs
      ``(chunk_id, f_best, f_new)`` checkpoints plus the structured fault
      events (``fetch_error``, ``quarantine``, ``budget_drop``,
      ``short_chunk``, ``ckpt_fallback``; ``fit`` appends
      ``kernel_fallback`` — see the README trace-event glossary).
    * ``checkpoint_dir`` — where the run checkpointed, if anywhere.
    * ``config`` — the :class:`repro.api.BigMeansConfig` that ran.
    * ``extras`` — strategy-specific detail (resolved auto strategy, final
      cluster counts, worker topology, ...).
    """

    centroids: Any
    objective: float
    algorithm: str = "big_means"
    strategy: str | None = None
    n_chunks: int = 0
    n_accepted: int = 0
    n_iterations: int = 0
    n_dist_evals: float = math.nan
    wall_time_s: float = 0.0
    trace: list = dataclasses.field(default_factory=list)
    checkpoint_dir: str | None = None
    config: Any = None
    extras: dict = dataclasses.field(default_factory=dict)

    @property
    def health(self) -> dict | None:
        """The run-health summary (streaming strategies): chunk accounting
        (``done + failed + dropped + quarantined == fetched``), checkpoint
        fallbacks and quarantine reasons; ``fit`` adds any
        ``kernel_fallbacks`` taken during the call.  None when the strategy
        does not stream."""
        return self.extras.get("health")

    @property
    def k(self) -> int:
        return self.centroids.shape[0]

    @property
    def n_features(self) -> int:
        return self.centroids.shape[1]

    def to_row(self) -> dict:
        """A flat, JSON-safe record of this fit (the evalsuite/benchmark
        row contract — everything scalar, nothing device-resident)."""
        nd = self.n_dist_evals
        return {
            "algorithm": self.algorithm,
            "strategy": self.strategy,
            "objective": float(self.objective),
            "k": int(self.k),
            "n_features": int(self.n_features),
            "n_chunks": int(self.n_chunks),
            "n_accepted": int(self.n_accepted),
            "n_iterations": int(self.n_iterations),
            "n_dist_evals": None if math.isnan(nd) else float(nd),
            "wall_time_s": float(self.wall_time_s),
            "fit": self.extras.get("fit"),
        }

    def summary(self) -> str:
        via = f" via {self.strategy}" if self.strategy else ""
        nd = ("n_d=nan" if math.isnan(self.n_dist_evals)
              else f"n_d={self.n_dist_evals:.3e}")
        return (f"{self.algorithm}{via}: f={self.objective:.6e}  "
                f"k={self.k}  chunks={self.n_chunks}  "
                f"accepted={self.n_accepted}  iters={self.n_iterations}  "
                f"{nd}  wall={self.wall_time_s:.2f}s")
