"""Data sources: one protocol over "array here, provider there".

The drivers historically split along data access: in-core drivers took a
materialized array, the streaming runner took a ``provider(chunk_id)``
callable.  A :class:`DataSource` exposes *both* views where possible —
``as_array()`` for the in-core strategies and ``provider(s, seed)`` for the
streaming strategy — so the execution strategy becomes a config knob instead
of a calling convention.

Chunk sampling uses the same counter-based scheme everywhere (NumPy
``default_rng((seed, chunk_id))`` over row indices, with replacement):
:class:`ArraySource` and :class:`MemmapSource` over the same rows serve
byte-identical chunks, and restarts replay identical streams.

``provider(..., dtype=...)`` controls the dtype chunks are served in:
an explicit dtype always wins, ``None`` means the source's native default.
``BigMeansConfig(precision='bf16')`` makes the streaming strategy request
``ml_dtypes.bfloat16`` chunks (``repro.kernels.precision.host_dtype``), so
the cast happens on the host (in the prefetch thread) and host->device
transfers move half the bytes.
"""
from __future__ import annotations

import os
from typing import Any, Callable, Iterable, Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class DataSource(Protocol):
    """What a strategy needs from data: feature count + one or both views."""

    @property
    def n_features(self) -> int: ...

    @property
    def n_rows(self) -> int | None: ...

    @property
    def in_core(self) -> bool: ...

    @property
    def prefers_streaming(self) -> bool: ...

    def as_array(self):
        """The full dataset as a 2-D array (in-core strategies)."""
        ...

    def provider(self, s: int, *, seed: int = 0,
                 with_replacement: bool = True,
                 dtype=None) -> Callable[[int], np.ndarray]:
        """A ``chunk_id -> [s, n]`` fetcher (streaming strategy).

        ``dtype``: explicit request wins; ``None`` serves the source's
        native default (float32 for array/provider/iterator sources, the
        file dtype for memmaps).
        """
        ...


class _SourceBase:
    prefers_streaming = False
    n_rows: int | None = None

    @property
    def in_core(self) -> bool:
        return True

    def as_array(self):
        raise TypeError(
            f"{type(self).__name__} cannot be materialized in-core; use the "
            "'streaming' strategy (or 'auto', which picks it)")

    def _uniform_chunk_ids(self, m: int, s: int, seed: int, chunk_id: int,
                           with_replacement: bool = True):
        rng = np.random.default_rng((seed, chunk_id))
        if with_replacement:
            idx = rng.integers(0, m, size=s)
        else:
            idx = rng.choice(m, size=s, replace=False)
        # Canonical (sorted) row order: mostly-sequential reads off disk for
        # memmaps, and byte-identical chunks across adapters over equal rows.
        idx.sort()
        return idx


class ArraySource(_SourceBase):
    """In-core array (np / jax).  Serves both views."""

    def __init__(self, X):
        if getattr(X, "ndim", None) != 2:
            raise ValueError(f"expected a 2-D array, got shape "
                             f"{getattr(X, 'shape', None)!r}")
        self.X = X

    @property
    def n_features(self) -> int:
        return self.X.shape[1]

    @property
    def n_rows(self) -> int:
        return self.X.shape[0]

    def as_array(self):
        return self.X

    def provider(self, s: int, *, seed: int = 0, with_replacement: bool = True,
                 dtype=None):
        X = np.asarray(self.X)
        m = X.shape[0]
        dtype = np.float32 if dtype is None else dtype

        def fetch(chunk_id: int) -> np.ndarray:
            idx = self._uniform_chunk_ids(m, s, seed, chunk_id,
                                          with_replacement)
            return np.asarray(X[idx], dtype=dtype)

        return fetch


class MemmapSource(_SourceBase):
    """An ``.npy`` file served through ``np.memmap`` (never fully loaded on
    the streaming path; ``as_array`` does load it, for in-core strategies on
    datasets that happen to fit)."""

    prefers_streaming = True

    def __init__(self, path: str | os.PathLike, *, dtype=np.float32):
        self.path = os.fspath(path)
        self.dtype = dtype
        self.mm = np.load(self.path, mmap_mode="r")
        if self.mm.ndim != 2:
            raise ValueError(f"{self.path}: expected 2-D data, got shape "
                             f"{self.mm.shape}")

    @property
    def n_features(self) -> int:
        return self.mm.shape[1]

    @property
    def n_rows(self) -> int:
        return self.mm.shape[0]

    def as_array(self):
        return np.asarray(self.mm, dtype=self.dtype)

    def provider(self, s: int, *, seed: int = 0, with_replacement: bool = True,
                 dtype=None):
        mm = self.mm
        m = mm.shape[0]
        # Explicit request wins; None falls back to the source's own dtype.
        dtype = self.dtype if dtype is None else dtype

        def fetch(chunk_id: int) -> np.ndarray:
            idx = self._uniform_chunk_ids(m, s, seed, chunk_id,
                                          with_replacement)
            return np.asarray(mm[idx], dtype=dtype)

        return fetch


class ProviderSource(_SourceBase):
    """A user ``chunk_id -> [s, n]`` callable (the runner's native contract).

    ``n_features`` is probed from chunk 0 if not given.  The callable owns
    the chunk size; the config's ``s`` should match what it serves.
    """

    prefers_streaming = True

    def __init__(self, fn: Callable[[int], np.ndarray], *,
                 n_features: int | None = None, n_rows: int | None = None):
        self.fn = fn
        self._n_features = n_features
        self.n_rows = n_rows
        self._probe: np.ndarray | None = None

    @property
    def in_core(self) -> bool:
        return False

    @property
    def n_features(self) -> int:
        if self._n_features is None:
            probe = np.asarray(self.fn(0))
            if probe.ndim != 2:
                raise ValueError(
                    f"provider returned shape {probe.shape}; expected [s, n]")
            # cache the probed chunk: provider may be expensive or
            # non-idempotent, and the run will ask for chunk 0 first anyway
            self._probe = probe
            self._n_features = int(probe.shape[1])
        return self._n_features

    def provider(self, s: int, *, seed: int = 0, with_replacement: bool = True,
                 dtype=None):
        dtype = np.float32 if dtype is None else dtype

        # the callable owns chunk contents; sampling knobs don't apply
        def fetch(chunk_id: int) -> np.ndarray:
            if chunk_id == 0 and self._probe is not None:
                out, self._probe = self._probe, None
                return np.asarray(out, dtype=dtype)
            return np.asarray(self.fn(chunk_id), dtype=dtype)

        return fetch


class IteratorSource(_SourceBase):
    """A stream of ``[s, n]`` chunk arrays (generator, DataLoader, socket...).

    Chunks are consumed in order; a small reorder cache absorbs the
    out-of-order ids a prefetch queue may request.  One-shot: a second fit
    over the same iterator continues where the first stopped.  When the
    stream runs dry before the chunk budget, the run ends cleanly
    (``EndOfStream``) instead of counting phantom fetch failures.
    """

    prefers_streaming = True

    def __init__(self, chunks: Iterable, *, n_features: int | None = None):
        self._it = iter(chunks)
        self._cache: dict[int, np.ndarray] = {}
        self._next_seq = 0
        self._n_features = n_features

    @property
    def in_core(self) -> bool:
        return False

    @property
    def n_features(self) -> int:
        if self._n_features is None:
            first = np.asarray(next(self._it))
            self._cache[self._next_seq] = first
            self._next_seq += 1
            self._n_features = int(first.shape[1])
        return self._n_features

    def provider(self, s: int, *, seed: int = 0, with_replacement: bool = True,
                 dtype=None):
        from repro.cluster.runner import EndOfStream

        dtype = np.float32 if dtype is None else dtype

        def fetch(chunk_id: int) -> np.ndarray:
            while chunk_id not in self._cache:
                try:
                    self._cache[self._next_seq] = np.asarray(next(self._it))
                except StopIteration:
                    raise EndOfStream(
                        f"chunk stream exhausted before chunk {chunk_id}"
                    ) from None
                self._next_seq += 1
            return np.asarray(self._cache.pop(chunk_id), dtype=dtype)

        return fetch


def as_source(data: Any, *, n_features: int | None = None) -> DataSource:
    """Coerce anything reasonable into a :class:`DataSource`.

    * ``DataSource`` — passed through;
    * 2-D array (np / jax) — :class:`ArraySource`;
    * ``str`` / ``os.PathLike`` (an ``.npy`` path) — :class:`MemmapSource`;
    * callable — :class:`ProviderSource`;
    * iterable / iterator of chunks — :class:`IteratorSource`.
    """
    if isinstance(data, (ArraySource, MemmapSource, ProviderSource,
                         IteratorSource)):
        return data
    if isinstance(data, DataSource) and not callable(data):
        return data
    if isinstance(data, (str, os.PathLike)):
        return MemmapSource(data)
    if hasattr(data, "ndim") and hasattr(data, "shape"):
        return ArraySource(data)
    if callable(data):
        return ProviderSource(data, n_features=n_features)
    if hasattr(data, "__iter__") or hasattr(data, "__next__"):
        return IteratorSource(data, n_features=n_features)
    raise TypeError(
        f"cannot build a DataSource from {type(data).__name__}; pass an "
        "array, an .npy path, a provider(chunk_id) callable, an iterator of "
        "chunks, or a DataSource")
