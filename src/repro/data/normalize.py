"""Feature normalization (the paper evaluates min-max normalized variants)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def minmax_normalize(x: jax.Array, eps: float = 1e-12) -> jax.Array:
    lo = jnp.min(x, axis=0, keepdims=True)
    hi = jnp.max(x, axis=0, keepdims=True)
    return (x - lo) / jnp.maximum(hi - lo, eps)


def streaming_minmax(chunks) -> tuple[jax.Array, jax.Array]:
    """One pass over an iterable of chunks -> (lo, hi) per feature.

    The paper notes normalization is ideally folded into data collection; this
    helper is the single-extra-pass fallback for stored datasets.
    """
    lo = hi = None
    for c in chunks:
        clo = jnp.min(c, axis=0)
        chi = jnp.max(c, axis=0)
        lo = clo if lo is None else jnp.minimum(lo, clo)
        hi = chi if hi is None else jnp.maximum(hi, chi)
    return lo, hi
