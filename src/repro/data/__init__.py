from repro.data.synthetic import gmm_dataset, gmm_memmap, paper_surrogate
from repro.data.normalize import minmax_normalize
