from repro.data.synthetic import gmm_dataset, paper_surrogate
from repro.data.normalize import minmax_normalize
