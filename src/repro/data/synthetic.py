"""Deterministic synthetic datasets.

The paper's 19 real datasets are not reachable offline; benchmarks use
Gaussian-mixture *surrogates* with the same (m, n) and a controlled cluster
structure.  Generation is chunk-streamable: ``gmm_chunk(seed, chunk_id)``
produces the same rows regardless of how many chunks are materialized at
once, so the out-of-core runner and the in-core tests see identical data.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class GMMSpec(NamedTuple):
    m: int                 # number of points
    n: int                 # feature dimension
    components: int        # true mixture components
    spread: float = 5.0    # component-mean scale relative to unit noise
    noise: float = 1.0
    seed: int = 0


def _component_params(spec: GMMSpec) -> tuple[jax.Array, jax.Array]:
    key = jax.random.PRNGKey(spec.seed)
    kmu, kw = jax.random.split(key)
    means = jax.random.normal(kmu, (spec.components, spec.n)) * spec.spread
    logits = jax.random.uniform(kw, (spec.components,), minval=-0.5, maxval=0.5)
    return means, logits


@functools.partial(jax.jit, static_argnames=("spec", "chunk_size"))
def gmm_chunk(spec: GMMSpec, chunk_id: int, chunk_size: int) -> jax.Array:
    """Rows [chunk_id*chunk_size, ...) of the virtual dataset. [chunk_size, n]."""
    means, logits = _component_params(spec)
    key = jax.random.fold_in(jax.random.PRNGKey(spec.seed + 1), chunk_id)
    kc, kn = jax.random.split(key)
    comp = jax.random.categorical(kc, logits, shape=(chunk_size,))
    noise = jax.random.normal(kn, (chunk_size, spec.n)) * spec.noise
    return means[comp] + noise


# Generation width shared by gmm_dataset and gmm_memmap.  gmm_chunk folds
# the chunk *id* into the PRNG, so rows depend on this width: both
# materializers must use the same value or they produce different data.
_GEN_CHUNK = 1 << 16


def gmm_dataset(spec: GMMSpec) -> jax.Array:
    """Materialize the full [m, n] dataset (in-core use)."""
    chunk = _GEN_CHUNK
    nchunks = -(-spec.m // chunk)
    parts = [np.asarray(gmm_chunk(spec, i, chunk)) for i in range(nchunks)]
    return jnp.asarray(np.concatenate(parts, axis=0)[: spec.m])


def gmm_memmap(spec: GMMSpec, path: str) -> str:
    """Materialize the dataset to an on-disk ``.npy`` memmap, chunk by chunk.

    Bounded RAM (one generation chunk at a time) and bitwise deterministic
    for a given (spec, backend).  The generation chunking is pinned to
    ``gmm_dataset``'s (``_GEN_CHUNK``), so the memmap holds byte-identical
    rows to the in-core path.  Returns ``path``.
    """
    chunk = _GEN_CHUNK
    out = np.lib.format.open_memmap(
        path, mode="w+", dtype=np.float32, shape=(spec.m, spec.n))
    nchunks = -(-spec.m // chunk)
    for i in range(nchunks):
        lo = i * chunk
        hi = min(lo + chunk, spec.m)
        out[lo:hi] = np.asarray(gmm_chunk(spec, i, chunk),
                                dtype=np.float32)[: hi - lo]
    out.flush()
    del out
    return path


# (m, n) signatures of the paper's datasets (Table 1), used as surrogate
# shapes in benchmarks — scaled down by `scale` to fit the CPU container.
PAPER_DATASETS: dict[str, tuple[int, int]] = {
    "cord19": (599616, 768),
    "hepmass": (10500000, 28),
    "uscensus": (2458285, 68),
    "gisette": (13500, 5000),
    "music": (106574, 518),
    "protein": (145751, 74),
    "miniboone": (130064, 50),
    "mfcc": (85134, 58),
    "isolet": (7797, 617),
    "sensorless": (58509, 48),
    "news": (39644, 58),
    "gas": (13910, 128),
    "road3d": (434874, 3),
    "kegg": (53413, 20),
    "skin": (245057, 3),
    "shuttle": (58000, 9),
    "eeg": (14980, 14),
    "pla85900": (85900, 2),
    "d15112": (15112, 2),
}


def paper_surrogate(
    name: str, *, scale: float = 1.0, components: int = 25, seed: int = 0
) -> tuple[GMMSpec, jax.Array]:
    """GMM surrogate with the paper dataset's aspect (m scaled, n exact)."""
    m, n = PAPER_DATASETS[name]
    m = max(int(m * scale), 1024)
    spec = GMMSpec(m=m, n=n, components=components, seed=seed)
    return spec, gmm_dataset(spec)
