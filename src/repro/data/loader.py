"""Out-of-core dataset loaders for the streaming runner.

``MemmapProvider`` serves uniform random chunks from an .npy file without
loading it (the production path for the paper's GB-scale datasets);
``csv_to_npy`` is the one-off ingestion helper (streaming, bounded RAM).
Chunks are sampled with a counter-based PRNG keyed on (seed, chunk_id), so
restarts and elastic worker counts replay identical streams (DESIGN §6).
"""
from __future__ import annotations

import csv as _csv
import os

import numpy as np


class MemmapProvider:
    """provider(chunk_id) -> [s, n] float32, uniform with replacement."""

    def __init__(self, path: str, s: int, *, seed: int = 0,
                 dtype=np.float32):
        self.mm = np.load(path, mmap_mode="r")
        assert self.mm.ndim == 2, self.mm.shape
        self.s = s
        self.seed = seed
        self.dtype = dtype

    @property
    def shape(self):
        return self.mm.shape

    def __call__(self, chunk_id: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, chunk_id))
        idx = rng.integers(0, self.mm.shape[0], size=self.s)
        idx.sort()                      # mostly-sequential reads off disk
        return np.asarray(self.mm[idx], dtype=self.dtype)


def csv_to_npy(csv_path: str, npy_path: str, *, skip_header: bool = True,
               usecols=None, batch_rows: int = 65536) -> tuple[int, int]:
    """Stream a numeric CSV into a .npy (two passes, O(batch) RAM).

    Returns (rows, cols).  Use once at ingestion; MemmapProvider serves the
    result forever after.
    """
    # pass 1: count rows / detect width
    with open(csv_path, newline="") as f:
        reader = _csv.reader(f)
        if skip_header:
            next(reader)
        first = next(reader)
        cols = len(usecols) if usecols else len(first)
        rows = 1 + sum(1 for _ in reader)

    out = np.lib.format.open_memmap(
        npy_path, mode="w+", dtype=np.float32, shape=(rows, cols))
    with open(csv_path, newline="") as f:
        reader = _csv.reader(f)
        if skip_header:
            next(reader)
        buf, written = [], 0
        for row in reader:
            vals = [row[i] for i in usecols] if usecols else row
            buf.append(vals)
            if len(buf) >= batch_rows:
                out[written:written + len(buf)] = np.asarray(buf, np.float32)
                written += len(buf)
                buf = []
        if buf:
            out[written:written + len(buf)] = np.asarray(buf, np.float32)
            written += len(buf)
    out.flush()
    assert written == rows, (written, rows)
    return rows, cols


def sharded_provider(provider, worker: int, n_workers: int):
    """Partition one chunk stream across workers by chunk id (for host-level
    multi-process deployments where each worker owns disjoint chunk ids)."""
    def shard(chunk_id: int):
        return provider(chunk_id * n_workers + worker)
    return shard
