"""Multi-start K-means: n_init restarts (Forgy or K-means++ init), keep best.

This is the paper's "K-means++" competitor column when ``init='kmeans++'``
and the classical multi-start K-means when ``init='forgy'``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import kmeans
from repro.core.kmeanspp import kmeanspp


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_init", "init", "candidates", "max_iters", "tol", "impl"),
)
def multistart_kmeans(
    X: jax.Array,
    key: jax.Array,
    *,
    k: int,
    n_init: int = 3,
    init: str = "kmeans++",
    candidates: int = 3,
    max_iters: int = 300,
    tol: float = 1e-4,
    impl: str = "auto",
) -> kmeans.KMeansResult:
    def one(key):
        if init == "kmeans++":
            c0 = kmeanspp(X, key, k, candidates=candidates)
        elif init == "forgy":
            idx = jax.random.choice(key, X.shape[0], (k,), replace=False)
            c0 = X[idx]
        else:
            raise ValueError(init)
        res = kmeans.lloyd(X, c0, max_iters=max_iters, tol=tol, impl=impl)
        return res

    def body(best, key):
        res = one(key)
        better = res.objective < best.objective
        take = lambda a, b: jnp.where(
            jnp.reshape(better, (1,) * a.ndim), a, b
        )
        return jax.tree.map(take, res, best), res.objective

    keys = jax.random.split(key, n_init)
    first = one(keys[0])
    best, objs = jax.lax.scan(body, first, keys[1:])
    return best
