"""Forgy K-means (paper §5.2): uniform-k-point init + full-data Lloyd."""
from __future__ import annotations

import functools

import jax

from repro.core import kmeans


@functools.partial(jax.jit, static_argnames=("k", "max_iters", "tol", "impl"))
def forgy_kmeans(
    X: jax.Array,
    key: jax.Array,
    *,
    k: int,
    max_iters: int = 300,
    tol: float = 1e-4,
    impl: str = "auto",
) -> kmeans.KMeansResult:
    idx = jax.random.choice(key, X.shape[0], (k,), replace=False)
    c0 = X[idx]
    return kmeans.lloyd(X, c0, max_iters=max_iters, tol=tol, impl=impl)
