"""Lightweight coresets (Bachem et al., paper §5.1 eq. (10))."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import kmeans
from repro.core.kmeanspp import kmeanspp


@functools.partial(
    jax.jit, static_argnames=("k", "s", "candidates", "max_iters", "tol", "impl")
)
def lightweight_coreset_kmeans(
    X: jax.Array,
    key: jax.Array,
    *,
    k: int,
    s: int,
    candidates: int = 3,
    max_iters: int = 300,
    tol: float = 1e-4,
    impl: str = "auto",
) -> kmeans.KMeansResult:
    """Build an (eps,k)-lightweight coreset of size s, cluster it weighted."""
    X = X.astype(jnp.float32)
    m = X.shape[0]
    mu = jnp.mean(X, axis=0)
    dmu = jnp.sum((X - mu) ** 2, axis=1)                   # two-pass: q(x)
    q = 0.5 / m + 0.5 * dmu / jnp.maximum(jnp.sum(dmu), 1e-30)

    key, ks, kc = jax.random.split(key, 3)
    idx = jax.random.categorical(ks, jnp.log(q), shape=(s,))
    C = X[idx]
    w = 1.0 / (s * q[idx])                                 # unbiased weights

    c0 = kmeanspp(C, kc, k, candidates=candidates, weights=w)
    return kmeans.lloyd(C, c0, weights=w, max_iters=max_iters, tol=tol,
                        impl=impl)
