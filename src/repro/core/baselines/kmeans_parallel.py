"""K-means|| (Bahmani et al., paper §5.3) — scalable K-means++.

Fixed-shape JAX adaptation: the original samples each point independently
with probability min(1, l*d(x)/phi) per round (variable count); we sample
exactly ``l`` points per round from the same distribution (multinomial with
replacement).  The expected oversampling per round matches; the deviation is
documented in DESIGN.md.  Paper settings: l = 2k, r = 5 rounds for the
largest datasets, r = log(psi) otherwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import kmeans
from repro.core.kmeanspp import kmeanspp
from repro.core.kmeanspp import _safe_d2_logits
from repro.kernels import ops, ref


@functools.partial(
    jax.jit, static_argnames=("k", "l", "rounds", "max_iters", "tol", "impl")
)
def kmeans_parallel(
    X: jax.Array,
    key: jax.Array,
    *,
    k: int,
    l: int | None = None,
    rounds: int = 5,
    max_iters: int = 300,
    tol: float = 1e-4,
    impl: str = "auto",
) -> kmeans.KMeansResult:
    X = X.astype(jnp.float32)
    m, n = X.shape
    if l is None:
        l = 2 * k                                    # paper's optimal setting

    key, k0 = jax.random.split(key)
    first = X[jax.random.randint(k0, (), 0, m)]
    pool = jnp.zeros((1 + l * rounds, n), jnp.float32).at[0].set(first)
    d = ref.min_update_ref(jnp.full((m,), jnp.inf, jnp.float32), X, first)

    def round_body(r, carry):
        key, pool, d = carry
        key, kr = jax.random.split(key)
        idx = jax.random.categorical(kr, _safe_d2_logits(d), shape=(l,))
        newpts = X[idx]                              # [l, n]
        pool = jax.lax.dynamic_update_slice(pool, newpts, (1 + r * l, 0))
        dc = ref.pairwise_sqdist_ref(X, newpts)      # [m, l]
        d = jnp.minimum(d, jnp.min(dc, axis=1))
        return key, pool, d

    key, pool, d = jax.lax.fori_loop(0, rounds, round_body, (key, pool, d))

    # Weight pool members by the number of dataset points closest to them,
    # then recluster the weighted pool down to k with K-means++ and Lloyd.
    ids, _ = ops.assign(X, pool, impl=impl)
    _, w = ops.update(X, ids, pool.shape[0], impl=impl)
    key, k1 = jax.random.split(key)
    c0 = kmeanspp(pool, k1, k, weights=w)
    pooled = kmeans.lloyd(pool, c0, weights=w, max_iters=max_iters, tol=tol,
                          impl=impl)
    # Final Lloyd on the full dataset from the K-means|| seeds.
    return kmeans.lloyd(X, pooled.centroids, max_iters=max_iters, tol=tol,
                        impl=impl)
