"""Decomposition/Aggregation MSSC (paper §5.4).

Phase 1: partition a sample of the data into q independent chunks, cluster
each into k clusters (K-means++ init + Lloyd), pool all q*k centroids
weighted by their cluster sizes.  Phase 2: cluster the weighted pool into k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import kmeans
from repro.core.kmeanspp import kmeanspp


@functools.partial(
    jax.jit,
    static_argnames=("k", "s", "q", "candidates", "max_iters", "tol", "impl"),
)
def da_mssc(
    X: jax.Array,
    key: jax.Array,
    *,
    k: int,
    s: int,
    q: int,
    candidates: int = 3,
    max_iters: int = 300,
    tol: float = 1e-4,
    impl: str = "auto",
) -> kmeans.KMeansResult:
    X = X.astype(jnp.float32)
    m, n = X.shape

    key, kperm = jax.random.split(key)
    idx = jax.random.randint(kperm, (q, s), 0, m)          # q chunks of size s
    chunks = X[idx]                                        # [q, s, n]

    def cluster_chunk(chunk, key):
        c0 = kmeanspp(chunk, key, k, candidates=candidates)
        res = kmeans.lloyd(chunk, c0, max_iters=max_iters, tol=tol, impl=impl)
        return res.centroids, res.counts

    keys = jax.random.split(key, q + 1)
    cents, counts = jax.lax.map(
        lambda args: cluster_chunk(*args), (chunks, keys[1:])
    )                                                      # [q,k,n], [q,k]
    pool = cents.reshape(q * k, n)
    w = counts.reshape(q * k)

    c0 = kmeanspp(pool, keys[0], k, candidates=candidates, weights=w)
    return kmeans.lloyd(pool, c0, weights=w, max_iters=max_iters, tol=tol,
                        impl=impl)
