"""Ward's agglomerative clustering (paper §5.5) — nearest-neighbor chain.

Deterministic, O(m^2) memory / ~O(m^2) time via the NN-chain algorithm with
the Lance-Williams update for Ward's criterion.  As in the paper, this is a
small/medium-data baseline only (it exhausts RAM on big data — that failure
mode is part of the paper's point and is reproduced by the m^2 matrix).
Implemented in NumPy: hierarchical merging is inherently sequential/dynamic
and does not benefit from jit.
"""
from __future__ import annotations

import numpy as np


def ward(X, k: int):
    """Cluster rows of X into k clusters.  Returns (centroids [k,n], labels [m])."""
    X = np.asarray(X, dtype=np.float64)
    m, n = X.shape
    if m > 20000:
        raise MemoryError(
            f"Ward's method needs an O(m^2) distance matrix; m={m} is 'big "
            "data' by the paper's definition and intentionally unsupported."
        )
    # Ward distance between singletons is ||a-b||^2 / 2 * (1*1/(1+1)) — any
    # monotone scaling works; use d = ||a-b||^2 * (na*nb)/(na+nb).
    sq = np.sum(X * X, axis=1)
    d = sq[:, None] + sq[None, :] - 2.0 * (X @ X.T)
    np.maximum(d, 0.0, out=d)
    d *= 0.5                                                 # na=nb=1
    np.fill_diagonal(d, np.inf)

    size = np.ones(m)
    active = np.ones(m, dtype=bool)
    parent = np.arange(m)
    n_active = m
    chain: list[int] = []

    while n_active > k:
        if not chain:
            chain.append(int(np.argmax(active)))
        while True:
            a = chain[-1]
            row = d[a].copy()
            row[~active] = np.inf
            row[a] = np.inf
            b = int(np.argmin(row))
            if len(chain) > 1 and b == chain[-2]:
                break                                        # reciprocal pair
            chain.append(b)
        b = chain.pop()
        a = chain.pop()
        # Lance-Williams (Ward): d(ab, c)
        na, nb, nc = size[a], size[b], size
        dab = d[a, b]
        new = ((na + nc) * d[a] + (nb + nc) * d[b] - nc * dab) / (na + nb + nc)
        d[a, :] = new
        d[:, a] = new
        d[a, a] = np.inf
        active[b] = False
        d[b, :] = np.inf
        d[:, b] = np.inf
        size[a] = na + nb
        parent[parent == b] = a
        n_active -= 1

    # Labels: compress the union roots into [0, k).
    roots = np.flatnonzero(active)
    lut = {int(r): i for i, r in enumerate(roots)}
    # parent holds direct merge targets; resolve transitively.
    lab = parent.copy()
    for _ in range(m):  # bounded; usually converges in a few passes
        nxt = parent[lab]
        if np.array_equal(nxt, lab):
            break
        lab = nxt
    labels = np.array([lut[int(r)] for r in lab])
    centroids = np.stack([X[labels == i].mean(axis=0) for i in range(k)])
    return centroids.astype(np.float32), labels.astype(np.int32)
