"""Competitor algorithms from the paper's §5 (implemented, not stubbed)."""
from repro.core.baselines.forgy import forgy_kmeans
from repro.core.baselines.multistart import multistart_kmeans
from repro.core.baselines.kmeans_parallel import kmeans_parallel
from repro.core.baselines.coreset import lightweight_coreset_kmeans
from repro.core.baselines.da_mssc import da_mssc
from repro.core.baselines.ward import ward

__all__ = [
    "forgy_kmeans",
    "multistart_kmeans",
    "kmeans_parallel",
    "lightweight_coreset_kmeans",
    "da_mssc",
    "ward",
]
