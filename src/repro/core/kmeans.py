"""K-means (Lloyd) local search — Algorithm 1 of the paper.

Implemented as a ``lax.while_loop`` over fused assignment/update steps so it
jits, shards, and nests inside the Big-means chunk scan.  Convergence follows
the paper's experimental setting: relative objective tolerance OR an
iteration cap.  Degenerate (empty) clusters keep their previous position and
are reported in the result mask — Big-means re-seeds them with K-means++ on
the next chunk (the paper's degeneracy strategy).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


class KMeansResult(NamedTuple):
    centroids: jax.Array       # [k, n] f32
    objective: jax.Array       # scalar f32: f(C_final, P)
    counts: jax.Array          # [k] f32 cluster sizes at the final assignment
    degenerate: jax.Array      # [k] bool: counts == 0
    iterations: jax.Array      # scalar i32: Lloyd iterations executed
    assignments: jax.Array     # [m] i32


class _Carry(NamedTuple):
    centroids: jax.Array
    f_prev: jax.Array
    f_curr: jax.Array
    it: jax.Array


@functools.partial(jax.jit, static_argnames=("max_iters", "tol", "impl"))
def lloyd(
    points: jax.Array,
    init_centroids: jax.Array,
    weights: jax.Array | None = None,
    *,
    max_iters: int = 300,
    tol: float = 1e-4,
    impl: str = "auto",
) -> KMeansResult:
    """Run Lloyd's algorithm from ``init_centroids`` on an in-memory chunk.

    ``weights`` enables the weighted variant used by coreset / K-means||
    baselines (w_i multiplies both the objective and the centroid update).
    """
    if points.dtype != jnp.bfloat16:
        points = points.astype(jnp.float32)
    init_centroids = init_centroids.astype(jnp.float32)
    k = init_centroids.shape[0]
    inf = jnp.float32(jnp.inf)

    def step(c):
        # single-HBM-pass fused kernel on TPU; two-pass fallback elsewhere
        sums, counts, f = ops.fused_step(points, c, weights=weights, impl=impl)
        new_c = jnp.where(counts[:, None] > 0, sums / counts[:, None], c)
        return new_c, f

    def cond(s: _Carry):
        # Relative-tolerance convergence on consecutive objectives (paper §5.7):
        # stop when |f_prev - f_curr| <= tol * f_prev, or at the iteration cap.
        # The first two iterations run unconditionally (f_prev/f_curr start inf).
        converged = jnp.abs(s.f_prev - s.f_curr) <= tol * jnp.abs(s.f_prev)
        return jnp.logical_and(
            s.it < max_iters, jnp.logical_or(s.it < 2, ~converged)
        )

    def body(s: _Carry):
        new_c, f = step(s.centroids)
        return _Carry(new_c, s.f_curr, f, s.it + 1)

    init = _Carry(init_centroids, inf, inf, jnp.int32(0))
    final = jax.lax.while_loop(cond, body, init)

    # One last assignment against the final centroids: exact f(C, P), final
    # cluster sizes and the degeneracy mask (counts are those of the *final*
    # centroids, which is what Big-means' re-seeding needs).
    ids, d = ops.assign(points, final.centroids, impl=impl)
    _, counts = ops.update(points, ids, k, weights=weights, impl=impl)
    f = jnp.sum(d * weights) if weights is not None else jnp.sum(d)
    return KMeansResult(
        centroids=final.centroids,
        objective=f,
        counts=counts,
        degenerate=counts == 0,
        iterations=final.it,
        assignments=ids,
    )
