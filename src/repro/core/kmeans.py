"""K-means (Lloyd) local search — Algorithm 1 of the paper.

Implemented as a *masked bounded iteration*: the loop carry holds a
per-search ``active`` flag and every update is gated on it, so a converged
search becomes a no-op while the loop keeps running.  For a single chunk
this is exactly the old ``while_loop`` semantics (the loop exits as soon as
``active`` drops), but the scheme is also ``jax.vmap``-able: vmapping over a
``[B, s, n]`` chunk batch turns the condition into "any stream active" and
the masking keeps converged streams frozen — B concurrent Lloyd searches in
one fused computation, with exact per-stream iteration counts for the
paper's ``n_d`` accounting.

:func:`lloyd_batched` is the explicitly batched variant: same masked
scheme over a leading batch axis, routed through the batched fused kernel
(``ops.fused_step_batched``) so all B streams advance in one kernel launch
per iteration.

Convergence follows the paper's experimental setting: relative objective
tolerance OR an iteration cap.  Degenerate (empty) clusters keep their
previous position and are reported in the result mask — Big-means re-seeds
them with K-means++ on the next chunk (the paper's degeneracy strategy).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels import precision as px


class KMeansResult(NamedTuple):
    centroids: jax.Array       # [k, n] f32            (batched: [B, k, n])
    objective: jax.Array       # scalar f32: f(C_final, P)        ([B])
    counts: jax.Array          # [k] f32 final cluster sizes      ([B, k])
    degenerate: jax.Array      # [k] bool: counts == 0            ([B, k])
    iterations: jax.Array      # scalar i32: Lloyd iterations     ([B])
    assignments: jax.Array     # [m] i32                          ([B, m])


class _Carry(NamedTuple):
    centroids: jax.Array
    f_prev: jax.Array
    f_curr: jax.Array
    it: jax.Array
    active: jax.Array


def _advance(step_fn, s: _Carry, *, max_iters: int, tol: float,
             bcast) -> _Carry:
    """One masked Lloyd iteration: inactive streams are no-ops.

    ``bcast`` reshapes the [B]-shaped (or scalar) active mask for the
    centroid arrays.  The convergence test reproduces the paper's §5.7
    rule — stop when |f_prev - f_curr| <= tol * |f_prev|, or at the
    iteration cap; the first two iterations run unconditionally.
    """
    new_c, f = step_fn(s.centroids)
    act = s.active
    new_c = jnp.where(bcast(act), new_c, s.centroids)
    f_prev = jnp.where(act, s.f_curr, s.f_prev)
    f_curr = jnp.where(act, f, s.f_curr)
    it = s.it + act.astype(jnp.int32)
    converged = jnp.abs(f_prev - f_curr) <= tol * jnp.abs(f_prev)
    keep_going = jnp.logical_and(
        it < max_iters, jnp.logical_or(it < 2, ~converged)
    )
    return _Carry(new_c, f_prev, f_curr, it, jnp.logical_and(act, keep_going))


@functools.partial(
    jax.jit, static_argnames=("max_iters", "tol", "impl", "precision"))
def lloyd(
    points: jax.Array,
    init_centroids: jax.Array,
    weights: jax.Array | None = None,
    *,
    max_iters: int = 300,
    tol: float = 1e-4,
    impl: str = "auto",
    precision: str = "auto",
) -> KMeansResult:
    """Run Lloyd's algorithm from ``init_centroids`` on an in-memory chunk.

    ``weights`` enables the weighted variant used by coreset / K-means||
    baselines (w_i multiplies both the objective and the centroid update).
    ``precision`` sets the chunk storage / MXU element type (bf16 halves the
    streamed bytes, int8 quarters them); centroids, the objective and the
    convergence test stay f32.

    Under ``'int8'`` the hot loop runs on the quantized chunk (``points``
    may arrive as a pre-quantized
    :class:`~repro.kernels.precision.QuantizedChunk` from the streaming
    engine) while a full-width f32 view is retained for the acceptance
    epilogue below — the same f32-contraction rule the bf16 path follows.
    """
    precision = px.resolve(precision, points.dtype)
    if precision == "int8":
        # Full-width view for the accepting objective; int8 codes for the
        # bandwidth-bound loop.  A pre-quantized chunk dequantizes to the
        # values the contractions actually see — the best view available.
        points_eval = (px.dequantize(points)
                       if isinstance(points, px.QuantizedChunk)
                       else points.astype(jnp.float32))
        points = px.as_quantized(points)
    else:
        points = px.cast_storage(points, precision)
        points_eval = points
    init_centroids = init_centroids.astype(jnp.float32)
    k = init_centroids.shape[0]
    inf = jnp.float32(jnp.inf)

    def step(c):
        # single-HBM-pass fused kernel on TPU; two-pass fallback elsewhere
        sums, counts, f = ops.fused_step(points, c, weights=weights, impl=impl,
                                         precision=precision)
        new_c = jnp.where(counts[:, None] > 0, sums / counts[:, None], c)
        return new_c, f

    def body(s: _Carry):
        return _advance(step, s, max_iters=max_iters, tol=tol,
                        bcast=lambda a: a)

    init = _Carry(init_centroids, inf, inf, jnp.int32(0),
                  jnp.bool_(max_iters > 0))
    final = jax.lax.while_loop(lambda s: s.active, body, init)

    # One last assignment against the final centroids: exact f(C, P), final
    # cluster sizes and the degeneracy mask (counts are those of the *final*
    # centroids, which is what Big-means' re-seeding needs).  This objective
    # is what f_best acceptance compares, so its contractions run f32 even
    # under bf16/int8 storage (on the full-width view): reduced-precision
    # dots in ||x||^2 - 2x.c + ||c||^2 cancel catastrophically for points
    # near their centroid and the clamp at 0 turns that into a one-sided
    # low bias.
    eval_prec = "f32" if precision in ("bf16", "int8") else precision
    ids, d = ops.assign(points_eval, final.centroids, impl=impl,
                        precision=eval_prec)
    upd_x, upd_prec = ((points_eval, "f32") if precision == "int8"
                       else (points, precision))
    _, counts = ops.update(upd_x, ids, k, weights=weights, impl=impl,
                           precision=upd_prec)
    f = jnp.sum(d * weights) if weights is not None else jnp.sum(d)
    return KMeansResult(
        centroids=final.centroids,
        objective=f,
        counts=counts,
        degenerate=counts == 0,
        iterations=final.it,
        assignments=ids,
    )


@functools.partial(
    jax.jit, static_argnames=("max_iters", "tol", "impl", "precision"))
def lloyd_batched(
    points: jax.Array,
    init_centroids: jax.Array,
    *,
    max_iters: int = 300,
    tol: float = 1e-4,
    impl: str = "auto",
    precision: str = "auto",
) -> KMeansResult:
    """B concurrent Lloyd searches: ``points`` [B, s, n], ``init`` [B, k, n].

    Every field of the result gains a leading batch axis.  Each stream stops
    updating once its own tolerance test fires (masked no-op), so
    ``iterations`` matches B independent :func:`lloyd` calls exactly; the
    loop runs until the slowest stream converges.  One fused-kernel launch
    advances all streams per iteration.
    """
    precision = px.resolve(precision, points.dtype)
    if precision == "int8":
        # Same split as `lloyd`: quantized codes drive the loop, a
        # full-width f32 view feeds the acceptance epilogue.
        points_eval = (px.dequantize(points)
                       if isinstance(points, px.QuantizedChunk)
                       else points.astype(jnp.float32))
        points = px.as_quantized(points)
    else:
        points = px.cast_storage(points, precision)
        points_eval = points
    init_centroids = init_centroids.astype(jnp.float32)
    batch, k = init_centroids.shape[0], init_centroids.shape[1]
    inf = jnp.full((batch,), jnp.inf, jnp.float32)

    def step(c):
        sums, counts, f = ops.fused_step_batched(points, c, impl=impl,
                                                 precision=precision)
        new_c = jnp.where(counts[..., None] > 0, sums / counts[..., None], c)
        return new_c, f                          # [B, k, n], [B]

    def body(s: _Carry):
        return _advance(step, s, max_iters=max_iters, tol=tol,
                        bcast=lambda a: a[:, None, None])

    init = _Carry(init_centroids, inf, inf,
                  jnp.zeros((batch,), jnp.int32),
                  jnp.full((batch,), max_iters > 0))
    final = jax.lax.while_loop(lambda s: jnp.any(s.active), body, init)

    # Final per-stream evaluation (same two-pass epilogue as `lloyd`).  The
    # epilogue stays on the jnp oracle (the Pallas kernels are not batched
    # at this callsite), mapped per stream rather than vmapped so each
    # stream's distance matrix stays cache-resident on CPU.
    eff = ops.resolve_impl(impl)
    if eff.startswith("pallas"):
        eff = "ref"

    # Same f32 objective epilogue as `lloyd` (see comment there): the
    # accepting f(C, P) never pays bf16/int8 cancellation — it runs on the
    # full-width view with f32 contractions.
    eval_prec = "f32" if precision in ("bf16", "int8") else precision
    upd_prec = "f32" if precision == "int8" else precision

    def _finalize(xc):
        x, c = xc
        ids_b, d_b = ops.assign(x, c, impl=eff, precision=eval_prec)
        counts_b = ops.update(x, ids_b, k, impl=eff, precision=upd_prec)[1]
        return ids_b, jnp.sum(d_b), counts_b

    ids, f, counts = jax.lax.map(_finalize, (points_eval, final.centroids))
    return KMeansResult(
        centroids=final.centroids,
        objective=f,
        counts=counts,
        degenerate=counts == 0,
        iterations=final.it,
        assignments=ids,
    )
