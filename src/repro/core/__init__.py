"""Paper core: Big-means MSSC decomposition clustering."""
from repro.core.bigmeans import (
    BigMeansState,
    ChunkInfo,
    big_means,
    big_means_sharded,
    chunk_step,
    init_state,
    sample_chunk,
)
from repro.core.kmeans import KMeansResult, lloyd
from repro.core.kmeanspp import kmeanspp, seed
from repro.core.objective import chunk_objective, full_assignment, full_objective

__all__ = [
    "BigMeansState",
    "ChunkInfo",
    "KMeansResult",
    "big_means",
    "big_means_sharded",
    "chunk_objective",
    "chunk_step",
    "full_assignment",
    "full_objective",
    "init_state",
    "kmeanspp",
    "lloyd",
    "sample_chunk",
    "seed",
]
