"""Paper core: Big-means MSSC decomposition clustering."""
from repro.core.bigmeans import (
    BigMeansState,
    ChunkInfo,
    big_means,
    big_means_batched,
    big_means_sharded,
    broadcast_state,
    chunk_step,
    chunk_step_batched,
    init_state,
    reduce_state,
    sample_chunk,
)
from repro.core.kmeans import KMeansResult, lloyd, lloyd_batched
from repro.core.kmeanspp import kmeanspp, seed, seed_batched
from repro.core.objective import chunk_objective, full_assignment, full_objective

__all__ = [
    "BigMeansState",
    "ChunkInfo",
    "KMeansResult",
    "big_means",
    "big_means_batched",
    "big_means_sharded",
    "broadcast_state",
    "chunk_objective",
    "chunk_step",
    "chunk_step_batched",
    "full_assignment",
    "full_objective",
    "init_state",
    "kmeanspp",
    "lloyd",
    "lloyd_batched",
    "reduce_state",
    "sample_chunk",
    "seed",
    "seed_batched",
]
