"""MSSC objective (eq. (1) of the paper) and full-dataset evaluation."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ops


def chunk_objective(
    points: jax.Array,
    centroids: jax.Array,
    weights: jax.Array | None = None,
    *,
    impl: str = "auto",
) -> jax.Array:
    """f(C, P) = sum_i w_i * min_j ||p_i - c_j||^2 on an in-memory chunk."""
    _, d = ops.assign(points, centroids, impl=impl)
    if weights is not None:
        d = d * weights
    return jnp.sum(d)


@functools.partial(jax.jit, static_argnames=("batch", "impl"))
def full_objective(
    points: jax.Array,
    centroids: jax.Array,
    *,
    batch: int = 262144,
    impl: str = "ref_chunked",
) -> jax.Array:
    """Objective over the whole dataset, streamed in batches (bounded RAM)."""
    _, d = ops.assign(points, centroids, impl=impl, chunk=batch)
    return jnp.sum(d)


@functools.partial(jax.jit, static_argnames=("batch", "impl"))
def full_assignment(
    points: jax.Array,
    centroids: jax.Array,
    *,
    batch: int = 262144,
    impl: str = "ref_chunked",
) -> tuple[jax.Array, jax.Array]:
    """Final pass of Algorithm 3 (line 14): assign every point to its centroid."""
    ids, d = ops.assign(points, centroids, impl=impl, chunk=batch)
    return ids, jnp.sum(d)
