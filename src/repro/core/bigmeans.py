"""Big-means (Algorithm 3): the jitted chunk-step core and its state algebra.

This module owns the *numerics* every execution composition reuses
unchanged: :func:`chunk_step` / :func:`chunk_step_batched` (re-seed
degenerate slots, Lloyd, keep-the-best, n_d accounting), the
``BigMeansState`` algebra (:func:`broadcast_state` / :func:`reduce_state` /
the incumbent-exchange helpers) and the uniform :func:`sample_chunk`
decomposition sampler.

The chunk *loops* live in :mod:`repro.engine` — one scheduler / topology /
sync-policy core instead of four hand-rolled drivers.  The historical
entry points remain as thin assemblies of engine pieces, with bit-identical
trajectories:

* :func:`big_means` — the paper's sequential algorithm
  (:func:`repro.engine.incore.sequential`).
* :func:`big_means_batched` — B incumbent streams on one device, optionally
  stream-mesh sharded (``engine.incore.batched_local`` /
  ``batched_stream_mesh``).  ``batch=1`` follows the same key schedule and
  chunk stream as :func:`big_means` (fp-identical on the reference path).
* :func:`big_means_sharded` — multi-worker chunk streams with a periodic
  argmin-all-reduce exchange (``engine.incore.worker_sharded``).
  ``sync_every=1`` is the "collective" mode, ``sync_every=n_chunks`` the
  "competitive" mode; world size 1 recovers the paper exactly.
* ``repro.cluster.runner`` — the out-of-core host loop
  (``engine.stream.run_stream`` + the default middleware stack).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kmeans, kmeanspp


class BigMeansState(NamedTuple):
    centroids: jax.Array     # [k, n] f32 — incumbent C
    degenerate: jax.Array    # [k] bool  — degeneracy mask of the incumbent
    f_best: jax.Array        # scalar f32 — f(C, P_C) on the incumbent's chunk
    n_accepted: jax.Array    # scalar i32
    n_dist_evals: jax.Array  # scalar f32 — paper's n_d counter (analytic)


class ChunkInfo(NamedTuple):
    f_new: jax.Array
    accepted: jax.Array
    lloyd_iters: jax.Array
    n_degenerate: jax.Array


def init_state(k: int, n: int) -> BigMeansState:
    return BigMeansState(
        centroids=jnp.zeros((k, n), jnp.float32),
        degenerate=jnp.ones((k,), bool),
        f_best=jnp.float32(jnp.inf),
        n_accepted=jnp.int32(0),
        n_dist_evals=jnp.float32(0.0),
    )


@functools.partial(
    jax.jit,
    static_argnames=("max_iters", "tol", "candidates", "impl", "precision"),
)
def chunk_step(
    points: jax.Array,
    state: BigMeansState,
    key: jax.Array,
    *,
    max_iters: int = 300,
    tol: float = 1e-4,
    candidates: int = 3,
    impl: str = "auto",
    precision: str = "auto",
) -> tuple[BigMeansState, ChunkInfo]:
    """Process one chunk P (Algorithm 3, lines 5-12)."""
    k = state.centroids.shape[0]
    s = points.shape[0]

    # line 7: re-initialize degenerate centroids with K-means++ on this chunk.
    # Seeding is the identity when no slot is degenerate, so the whole probe
    # loop is skipped at runtime in that (steady-state) case — on CPU the
    # D^2 probes are the dominant per-chunk cost.
    c_init = jax.lax.cond(
        jnp.any(state.degenerate),
        lambda: kmeanspp.seed(
            points, key, k,
            init=state.centroids,
            degenerate=state.degenerate,
            candidates=candidates,
        ),
        lambda: state.centroids.astype(jnp.float32),
    )
    # line 8: local search
    res = kmeans.lloyd(points, c_init, max_iters=max_iters, tol=tol, impl=impl,
                       precision=precision)

    # lines 9-11: keep the best (objectives of equal-size chunks compared)
    accepted = res.objective < state.f_best
    n_deg = jnp.sum(state.degenerate)
    n_d = state.n_dist_evals + jnp.float32(s) * (
        jnp.float32(k) * (res.iterations + 2) + jnp.float32(candidates) * n_deg
    )
    new_state = BigMeansState(
        centroids=jnp.where(accepted, res.centroids, state.centroids),
        degenerate=jnp.where(accepted, res.degenerate, state.degenerate),
        f_best=jnp.where(accepted, res.objective, state.f_best),
        n_accepted=state.n_accepted + accepted.astype(jnp.int32),
        n_dist_evals=n_d,
    )
    info = ChunkInfo(
        f_new=res.objective,
        accepted=accepted,
        lloyd_iters=res.iterations,
        n_degenerate=jnp.sum(res.degenerate),
    )
    return new_state, info


def sample_chunk(
    X: jax.Array, key: jax.Array, s: int, *, with_replacement: bool = True
) -> jax.Array:
    """Uniform random chunk of s rows (the paper's decomposition sampler).

    With replacement by default: for s << m the two schemes are statistically
    indistinguishable and the replacement-free path costs an O(m) permutation.
    """
    m = X.shape[0]
    if with_replacement:
        idx = jax.random.randint(key, (s,), 0, m)
    else:
        idx = jax.random.choice(key, m, (s,), replace=False)
    return jnp.take(X, idx, axis=0)


def big_means(
    X: jax.Array,
    key: jax.Array,
    *,
    k: int,
    s: int,
    n_chunks: int,
    max_iters: int = 300,
    tol: float = 1e-4,
    candidates: int = 3,
    impl: str = "auto",
    with_replacement: bool = True,
    precision: str = "auto",
) -> tuple[BigMeansState, ChunkInfo]:
    """Sequential Big-means over an in-core dataset.  Returns (state, traces).

    Assembly shim: single-device topology, uniform schedule, scalar stream
    (:func:`repro.engine.incore.sequential`).
    """
    from repro.engine import incore

    return incore.sequential(
        X, key, k=k, s=s, n_chunks=n_chunks, max_iters=max_iters, tol=tol,
        candidates=candidates, impl=impl, with_replacement=with_replacement,
        precision=precision)


# ---------------------------------------------------------------------------
# Batched (single-device) chunk parallelism: B incumbent streams advance
# through Lloyd concurrently — the in-core analogue of the sharded driver's
# per-worker streams, with the argmin-exchange done by a gather instead of a
# collective.
# ---------------------------------------------------------------------------


def broadcast_state(state: BigMeansState, batch: int) -> BigMeansState:
    """Tile one incumbent into B streams; the stream counters start at zero
    so :func:`reduce_state` can re-aggregate them onto a base state."""
    zeroed = state._replace(
        n_accepted=jnp.int32(0), n_dist_evals=jnp.float32(0.0)
    )
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (batch,) + jnp.shape(a)), zeroed
    )


def reduce_state(
    states: BigMeansState, base: BigMeansState | None = None
) -> BigMeansState:
    """Argmin-reduce B streams into one incumbent (in-core `_exchange_best`,
    degenerate mask included).  Counters are summed across streams — they
    count work done, not who won — and added onto ``base`` when given."""
    winner = jnp.argmin(states.f_best)
    n_acc = jnp.sum(states.n_accepted)
    n_d = jnp.sum(states.n_dist_evals)
    if base is not None:
        n_acc = n_acc + base.n_accepted
        n_d = n_d + base.n_dist_evals
    return BigMeansState(
        centroids=states.centroids[winner],
        degenerate=states.degenerate[winner],
        f_best=states.f_best[winner],
        n_accepted=n_acc,
        n_dist_evals=n_d,
    )


def _sync_streams(states: BigMeansState) -> BigMeansState:
    """Give every stream the winner's incumbent; counters stay per-stream."""
    winner = jnp.argmin(states.f_best)
    batch = states.f_best.shape[0]

    def tile(a):
        return jnp.broadcast_to(a[winner], (batch,) + a.shape[1:])

    return states._replace(
        centroids=tile(states.centroids),
        degenerate=tile(states.degenerate),
        f_best=tile(states.f_best),
    )


@functools.partial(
    jax.jit,
    static_argnames=("max_iters", "tol", "candidates", "impl", "precision"),
)
def chunk_step_batched(
    points: jax.Array,
    states: BigMeansState,
    keys: jax.Array,
    *,
    max_iters: int = 300,
    tol: float = 1e-4,
    candidates: int = 3,
    impl: str = "auto",
    precision: str = "auto",
) -> tuple[BigMeansState, ChunkInfo]:
    """Process B chunks against B incumbent streams in one fused step.

    points [B, s, n], states with leading batch axis, keys [B, ...].  Per
    stream this is exactly :func:`chunk_step` (re-seed degenerate slots,
    Lloyd, keep-the-best, n_d accounting); across streams everything — the
    K-means++ probes, the Lloyd iterations, the final evaluation — runs as
    one batched computation.
    """
    k = states.centroids.shape[1]
    s = points.shape[1]

    # Same runtime skip as `chunk_step`: when no stream has a degenerate
    # slot (the steady state) the batched probe loop is bypassed entirely.
    c_init = jax.lax.cond(
        jnp.any(states.degenerate),
        lambda: kmeanspp.seed_batched(
            points, keys, k,
            init=states.centroids,
            degenerate=states.degenerate,
            candidates=candidates,
        ),
        lambda: states.centroids.astype(jnp.float32),
    )
    res = kmeans.lloyd_batched(
        points, c_init, max_iters=max_iters, tol=tol, impl=impl,
        precision=precision,
    )

    accepted = res.objective < states.f_best                    # [B]
    n_deg = jnp.sum(states.degenerate, axis=1)                  # [B]
    n_d = states.n_dist_evals + jnp.float32(s) * (
        jnp.float32(k) * (res.iterations + 2)
        + jnp.float32(candidates) * n_deg
    )
    new_states = BigMeansState(
        centroids=jnp.where(
            accepted[:, None, None], res.centroids, states.centroids),
        degenerate=jnp.where(
            accepted[:, None], res.degenerate, states.degenerate),
        f_best=jnp.where(accepted, res.objective, states.f_best),
        n_accepted=states.n_accepted + accepted.astype(jnp.int32),
        n_dist_evals=n_d,
    )
    info = ChunkInfo(
        f_new=res.objective,
        accepted=accepted,
        lloyd_iters=res.iterations,
        n_degenerate=jnp.sum(res.degenerate, axis=1),
    )
    return new_states, info


def big_means_batched(
    X: jax.Array,
    key: jax.Array,
    *,
    k: int,
    s: int,
    batch: int,
    rounds: int,
    sync_every: int = 1,
    max_iters: int = 300,
    tol: float = 1e-4,
    candidates: int = 3,
    impl: str = "auto",
    with_replacement: bool = True,
    precision: str = "auto",
    mesh=None,
    stream_axis: str = "streams",
) -> tuple[BigMeansState, ChunkInfo]:
    """Batched Big-means: B incumbent streams over ``rounds`` chunk rounds.

    Each round samples a ``[batch, s, n]`` chunk batch and advances all
    streams through one :func:`chunk_step_batched`; every ``sync_every``
    rounds the streams exchange incumbents (argmin-reduce, every stream
    continues from the winner).  Returns the final reduced incumbent and a
    ``[rounds * batch]`` trace.  ``batch=1`` recovers the sequential
    :func:`big_means` with ``n_chunks=rounds`` — same key schedule, same
    chunks, same incumbent trajectory (fp-identical on the reference
    path; under the Pallas kernels the batched variant agrees to kernel
    fp tolerance).

    With ``mesh`` (a 1-axis mesh named ``stream_axis``), the stream axis is
    sharded across the mesh devices: each device advances ``batch / ndev``
    streams and the periodic exchange goes through an argmin-all-gather —
    independent chunk streams are exactly the parallelism the paper's
    properties 6-7 promise, so extra devices scale throughput without
    changing the per-stream trajectories (same key schedule as the
    single-device batched driver).

    Assembly shim: uniform schedule + periodic sync on the single-device or
    stream-mesh topology (``repro.engine.incore.batched_local`` /
    ``batched_stream_mesh``).
    """
    from repro.engine import incore

    assert rounds % sync_every == 0, "sync_every must divide rounds"
    if mesh is not None:
        return incore.batched_stream_mesh(
            X, key, mesh=mesh, stream_axis=stream_axis, k=k, s=s,
            batch=batch, rounds=rounds, sync_every=sync_every,
            max_iters=max_iters, tol=tol, candidates=candidates, impl=impl,
            with_replacement=with_replacement, precision=precision,
        )
    return incore.batched_local(
        X, key, k=k, s=s, batch=batch, rounds=rounds, sync_every=sync_every,
        max_iters=max_iters, tol=tol, candidates=candidates, impl=impl,
        with_replacement=with_replacement, precision=precision,
    )


def _exchange_best(state: BigMeansState, axis: str) -> BigMeansState:
    """Keep-the-best across workers: tiny argmin-all-reduce on (f, C)."""
    f_all = jax.lax.all_gather(state.f_best, axis)            # [W]
    winner = jnp.argmin(f_all)
    c_all = jax.lax.all_gather(state.centroids, axis)         # [W, k, n]
    deg_all = jax.lax.all_gather(state.degenerate, axis)      # [W, k]
    return state._replace(
        centroids=c_all[winner],
        degenerate=deg_all[winner],
        f_best=f_all[winner],
    )


def big_means_sharded(
    X: jax.Array,
    key: jax.Array,
    *,
    mesh,
    k: int,
    s: int,
    chunks_per_worker: int,
    sync_every: int = 1,
    axes: tuple[str, ...] = ("data",),
    max_iters: int = 300,
    tol: float = 1e-4,
    candidates: int = 3,
    impl: str = "auto",
    with_replacement: bool = True,
    precision: str = "auto",
) -> tuple[BigMeansState, ChunkInfo]:
    """Multi-worker Big-means: X row-sharded over ``axes``; per-worker chunk
    streams with periodic incumbent exchange.

    Each worker samples chunks from its local shard (uniform placement makes
    local sampling equivalent to global sampling).  PRNG keys are folded with
    the worker index, so results are reproducible for a fixed topology.

    Assembly shim: worker-partitioned schedule + periodic sync on the
    worker-mesh topology (:func:`repro.engine.incore.worker_sharded`).
    """
    from repro.engine import incore

    return incore.worker_sharded(
        X, key, mesh=mesh, k=k, s=s, chunks_per_worker=chunks_per_worker,
        sync_every=sync_every, axes=axes, max_iters=max_iters, tol=tol,
        candidates=candidates, impl=impl, with_replacement=with_replacement,
        precision=precision)
