"""Big-means (Algorithm 3): decomposition-driven global search for MSSC.

Three drivers share one jitted ``chunk_step``:

* :func:`big_means` — the paper's sequential algorithm as a ``lax.scan`` over
  uniformly sampled chunks (in-core dataset).
* :func:`big_means_sharded` — the multi-worker generalization: every worker
  (one group of the ``workers`` mesh axis) runs an independent chunk stream
  against its own incumbent and the incumbents are exchanged by a tiny
  argmin-all-reduce every ``sync_every`` chunks.  ``sync_every=1`` is the
  "collective" mode, ``sync_every=n_chunks`` the "competitive" mode; world
  size 1 recovers the paper exactly.
* ``repro.cluster.runner`` — host-streaming driver (out-of-core data,
  checkpoints, stragglers) built on the same ``chunk_step``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import kmeans, kmeanspp


class BigMeansState(NamedTuple):
    centroids: jax.Array     # [k, n] f32 — incumbent C
    degenerate: jax.Array    # [k] bool  — degeneracy mask of the incumbent
    f_best: jax.Array        # scalar f32 — f(C, P_C) on the incumbent's chunk
    n_accepted: jax.Array    # scalar i32
    n_dist_evals: jax.Array  # scalar f32 — paper's n_d counter (analytic)


class ChunkInfo(NamedTuple):
    f_new: jax.Array
    accepted: jax.Array
    lloyd_iters: jax.Array
    n_degenerate: jax.Array


def init_state(k: int, n: int) -> BigMeansState:
    return BigMeansState(
        centroids=jnp.zeros((k, n), jnp.float32),
        degenerate=jnp.ones((k,), bool),
        f_best=jnp.float32(jnp.inf),
        n_accepted=jnp.int32(0),
        n_dist_evals=jnp.float32(0.0),
    )


@functools.partial(
    jax.jit, static_argnames=("max_iters", "tol", "candidates", "impl")
)
def chunk_step(
    points: jax.Array,
    state: BigMeansState,
    key: jax.Array,
    *,
    max_iters: int = 300,
    tol: float = 1e-4,
    candidates: int = 3,
    impl: str = "auto",
) -> tuple[BigMeansState, ChunkInfo]:
    """Process one chunk P (Algorithm 3, lines 5-12)."""
    k = state.centroids.shape[0]
    s = points.shape[0]

    # line 7: re-initialize degenerate centroids with K-means++ on this chunk
    c_init = kmeanspp.seed(
        points,
        key,
        k,
        init=state.centroids,
        degenerate=state.degenerate,
        candidates=candidates,
    )
    # line 8: local search
    res = kmeans.lloyd(points, c_init, max_iters=max_iters, tol=tol, impl=impl)

    # lines 9-11: keep the best (objectives of equal-size chunks compared)
    accepted = res.objective < state.f_best
    n_deg = jnp.sum(state.degenerate)
    n_d = state.n_dist_evals + jnp.float32(s) * (
        jnp.float32(k) * (res.iterations + 2) + jnp.float32(candidates) * n_deg
    )
    new_state = BigMeansState(
        centroids=jnp.where(accepted, res.centroids, state.centroids),
        degenerate=jnp.where(accepted, res.degenerate, state.degenerate),
        f_best=jnp.where(accepted, res.objective, state.f_best),
        n_accepted=state.n_accepted + accepted.astype(jnp.int32),
        n_dist_evals=n_d,
    )
    info = ChunkInfo(
        f_new=res.objective,
        accepted=accepted,
        lloyd_iters=res.iterations,
        n_degenerate=jnp.sum(res.degenerate),
    )
    return new_state, info


def sample_chunk(
    X: jax.Array, key: jax.Array, s: int, *, with_replacement: bool = True
) -> jax.Array:
    """Uniform random chunk of s rows (the paper's decomposition sampler).

    With replacement by default: for s << m the two schemes are statistically
    indistinguishable and the replacement-free path costs an O(m) permutation.
    """
    m = X.shape[0]
    if with_replacement:
        idx = jax.random.randint(key, (s,), 0, m)
    else:
        idx = jax.random.choice(key, m, (s,), replace=False)
    return jnp.take(X, idx, axis=0)


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "s", "n_chunks", "max_iters", "tol", "candidates", "impl",
        "with_replacement",
    ),
)
def big_means(
    X: jax.Array,
    key: jax.Array,
    *,
    k: int,
    s: int,
    n_chunks: int,
    max_iters: int = 300,
    tol: float = 1e-4,
    candidates: int = 3,
    impl: str = "auto",
    with_replacement: bool = True,
) -> tuple[BigMeansState, ChunkInfo]:
    """Sequential Big-means over an in-core dataset.  Returns (state, traces)."""
    if X.dtype != jnp.bfloat16:
        X = X.astype(jnp.float32)
    state = init_state(k, X.shape[1])

    def body(carry, key_i):
        state = carry
        ks, kc = jax.random.split(key_i)
        chunk = sample_chunk(X, ks, s, with_replacement=with_replacement)
        state, info = chunk_step(
            chunk, state, kc,
            max_iters=max_iters, tol=tol, candidates=candidates, impl=impl,
        )
        return state, info

    keys = jax.random.split(key, n_chunks)
    state, infos = jax.lax.scan(body, state, keys)
    return state, infos


def _exchange_best(state: BigMeansState, axis: str) -> BigMeansState:
    """Keep-the-best across workers: tiny argmin-all-reduce on (f, C)."""
    f_all = jax.lax.all_gather(state.f_best, axis)            # [W]
    winner = jnp.argmin(f_all)
    c_all = jax.lax.all_gather(state.centroids, axis)         # [W, k, n]
    deg_all = jax.lax.all_gather(state.degenerate, axis)      # [W, k]
    return state._replace(
        centroids=c_all[winner],
        degenerate=deg_all[winner],
        f_best=f_all[winner],
    )


def big_means_sharded(
    X: jax.Array,
    key: jax.Array,
    *,
    mesh,
    k: int,
    s: int,
    chunks_per_worker: int,
    sync_every: int = 1,
    axes: tuple[str, ...] = ("data",),
    max_iters: int = 300,
    tol: float = 1e-4,
    candidates: int = 3,
    impl: str = "auto",
    with_replacement: bool = True,
) -> tuple[BigMeansState, ChunkInfo]:
    """Multi-worker Big-means: X row-sharded over ``axes``; per-worker chunk
    streams with periodic incumbent exchange.

    Each worker samples chunks from its local shard (uniform placement makes
    local sampling equivalent to global sampling).  PRNG keys are folded with
    the worker index, so results are reproducible for a fixed topology.
    """
    assert chunks_per_worker % sync_every == 0, "sync_every must divide chunks"
    n_rounds = chunks_per_worker // sync_every
    axis = axes if len(axes) > 1 else axes[0]

    def worker(x_local, key):
        widx = jax.lax.axis_index(axes[0])
        if len(axes) > 1:
            for a in axes[1:]:
                widx = widx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
        key = jax.random.fold_in(key, widx)
        state = init_state(k, x_local.shape[1])

        def round_body(state, key_r):
            def body(state, key_i):
                ks, kc = jax.random.split(key_i)
                chunk = sample_chunk(
                    x_local, ks, s, with_replacement=with_replacement
                )
                return chunk_step(
                    chunk, state, kc,
                    max_iters=max_iters, tol=tol,
                    candidates=candidates, impl=impl,
                )

            keys = jax.random.split(key_r, sync_every)
            state, infos = jax.lax.scan(body, state, keys)
            state = _exchange_best(state, axis)
            return state, infos

        keys = jax.random.split(key, n_rounds)
        state, infos = jax.lax.scan(round_body, state, keys)
        infos = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), infos)
        # distance-eval counter: aggregate across workers (paper's n_d).
        total_nd = jax.lax.psum(state.n_dist_evals, axis)
        total_acc = jax.lax.psum(state.n_accepted, axis)
        state = state._replace(n_dist_evals=total_nd, n_accepted=total_acc)
        return state, infos

    shard = jax.shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axes), P()),
        out_specs=(
            BigMeansState(P(), P(), P(), P(), P()),
            ChunkInfo(*([P(axes[0])] * 4)),
        ),
        check_vma=False,
    )
    xd = X if X.dtype == jnp.bfloat16 else X.astype(jnp.float32)
    return shard(xd, key)
