"""K-means++ seeding (Algorithm 2) and degenerate-cluster re-seeding.

One routine covers both uses in the paper:

* fresh seeding: all k slots are "degenerate" and get sampled;
* Big-means re-initialization (Algorithm 3, line 7): only the degenerate
  slots of the incumbent are re-sampled, distances are measured against the
  union of surviving centroids and already-placed seeds.

Following the paper's experimental setup, each new seed is chosen among
``candidates`` (default 3) D^2-sampled proposals, keeping the one that
minimizes the resulting potential ("greedy K-means++").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import pairwise_sqdist_ref

# np scalar, not jnp: a module-level jnp constant would initialize the XLA
# backend at import time, which breaks jax.distributed.initialize() (it must
# run before the first JAX computation in a multi-host process).  Same f32
# dtype and bits inside every op that consumes it.
_BIG = np.float32(1e30)


def _safe_d2_logits(d: jax.Array) -> jax.Array:
    """log-weights for D^2 sampling; uniform fallback when all distances are 0."""
    total = jnp.sum(d)
    logits = jnp.log(jnp.maximum(d, 1e-30))
    return jnp.where(total > 0, logits, jnp.zeros_like(d))


@functools.partial(jax.jit, static_argnames=("k", "candidates"))
def seed(
    points: jax.Array,
    key: jax.Array,
    k: int,
    *,
    init: jax.Array | None = None,
    degenerate: jax.Array | None = None,
    candidates: int = 3,
    weights: jax.Array | None = None,
) -> jax.Array:
    """Return [k, n] centroids; non-degenerate rows of ``init`` are kept.

    ``weights`` (optional, [s]) makes this the weighted D^2 sampling used by
    the coreset / K-means|| baselines: sampling probabilities and potentials
    are both scaled by w_i.
    """
    if points.dtype != jnp.bfloat16:
        points = points.astype(jnp.float32)
    s, n = points.shape
    w = None if weights is None else weights.astype(jnp.float32)

    if init is None:
        init = jnp.zeros((k, n), jnp.float32)
        degenerate = jnp.ones((k,), bool)
    init = init.astype(jnp.float32)
    assert degenerate is not None

    # Point norms hoisted out of the seeding loop: every candidate-distance
    # probe below reads the chunk once (dot) instead of twice (dot + norm).
    x2 = jnp.sum(jnp.square(points.astype(jnp.float32)), axis=-1,
                 keepdims=True)

    # Distance of every point to the nearest *surviving* centroid.
    d_all = pairwise_sqdist_ref(points, init, x2)                 # [s, k]
    d_all = jnp.where(degenerate[None, :], _BIG, d_all)
    d0 = jnp.minimum(jnp.min(d_all, axis=1), _BIG)                # [s]

    def body(j, carry):
        key, c, d = carry
        key, k1 = jax.random.split(key)
        dw = d if w is None else d * w
        logits = _safe_d2_logits(dw)
        cand_idx = jax.random.categorical(k1, logits, shape=(candidates,))
        cands = points[cand_idx]                                  # [L, n]
        dc = pairwise_sqdist_ref(points, cands, x2)               # [s, L]
        newd = jnp.minimum(d[:, None], dc)                        # [s, L]
        pot = newd if w is None else newd * w[:, None]
        potentials = jnp.sum(pot, axis=0)                         # [L]
        b = jnp.argmin(potentials)
        is_deg = degenerate[j]
        chosen = jnp.where(is_deg, cands[b].astype(c.dtype), c[j])
        d = jnp.where(is_deg, newd[:, b], d)
        return key, c.at[j].set(chosen), d

    _, c, _ = jax.lax.fori_loop(0, k, body, (key, init, d0))
    return c


def kmeanspp(
    points: jax.Array,
    key: jax.Array,
    k: int,
    *,
    candidates: int = 3,
    weights: jax.Array | None = None,
):
    """Fresh K-means++ seeding of k centers (paper Algorithm 2)."""
    return seed(points, key, k, candidates=candidates, weights=weights)


@functools.partial(jax.jit, static_argnames=("k", "candidates"))
def seed_batched(
    points: jax.Array,
    keys: jax.Array,
    k: int,
    *,
    init: jax.Array,
    degenerate: jax.Array,
    candidates: int = 3,
) -> jax.Array:
    """Per-stream re-seeding for B concurrent chunk streams.

    points [B, s, n], keys [B, ...], init [B, k, n], degenerate [B, k] ->
    [B, k, n].  :func:`seed` is vmap-safe (gathers, ``fori_loop`` and
    categorical sampling all batch), so this is one fused computation, not
    B sequential seeding loops.
    """

    def one(p, kk, c0, deg):
        return seed(p, kk, k, init=c0, degenerate=deg, candidates=candidates)

    return jax.vmap(one)(points, keys, init, degenerate)
