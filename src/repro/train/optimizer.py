"""AdamW + LR schedules (self-contained; optax is not available offline).

State layout mirrors params (pytree of (mu, nu)); moments are stored in the
same sharding as the parameters, so under FSDP the optimizer state is
ZeRO-3-sharded for free.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


def constant(lr: float) -> Schedule:
    return lambda step: jnp.float32(lr)


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.0) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return sched


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def adamw(
    lr: Schedule | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float | None = 1.0,
) -> Optimizer:
    sched = constant(lr) if isinstance(lr, (int, float)) else lr

    def init(params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(
            step=jnp.int32(0),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(grads, state: AdamWState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip is not None:
            gnorm = jnp.sqrt(
                sum(jnp.sum(g * g) for g in jax.tree.leaves(grads))
            )
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1t = 1.0 - b1 ** step.astype(jnp.float32)
        b2t = 1.0 - b2 ** step.astype(jnp.float32)
        lr_t = sched(step)

        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)

        def upd(p, m, v):
            mhat = m / b1t
            vhat = v / b2t
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
                jnp.float32
            )
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)
