"""Logical-axis sharding rules (MaxText-style, minimal).

Model code annotates activations with *logical* axes via ``shard(x, ...)``;
parameters get PartitionSpecs from name-based rules.  The mapping to physical
mesh axes adapts to whichever mesh is active:

  single-pod mesh  (data=16, model=16):  fsdp=('data',)           batch=('data',)
  multi-pod  mesh  (pod=2, data=16, model=16): fsdp=('pod','data') batch=('pod','data')

Outside a mesh context (CPU smoke tests) everything is a no-op.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


def _current_mesh() -> Mesh | None:
    return getattr(_ctx, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None):
    prev = _current_mesh()
    _ctx.mesh = mesh
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _ctx.mesh = prev


def physical_axes(mesh: Mesh, logical: str):
    """logical axis name -> physical mesh axes (tuple) or None."""
    names = mesh.axis_names
    batchish = tuple(a for a in ("pod", "data") if a in names)
    table = {
        "batch": batchish,
        "fsdp": batchish,
        "seq": batchish,          # sequence sharding reuses the data axes
        "seqtp": ("model",) if "model" in names else (),  # sequence parallel
        "model": ("model",) if "model" in names else (),
        "expert": ("model",) if "model" in names else (),
        None: (),
    }
    axes = table.get(logical, ())
    return axes if axes else None


def _axis_prod(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    n = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= sizes[a]
    return n


def spec(mesh: Mesh, *logical, shape: tuple | None = None) -> P:
    """PartitionSpec for logical axes; with ``shape`` given, any dim not
    divisible by its mesh-axis product falls back to replicated (e.g. 5 KV
    heads on a 16-way model axis, or a vocab not divisible by 16).

    Singleton physical-axis tuples are normalized to the bare axis name:
    ``P("model", "data")`` and ``P(("model",), ("data",))`` shard
    identically but do not compare equal, and the scalar form is the
    conventional spelling.
    """
    phys = [physical_axes(mesh, a) for a in logical]
    if shape is not None:
        phys = [
            p if p is None or s % _axis_prod(mesh, p) == 0 else None
            for p, s in zip(phys, shape)
        ]
    phys = [
        p[0] if isinstance(p, tuple) and len(p) == 1 else p for p in phys
    ]
    return P(*phys)


def shard(x, *logical):
    """Annotate an activation with logical axes (no-op without a mesh)."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec(mesh, *logical, shape=x.shape))
    )


def seq_axis():
    """Logical axis for the sequence dim of the residual stream: 'seqtp'
    under sequence parallelism (flags.SEQ_PARALLEL), replicated otherwise."""
    from repro.models import flags
    return "seqtp" if flags.SEQ_PARALLEL else None


def kv_cache_logical(mesh: Mesh, shape: tuple) -> tuple:
    """Logical axes for a KV cache [..., B, S, KV, hd] (optionally with a
    leading layer dim).  Batch over the data axes when divisible, else
    sequence over them.  The model axis goes to KV heads when they divide
    it; otherwise (GQA with few KV heads) it shards the *sequence* dim —
    flash-decoding-style partial softmax, collectives inserted by GSPMD —
    instead of replicating the cache TP-ways (see EXPERIMENTS.md §Perf)."""
    from repro.models import flags
    B, S, KV = shape[-4], shape[-3], shape[-2]
    nb = _axis_prod(mesh, physical_axes(mesh, "batch"))
    nm = _axis_prod(mesh, physical_axes(mesh, "model"))
    lead = (None,) * (len(shape) - 4)
    batch_ax, seq_ax = ("batch", None) if B % nb == 0 else (None, "seq")
    if KV % nm == 0:
        return lead + (batch_ax, seq_ax, "model", None)
    if flags.KV_SHARD_SEQ and S % nm == 0 and seq_ax is None:
        return lead + (batch_ax, "seqtp", None, None)
    return lead + (batch_ax, seq_ax, None, None)


def shard_kv_cache(x):
    """Apply the KV-cache rule to a [B, S, KV, hd] activation."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    logical = kv_cache_logical(mesh, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec(mesh, *logical, shape=x.shape))
    )


# ---------------------------------------------------------------------------
# Parameter rules: leaf-name based.  Shapes listed trailing-aligned; a
# leading layer-stack dim gets None automatically.
# ---------------------------------------------------------------------------
_PARAM_RULES: dict[str, tuple] = {
    # embeddings / heads
    "embedding": ("model", "fsdp"),          # [V, D]
    "lm_head": ("fsdp", "model"),            # [D, V]
    "frontend_proj": (None, "fsdp"),         # [raw, D]
    # attention
    "wq": ("fsdp", "model", None),           # [D, H, hd]
    "wk": ("fsdp", "model", None),           # [D, KV, hd]
    "wv": ("fsdp", "model", None),
    "wo": ("model", None, "fsdp"),           # [H, hd, D]
    "q_norm": (None,),
    "k_norm": (None,),
    # dense mlp
    "w_gate": ("fsdp", "model"),             # [D, F]
    "w_up": ("fsdp", "model"),
    "w_down": ("model", "fsdp"),             # [F, D]
    # moe
    "router": ("fsdp", None),                # [D, E]
    "e_gate": ("expert", "fsdp", None),      # [E, D, Fe]
    "e_up": ("expert", "fsdp", None),
    "e_down": ("expert", None, "fsdp"),      # [E, Fe, D]
    # ssm
    "in_proj": ("fsdp", "model"),            # [D, zxbcdt]
    "out_proj": ("model", "fsdp"),           # [d_inner, D]
    "conv_w": (None, "model"),               # [width, channels]
    "conv_b": ("model",),
    "A_log": ("model",),                     # [H]
    "ssm_D": ("model",),
    "dt_bias": ("model",),
    # norms
    "scale": (None,),
}


def param_pspec(path: tuple, shape: tuple) -> tuple:
    """Logical spec for a parameter leaf, derived from its key path."""
    name = None
    for part in reversed(path):
        key = getattr(part, "key", None) or getattr(part, "name", str(part))
        if key in _PARAM_RULES:
            name = key
            break
    if name is None:
        return (None,) * len(shape)
    logical = _PARAM_RULES[name]
    pad = len(shape) - len(logical)
    return (None,) * pad + tuple(logical)


def param_shardings(mesh: Mesh, params_shape):
    """pytree of NamedSharding matching a params (shape) pytree."""

    def leaf(path, x):
        logical = param_pspec(path, x.shape)
        return NamedSharding(mesh, spec(mesh, *logical, shape=x.shape))

    return jax.tree_util.tree_map_with_path(leaf, params_shape)
