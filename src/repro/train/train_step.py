"""Train / serve step factories for the LM zoo.

``make_train_step``: loss -> grad -> AdamW, bf16 compute / fp32 state,
full remat via the scanned stack.  ``make_serve_step``: one decode token
against the KV/SSM cache.  Both are pure functions of (state, batch) so they
lower AOT with explicit shardings in the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.registry import model_fns
from repro.train.optimizer import Optimizer


def make_train_step(cfg, opt: Optimizer):
    mod = model_fns(cfg)

    def train_step(params, opt_state, batch):
        from repro.models import flags
        if flags.BF16_GRADS:
            # differentiate against a bf16 weight copy: gradient
            # reduce-scatters move half the bytes; fp32 master update.
            def loss_of(p16):
                return mod.loss_fn(cfg, p16, batch)

            p16 = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 and p.ndim > 1 else p, params)
            loss, grads = jax.value_and_grad(loss_of)(p16)
        else:
            loss, grads = jax.value_and_grad(
                lambda p: mod.loss_fn(cfg, p, batch)
            )(params)
        new_params, new_opt = opt.update(grads, opt_state, params)
        metrics = {"loss": loss}
        return new_params, new_opt, metrics

    return train_step


def make_serve_step(cfg):
    mod = model_fns(cfg)

    def serve_step(params, cache, token, pos):
        logits, new_cache = mod.decode_step(cfg, params, cache, token, pos)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, new_cache

    return serve_step


def make_prefill_step(cfg, max_seq: int):
    mod = model_fns(cfg)

    if cfg.family == "encdec":
        def prefill_step(params, tokens, frontend):
            return mod.prefill(cfg, params, tokens, frontend, max_seq)
    elif cfg.family == "vlm":
        def prefill_step(params, tokens, frontend):
            return mod.prefill(cfg, params, tokens, max_seq,
                               frontend=frontend)
    else:
        def prefill_step(params, tokens):
            return mod.prefill(cfg, params, tokens, max_seq)

    return prefill_step
