"""The regression gate: fail the build when quality or speed regresses.

Diffs a fresh ``BENCH_suite.json`` against the committed baseline
(``results/BENCH_baseline.json``) cell by cell, with per-metric
tolerances, and exits non-zero on regression — so a PR can no longer
trade clustering quality for throughput silently.

What fails the gate (per (dataset, method) cell):

* ε regression — ``epsilon_mean`` rose more than ``--eps-tol``
  (absolute, in units of relative error: 0.05 = five points of ε);
* success-rate drop beyond ``--success-drop``;
* wall-time regression — ``wall_mean_s`` more than ``--wall-ratio``
  times the baseline's (ratio, not absolute: CI containers are noisy;
  cells faster than ``--wall-floor`` seconds are never wall-gated);
* a baseline cell missing from the fresh run, or either artifact
  failing schema validation.

What only warns: new cells not in the baseline (coverage grew), and ε
*improvements* beyond tolerance (refresh the committed baseline and, if a
run beat the best-known objective, the registry's ``f_star``).

    PYTHONPATH=src python -m repro.evalsuite.gate \
        --baseline results/BENCH_baseline.json --fresh BENCH_suite.json \
        [--report gate_report.txt]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from repro.evalsuite import schema

DEFAULT_EPS_TOL = 0.05       # absolute increase in epsilon_mean
DEFAULT_SUCCESS_DROP = 0.5   # absolute drop in success_rate
DEFAULT_WALL_RATIO = 2.5     # fresh wall_mean_s / baseline wall_mean_s
DEFAULT_WALL_FLOOR = 0.5     # seconds; faster baseline cells aren't gated


@dataclasses.dataclass
class GateResult:
    failures: list = dataclasses.field(default_factory=list)
    warnings: list = dataclasses.field(default_factory=list)
    checked: int = 0

    @property
    def ok(self) -> bool:
        return not self.failures

    def report(self) -> str:
        lines = [f"evalsuite gate: {self.checked} cell(s) compared"]
        for w in self.warnings:
            lines.append(f"  WARN  {w}")
        for f in self.failures:
            lines.append(f"  FAIL  {f}")
        lines.append("RESULT: " + ("PASS" if self.ok else
                                   f"FAIL ({len(self.failures)} regression(s))"))
        return "\n".join(lines)


def _cells(doc: dict) -> dict:
    return {(c["dataset"], c["method"]): c for c in doc["cells"]}


def compare(
    baseline: dict,
    fresh: dict,
    *,
    eps_tol: float = DEFAULT_EPS_TOL,
    success_drop: float = DEFAULT_SUCCESS_DROP,
    wall_ratio: float = DEFAULT_WALL_RATIO,
    wall_floor: float = DEFAULT_WALL_FLOOR,
    check_wall: bool = True,
) -> GateResult:
    """Diff two suite documents; tolerances are per-metric, per the module
    header.  Schema-validates both first: a malformed artifact is itself a
    gate failure, never a silent pass."""
    out = GateResult()
    for name, doc in (("baseline", baseline), ("fresh", fresh)):
        errors = schema.validate(doc, schema.SUITE_SCHEMA)
        if errors:
            out.failures.append(
                f"{name} artifact is schema-invalid: {errors[0]} "
                f"(+{len(errors) - 1} more)" if len(errors) > 1 else
                f"{name} artifact is schema-invalid: {errors[0]}")
    if out.failures:
        return out

    base_f_star = {d["name"]: d["f_star"] for d in baseline["datasets"]}
    for d in fresh["datasets"]:
        b = base_f_star.get(d["name"])
        if b is not None and d["f_star"] is not None and d["f_star"] != b:
            out.warnings.append(
                f"{d['name']}: f_star differs from baseline "
                f"({d['f_star']:.6g} vs {b:.6g}) — ε columns are not "
                "directly comparable; refresh the baseline")

    base_cells, fresh_cells = _cells(baseline), _cells(fresh)
    for key in sorted(set(fresh_cells) - set(base_cells)):
        out.warnings.append(f"{key[0]}/{key[1]}: new cell (not in baseline)")
    for key in sorted(base_cells):
        ds_name, method = key
        b = base_cells[key]
        f = fresh_cells.get(key)
        if f is None:
            out.failures.append(
                f"{ds_name}/{method}: cell missing from fresh run "
                "(coverage regressed)")
            continue
        out.checked += 1

        d_eps = f["epsilon_mean"] - b["epsilon_mean"]
        if d_eps > eps_tol:
            out.failures.append(
                f"{ds_name}/{method}: epsilon_mean "
                f"{b['epsilon_mean']:+.4f} -> {f['epsilon_mean']:+.4f} "
                f"(+{d_eps:.4f} > tol {eps_tol})")
        elif d_eps < -eps_tol:
            out.warnings.append(
                f"{ds_name}/{method}: epsilon_mean improved "
                f"{b['epsilon_mean']:+.4f} -> {f['epsilon_mean']:+.4f}; "
                "consider refreshing the committed baseline")
        if f["epsilon_min"] < 0:
            out.warnings.append(
                f"{ds_name}/{method}: run beat best-known f_star "
                f"(epsilon_min={f['epsilon_min']:+.4f}); update the "
                "registry f_star")

        drop = b["success_rate"] - f["success_rate"]
        if drop > success_drop:
            out.failures.append(
                f"{ds_name}/{method}: success_rate "
                f"{b['success_rate']:.2f} -> {f['success_rate']:.2f} "
                f"(drop {drop:.2f} > tol {success_drop})")

        if check_wall and b["wall_mean_s"] >= wall_floor:
            ratio = f["wall_mean_s"] / b["wall_mean_s"]
            if ratio > wall_ratio:
                out.failures.append(
                    f"{ds_name}/{method}: wall_mean_s "
                    f"{b['wall_mean_s']:.2f} -> {f['wall_mean_s']:.2f} "
                    f"({ratio:.2f}x > tol {wall_ratio}x)")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff a fresh BENCH_suite.json against the committed "
                    "baseline; non-zero exit on regression.")
    ap.add_argument("--baseline", default="results/BENCH_baseline.json")
    ap.add_argument("--fresh", default="BENCH_suite.json")
    ap.add_argument("--report", default=None,
                    help="also write the report to this file")
    ap.add_argument("--eps-tol", type=float, default=DEFAULT_EPS_TOL)
    ap.add_argument("--success-drop", type=float,
                    default=DEFAULT_SUCCESS_DROP)
    ap.add_argument("--wall-ratio", type=float, default=DEFAULT_WALL_RATIO)
    ap.add_argument("--wall-floor", type=float, default=DEFAULT_WALL_FLOOR)
    ap.add_argument("--no-wall", action="store_true",
                    help="skip wall-time gating (quality only)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    result = compare(
        baseline, fresh,
        eps_tol=args.eps_tol, success_drop=args.success_drop,
        wall_ratio=args.wall_ratio, wall_floor=args.wall_floor,
        check_wall=not args.no_wall)
    report = result.report()
    print(report)
    if args.report:
        with open(args.report, "w") as f:
            f.write(report + "\n")
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
