"""Quality metrics of the paper's §5 comparison protocol.

Everything here is pure arithmetic over run records, so the suite and the
gate share one definition of every number they exchange:

* relative clustering error ``ε = (f − f*) / f*`` against the committed
  best-known objective ``f*`` (the paper's E_A, as a fraction, not %);
* success rate over seeds: the fraction of runs with ``ε <= tol``
  (the paper reports min/mean/max over executions; success rate is the
  CI-friendly scalar of the same distribution);
* run-level time-to-target curves: for a grid of wall-time budgets ``t``,
  the fraction of runs that both succeeded and finished within ``t``.
  Granularity is one point per *run* (the suite does not timestamp
  intra-run trace entries), which is exactly the paper's equal-budget
  question — "given t seconds, how often does this method reach the
  target?" — not an anytime curve.
"""
from __future__ import annotations

import math
from typing import Iterable, Sequence


def relative_error(f: float, f_star: float) -> float:
    """ε = (f − f*)/f* — negative means a new best-known objective."""
    if not (f_star and math.isfinite(f_star)):
        raise ValueError(f"f_star must be finite and non-zero, got {f_star!r}")
    return (f - f_star) / f_star


def success_rate(epsilons: Iterable[float], tol: float) -> float:
    """Fraction of runs with ε <= tol (NaN ε never succeeds)."""
    eps = list(epsilons)
    if not eps:
        raise ValueError("success_rate of zero runs is undefined")
    return sum(1 for e in eps if e <= tol) / len(eps)


def time_to_target_curve(
    runs: Sequence[tuple[float, bool]],
    grid: Sequence[float] | None = None,
) -> list[list[float]]:
    """``[[t, fraction-of-runs-succeeded-within-t], ...]`` over a time grid.

    ``runs`` is ``(wall_s, success)`` per run.  With no explicit grid, the
    curve is evaluated at each successful run's own wall time (the points
    where it actually steps), so it is exact and minimal.
    """
    if grid is None:
        grid = sorted({w for w, ok in runs if ok})
        if not grid:                       # nothing succeeded: one flat point
            grid = [max((w for w, _ in runs), default=0.0)]
    n = len(runs)
    curve = []
    for t in grid:
        frac = sum(1 for w, ok in runs if ok and w <= t) / n if n else 0.0
        curve.append([float(t), frac])
    return curve


def aggregate_cell(
    dataset: str,
    method: str,
    kind: str,
    rows: Sequence[dict],
    *,
    success_tol: float,
) -> dict:
    """One (dataset, method) cell from its per-seed rows (schema `_CELL_SCHEMA`)."""
    if not rows:
        raise ValueError(f"cell ({dataset}, {method}) has no rows")
    eps = [r["epsilon"] for r in rows]
    walls = [r["wall_s"] for r in rows]
    return {
        "dataset": dataset,
        "method": method,
        "kind": kind,
        "n_seeds": len(rows),
        "epsilon_mean": float(sum(eps) / len(eps)),
        "epsilon_min": float(min(eps)),
        "epsilon_max": float(max(eps)),
        "success_rate": success_rate(eps, success_tol),
        "wall_mean_s": float(sum(walls) / len(walls)),
        "time_to_target": time_to_target_curve(
            [(r["wall_s"], r["success"]) for r in rows]),
    }
