"""The multi-host suite cell: a 2-process ``host_mesh`` run as one row.

In-process cells call :func:`repro.api.fit` directly; a ``host_mesh``
cell cannot — ``jax.distributed`` wants one OS process per rank.  So
this module is both sides of that boundary:

* :func:`run_cell` (parent) — launches ``hosts`` copies of this module's
  CLI via :func:`repro.engine.hostmesh.launch_local`, checks that every
  rank finished and agreed bitwise on ``(f_best, C_best)``, and folds the
  per-rank reports into one suite row (schema ``_ROW_SCHEMA``-compatible,
  minus ε which the suite runner owns).
* ``python -m repro.evalsuite.hostcell`` (child, one per rank) — rebuilds
  the dataset from the registry, fits with ``topology='host_mesh'``
  (bootstrap read from the ``REPRO_*`` env the launcher set), and prints
  a single ``RESULT {...}`` JSON line.

Wall time per row is the slowest rank's ``fit()`` wall — the fleet is as
slow as its slowest member — which includes jit compile: subprocess runs
are always cold, so there is no warm-up protocol to exclude it (and the
committed baseline measures the same way).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.evalsuite import datasets as ds

DEFAULT_TIMEOUT_S = 420.0


def run_cell(spec, m, seed: int, *, data_root: str | None = None,
             verbose: bool = True, timeout_s: float = DEFAULT_TIMEOUT_S
             ) -> dict:
    """One (dataset, seed) run of the multi-host cell ``m``; returns the
    suite row.  Any rank failing — or ranks disagreeing on the incumbent —
    raises, so a broken exchange can never masquerade as a slow cell."""
    from repro.engine.hostmesh import launch_local

    overrides = dict(m.overrides)
    hosts = int(overrides.pop("hosts", 2))
    # Materialize the memmap once up front so the ranks share the file
    # instead of racing to generate it.
    ds.materialize(spec, data_root)
    argv = [sys.executable, "-m", "repro.evalsuite.hostcell",
            "--dataset", spec.name, "--seed", str(seed),
            "--overrides", json.dumps(overrides)]
    if data_root:
        argv += ["--data-root", data_root]
    procs = launch_local(argv, hosts, timeout_s=timeout_s)

    reports = {}
    for p in procs:
        line = next((ln for ln in p.output.splitlines()
                     if ln.startswith("RESULT ")), None)
        if p.returncode != 0 or line is None:
            tail = "\n".join(p.output.splitlines()[-15:])
            raise RuntimeError(
                f"hostcell rank {p.rank} failed (rc={p.returncode}) on "
                f"{spec.name} seed {seed}:\n{tail}")
        reports[p.rank] = json.loads(line[len("RESULT "):])
    objectives = {r["objective"] for r in reports.values()}
    if len(objectives) != 1:
        raise RuntimeError(
            f"hostcell ranks disagree on f_best after final exchange: "
            f"{sorted(objectives)} ({spec.name} seed {seed})")

    r0 = reports[0]
    row = {
        "dataset": spec.name,
        "method": m.name,
        "kind": m.kind,
        "seed": seed,
        "f_full": float(r0["f_full"]),
        "f_native": float(r0["objective"]),
        "wall_s": max(float(r["wall_time_s"]) for r in reports.values()),
        "n_chunks": int(r0["n_chunks"]),
        "n_iterations": int(r0["n_iterations"]),
        "n_accepted": int(r0["n_accepted"]),
        "strategy": r0["strategy"],
        "fit": dict(r0["fit"] or {}, hosts=hosts),
    }
    if verbose:
        print(f"[suite] {spec.name:14s} {m.name:22s} seed={seed} "
              f"f={row['f_full']:.5e}  wall={row['wall_s']:6.2f}s "
              f"({hosts} procs)", flush=True)
    return row


def _rank_main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", required=True)
    ap.add_argument("--seed", type=int, required=True)
    ap.add_argument("--overrides", default="{}")
    ap.add_argument("--data-root", default=None)
    ap.add_argument("--sync-timeout-s", type=float, default=60.0)
    args = ap.parse_args(argv)

    # Import order matters: repro.api before any JAX computation, and the
    # host_mesh bootstrap inside fit() before the first one.
    from repro.api import BigMeansConfig, TopologySpec, evaluate, fit

    spec = ds.get_dataset(args.dataset)
    cfg = BigMeansConfig(
        k=spec.k, s=spec.s, n_chunks=spec.n_chunks, seed=args.seed,
        log_every=0,
        topology=TopologySpec(kind="host_mesh",
                              sync_timeout_s=args.sync_timeout_s),
        **json.loads(args.overrides))
    source = ds.source(spec, args.data_root)
    t0 = time.monotonic()
    result = fit(source, cfg, method="streaming")
    row = result.to_row()
    row["wall_time_s"] = time.monotonic() - t0
    host = result.extras.get("host", {})
    ranks = result.extras.get("health", {}).get("ranks", [])
    if ranks:   # fleet totals, not this rank's shard
        row["n_chunks"] = sum(int(h["chunks_done"]) for h in ranks)
    if host.get("rank", 0) == 0:
        _, f_full = evaluate(result, source.as_array())
        row["f_full"] = float(f_full)
    print("RESULT " + json.dumps(row), flush=True)
    # Skip the jax.distributed atexit teardown: peers may already be gone
    # by now and the barrier there would turn a clean run into a hang.
    sys.stdout.flush()
    os._exit(0)


if __name__ == "__main__":
    _rank_main()
