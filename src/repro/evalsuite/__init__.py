"""`repro.evalsuite` — the §5 reproduction & regression harness.

The paper's empirical claim is two-dimensional: Big-means must match or
beat the §5 baselines on *solution quality* (relative clustering error
ε = (f − f*)/f* against a best-known objective f*) while spending less
time under an equal data budget.  This package makes that claim a gated,
versioned artifact instead of a pile of ad-hoc benchmark scripts:

* :mod:`repro.evalsuite.datasets` — the dataset registry: deterministic
  GMM surrogates at paper-like shapes, on-disk memmap materialization,
  and a committed best-known objective ``f_star`` per dataset.
* :mod:`repro.evalsuite.metrics` — ε, success rate over seeds, and
  run-level time-to-target curves.
* :mod:`repro.evalsuite.schema` — the versioned JSON schema every
  ``BENCH_*.json`` artifact is validated against before it is written.
* :mod:`repro.evalsuite.suite` — the suite runner: Big-means strategies
  × precision × scheduler plus the §5 baseline registry, swept over the
  dataset registry under an equal chunk budget through ``repro.api.fit``.
* :mod:`repro.evalsuite.gate` — the regression gate: diff a fresh suite
  run against the committed ``results/BENCH_baseline.json`` with
  per-metric tolerances; non-zero exit on quality or runtime regression.

CLI entry points::

    PYTHONPATH=src python -m benchmarks.suite --quick
    PYTHONPATH=src python -m repro.evalsuite.gate \
        --baseline results/BENCH_baseline.json --fresh BENCH_suite.json
"""
from repro.evalsuite.datasets import DatasetSpec, get_dataset, list_datasets
from repro.evalsuite.metrics import (
    aggregate_cell,
    relative_error,
    success_rate,
    time_to_target_curve,
)
from repro.evalsuite.schema import SCHEMA_VERSION, check, validate

__all__ = [
    "DatasetSpec",
    "SCHEMA_VERSION",
    "aggregate_cell",
    "check",
    "get_dataset",
    "list_datasets",
    "relative_error",
    "success_rate",
    "time_to_target_curve",
    "validate",
]
