"""The suite runner: §5's quality/speed comparison as one artifact.

Sweeps a method matrix — Big-means execution strategies × precision ×
scheduler, plus the §5 baseline registry — over the dataset registry,
every call through the same :func:`repro.api.fit`, and emits one
schema-validated ``BENCH_suite.json`` plus a per-run CSV.

Equal-budget protocol (the paper's comparison rule, and the one already
used by ``benchmarks/engine_compare``): every Big-means cell on a dataset
gets the SAME total chunk budget ``n_chunks × s`` from the registry spec,
whatever its strategy, batch width, precision or scheduler — so a cell
can only win by using the budget better, not by getting more of it.
Baselines are full-data algorithms; they run the paper's §5 protocol on
the identical dataset and are compared on the same full-data objective
f(C, X) (via :func:`repro.api.evaluate`) and wall clock.

Tiers: ``quick`` is the PR-gate (small-m datasets, 2 seeds, minutes on a
2-vCPU container); ``full`` is the nightly sweep (all datasets, more
seeds, the bf16/int8 and competitive-scheduler cells).
"""
from __future__ import annotations

import csv
import dataclasses
import os
from typing import Sequence

from repro.evalsuite import datasets as ds
from repro.evalsuite import metrics, schema

DEFAULT_SUCCESS_TOL = 0.05        # a run "succeeds" if ε <= 5% of f*
SEEDS = {"quick": (0, 1), "full": (0, 1, 2, 3, 4)}

PROTOCOL = (
    "equal-budget: every big-means cell gets the dataset's n_chunks x s "
    "sample budget regardless of strategy/batch/precision/scheduler; "
    "baselines run their §5 full-data protocol on the identical memmap; "
    "all cells compared on full-data f(C, X) and wall seconds; "
    "one untimed warm-up fit per cell excludes jit compile from walls; "
    "epsilon = (f - f_star)/f_star vs the committed best-known f_star"
)


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """One column of the comparison matrix.

    ``method`` is what :func:`repro.api.fit` receives; ``overrides`` are
    applied on top of the dataset's protocol config (strategy knobs only
    — never ``k``/``s``/``n_chunks``, which the equal-budget rule owns).
    ``runner`` picks the execution harness: ``"fit"`` runs in-process,
    ``"host2p"`` launches a multi-process ``host_mesh`` fleet per seed
    (:mod:`repro.evalsuite.hostcell`; ``overrides['hosts']`` sizes it).
    """

    name: str
    kind: str                      # "bigmeans" | "baseline"
    method: str
    overrides: dict = dataclasses.field(default_factory=dict)
    tiers: tuple = ("quick", "full")
    runner: str = "fit"            # "fit" (in-process) | "host2p"


METHODS: tuple[MethodSpec, ...] = (
    # Big-means strategy x precision x scheduler cells
    MethodSpec("bm/sequential", "bigmeans", "sequential"),
    MethodSpec("bm/batched", "bigmeans", "batched", {"batch": 4}),
    MethodSpec("bm/batched-bf16", "bigmeans", "batched",
               {"batch": 4, "precision": "bf16"}, tiers=("full",)),
    MethodSpec("bm/batched-int8", "bigmeans", "batched",
               {"batch": 4, "precision": "int8"}, tiers=("full",)),
    MethodSpec("bm/competitive-s", "bigmeans", "streaming",
               {"batch": 4, "scheduler": "competitive_s", "sync_every": 2},
               tiers=("full",)),
    # cross-host incumbent exchange: same equal-budget streaming protocol,
    # split over a 2-process jax.distributed fleet (bit-identical to the
    # single-process run by construction — run_cell asserts rank agreement)
    MethodSpec("bm/hostmesh-2p", "bigmeans", "streaming",
               {"batch": 4, "sync_every": 2, "hosts": 2},
               runner="host2p"),
    # §5 baselines (full-data competitors through the same fit())
    MethodSpec("baseline/forgy", "baseline", "forgy"),
    MethodSpec("baseline/kmeanspp", "baseline", "kmeanspp"),
    MethodSpec("baseline/coreset", "baseline", "coreset"),
    MethodSpec("baseline/da_mssc", "baseline", "da_mssc", tiers=("full",)),
)


def list_methods(tier: str | None = None) -> list[str]:
    return [m.name for m in METHODS if tier is None or tier in m.tiers]


def _run_cell(spec: ds.DatasetSpec, m: MethodSpec, seed: int, source, X,
              verbose: bool) -> dict:
    from repro.api import BigMeansConfig, evaluate, fit

    cfg = BigMeansConfig(k=spec.k, s=spec.s, n_chunks=spec.n_chunks,
                         seed=seed, log_every=0, **m.overrides)
    result = fit(source, cfg, method=m.method)
    _, f_full = evaluate(result, X)
    base = result.to_row()                 # the FitResult row contract
    row = {
        "dataset": spec.name,
        "method": m.name,
        "kind": m.kind,
        "seed": seed,
        "f_full": float(f_full),
        "f_native": base["objective"],
        "wall_s": base["wall_time_s"],
        "n_chunks": base["n_chunks"],
        "n_iterations": base["n_iterations"],
        "n_accepted": base["n_accepted"],
        "strategy": base["strategy"],
        "fit": base["fit"],
    }
    if verbose:
        print(f"[suite] {spec.name:14s} {m.name:22s} seed={seed} "
              f"f={f_full:.5e}  wall={row['wall_s']:6.2f}s", flush=True)
    return row


def run_suite(
    tier: str = "full",
    *,
    seeds: Sequence[int] | None = None,
    dataset_names: Sequence[str] | None = None,
    method_names: Sequence[str] | None = None,
    data_root: str | None = None,
    success_tol: float = DEFAULT_SUCCESS_TOL,
    verbose: bool = True,
) -> dict:
    """Run the sweep; return the (schema-valid) BENCH_suite document.

    ``dataset_names`` / ``method_names`` restrict the matrix (tests use a
    single tiny cell); default is everything in ``tier``.
    """
    if tier not in ("quick", "full"):
        raise ValueError(f"unknown tier {tier!r}; known: quick, full")
    seeds = tuple(seeds if seeds is not None else SEEDS[tier])
    specs = [ds.get_dataset(n)
             for n in (dataset_names or ds.list_datasets(tier))]
    if method_names is not None:
        unknown = set(method_names) - {m.name for m in METHODS}
        if unknown:
            raise KeyError(
                f"unknown methods {sorted(unknown)}; known: {list_methods()}")
        methods = [m for m in METHODS if m.name in method_names]
    else:
        methods = [m for m in METHODS if tier in m.tiers]
    if not specs or not methods or not seeds:
        raise ValueError("empty sweep: need >=1 dataset, method and seed")

    rows: list[dict] = []
    dataset_records = []
    for spec in specs:
        source = ds.source(spec, data_root)
        X = source.as_array()
        ds_rows = []
        for m in methods:
            if m.runner == "host2p":
                # Subprocess fleets are always cold (each launch compiles
                # fresh), so there is no warm-up to run — the committed
                # baseline's walls include compile the same way.
                from repro.evalsuite import hostcell

                ds_rows.extend(
                    hostcell.run_cell(spec, m, seed, data_root=data_root,
                                      verbose=verbose)
                    for seed in seeds)
                continue
            # Warm-up: one untimed fit per (dataset, method) cell so the
            # timed rows measure steady-state, not one-off jit compiles
            # (without this, seed 0's wall is ~95% compile on small cells
            # and the gated wall_mean_s tracks compiler noise, not cost).
            _run_cell(spec, m, seeds[0], source, X, verbose=False)
            ds_rows.extend(_run_cell(spec, m, seed, source, X, verbose)
                           for seed in seeds)

        # ε needs f*: the committed best-known value, or — during
        # bootstrap, before one is committed — the best f of this very
        # run (recorded as such in the artifact).
        record = spec.to_record()
        if spec.f_star is None:
            record["f_star"] = min(r["f_full"] for r in ds_rows)
            record["f_star_source"] = "run-best (uncommitted bootstrap)"
        else:
            record["f_star_source"] = "committed"
        for r in ds_rows:
            r["epsilon"] = metrics.relative_error(r["f_full"],
                                                  record["f_star"])
            r["success"] = r["epsilon"] <= success_tol
        dataset_records.append(record)
        rows.extend(ds_rows)

    cells = [
        metrics.aggregate_cell(
            spec.name, m.name, m.kind,
            [r for r in rows
             if r["dataset"] == spec.name and r["method"] == m.name],
            success_tol=success_tol)
        for spec in specs for m in methods
    ]
    doc = schema.envelope(
        "suite", rows,
        tier=tier,
        seeds=list(seeds),
        success_tol=success_tol,
        protocol=PROTOCOL,
        datasets=dataset_records,
        cells=cells,
    )
    schema.check(doc, schema.SUITE_SCHEMA, what="BENCH_suite document")
    return doc


def write_outputs(doc: dict, json_path: str, csv_path: str | None = None
                  ) -> None:
    """Validate + write the suite artifact (and the per-run CSV)."""
    schema.write_bench(json_path, doc, schema.SUITE_SCHEMA)
    if csv_path:
        os.makedirs(os.path.dirname(csv_path) or ".", exist_ok=True)
        cols = ["dataset", "method", "kind", "seed", "f_full", "epsilon",
                "success", "wall_s", "n_chunks", "n_iterations",
                "n_accepted", "strategy"]
        with open(csv_path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(cols)
            for r in doc["rows"]:
                w.writerow([r.get(c, "") for c in cols])
