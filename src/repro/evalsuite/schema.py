"""Versioned JSON schema for every ``BENCH_*.json`` artifact.

Benchmark artifacts used to be free-form dicts whose shapes drifted per
script; nothing could diff two of them mechanically.  Every artifact now
carries ``schema_version`` and is validated against a schema *before* it
is written (and again by the gate before it is trusted), so a malformed
run fails at the producer, not three PRs later in a regression diff.

Two schemas:

* :data:`ENVELOPE_SCHEMA` — the shared envelope all bench artifacts obey
  (``BENCH_batched`` / ``BENCH_precision`` / ``BENCH_engine`` /
  ``BENCH_suite``): a bench name, host context, and a list of row dicts.
* :data:`SUITE_SCHEMA` — the full contract of ``BENCH_suite.json``:
  dataset specs with committed ``f_star``, one row per
  (dataset, method, seed) run, and one aggregated cell per
  (dataset, method) with ε statistics, success rate and time-to-target.

Validation is a built-in subset of JSON Schema (no external dependency —
the container must not grow deps): ``type``, ``required``,
``properties``, ``items``, ``enum``, ``const``, ``minimum``,
``minItems``.  Unknown keys are allowed everywhere (artifacts may carry
extra context), unknown schema keywords are a programming error.
"""
from __future__ import annotations

import json
import os
from typing import Any

SCHEMA_VERSION = "repro.bench/1"

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "boolean": bool,
    "integer": int,
    "null": type(None),
}

_KEYWORDS = {
    "type", "required", "properties", "items", "enum", "const",
    "minimum", "minItems",
    # documentation-only keywords, ignored by the validator
    "$id", "description", "title",
}


def _type_ok(value: Any, expected: str) -> bool:
    if expected == "number":
        return isinstance(value, (int, float)) and not isinstance(value, bool)
    if expected == "integer":
        return isinstance(value, int) and not isinstance(value, bool)
    return isinstance(value, _TYPES[expected])


def validate(doc: Any, schema: dict, path: str = "$") -> list[str]:
    """Validate ``doc`` against ``schema``; return a list of error strings
    (empty = valid).  Supports the subset documented in the module header."""
    unknown = set(schema) - _KEYWORDS
    if unknown:
        raise ValueError(f"unsupported schema keywords at {path}: {unknown}")
    errors: list[str] = []

    if "const" in schema and doc != schema["const"]:
        errors.append(f"{path}: expected {schema['const']!r}, got {doc!r}")
    if "enum" in schema and doc not in schema["enum"]:
        errors.append(f"{path}: {doc!r} not in {schema['enum']!r}")

    expected = schema.get("type")
    if expected is not None:
        types = expected if isinstance(expected, list) else [expected]
        if not any(_type_ok(doc, t) for t in types):
            errors.append(
                f"{path}: expected type {expected}, got "
                f"{type(doc).__name__} ({doc!r:.60})")
            return errors          # downstream keywords assume the type

    if isinstance(doc, dict):
        for field in schema.get("required", ()):
            if field not in doc:
                errors.append(f"{path}: missing required field {field!r}")
        for field, sub in schema.get("properties", {}).items():
            if field in doc:
                errors.extend(validate(doc[field], sub, f"{path}.{field}"))

    if isinstance(doc, list):
        if "minItems" in schema and len(doc) < schema["minItems"]:
            errors.append(
                f"{path}: expected >= {schema['minItems']} items, "
                f"got {len(doc)}")
        if "items" in schema:
            for i, item in enumerate(doc):
                errors.extend(validate(item, schema["items"], f"{path}[{i}]"))

    if "minimum" in schema and _type_ok(doc, "number"):
        if doc < schema["minimum"]:
            errors.append(f"{path}: {doc!r} < minimum {schema['minimum']!r}")

    return errors


def check(doc: Any, schema: dict, what: str = "document") -> None:
    """Raise ``ValueError`` with every validation error if ``doc`` is invalid."""
    errors = validate(doc, schema)
    if errors:
        raise ValueError(
            f"{what} failed schema validation ({len(errors)} error(s)):\n  "
            + "\n  ".join(errors))


_HOST_SCHEMA = {
    "type": "object",
    "required": ["cpu_count", "xla_devices"],
    "properties": {
        "cpu_count": {"type": ["integer", "null"]},
        "xla_devices": {"type": "integer", "minimum": 1},
    },
}

# The shared envelope: what every BENCH_*.json must carry so artifacts can
# be discovered, attributed to a host, and diffed mechanically.
ENVELOPE_SCHEMA = {
    "$id": "repro.bench.envelope/1",
    "type": "object",
    "required": ["schema_version", "bench", "host", "rows"],
    "properties": {
        "schema_version": {"const": SCHEMA_VERSION},
        "bench": {"type": "string"},
        "host": _HOST_SCHEMA,
        "rows": {"type": "array", "items": {"type": "object"}},
    },
}

_DATASET_SCHEMA = {
    "type": "object",
    "required": ["name", "paper_name", "m", "n", "k", "s", "n_chunks",
                 "f_star"],
    "properties": {
        "name": {"type": "string"},
        "paper_name": {"type": "string"},
        "m": {"type": "integer", "minimum": 1},
        "n": {"type": "integer", "minimum": 1},
        "k": {"type": "integer", "minimum": 1},
        "s": {"type": "integer", "minimum": 1},
        "n_chunks": {"type": "integer", "minimum": 1},
        "f_star": {"type": ["number", "null"]},
    },
}

_ROW_SCHEMA = {
    "type": "object",
    "required": ["dataset", "method", "seed", "f_full", "epsilon",
                 "success", "wall_s"],
    "properties": {
        "dataset": {"type": "string"},
        "method": {"type": "string"},
        "kind": {"enum": ["bigmeans", "baseline"]},
        "seed": {"type": "integer"},
        "f_full": {"type": "number"},
        "epsilon": {"type": "number"},
        "success": {"type": "boolean"},
        "wall_s": {"type": "number", "minimum": 0},
        "n_chunks": {"type": "integer"},
        "n_iterations": {"type": "integer"},
        "n_accepted": {"type": "integer"},
    },
}

_CELL_SCHEMA = {
    "type": "object",
    "required": ["dataset", "method", "kind", "n_seeds", "epsilon_mean",
                 "epsilon_min", "epsilon_max", "success_rate",
                 "wall_mean_s", "time_to_target"],
    "properties": {
        "dataset": {"type": "string"},
        "method": {"type": "string"},
        "kind": {"enum": ["bigmeans", "baseline"]},
        "n_seeds": {"type": "integer", "minimum": 1},
        "epsilon_mean": {"type": "number"},
        "epsilon_min": {"type": "number"},
        "epsilon_max": {"type": "number"},
        "success_rate": {"type": "number", "minimum": 0},
        "wall_mean_s": {"type": "number", "minimum": 0},
        "time_to_target": {
            "type": "array",
            "items": {"type": "array", "items": {"type": "number"},
                      "minItems": 2},
        },
    },
}

# The full BENCH_suite.json contract (a superset of the envelope).
SUITE_SCHEMA = {
    "$id": "repro.bench.suite/1",
    "type": "object",
    "required": ["schema_version", "bench", "host", "rows", "tier",
                 "success_tol", "protocol", "datasets", "cells"],
    "properties": {
        "schema_version": {"const": SCHEMA_VERSION},
        "bench": {"const": "suite"},
        "tier": {"enum": ["quick", "full"]},
        "success_tol": {"type": "number", "minimum": 0},
        "protocol": {"type": "string"},
        "host": _HOST_SCHEMA,
        "datasets": {"type": "array", "items": _DATASET_SCHEMA,
                     "minItems": 1},
        "rows": {"type": "array", "items": _ROW_SCHEMA, "minItems": 1},
        "cells": {"type": "array", "items": _CELL_SCHEMA, "minItems": 1},
    },
}


def host_info() -> dict:
    """The host context every artifact records (trajectories are only
    comparable like-for-like: a 2-vCPU CI container is not a TPU host)."""
    import jax

    return {
        "cpu_count": os.cpu_count(),
        "xla_devices": len(jax.devices()),
        "backend": jax.default_backend(),
    }


def envelope(bench: str, rows: list[dict], **extra) -> dict:
    """Build a schema-versioned artifact envelope around ``rows``."""
    doc = {
        "schema_version": SCHEMA_VERSION,
        "bench": bench,
        "host": host_info(),
        "rows": rows,
    }
    doc.update(extra)
    return doc


def write_bench(path: str, doc: dict, schema: dict | None = None) -> str:
    """Validate ``doc`` (envelope schema by default) and write it to ``path``.

    The validate-then-write order is the point: a producer bug yields a
    loud ValueError, never a malformed committed artifact.
    """
    check(doc, schema or ENVELOPE_SCHEMA, what=os.path.basename(path))
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=False)
        f.write("\n")
    return path
