"""The suite's dataset registry: paper-shaped workloads with committed f*.

Each entry is a deterministic GMM surrogate of one of the paper's Table 1
datasets (real datasets are not reachable offline), scaled so the whole
registry tier runs on a small CPU container, together with the clustering
protocol for that dataset (k, chunk size s, equal chunk budget) and the
committed best-known full-data objective ``f_star`` that the relative
error ε is measured against.

``f_star`` is a *best-known* value, exactly as in the paper: the lowest
full-data objective any method in the suite has ever achieved on that
dataset, refreshed deliberately (see README "Reproduction suite") — never
silently.  A run that beats it gets ε < 0 and the gate flags the record
so the committed value can be updated in review.

Datasets materialize to on-disk ``.npy`` memmaps via
:func:`repro.data.synthetic.gmm_memmap` — bitwise deterministic per
(spec, backend), so every suite run, restart, and CI job clusters
byte-identical data, and the streaming strategies exercise the real
out-of-core path instead of an in-core shortcut.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile

from repro.data.synthetic import GMMSpec, PAPER_DATASETS, gmm_memmap


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """One registry entry: the workload plus its comparison protocol.

    * ``name`` — registry key (also the memmap filename stem).
    * ``paper_name`` — the Table 1 dataset this surrogates (feature
      dimension ``n`` matches it exactly; ``m`` is scaled down).
    * ``m`` / ``n`` / ``components`` / ``spread`` / ``seed`` — the GMM.
    * ``k`` — cluster count for this cell (the paper sweeps k per
      dataset; the registry pins one representative k per entry).
    * ``s`` — Big-means chunk size.
    * ``n_chunks`` — the equal chunk budget every Big-means strategy
      gets on this dataset.
    * ``f_star`` — committed best-known full-data objective f(C, X);
      ``None`` only during bootstrap (ε is then measured against the
      best f of the current run and the artifact says so).
    * ``tiers`` — which suite tiers include this dataset.
    """

    name: str
    paper_name: str
    m: int
    n: int
    components: int
    k: int
    s: int
    n_chunks: int
    spread: float = 4.0
    seed: int = 0
    f_star: float | None = None
    tiers: tuple = ("quick", "full")

    @property
    def gmm(self) -> GMMSpec:
        return GMMSpec(m=self.m, n=self.n, components=self.components,
                       spread=self.spread, seed=self.seed)

    def to_record(self) -> dict:
        """The dataset block of BENCH_suite.json (schema `_DATASET_SCHEMA`)."""
        return {
            "name": self.name,
            "paper_name": self.paper_name,
            "m": self.m,
            "n": self.n,
            "components": self.components,
            "k": self.k,
            "s": self.s,
            "n_chunks": self.n_chunks,
            "seed": self.seed,
            "f_star": self.f_star,
        }


def _entry(name, paper_name, m, k, s, n_chunks, *, f_star=None,
           tiers=("quick", "full"), components=25, seed=0):
    n = PAPER_DATASETS[paper_name][1]
    return DatasetSpec(name=name, paper_name=paper_name, m=m, n=n,
                       components=components, k=k, s=s, n_chunks=n_chunks,
                       f_star=f_star, tiers=tiers, seed=seed)


# Committed f_star values are the best full-data objective observed across
# all suite methods × seeds on this container (refresh procedure: README
# "Reproduction suite").  Keep 6 significant digits: ε tolerances are
# O(1e-2), so rounding noise at 1e-6 relative is irrelevant.
REGISTRY: dict[str, DatasetSpec] = {
    spec.name: spec for spec in [
        # quick tier: small-m surrogates, minutes on a 2-vCPU container
        _entry("hepmass-16k", "hepmass", m=16384, k=15, s=2048, n_chunks=24,
               f_star=2159652.0),
        _entry("road3d-24k", "road3d", m=24576, k=15, s=2048, n_chunks=24,
               f_star=97640.1),
        # full tier: larger m, wider n, bigger budgets (nightly CI)
        _entry("uscensus-48k", "uscensus", m=49152, k=20, s=4096, n_chunks=48,
               f_star=10210814.0, tiers=("full",)),
        _entry("mfcc-32k", "mfcc", m=32768, k=20, s=4096, n_chunks=48,
               f_star=5615986.0, tiers=("full",)),
        _entry("skin-64k", "skin", m=65536, k=15, s=4096, n_chunks=48,
               f_star=259865.7, tiers=("full",)),
    ]
}


def list_datasets(tier: str | None = None) -> list[str]:
    """Registry names, optionally restricted to a suite tier."""
    return [name for name, spec in REGISTRY.items()
            if tier is None or tier in spec.tiers]


def get_dataset(name: str) -> DatasetSpec:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown dataset {name!r}; known: {list_datasets()}") from None


def default_root() -> str:
    """Where materialized memmaps live unless the caller says otherwise."""
    return os.path.join(tempfile.gettempdir(), "repro-evalsuite-datasets")


def materialize(spec: DatasetSpec, root: str | None = None) -> str:
    """Ensure ``spec``'s memmap exists on disk; return its path.

    Generation is deterministic (same spec ⇒ bitwise-identical file) and
    the filename embeds a digest of the generating GMM parameters, so an
    existing file is reused only when it holds exactly this spec's data —
    editing a registry entry (seed, spread, m, ...) under the same name
    can never silently serve stale rows from a previous definition.
    """
    import hashlib

    root = root or default_root()
    os.makedirs(root, exist_ok=True)
    digest = hashlib.sha256(repr(spec.gmm).encode()).hexdigest()[:10]
    path = os.path.join(root, f"{spec.name}-{digest}.npy")
    if not os.path.exists(path):
        # write via a temp name + rename: a killed run never leaves a
        # half-written file that a later run would trust
        tmp = path + ".tmp"
        gmm_memmap(spec.gmm, tmp)
        os.replace(tmp, path)
    return path


def source(spec: DatasetSpec, root: str | None = None):
    """A registry-backed :class:`repro.api.MemmapSource` for ``spec``."""
    from repro.api import MemmapSource

    return MemmapSource(materialize(spec, root))
