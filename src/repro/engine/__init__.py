"""`repro.engine` — one execution engine for every Big-means composition.

The paper's whole algorithm is *decomposition*: many small chunk-solves
exchanging incumbents.  The engine expresses that loop once, decomposed into
orthogonal pieces, so "which chunks", "where they run", "how streams sync"
and "what wraps the accept loop" compose freely instead of each living in
exactly one hand-rolled driver:

* :mod:`repro.engine.scheduler` — **ChunkScheduler**: uniform,
  worker-partitioned, and ``competitive_s`` (per-stream sample-size racing,
  arXiv:2403.18766).
* :mod:`repro.engine.topology` — **Topology**: single device, stream mesh
  (batch axis sharded via ``shard_map``), worker mesh.
* :mod:`repro.engine.sync` — **SyncPolicy**: collective (``sync_every=1``),
  periodic, competitive (``∞``) — the paper's parallel modes as data.
* :mod:`repro.engine.middleware` — the accept-loop **middleware stack**:
  checkpoint/resume, VNS ladder, time budget, trace/metrics, fetch-failure
  skip, chunk sanitizer + invariant guard — wrapping *any* composition.
* :mod:`repro.engine.faults` — the **fault-tolerance vocabulary**:
  transient/permanent taxonomy, retry policy with deterministic backoff,
  fetch watchdog, and the seedable :class:`FaultPlan` injection harness.
* :mod:`repro.engine.incore` — the jitted in-core chunk-loop cores (the
  historical drivers' scan bodies, bit-identical) + host-orchestrated
  sharded windows.
* :mod:`repro.engine.stream` — the out-of-core host loop (prefetch
  pipeline), single-device or stream-mesh.

The legacy entry points (``repro.core.bigmeans.big_means*``,
``repro.cluster.runner.run``) and every ``repro.api`` strategy are thin
assemblies of these pieces.
"""
from repro.engine import faults as faults
from repro.engine import hostmesh as hostmesh
from repro.engine import incore as incore
from repro.engine import middleware as middleware
from repro.engine import scheduler as scheduler
from repro.engine import stream as stream
from repro.engine import sync as sync
from repro.engine import topology as topology
from repro.engine.faults import (
    ChunkQuarantined,
    FaultPlan,
    FetchTimeout,
    HostDead,
    InvariantViolation,
    PermanentFault,
    RetryPolicy,
    TransientFault,
)
from repro.engine.middleware import (
    Checkpoint,
    ChunkSanitizer,
    EngineContext,
    FetchSkip,
    InvariantGuard,
    Middleware,
    MiddlewareStack,
    TimeBudget,
    TraceLog,
    VNSLadder,
    default_stack,
    load_loop_state,
)
from repro.engine.scheduler import (
    CompetitiveS,
    Uniform,
    WorkerPartitioned,
    get_scheduler,
    list_schedulers,
    register_scheduler,
)
from repro.engine.hostmesh import launch_local, run_host_stream
from repro.engine.stream import EndOfStream, RunnerMetrics, run_stream
from repro.engine.sync import SyncPolicy, collective, competitive, periodic
from repro.engine.topology import (
    HostMesh,
    SingleDevice,
    StreamMesh,
    TopologySpec,
    WorkerMesh,
    resolve,
)

__all__ = [
    "Checkpoint",
    "ChunkQuarantined",
    "ChunkSanitizer",
    "CompetitiveS",
    "EndOfStream",
    "EngineContext",
    "FaultPlan",
    "FetchSkip",
    "FetchTimeout",
    "HostDead",
    "HostMesh",
    "InvariantGuard",
    "InvariantViolation",
    "Middleware",
    "MiddlewareStack",
    "PermanentFault",
    "RetryPolicy",
    "RunnerMetrics",
    "SingleDevice",
    "StreamMesh",
    "SyncPolicy",
    "TimeBudget",
    "TopologySpec",
    "TraceLog",
    "TransientFault",
    "Uniform",
    "VNSLadder",
    "WorkerMesh",
    "WorkerPartitioned",
    "collective",
    "competitive",
    "default_stack",
    "faults",
    "get_scheduler",
    "hostmesh",
    "incore",
    "launch_local",
    "list_schedulers",
    "load_loop_state",
    "middleware",
    "periodic",
    "register_scheduler",
    "resolve",
    "run_host_stream",
    "run_stream",
    "scheduler",
    "stream",
    "sync",
    "topology",
]
