"""In-core execution: the jitted chunk-loop cores behind every driver.

These are the (moved, not rewritten) scan bodies of the historical
``big_means`` / ``big_means_batched`` / ``big_means_sharded`` drivers —
parameterized by the engine's orthogonal pieces instead of hard-coding one
composition each:

* the **scheduler** appears as the key schedule (``split(key, rounds*batch)``
  for the uniform schedule, ``fold_in(key, worker_index)`` for the
  worker-partitioned one);
* the **topology** selects the placement (:func:`sequential` /
  :func:`batched_local` on one device, :func:`batched_stream_mesh` /
  :func:`worker_sharded` under ``shard_map``);
* the **sync policy** is the ``sync_every`` static argument.

Trajectories are bit-identical to the pre-engine drivers: same jitted
functions, same static arguments, same key schedules.

:func:`worker_sharded_rounds` is the new piece: the same worker-sharded
window (``sync_every`` chunks per worker, then an argmin exchange) driven
from a *host* loop, one jitted segment per window, so the accept-loop
middleware stack (checkpoint/resume, time budget) composes with the
multi-worker topology — previously impossible.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.bigmeans import (
    BigMeansState,
    ChunkInfo,
    _exchange_best,
    _sync_streams,
    broadcast_state,
    chunk_step,
    chunk_step_batched,
    init_state,
    reduce_state,
    sample_chunk,
)
from repro.engine import middleware as mw
from repro.kernels import precision as px

def _cast_dataset(X, precision):
    """Dataset-level storage cast for the in-core drivers.

    int8 is the exception: scales are a *chunk* property (``s[f]`` over the
    chunk's points), so the dataset stays full-width here and each sampled
    chunk is quantized at Lloyd entry — same semantics as the streaming
    prefetcher, which quantizes per fetched chunk.
    """
    if px.resolve(precision, X.dtype) == "int8":
        return jnp.asarray(X, jnp.float32)
    return px.cast_storage(X, precision)


if hasattr(jax, "shard_map"):
    _shard_map = functools.partial(jax.shard_map, check_vma=False)
else:   # jax < 0.6: experimental API, `check_rep` instead of `check_vma`
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    _shard_map = functools.partial(_experimental_shard_map, check_rep=False)


# ---------------------------------------------------------------------------
# single-device, scalar stream (the paper's Algorithm 3)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "s", "n_chunks", "max_iters", "tol", "candidates", "impl",
        "with_replacement", "precision",
    ),
)
def sequential(
    X, key, *, k, s, n_chunks, max_iters=300, tol=1e-4, candidates=3,
    impl="auto", with_replacement=True, precision="auto",
):
    """Sequential Big-means over an in-core dataset.  Returns (state, traces)."""
    X = _cast_dataset(X, precision)
    state = init_state(k, X.shape[1])

    def body(carry, key_i):
        state = carry
        ks, kc = jax.random.split(key_i)
        chunk = sample_chunk(X, ks, s, with_replacement=with_replacement)
        state, info = chunk_step(
            chunk, state, kc,
            max_iters=max_iters, tol=tol, candidates=candidates, impl=impl,
            precision=precision,
        )
        return state, info

    keys = jax.random.split(key, n_chunks)
    state, infos = jax.lax.scan(body, state, keys)
    return state, infos


# ---------------------------------------------------------------------------
# single-device, B batched streams (uniform schedule, periodic sync)
# ---------------------------------------------------------------------------


def stream_keys(key, rounds: int, sync_every: int, batch: int):
    """[outer, sync_every, batch, ...] key schedule: chunk (r, b) gets
    split(key, rounds*batch)[r*batch + b] — for batch=1 this is
    byte-identical to the sequential schedule."""
    keys = jax.random.split(key, rounds * batch)
    return keys.reshape(
        (rounds // sync_every, sync_every, batch) + keys.shape[1:])


def stream_scan(X, states, keys, *, s, max_iters, tol, candidates, impl,
                with_replacement, sync_fn, precision="auto"):
    """Scan ``rounds`` chunk rounds over per-stream states; ``sync_fn``
    exchanges incumbents at each sync boundary."""

    def body(states, keys_i):                       # keys_i [batch, ...]
        split = jax.vmap(jax.random.split)(keys_i)  # [batch, 2, ...]
        ks, kc = split[:, 0], split[:, 1]
        chunks = jax.vmap(
            lambda kk: sample_chunk(X, kk, s, with_replacement=with_replacement)
        )(ks)
        return chunk_step_batched(
            chunks, states, kc,
            max_iters=max_iters, tol=tol, candidates=candidates, impl=impl,
            precision=precision,
        )

    def round_body(states, keys_r):                 # keys_r [sync, batch, ...]
        states, infos = jax.lax.scan(body, states, keys_r)
        return sync_fn(states), infos

    states, infos = jax.lax.scan(round_body, states, keys)
    # [outer, sync, batch, ...] -> [rounds * batch, ...], round-major order
    infos = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[3:]), infos)
    return states, infos


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "s", "batch", "rounds", "sync_every", "max_iters", "tol",
        "candidates", "impl", "with_replacement", "precision",
    ),
)
def batched_local(
    X, key, *, k, s, batch, rounds, sync_every, max_iters, tol, candidates,
    impl, with_replacement, precision="auto",
):
    X = _cast_dataset(X, precision)
    states = broadcast_state(init_state(k, X.shape[1]), batch)
    keys = stream_keys(key, rounds, sync_every, batch)
    states, infos = stream_scan(
        X, states, keys, s=s, max_iters=max_iters, tol=tol,
        candidates=candidates, impl=impl, with_replacement=with_replacement,
        sync_fn=_sync_streams, precision=precision,
    )
    return reduce_state(states), infos


@functools.partial(
    jax.jit,
    static_argnames=(
        "mesh", "stream_axis", "k", "s", "batch", "rounds", "sync_every",
        "max_iters", "tol", "candidates", "impl", "with_replacement",
        "precision",
    ),
)
def batched_stream_mesh(
    X, key, *, mesh, stream_axis, k, s, batch, rounds, sync_every,
    max_iters, tol, candidates, impl, with_replacement, precision="auto",
):
    ndev = mesh.shape[stream_axis]
    assert batch % ndev == 0, "stream mesh axis must divide batch"
    X = _cast_dataset(X, precision)
    n = X.shape[1]
    keys = stream_keys(key, rounds, sync_every, batch)

    def sync(states):
        """Global keep-the-best: local winner, then argmin-all-gather
        across devices; every stream continues from the global winner."""
        w = jnp.argmin(states.f_best)
        f_all = jax.lax.all_gather(states.f_best[w], stream_axis)      # [D]
        c_all = jax.lax.all_gather(states.centroids[w], stream_axis)
        d_all = jax.lax.all_gather(states.degenerate[w], stream_axis)
        g = jnp.argmin(f_all)
        bl = states.f_best.shape[0]
        return states._replace(
            centroids=jnp.broadcast_to(c_all[g], states.centroids.shape),
            degenerate=jnp.broadcast_to(d_all[g], states.degenerate.shape),
            f_best=jnp.broadcast_to(f_all[g], (bl,)),
        )

    def worker(x_rep, keys_local):          # [outer, sync, batch/D, ...]
        states = broadcast_state(init_state(k, n), keys_local.shape[2])
        states, infos = stream_scan(
            x_rep, states, keys_local, s=s, max_iters=max_iters, tol=tol,
            candidates=candidates, impl=impl,
            with_replacement=with_replacement, sync_fn=sync,
            precision=precision,
        )
        local = reduce_state(states)
        f_all = jax.lax.all_gather(local.f_best, stream_axis)
        c_all = jax.lax.all_gather(local.centroids, stream_axis)
        d_all = jax.lax.all_gather(local.degenerate, stream_axis)
        g = jnp.argmin(f_all)
        final = BigMeansState(
            centroids=c_all[g],
            degenerate=d_all[g],
            f_best=f_all[g],
            n_accepted=jax.lax.psum(local.n_accepted, stream_axis),
            n_dist_evals=jax.lax.psum(local.n_dist_evals, stream_axis),
        )
        return final, infos

    shard = _shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(), P(None, None, stream_axis, None)),
        out_specs=(
            BigMeansState(P(), P(), P(), P(), P()),
            ChunkInfo(*([P(stream_axis)] * 4)),
        ),
    )
    return shard(X, keys)


# ---------------------------------------------------------------------------
# worker mesh: one chunk stream per worker, argmin-all-reduce exchange
# ---------------------------------------------------------------------------


def worker_sharded(
    X, key, *, mesh, k, s, chunks_per_worker, sync_every=1, axes=("data",),
    max_iters=300, tol=1e-4, candidates=3, impl="auto",
    with_replacement=True, precision="auto",
):
    """Multi-worker Big-means: X row-sharded over ``axes``; per-worker chunk
    streams with periodic incumbent exchange.

    Each worker samples chunks from its local shard (uniform placement makes
    local sampling equivalent to global sampling).  PRNG keys are folded with
    the worker index, so results are reproducible for a fixed topology.
    """
    from repro.engine.topology import check_axes

    check_axes(mesh, axes)
    assert chunks_per_worker % sync_every == 0, "sync_every must divide chunks"
    n_rounds = chunks_per_worker // sync_every
    axis = axes if len(axes) > 1 else axes[0]

    def worker(x_local, key):
        widx = jax.lax.axis_index(axes[0])
        if len(axes) > 1:
            for a in axes[1:]:
                # mesh.shape is static — avoids jax.lax.axis_size, which
                # older jax versions lack inside shard_map.
                widx = widx * mesh.shape[a] + jax.lax.axis_index(a)
        key = jax.random.fold_in(key, widx)
        state = init_state(k, x_local.shape[1])

        def round_body(state, key_r):
            def body(state, key_i):
                ks, kc = jax.random.split(key_i)
                chunk = sample_chunk(
                    x_local, ks, s, with_replacement=with_replacement
                )
                return chunk_step(
                    chunk, state, kc,
                    max_iters=max_iters, tol=tol,
                    candidates=candidates, impl=impl, precision=precision,
                )

            keys = jax.random.split(key_r, sync_every)
            state, infos = jax.lax.scan(body, state, keys)
            state = _exchange_best(state, axis)
            return state, infos

        keys = jax.random.split(key, n_rounds)
        state, infos = jax.lax.scan(round_body, state, keys)
        infos = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), infos)
        # distance-eval counter: aggregate across workers (paper's n_d).
        total_nd = jax.lax.psum(state.n_dist_evals, axis)
        total_acc = jax.lax.psum(state.n_accepted, axis)
        state = state._replace(n_dist_evals=total_nd, n_accepted=total_acc)
        return state, infos

    shard = _shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axes), P()),
        out_specs=(
            BigMeansState(P(), P(), P(), P(), P()),
            ChunkInfo(*([P(axes[0])] * 4)),
        ),
    )
    xd = _cast_dataset(X, precision)
    return shard(xd, key)


# ---------------------------------------------------------------------------
# worker mesh, host-orchestrated: one jitted segment per sync window, so
# middleware (checkpoint/resume, time budget) runs between windows
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=(
        "mesh", "axes", "k", "s", "n_rounds", "sync_every", "max_iters",
        "tol", "candidates", "impl", "with_replacement", "precision",
    ),
)
def _sharded_segment(
    X, key, r, states, *, mesh, axes, k, s, n_rounds, sync_every,
    max_iters, tol, candidates, impl, with_replacement, precision,
):
    """Window ``r`` of the worker-sharded run: ``sync_every`` chunks per
    worker, then the argmin exchange — with the per-worker state stack
    ``[W, ...]`` passed in/out instead of living inside one big scan.

    The key schedule is byte-identical to :func:`worker_sharded`: each
    worker folds its index into the base key, splits ``n_rounds`` round
    keys, and consumes round ``r``'s — so an uninterrupted sequence of
    segments replays the one-shot driver's trajectory exactly.
    """
    axis = axes if len(axes) > 1 else axes[0]

    def worker(x_local, key, r, state_stack):
        widx = jax.lax.axis_index(axes[0])
        if len(axes) > 1:
            for a in axes[1:]:
                widx = widx * mesh.shape[a] + jax.lax.axis_index(a)
        kw = jax.random.fold_in(key, widx)
        key_r = jax.random.split(kw, n_rounds)[r]
        state = jax.tree.map(lambda a: a[0], state_stack)   # local stack: [1, ...]

        def body(state, key_i):
            ks, kc = jax.random.split(key_i)
            chunk = sample_chunk(
                x_local, ks, s, with_replacement=with_replacement)
            return chunk_step(
                chunk, state, kc,
                max_iters=max_iters, tol=tol, candidates=candidates,
                impl=impl, precision=precision,
            )

        keys = jax.random.split(key_r, sync_every)
        state, infos = jax.lax.scan(body, state, keys)
        state = _exchange_best(state, axis)
        return (jax.tree.map(lambda a: a[None], state),
                jax.tree.map(lambda a: a[None], infos))

    shard = _shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axes), P(), P(),
                  BigMeansState(*([P(axes)] * 5))),
        out_specs=(
            BigMeansState(*([P(axes)] * 5)),
            ChunkInfo(*([P(axes[0])] * 4)),
        ),
    )
    return shard(X, key, r, states)


def worker_sharded_rounds(
    X, key, *, mesh, k, s, chunks_per_worker, sync_every=1, axes=("data",),
    max_iters=300, tol=1e-4, candidates=3, impl="auto",
    with_replacement=True, precision="auto", cfg=None, middlewares=None,
    resume=True,
):
    """Worker-sharded Big-means with the accept loop on the host.

    Functionally :func:`worker_sharded` (bit-identical trajectories when no
    middleware interrupts), but each sync window is one jitted segment and
    the middleware stack runs between windows — enabling sharded +
    checkpoint/resume and sharded + time-budget compositions.

    Returns ``(state, infos, ctx)``; ``state`` is the reduced incumbent,
    ``infos`` the worker-major chunk trace of the windows that ran.
    """
    from repro.engine.topology import check_axes

    check_axes(mesh, axes)
    assert chunks_per_worker % sync_every == 0, "sync_every must divide chunks"
    n_rounds = chunks_per_worker // sync_every
    W = 1
    for a in axes:
        W *= int(mesh.shape[a])
    xd = _cast_dataset(X, precision)
    n = X.shape[1]

    stack = mw.MiddlewareStack(middlewares or [])
    states = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (W,) + a.shape), init_state(k, n))
    ctx = mw.EngineContext(cfg=cfg, key=key, metrics=None, state=states,
                           t0=time.monotonic(), last_s=s)
    ckpt = stack.find(mw.Checkpoint)
    start_round = 0
    if resume and ckpt is not None and ckpt.maybe_restore(ctx, states):
        start_round = ctx.step
        states, key = ctx.state, ctx.key
    if start_round >= n_rounds:
        start_round = n_rounds
    stack.on_start(ctx)

    window_infos = []
    for r in range(start_round, n_rounds):
        states, infos = _sharded_segment(
            xd, key, jnp.int32(r), states,
            mesh=mesh, axes=tuple(axes), k=k, s=s, n_rounds=n_rounds,
            sync_every=sync_every, max_iters=max_iters, tol=tol,
            candidates=candidates, impl=impl,
            with_replacement=with_replacement, precision=precision,
        )
        ctx.state, ctx.info = states, infos
        ctx.step = r + 1
        ctx.last_cid = (r + 1) * sync_every - 1
        window_infos.append(infos)
        stack.after_window(ctx)
        if stack.should_stop(ctx):
            break

    stack.on_finish(ctx)
    # reduce: post-exchange incumbents are replicated across workers; the
    # counters are per-worker and sum to the paper's global n_d / accepts.
    final = BigMeansState(
        centroids=states.centroids[0],
        degenerate=states.degenerate[0],
        f_best=states.f_best[0],
        n_accepted=jnp.sum(states.n_accepted),
        n_dist_evals=jnp.sum(states.n_dist_evals),
    )
    if window_infos:
        # [rounds][Wd, sync] -> [Wd, rounds, sync] -> worker-major flat,
        # matching the one-shot driver's trace order.
        infos = jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=1).reshape(
                (-1,) + xs[0].shape[2:]),
            *window_infos)
    else:
        infos = jax.tree.map(
            lambda a: jnp.zeros((0,) + a.shape[1:], a.dtype),
            _zero_infos(k))
    return final, infos, ctx


def _zero_infos(k):
    return ChunkInfo(
        f_new=jnp.zeros((1,), jnp.float32),
        accepted=jnp.zeros((1,), bool),
        lloyd_iters=jnp.zeros((1,), jnp.int32),
        n_degenerate=jnp.zeros((1,), jnp.int32),
    )
