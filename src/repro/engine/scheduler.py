"""ChunkScheduler — *which* chunk feeds *which* stream, at *what* size.

The paper's decomposition loop never cares where a chunk came from — only
that every stream keeps receiving i.i.d. uniform samples.  That makes the
feeding policy an orthogonal, pluggable axis:

* :class:`Uniform` — the classic schedule: round ``r`` feeds streams
  ``0..B-1`` with chunk ids ``r*B..r*B+B-1``, all at the configured ``s``.
  (In the jitted in-core drivers this is the ``split(key, rounds*batch)``
  key schedule; in the host loop it is the prefetcher's id order.)
* :class:`WorkerPartitioned` — the multi-worker schedule: every worker owns
  an id-disjoint stream, realized by folding the worker index into the PRNG
  key (``fold_in(key, widx)``) so a fixed topology replays exactly.
* :class:`CompetitiveS` — competitive stochastic sample-size optimization
  (arXiv:2403.18766): streams race *different* sample sizes ``s_b``; at
  every sync window all incumbents are scored on a common evaluation chunk
  and one stream is reallocated from the worst-performing size to the
  winning size.  The fleet converges onto the empirically best ``s``
  instead of trusting a hand-picked one.

Schedulers are host-side objects (the in-core drivers special-case the two
stateless ones); the registry lets follow-up samplers plug in by name.
"""
from __future__ import annotations

from typing import Callable

_SCHEDULERS: dict[str, Callable] = {}


def register_scheduler(name: str):
    def deco(factory):
        _SCHEDULERS[name] = factory
        return factory
    return deco


def get_scheduler(name: str, cfg=None):
    """Instantiate a scheduler by name from a config."""
    try:
        factory = _SCHEDULERS[name]
    except KeyError:
        raise KeyError(
            f"unknown scheduler {name!r}; known: {list_schedulers()}"
        ) from None
    return factory(cfg)


def list_schedulers() -> list[str]:
    return sorted(_SCHEDULERS)


class _StatelessScheduler:
    """Shared base: every stream gets the configured chunk size, nothing is
    ever reallocated.  All schedulers expose this interface so any of them
    can drive the stream loop."""

    name = "stateless"

    def __init__(self, cfg=None):
        self.s = None if cfg is None else cfg.s

    def sizes(self, batch: int) -> list[int]:
        return [self.s] * batch

    @property
    def fetch_s(self):
        return self.s

    def observe_window(self, scores, sizes):
        return []           # stateless: nothing to reallocate


@register_scheduler("uniform")
class Uniform(_StatelessScheduler):
    """The classic schedule: ids in round-major order, one size for all."""

    name = "uniform"


@register_scheduler("worker")
class WorkerPartitioned(_StatelessScheduler):
    """Descriptor for the multi-worker partitioned schedule (the sharded
    drivers realize it on-device via ``fold_in(key, worker_index)``); in
    the stream loop it behaves like :class:`Uniform`."""

    name = "worker"


def default_ladder(k: int, s: int) -> tuple:
    """A geometric 3-rung ladder around the configured chunk size."""
    return (max(k, s // 2), s, 2 * s)


@register_scheduler("competitive_s")
class CompetitiveS:
    """Race per-stream sample sizes; reallocate toward the winning ``s``.

    ``ladder`` sizes are dealt round-robin over the ``batch`` streams.
    After every sync window, :meth:`observe_window` compares the best
    common-eval-chunk score achieved by each size and moves one stream from
    the worst size with spares onto the best (adopting the winner stream's
    incumbent, acceptance threshold rescaled to the new chunk size).  Every
    size keeps at least one explorer stream — early windows favour small
    sizes (they accept fast) while large sizes mature slowly, so killing a
    size on early evidence loses the race; the final allocation plus the
    eval-based final reduce is the optimizer's answer.

    Chunks are fetched at ``fetch_s = max(ladder)`` and sliced per stream,
    so one provider serves every size and replay invariance is preserved
    (per-chunk keys remain ``fold_in(seed, chunk_id)``).

    ``stream_offset`` shifts the round-robin deal: a host-mesh rank owning
    global streams ``[offset, offset + batch)`` deals its local ladder from
    the global stream index, so the fleet-wide size assignment matches the
    single-process run of the same global batch.
    """

    name = "competitive_s"

    def __init__(self, cfg=None, *, ladder=None, batch=None,
                 stream_offset: int = 0):
        if cfg is not None:
            ladder = tuple(cfg.competitive_ladder) or default_ladder(
                cfg.k, cfg.s)
            batch = cfg.batch
        if not ladder or batch is None:
            raise ValueError("CompetitiveS needs a size ladder and a batch")
        if batch < 2:
            raise ValueError(
                f"competitive_s races streams against each other; it needs "
                f"batch >= 2, got {batch}")
        self.ladder = tuple(sorted(set(int(x) for x in ladder)))
        self.s_of = [self.ladder[(stream_offset + b) % len(self.ladder)]
                     for b in range(batch)]
        self.history: list[dict] = []

    @property
    def fetch_s(self) -> int:
        return max(self.ladder)

    def sizes(self, batch: int) -> list[int]:
        return list(self.s_of)

    def observe_window(self, scores, sizes) -> list[tuple[int, int, int]]:
        """One reallocation step.

        ``scores[b]`` is stream b's incumbent quality on a COMMON evaluation
        set (the engine scores every incumbent on the same full-size chunk,
        because raw chunk objectives are not comparable across sizes: small
        chunks overfit and always look better per point).  Returns
        ``(stream, new_s, clone_from)`` moves: ``stream`` switches to
        ``new_s`` and adopts ``clone_from``'s incumbent (the engine rescales
        the cloned acceptance threshold by ``new_s / sizes[clone_from]``).
        """
        best_of_size: dict[int, float] = {}
        best_stream_of_size: dict[int, int] = {}
        for b, (s, sc) in enumerate(zip(sizes, scores)):
            if s not in best_of_size or sc < best_of_size[s]:
                best_of_size[s] = sc
                best_stream_of_size[s] = b
        ranking = sorted(best_of_size, key=best_of_size.get)
        self.history.append({
            "sizes": list(sizes),
            "eval_best": {s: best_of_size[s] for s in ranking},
            "winner_s": ranking[0],
        })
        if len(ranking) < 2:
            return []               # one size left: converged
        win_s = ranking[0]
        # reallocate from the worst size that still has a spare stream —
        # every size keeps >= 1 explorer, so an early-round loser (large s
        # matures slowly) can still win later windows and the final
        # eval-based reduce always sees every size's best incumbent
        for lose_s in reversed(ranking):
            if lose_s == win_s:
                return []           # only the winner has spares: converged
            losers = [b for b, s in enumerate(sizes) if s == lose_s]
            if len(losers) > 1:
                break
        else:
            return []
        # move the worst stream of the losing size onto the winning size
        moved = max(losers, key=lambda b: scores[b])
        clone_from = best_stream_of_size[win_s]
        self.s_of = list(sizes)
        self.s_of[moved] = win_s
        self.history[-1]["moved"] = (moved, lose_s, win_s)
        return [(moved, win_s, clone_from)]
