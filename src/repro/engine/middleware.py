"""Accept-loop middleware: capabilities that wrap *any* engine composition.

Checkpointing, the VNS chunk-size ladder, wall-clock budgets, progress
tracing and fetch-failure skipping were historically welded into the
streaming runner's loop body — which is why "sharded with checkpoints" or
"time-budgeted batched" could not be expressed.  Here each capability is a
:class:`Middleware` with narrow hooks, and a :class:`MiddlewareStack`
composes them around whichever loop the engine runs (the out-of-core stream
loop or the host-orchestrated sharded rounds).

Hook order per window: ``transform_chunk`` (as chunks arrive) →
``after_window`` (incumbent advanced) → ``should_stop``.  The stack calls
hooks in list order, so put policy middleware (VNS) before observers
(trace, checkpoint) — :func:`default_stack` does.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster import checkpoint as ckpt_lib
from repro.engine import faults


@dataclasses.dataclass
class EngineContext:
    """Mutable per-run state threaded through every hook.

    ``state`` is the incumbent (scalar ``BigMeansState``, or the stacked
    per-worker/per-stream states in mesh compositions); ``info`` the latest
    window's ``ChunkInfo``; ``rung``/``stall``/``last_s`` the VNS loop state
    (checkpointed alongside the incumbent so a resume continues the ladder
    instead of silently resetting it).
    """

    cfg: Any
    key: Any
    metrics: Any
    state: Any = None
    info: Any = None
    step: int = 0                   # chunks (stream loop) / rounds (sharded)
    start_step: int = 0
    last_cid: int = -1
    batch_len: int = 0
    t0: float = 0.0
    rung: int = 0
    stall: int = 0
    last_s: int = 0
    stop_reason: str | None = None
    extras: dict = dataclasses.field(default_factory=dict)


class Middleware:
    """Base class: every hook is a no-op."""

    def on_start(self, ctx: EngineContext) -> None:
        pass

    def transform_chunk(self, ctx: EngineContext, cid: int, chunk):
        return chunk

    def on_fetch_error(self, ctx: EngineContext, cid: int, err: str) -> None:
        pass

    def after_window(self, ctx: EngineContext) -> None:
        pass

    def should_stop(self, ctx: EngineContext) -> bool:
        return False

    def on_finish(self, ctx: EngineContext) -> None:
        pass


class MiddlewareStack:
    def __init__(self, middlewares):
        self.middlewares = list(middlewares)

    def __iter__(self):
        return iter(self.middlewares)

    def find(self, cls):
        for m in self.middlewares:
            if isinstance(m, cls):
                return m
        return None

    def on_start(self, ctx):
        for m in self.middlewares:
            m.on_start(ctx)

    def transform_chunk(self, ctx, cid, chunk):
        for m in self.middlewares:
            chunk = m.transform_chunk(ctx, cid, chunk)
        return chunk

    def on_fetch_error(self, ctx, cid, err):
        for m in self.middlewares:
            m.on_fetch_error(ctx, cid, err)

    def after_window(self, ctx):
        for m in self.middlewares:
            m.after_window(ctx)

    def should_stop(self, ctx) -> bool:
        for m in self.middlewares:
            if m.should_stop(ctx):
                if ctx.stop_reason is None:
                    ctx.stop_reason = type(m).__name__
                return True
        return False

    def on_finish(self, ctx):
        for m in self.middlewares:
            m.on_finish(ctx)


class TimeBudget(Middleware):
    """The paper's ``cpu_max`` stop condition, composable with any loop."""

    def __init__(self, budget_s: float):
        self.budget_s = budget_s

    def should_stop(self, ctx) -> bool:
        return time.monotonic() - ctx.t0 > self.budget_s


class VNSLadder(Middleware):
    """Chunk-size variable-neighbourhood shaking (§6 extension): a stall of
    ``patience`` unaccepted chunks escalates to the next (smaller) rung;
    any acceptance resets to the base neighbourhood."""

    def __init__(self, s: int, ladder, patience: int):
        self.ladder = (s,) + tuple(ladder)
        self.patience = patience

    def transform_chunk(self, ctx, cid, chunk):
        s_now = self.ladder[ctx.rung]
        if chunk.shape[0] > s_now:
            chunk = chunk[:s_now]           # VNS: shrink the neighbourhood
        return chunk

    def after_window(self, ctx):
        n_acc = int(np.sum(np.asarray(ctx.info.accepted)))
        if n_acc:
            ctx.rung, ctx.stall = 0, 0      # success -> base neighbourhood
        elif len(self.ladder) > 1:
            ctx.stall += int(np.size(np.asarray(ctx.info.accepted)))
            if ctx.stall >= self.patience:
                ctx.rung = min(ctx.rung + 1, len(self.ladder) - 1)
                ctx.stall = 0


class TraceLog(Middleware):
    """Progress trace entries at the legacy cadence."""

    def __init__(self, every: int, batch: int):
        self.every = every
        self.batch = batch

    def after_window(self, ctx):
        m = ctx.metrics
        if ctx.info is None:            # window where no stream stepped
            return
        if self.every and m.chunks_done % self.every < self.batch:
            m.trace.append(
                (ctx.last_cid, float(np.asarray(ctx.state.f_best).min()),
                 float(np.min(np.asarray(ctx.info.f_new)))))


class FetchSkip(Middleware):
    """Account for failed fetches: chunks are i.i.d. samples, so a lost one
    is skipped (natively fault-tolerant) but never silently — the metrics
    count it and the trace records the cause."""

    def on_fetch_error(self, ctx, cid, err):
        ctx.metrics.chunks_failed += 1
        ctx.metrics.trace.append(("fetch_error", cid, err))


class ChunkSanitizer(Middleware):
    """Validate a chunk before it can reach acceptance.

    A NaN/Inf-poisoned or wrong-shape chunk must never be compared against
    ``f_best`` (NaN comparisons silently reject, ``-inf`` silently *wins*,
    a shape mismatch crashes the jitted step): raise
    :class:`repro.engine.faults.ChunkQuarantined` and let the loop account
    for it as ``("quarantine", cid, reason)`` + ``chunks_quarantined``.
    Quarantine is statistically free — chunks are i.i.d. samples — but
    never silent.
    """

    def transform_chunk(self, ctx, cid, chunk):
        n = int(ctx.state.centroids.shape[-1])
        if chunk.ndim != 2 or int(chunk.shape[1]) != n:
            raise faults.ChunkQuarantined(
                f"bad shape {tuple(map(int, chunk.shape))}, want (*, {n})")
        if int(chunk.shape[0]) < int(ctx.cfg.k):
            raise faults.ChunkQuarantined(
                f"chunk has {int(chunk.shape[0])} rows < k={ctx.cfg.k}")
        if not bool(jnp.all(jnp.isfinite(chunk))):
            raise faults.ChunkQuarantined("non-finite values (NaN/Inf)")
        return chunk


class InvariantGuard(Middleware):
    """Post-accept invariants: ``f_best`` stays finite and, in fold mode,
    monotone non-increasing *per point*.

    Acceptance only ever lowers ``f_best``; the sole legitimate raw change
    upward is the chunk-size rescale (objectives are sums over ``s``
    points), which preserves ``f_best / s``.  So the per-point incumbent
    must never rise — if it does (or goes NaN / ``-inf``), the run is
    corrupt and must stop loudly rather than stream on.  Persistent-stream
    mode tracks only finiteness: per-stream sizes make raw objectives
    incomparable across windows there.
    """

    def __init__(self, rtol: float = 1e-4):
        self.rtol = rtol
        self._best_per_point = float("inf")

    def after_window(self, ctx):
        f = float(np.min(np.asarray(ctx.state.f_best)))
        if np.isnan(f) or f == -np.inf:
            raise faults.InvariantViolation(
                f"f_best became {f!r}: acceptance was poisoned by bad data")
        if not np.isfinite(f) or ctx.extras.get("stream_mode") != "fold":
            return
        per_point = f / max(int(ctx.last_s), 1)
        if per_point > self._best_per_point * (1.0 + self.rtol):
            raise faults.InvariantViolation(
                f"f_best per point rose: {per_point:.6e} after "
                f"{self._best_per_point:.6e} (monotone non-increasing "
                "acceptance violated)")
        self._best_per_point = min(self._best_per_point, per_point)


class Checkpoint(Middleware):
    """Persist the *full* loop state: ``((state, key), vns_aux)`` where
    ``vns_aux = [rung, stall, last_s]``.

    ``last_s`` makes the post-resume objective rescale exact (objectives are
    sums over the chunk's points; comparing across sizes needs the incumbent
    rescaled by the size ratio), and ``(rung, stall)`` resumes the VNS
    ladder where it stopped instead of silently resetting it.  Checkpoints
    written by older versions (no aux leaf) restore with ladder state reset
    to the base rung.
    """

    def __init__(self, directory: str, every: int, batch: int,
                 step_from: str = "chunks"):
        # step_from: what a checkpoint "step" indexes — the next chunk id
        # ("chunks", the stream loop's legacy semantics) or ctx.step
        # ("step", the sharded rounds loop's window index).
        self.directory = directory
        self.every = every
        self.batch = batch
        self.step_from = step_from

    def _step(self, ctx) -> int:
        return ctx.step if self.step_from == "step" else ctx.last_cid + 1

    def _payload(self, ctx):
        aux = np.asarray([ctx.rung, ctx.stall, ctx.last_s], dtype=np.int64)
        return ((ctx.state, ctx.key), aux)

    def maybe_restore(self, ctx, example_state):
        """Restore the newest *intact* checkpoint into ``ctx`` (state, key,
        step and VNS loop state); no-op when the directory holds none.

        Self-healing: a corrupt newest ``step_*`` (truncated write, bad
        digest) falls back to the newest intact one, recorded as a
        ``("ckpt_fallback", step)`` trace event; when every stored
        checkpoint is corrupt the run restarts fresh with
        ``("ckpt_fallback", None)`` instead of crashing.
        """
        latest = ckpt_lib.latest_step(self.directory)
        if latest is None:
            return False
        step = ckpt_lib.latest_intact_step(self.directory)
        if step is None:
            ctx.metrics.trace.append(("ckpt_fallback", None))
            return False
        if step != latest:
            ctx.metrics.trace.append(("ckpt_fallback", step))
        example_new = ((example_state, ctx.key),
                       np.zeros(3, dtype=np.int64))
        n = ckpt_lib.n_leaves(self.directory, step)
        if n == len(jax.tree.flatten(example_new)[0]):
            ((state, key), aux), step = ckpt_lib.restore(
                self.directory, example_new, step=step)
            aux = np.asarray(aux)
            ctx.rung, ctx.stall = int(aux[0]), int(aux[1])
            ctx.last_s = int(aux[2])
        else:                       # legacy (state, key) checkpoint
            (state, key), step = ckpt_lib.restore(
                self.directory, (example_state, ctx.key), step=step)
        ctx.state, ctx.key = state, key
        ctx.step = ctx.start_step = step
        return True

    def after_window(self, ctx):
        if (ctx.last_cid + 1) % self.every < self.batch:
            ckpt_lib.save(self.directory, self._step(ctx),
                          self._payload(ctx))

    def on_finish(self, ctx):
        ckpt_lib.save(self.directory, ctx.step, self._payload(ctx))


def load_loop_state(directory: str):
    """Debug/test helper: the VNS aux payload of the latest checkpoint, as
    ``{'rung', 'stall', 'last_s'}`` (None for legacy checkpoints)."""
    import os

    step = ckpt_lib.latest_step(directory)
    if step is None:
        return None
    n = ckpt_lib.n_leaves(directory, step)
    data = np.load(os.path.join(
        directory, f"step_{step:012d}", "arrays.npz"))
    aux = data[f"a{n - 1}"]                 # the aux leaf flattens last
    if aux.shape != (3,):
        return None
    return {"rung": int(aux[0]), "stall": int(aux[1]), "last_s": int(aux[2])}


def default_stack(cfg, *, for_streaming: bool = True) -> MiddlewareStack:
    """The streaming runner's historical capability set, as a stack.

    Order matters: the sanitizer (chunk admission) before VNS (policy),
    then observers (trace, checkpoint), the stop condition, and the
    invariant guard last.  ``cfg.validate_chunks=False`` drops the
    sanitizer and guard (bit-for-bit legacy admission).
    """
    mws: list[Middleware] = []
    if for_streaming:
        mws.append(FetchSkip())
    validate = getattr(cfg, "validate_chunks", True)
    if for_streaming and validate:
        mws.append(ChunkSanitizer())
    if cfg.vns_ladder:
        mws.append(VNSLadder(cfg.s, cfg.vns_ladder, cfg.vns_patience))
    if cfg.log_every and for_streaming:
        mws.append(TraceLog(cfg.log_every, cfg.batch))
    if cfg.ckpt_dir:
        mws.append(Checkpoint(cfg.ckpt_dir, cfg.ckpt_every, cfg.batch))
    if cfg.time_budget_s is not None:
        mws.append(TimeBudget(cfg.time_budget_s))
    if for_streaming and validate:
        mws.append(InvariantGuard())
    return MiddlewareStack(mws)
