"""The out-of-core accept loop: one host loop for every composition.

Chunks are *fetched* by a provider (memmap slice, distributed-FS shard,
synthetic generator), staged through a prefetch pipeline, and fed to the
jitted ``chunk_step`` / ``chunk_step_batched`` kernels — on one device or,
with a :class:`~repro.engine.topology.StreamMesh`, with the stream batch
axis sharded over a device mesh (out-of-core data on multi-device hardware:
the production big-data scenario).  Capabilities (checkpoint/resume, VNS,
time budget, tracing, fetch-failure skip) come from the middleware stack,
not from the loop body.

Design properties (DESIGN.md §6) are unchanged from the historical runner:

* **fault tolerance** — global state is (C, degenerate, f_best, step, key):
  kilobytes.  A lost/failed chunk is simply skipped: chunks are i.i.d.
  uniform samples, so dropping one changes nothing statistically.  On top
  of that baseline, :mod:`repro.engine.faults` adds bounded retries with
  deterministic backoff (``cfg.retries``), a fetch watchdog that turns a
  hung provider into a retryable fault (``cfg.fetch_timeout_s``), and a
  chunk sanitizer + post-accept invariant guard (``cfg.validate_chunks``)
  that quarantine NaN/Inf/wrong-shape chunks before they can poison
  ``f_best`` acceptance.
* **replay invariance** — per-chunk keys are ``fold_in(key, chunk_id)``:
  restarts, batch sizes, prefetch depths and device counts replay the
  identical sample stream.
* **pipelining** — a background thread prefetches chunks into a bounded
  queue and stages them on device; under ``precision='bf16'`` it casts on
  the host first, halving host→device bytes, and under ``'int8'`` it
  quantizes on the host (per-feature scales) and ships int8 codes — a
  quarter of the f32 link bytes — dequantizing on device off the main
  thread so downstream consumers still see a plain f32 chunk.  Double
  buffering continues *inside* the fused kernel: the ``pipeline='dma'``
  autotune candidate overlaps the HBM copy of point tile i+1 with compute
  on tile i (``kernels/fused_step.py``).

Two stream-state modes share the loop:

* **fold** (collective sync, the historical behaviour): one incumbent;
  each batch broadcasts it into B streams, steps, and argmin-reduces back.
* **persistent streams** (periodic/competitive sync, and the
  ``competitive_s`` scheduler): B incumbents persist across batches and
  exchange only at sync boundaries — the paper's competitive mode, now
  expressible out-of-core.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bigmeans
from repro.engine import faults
from repro.engine import middleware as mw
from repro.engine import scheduler as sched_lib
from repro.engine import sync as sync_lib
from repro.engine import topology as topo_lib

ChunkProvider = Callable[[int], np.ndarray]


class EndOfStream(Exception):
    """Raised by a provider to end the run cleanly before ``n_chunks``
    (e.g. a finite chunk iterator ran dry).  Not counted as a failure."""


@dataclasses.dataclass
class RunnerMetrics:
    """``trace`` holds ``(chunk_id, f_best, f_new)`` progress entries plus
    the structured events: ``("fetch_error", chunk_id, "ExcType: message")``
    for failed fetches (retries exhausted), ``("quarantine", chunk_id,
    reason)`` for chunks that arrived with unusable data (NaN/Inf, wrong
    shape), ``("budget_drop", (chunk_ids...))`` for chunks fetched but
    dropped un-stepped at a budget stop, ``("short_chunk", cid, rows,
    need)`` for ragged tails, and ``("ckpt_fallback", step)`` when restore
    healed past a corrupt checkpoint — so ``chunks_done + chunks_failed +
    chunks_dropped + chunks_quarantined`` always reconciles with the number
    of chunks fetched."""
    chunks_done: int = 0
    chunks_failed: int = 0
    chunks_dropped: int = 0
    chunks_quarantined: int = 0
    accepted: int = 0
    lloyd_iters: int = 0
    wall_time_s: float = 0.0
    f_best: float = float("inf")
    trace: list = dataclasses.field(default_factory=list)
    # multi-host runs (repro.engine.hostmesh): the final per-rank health
    # gather — {"rank", "processes", "winner_rank", "per_rank": [...]}.
    host: dict | None = None


class _FetchFailure:
    """A failed chunk fetch: carries the provider's exception type+message,
    its fault class and how many attempts were burned on it."""

    __slots__ = ("error", "kind", "attempts")

    def __init__(self, exc: BaseException, kind: str = faults.TRANSIENT,
                 attempts: int = 1):
        self.error = f"{type(exc).__name__}: {exc}"
        self.kind = kind
        self.attempts = attempts


def _stage_quantized(arr):
    """int8 host->device hand-off for the prefetch pipeline.

    Quantize on the host thread (per-feature scales, the canonical scheme
    of :mod:`repro.kernels.precision`), ship int8 codes + one f32 scale row
    — roughly a quarter of the f32 host->device bytes — then dequantize on
    device, still off the main thread.  The consumer sees a plain f32 chunk
    (sanitizer, K-means++ seeding and stream slicing are untouched) whose
    values are exactly the quantized representation; ``lloyd`` re-quantizes
    deterministically, so results are identical to shipping f32.

    Non-finite chunks ship unquantized: NaN/Inf must reach the chunk
    sanitizer verbatim (int8 codes would silently launder them into
    in-range garbage).
    """
    from repro.kernels import precision as px

    if not np.isfinite(arr).all():
        return jax.device_put(arr)
    q, scale = px.host_quantize(arr)
    qd = jax.device_put(q)
    sd = jax.device_put(scale)
    return qd.astype(jnp.float32) * sd[None, :]


def _fetch_resilient(provider, cid, fault_injector, dtype, *,
                     retry=None, timeout=None, wait=time.sleep,
                     aborted=None, stage=jax.device_put):
    """One guarded chunk fetch: watchdog + classify + bounded retry.

    Returns the device-staged chunk, raises :class:`EndOfStream`, or
    returns a :class:`_FetchFailure` once the fault is terminal (permanent
    class, or a transient one with the retry budget exhausted).  A hung
    provider becomes a retryable :class:`repro.engine.faults.FetchTimeout`
    via the watchdog, so the calling thread is never blocked for longer
    than ``timeout`` per attempt.  ``stage`` is the host->device hand-off
    (:func:`_stage_quantized` under ``precision='int8'``).
    """

    def attempt_once():
        if fault_injector is not None:
            fault_injector(cid)
        return np.asarray(provider(cid), dtype=dtype)

    attempt = 0
    while True:
        try:
            arr = faults.call_with_timeout(
                attempt_once, timeout, name=f"fetch-watchdog-{cid}")
            return stage(arr)
        except EndOfStream:
            raise
        except Exception as exc:
            kind = faults.classify(exc)
            retries = retry.retries if retry is not None else 0
            if (kind == faults.TRANSIENT and attempt < retries
                    and not (aborted is not None and aborted())):
                wait(retry.delay(cid, attempt))
                attempt += 1
                continue
            return _FetchFailure(exc, kind=kind, attempts=attempt + 1)


class _Prefetcher:
    """Background chunk fetcher: provider call + np conversion + device_put
    run off the main thread, double-buffered through a bounded queue.

    Yields ``(chunk_id, chunk-or-_FetchFailure)`` in id order; a
    ``_FetchFailure`` marks a failed fetch (the provider raised, or kept
    raising transiently past the retry budget) so the consumer can account
    for it and record the cause.  With ``timeout`` set, each provider call
    runs under the :func:`repro.engine.faults.call_with_timeout` watchdog:
    a hung provider is abandoned on a daemon thread and surfaces as a
    retryable fault, so the worker — and therefore :meth:`close` — stays
    deterministic.
    """

    _DONE = object()

    def __init__(self, provider, ids, depth,
                 fault_injector=None, dtype=np.float32,
                 retry=None, timeout=None, stage=jax.device_put):
        self._provider = provider
        self._ids = ids
        self._dtype = dtype
        self._fault_injector = fault_injector
        self._retry = retry
        self._timeout = timeout
        self._stage = stage
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _fetch(self, cid):
        try:
            return _fetch_resilient(
                self._provider, cid, self._fault_injector, self._dtype,
                retry=self._retry, timeout=self._timeout,
                wait=self._stop.wait, aborted=self._stop.is_set,
                stage=self._stage)
        except EndOfStream:
            return self._DONE

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _work(self):
        for cid in self._ids:
            if self._stop.is_set():
                return
            item = self._fetch(cid)
            if item is self._DONE:          # provider signalled end-of-stream
                break
            if not self._put((cid, item)):
                return
        self._put(self._DONE)

    def __iter__(self) -> Iterator:
        while True:
            item = self._q.get()
            if item is self._DONE:
                return
            yield item

    def close(self):
        self._stop.set()
        # Drain so a blocked producer can observe the stop flag and exit.
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)


def _sync_chunks(provider, ids, fault_injector, dtype=np.float32,
                 retry=None, timeout=None, stage=jax.device_put):
    """prefetch=0 fallback: fetch in the main thread (debug / determinism),
    with the same retry/watchdog semantics as the prefetch pipeline."""
    for cid in ids:
        try:
            yield cid, _fetch_resilient(
                provider, cid, fault_injector, dtype,
                retry=retry, timeout=timeout, stage=stage)
        except EndOfStream:
            return


def _mesh_put(topology, tree):
    """Shard leading (stream) axes over the stream mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    sharding = NamedSharding(topology.mesh, P(topology.axis))
    return jax.tree.map(lambda a: jax.device_put(a, sharding), tree)


class _StepKernel:
    """One batched accept step against the chosen topology."""

    def __init__(self, cfg, key, topology):
        self.cfg = cfg
        self.key = key
        self.topology = topology

    def _kwargs(self):
        cfg = self.cfg
        return dict(max_iters=cfg.max_iters, tol=cfg.tol,
                    candidates=cfg.candidates, impl=cfg.impl,
                    precision=getattr(cfg, "precision", "auto"))

    def keys_for(self, cids):
        # Per-chunk keys are folded from (seed, chunk_id): restarts, batch
        # sizes and worker-count changes replay the identical sample stream.
        return [jax.random.fold_in(self.key, cid) for cid in cids]

    def step_one(self, chunk, state, cid):
        return bigmeans.chunk_step(
            chunk, state, self.keys_for([cid])[0], **self._kwargs())

    def step_states(self, chunks, states, cids):
        """Advance B persistent streams by their chunks (stacked [B, s, n])."""
        keys = jnp.stack(self.keys_for(cids))
        mesh = isinstance(self.topology, topo_lib.StreamMesh)
        if mesh and chunks.shape[0] % self.topology.devices == 0:
            chunks, states, keys = _mesh_put(
                self.topology, (chunks, states, keys))
        return bigmeans.chunk_step_batched(
            chunks, states, keys, **self._kwargs())

    def step_fold(self, state, pending):
        """Advance one incumbent by len(pending) concurrent chunk streams."""
        if len(pending) == 1:
            return self.step_one(pending[0][1], state, pending[0][0])
        chunks = jnp.stack([c for _, c in pending])
        states = bigmeans.broadcast_state(state, len(pending))
        states, info = self.step_states(
            chunks, states, [cid for cid, _ in pending])
        return bigmeans.reduce_state(states, base=state), info


def run_stream(
    provider: ChunkProvider,
    cfg,
    *,
    n_features: int,
    resume: bool = True,
    fault_injector: Callable[[int], None] | None = None,
    key: jax.Array | None = None,
    middlewares=None,
    topology=None,
    scheduler=None,
    sync=None,
    host=None,
) -> tuple[bigmeans.BigMeansState, RunnerMetrics]:
    """Stream chunks through Big-means until the chunk count or a middleware
    stop condition (time budget, custom) ends the run.

    ``cfg`` is a `repro.api.BigMeansConfig` (or anything with the same
    fields).  ``middlewares``/``topology``/``scheduler``/``sync`` default to
    the config-derived assembly (:func:`repro.engine.middleware
    .default_stack`, :func:`repro.engine.topology.for_streams`,
    ``cfg.scheduler``, ``cfg.sync``/``cfg.sync_every``).

    ``host`` plugs in a :class:`repro.engine.hostmesh.HostExchanger` for
    multi-host runs: it owns this rank's chunk-id shard, the cross-host
    incumbent exchange at sync windows, and the final argmin-reduce.  With
    ``host=None`` (every single-process run) the loop body is untouched.
    """
    if key is None:
        key = jax.random.PRNGKey(cfg.seed)
    scheduler = scheduler if scheduler is not None else sched_lib.get_scheduler(
        getattr(cfg, "scheduler", "uniform"), cfg)
    sync = sync if sync is not None else sync_lib.from_config(cfg)
    topology = topology if topology is not None else topo_lib.for_streams(cfg)
    if isinstance(topology, topo_lib.WorkerMesh):
        raise ValueError(
            "the stream loop parallelizes over the stream axis; use "
            "StreamMesh (or the 'sharded' strategy for worker meshes)")
    if isinstance(topology, topo_lib.HostMesh) and host is None:
        raise ValueError(
            "host_mesh runs go through repro.engine.hostmesh."
            "run_host_stream (or fit(), which routes there): the stream "
            "loop needs the exchanger's chunk-id shard and sync hooks")
    if middlewares is None:
        stack = mw.default_stack(cfg)
    elif isinstance(middlewares, mw.MiddlewareStack):
        stack = middlewares
    else:
        stack = mw.MiddlewareStack(middlewares)

    competitive_sched = isinstance(scheduler, sched_lib.CompetitiveS)
    persistent = competitive_sched or (cfg.batch > 1 and sync.every != 1)
    if persistent and cfg.vns_ladder:
        raise ValueError(
            "vns_ladder requires collective sync (sync_every=1): the ladder "
            "re-sizes the single incumbent's chunks, which is incompatible "
            "with persistent per-stream incumbents")
    if competitive_sched and isinstance(topology, topo_lib.StreamMesh):
        raise ValueError(
            "competitive_s schedules ragged per-stream chunk sizes, which "
            "cannot shard over a stream mesh; use the single-device "
            "topology")

    state = bigmeans.init_state(cfg.k, n_features)
    metrics = RunnerMetrics()
    ctx = mw.EngineContext(cfg=cfg, key=key, metrics=metrics, state=state,
                           t0=time.monotonic(), last_s=cfg.s)
    ckpt = stack.find(mw.Checkpoint)
    if resume and ckpt is not None:
        ckpt.maybe_restore(ctx, state)
        state, key = ctx.state, ctx.key
    start_chunk = ctx.start_step
    if host is not None:
        # collective start: every rank adopts rank 0's restored
        # (state, key, step) so the fleet resumes the same global window
        state, key, start_chunk = host.sync_start(ctx, state, key)
    metrics.f_best = float(np.asarray(state.f_best).min())

    from repro.kernels import precision as px

    precision = getattr(cfg, "precision", "auto")
    host_dtype = px.host_dtype(precision) or np.float32
    # int8 ships quantized codes over the host->device link (~1/4 of the
    # f32 bytes) and dequantizes on device, still off the main thread.
    stage = _stage_quantized if precision == "int8" else jax.device_put
    ids = (host.chunk_ids(start_chunk) if host is not None
           else range(start_chunk, cfg.n_chunks))
    retry = faults.RetryPolicy.from_config(cfg)
    timeout = getattr(cfg, "fetch_timeout_s", None)
    source = (
        _Prefetcher(provider, ids, cfg.prefetch, fault_injector, host_dtype,
                    retry=retry, timeout=timeout, stage=stage)
        if cfg.prefetch > 0
        else _sync_chunks(provider, ids, fault_injector, host_dtype,
                          retry=retry, timeout=timeout, stage=stage)
    )
    kernel = _StepKernel(cfg, key, topology)
    ctx.extras["stream_mode"] = "persistent" if persistent else "fold"
    stack.on_start(ctx)

    runner_fn = _run_persistent if persistent else _run_fold
    try:
        state = runner_fn(source, state, ctx, stack, kernel, scheduler, sync,
                          host)
    finally:
        if isinstance(source, _Prefetcher):
            source.close()

    ctx.state = state
    ctx.step = start_chunk + metrics.chunks_done
    if host is not None:
        # final cross-host argmin-reduce + counter merge + health gather;
        # a dead peer surfaces here as a typed HostDead, never a hang
        state = host.finalize(ctx, state)
        ctx.state = state
        ctx.step = host.global_step
    stack.on_finish(ctx)
    metrics.wall_time_s = time.monotonic() - ctx.t0
    metrics.f_best = float(np.asarray(state.f_best).min())
    return state, metrics


def _drop_pending(ctx, pending):
    """Budget-stop accounting for fetched-but-unstepped chunks (so
    done + failed + dropped + quarantined reconciles with fetched)."""
    if pending:
        ctx.metrics.chunks_dropped += len(pending)
        ctx.metrics.trace.append(
            ("budget_drop", tuple(cid for cid, _ in pending)))


def _sanitize(ctx, stack, chunk_id, chunk):
    """Run the middleware transform chain; a quarantined chunk is counted
    and traced, and ``None`` is returned so the loop skips it."""
    try:
        return stack.transform_chunk(ctx, chunk_id, chunk)
    except faults.ChunkQuarantined as q:
        ctx.metrics.chunks_quarantined += 1
        ctx.metrics.trace.append(("quarantine", chunk_id, q.reason))
        return None


def _consume_info(ctx, info):
    m = ctx.metrics
    m.accepted += int(np.sum(np.asarray(info.accepted)))
    m.lloyd_iters += int(np.sum(np.asarray(info.lloyd_iters)))


def _run_fold(source, state, ctx, stack, kernel, scheduler, sync, host=None):
    """Collective mode: one incumbent, argmin-reduced after every batch."""
    cfg = ctx.cfg
    metrics = ctx.metrics
    pending: list = []

    def flush(state):
        state, info = kernel.step_fold(state, pending)
        metrics.chunks_done += len(pending)
        ctx.last_cid = pending[-1][0]
        pending.clear()
        _consume_info(ctx, info)
        if host is not None:
            # cross-host exchange BEFORE after_window, so the (rank-0)
            # checkpoint holds the post-exchange global incumbent at the
            # global chunk frontier
            state = host.fold_boundary(ctx, state)
        ctx.state, ctx.info = state, info
        ctx.step = (host.global_step if host is not None
                    else ctx.start_step + metrics.chunks_done)
        stack.after_window(ctx)
        return state

    stopped = False
    for chunk_id, chunk in source:
        if stack.should_stop(ctx):
            stopped = True
            # the item in hand was already consumed from the source:
            # account for it (failed or dropped), never lose it silently
            if isinstance(chunk, _FetchFailure):
                stack.on_fetch_error(ctx, chunk_id, chunk.error)
            elif chunk is None:
                metrics.chunks_failed += 1
            else:
                pending.append((chunk_id, chunk))
            break
        if chunk is None or isinstance(chunk, _FetchFailure):
            if isinstance(chunk, _FetchFailure):
                stack.on_fetch_error(ctx, chunk_id, chunk.error)
            else:
                metrics.chunks_failed += 1
            continue
        chunk = _sanitize(ctx, stack, chunk_id, chunk)
        if chunk is None:
            continue
        if pending and chunk.shape != pending[0][1].shape:
            # ragged chunk (short tail / VNS rung change mid-batch):
            # flush the homogeneous batch first, then start a new one
            state = flush(state)
        if chunk.shape[0] != ctx.last_s and np.isfinite(float(state.f_best)):
            # objectives are sums over s points: rescale the incumbent's
            # objective so acceptance compares per-point quality
            state = state._replace(
                f_best=state.f_best * (chunk.shape[0] / ctx.last_s))
        ctx.last_s = chunk.shape[0]
        pending.append((chunk_id, chunk))
        if len(pending) < cfg.batch:
            continue
        state = flush(state)
        if stack.should_stop(ctx):
            stopped = True
            break
    else:
        if pending:                     # final partial batch
            state = flush(state)
    if stopped:
        _drop_pending(ctx, pending)
    return state


def _run_persistent(source, state, ctx, stack, kernel, scheduler, sync,
                    host=None):
    """Persistent-stream mode: B incumbents advance across batches and
    exchange only at sync boundaries (periodic/competitive modes, and the
    ``competitive_s`` sample-size race)."""
    cfg = ctx.cfg
    metrics = ctx.metrics
    B = cfg.batch
    base = state                        # restored counters live here
    states = bigmeans.broadcast_state(state, B)
    sizes = list(scheduler.sizes(B))
    if any(s is None for s in sizes):
        sizes = [cfg.s] * B
    round_idx = 0
    pending: list = []
    competitive_sched = isinstance(scheduler, sched_lib.CompetitiveS)
    eval_chunk = None                   # last full-size chunk (common eval)

    def stream_scores(states):
        """Every incumbent scored on the SAME evaluation chunk — chunk
        objectives at different sizes are not comparable (small chunks
        overfit), a shared eval set is."""
        from repro.core.objective import chunk_objective

        return np.asarray(jax.vmap(
            lambda c: chunk_objective(eval_chunk, c, impl=cfg.impl)
        )(states.centroids), dtype=np.float64)

    def stream_slices(pending):
        """Assign this round's chunks to streams 0..len(pending)-1 and
        group them by that stream's chunk size.  A chunk too short for its
        stream (ragged tail of a finite source) is skipped — chunks are
        i.i.d. samples, so dropping one is statistically free — and
        returned for accounting."""
        groups: dict[int, list] = {}
        skipped: list = []
        for b, (cid, chunk) in enumerate(pending):
            s_b = sizes[b]
            if chunk.shape[0] < s_b:
                skipped.append((cid, int(chunk.shape[0]), s_b))
                continue
            groups.setdefault(s_b, []).append((b, cid, chunk[:s_b]))
        return groups, skipped

    def step_round(states, pending):
        groups, skipped = stream_slices(pending)
        for cid, rows, need in skipped:
            metrics.chunks_dropped += 1
            metrics.trace.append(("short_chunk", cid, rows, need))
        for s_b, members in sorted(groups.items()):
            idx = np.asarray([b for b, _, _ in members])
            chunks = jnp.stack([c for _, _, c in members])
            sub = jax.tree.map(lambda a: a[idx], states)
            sub, info = kernel.step_states(
                chunks, sub, [cid for _, cid, _ in members])
            states = jax.tree.map(
                lambda a, u: a.at[idx].set(u), states, sub)
            _consume_info(ctx, info)
            ctx.info = info
        metrics.chunks_done += len(pending) - len(skipped)
        ctx.last_cid = pending[-1][0]
        return states

    def reduce(states):
        """Final keep-the-best across streams.  At uniform sizes this is
        the plain argmin; under competitive_s the incumbents are scored on
        the common eval chunk (raw objectives are size-incomparable)."""
        if competitive_sched and eval_chunk is not None:
            w = int(np.argmin(stream_scores(states)))
        else:
            f = np.asarray(states.f_best, dtype=np.float64)
            w = int(np.argmin(f / np.asarray(sizes, dtype=np.float64)))
        ctx.extras["winner_s"] = int(sizes[w])
        return bigmeans.BigMeansState(
            centroids=states.centroids[w],
            degenerate=states.degenerate[w],
            f_best=states.f_best[w],
            n_accepted=jnp.sum(states.n_accepted) + base.n_accepted,
            n_dist_evals=jnp.sum(states.n_dist_evals) + base.n_dist_evals,
        )

    def boundary(states):
        nonlocal sizes
        if (round_idx + 1) % cfg.sync_every == 0:
            # scheduler observation window: competitive_s scores every
            # incumbent on the shared eval chunk and reallocates here
            if competitive_sched and eval_chunk is not None:
                scores = [float(f) for f in stream_scores(states)]
            else:
                scores = [float(f) for f in np.asarray(states.f_best)]
            moves = scheduler.observe_window(scores, list(sizes))
            for b, new_s, clone_from in moves:
                ratio = new_s / sizes[clone_from]
                states = states._replace(
                    centroids=states.centroids.at[b].set(
                        states.centroids[clone_from]),
                    degenerate=states.degenerate.at[b].set(
                        states.degenerate[clone_from]),
                    f_best=states.f_best.at[b].set(
                        states.f_best[clone_from] * ratio),
                )
            sizes = list(scheduler.sizes(B))
        if sync.boundary(round_idx):
            if competitive_sched and eval_chunk is not None:
                # cross-size collective exchange: every stream continues
                # from the eval winner, acceptance threshold rescaled to
                # its own chunk size (same per-point quality)
                scores = stream_scores(states)
                w = int(np.argmin(scores))
                s_eval = eval_chunk.shape[0]
                ratios = jnp.asarray(
                    [s_b / s_eval for s_b in sizes], dtype=jnp.float32)
                states = states._replace(
                    centroids=jnp.broadcast_to(
                        states.centroids[w], states.centroids.shape),
                    degenerate=jnp.broadcast_to(
                        states.degenerate[w], states.degenerate.shape),
                    f_best=jnp.float32(scores[w]) * ratios,
                )
            elif len(set(sizes)) == 1:
                # periodic argmin exchange (comparable only at equal sizes)
                states = bigmeans._sync_streams(states)
        return states

    stopped = False
    for chunk_id, chunk in source:
        if stack.should_stop(ctx):
            stopped = True
            # account for the consumed-but-unstepped item in hand
            if isinstance(chunk, _FetchFailure):
                stack.on_fetch_error(ctx, chunk_id, chunk.error)
            elif chunk is None:
                metrics.chunks_failed += 1
            else:
                pending.append((chunk_id, chunk))
            break
        if chunk is None or isinstance(chunk, _FetchFailure):
            if isinstance(chunk, _FetchFailure):
                stack.on_fetch_error(ctx, chunk_id, chunk.error)
            else:
                metrics.chunks_failed += 1
            continue
        chunk = _sanitize(ctx, stack, chunk_id, chunk)
        if chunk is None:               # quarantined: never the eval set
            continue
        eval_chunk = chunk              # raw (unsliced): the common eval set
        pending.append((chunk_id, chunk))
        if len(pending) < B:
            continue
        states = step_round(states, pending)
        pending = []
        if host is None:
            ctx.state = reduce(states)
            ctx.step = ctx.start_step + metrics.chunks_done
            stack.after_window(ctx)
            states = boundary(states)
        else:
            # host order: local boundary (observe + local sync) first, then
            # the cross-host exchange, and only then checkpoint — so the
            # rank-0 checkpoint holds the post-exchange global state at the
            # global window frontier
            states = boundary(states)
            states = host.persistent_boundary(ctx, states, sizes)
            ctx.state = reduce(states)
            ctx.step = host.global_step
            stack.after_window(ctx)
        round_idx += 1
        if stack.should_stop(ctx):
            stopped = True
            break
    else:
        if pending:                     # final partial round
            states = step_round(states, pending)
            pending = []
            ctx.state = reduce(states)
            ctx.step = (host.global_step if host is not None
                        else ctx.start_step + metrics.chunks_done)
            stack.after_window(ctx)
    if stopped:
        _drop_pending(ctx, pending)
    return reduce(states)
