"""SyncPolicy — the paper's parallel modes as *data*, not control flow.

Big-means parallelism is entirely characterized by how often the independent
chunk streams exchange incumbents (paper §4.2):

* **collective** — exchange after every round (``sync_every=1``): every
  stream always continues from the global best.
* **competitive** — never exchange until the end (``sync_every=∞``): streams
  race independently and the final argmin-reduce picks the winner.
* **periodic** — exchange every ``t`` rounds: the continuum in between.

Historically each driver hard-coded one point of this spectrum in its loop
structure; a :class:`SyncPolicy` makes the choice a value the engine threads
through any scheduler/topology composition.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SyncPolicy:
    """``every=None`` means "never until the final reduce" (competitive)."""

    every: int | None = 1
    name: str = "collective"

    def resolve(self, rounds: int) -> int:
        """The concrete ``sync_every`` for a run of ``rounds`` rounds.

        The jitted in-core drivers take a finite ``sync_every`` static
        argument; competitive (∞) resolves to a single sync after the last
        round, which is exactly the final argmin-reduce.
        """
        if self.every is None:
            return max(int(rounds), 1)
        return self.every

    def boundary(self, round_idx: int) -> bool:
        """Host loop: should streams exchange incumbents after this round?"""
        return self.every is not None and (round_idx + 1) % self.every == 0

    @property
    def final_only(self) -> bool:
        """True when streams never exchange before the final reduce
        (competitive mode) — multi-host runs skip every mid-run barrier,
        which is where the straggler tolerance comes from: a slow host
        simply loses the final argmin instead of stalling its peers."""
        return self.every is None


def collective() -> SyncPolicy:
    return SyncPolicy(1, "collective")


def periodic(every: int) -> SyncPolicy:
    if not isinstance(every, int) or every < 1:
        raise ValueError(f"periodic sync needs a positive int, got {every!r}")
    return SyncPolicy(every, "periodic" if every > 1 else "collective")


def competitive() -> SyncPolicy:
    return SyncPolicy(None, "competitive")


def from_config(cfg) -> SyncPolicy:
    """Map the ``BigMeansConfig`` knobs to a policy.

    ``cfg.sync`` names the mode; ``'auto'`` (and ``'periodic'``) read the
    period from the legacy ``cfg.sync_every`` knob, so existing configs keep
    their exact behaviour.
    """
    mode = getattr(cfg, "sync", "auto")
    if mode in ("auto", "periodic"):
        return periodic(cfg.sync_every)
    if mode == "collective":
        return collective()
    if mode == "competitive":
        return competitive()
    raise ValueError(
        f"unknown sync mode {mode!r}; known: auto, collective, periodic, "
        "competitive")
