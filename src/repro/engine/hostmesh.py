"""Multi-host chunk streaming over ``jax.distributed``.

The scale-out story for "infinitely tall" data (arXiv:2311.04517): every
process owns a **disjoint shard of the chunk-id stream** and advances its
local streams with the unchanged jitted ``chunk_step(_batched)`` kernels;
at sync windows the ranks exchange incumbents through the coordination
service that ``jax.distributed.initialize`` stands up (a keyed all-gather
over its KV store — kilobytes per window, no device collectives, so the
compiled kernels stay byte-identical to single-process runs).

Shard assignment keeps the *global* stream order: with global batch ``B``
over ``R`` hosts (``b = B/R`` local streams), window ``w`` gives rank ``r``
chunk ids ``w*B + r*b .. w*B + (r+1)*b - 1``.  Per-chunk PRNG keys are
``fold_in(key, chunk_id)`` and chunk sampling is a pure function of
``(seed, chunk_id)``, so every chunk's step result is independent of which
host computes it — which is what makes the 2-process run **bit-identical**
to the single-process run at equal chunk budget:

* fold mode (collective sync): each rank argmin-reduces its local streams,
  then the cross-host argmin of per-point ``f_best`` (ties broken by rank,
  i.e. by global stream index — matching ``jnp.argmin``'s first-index rule)
  picks the same winner the single-process ``reduce_state`` over all B
  streams would.
* counters are exchanged as **deltas** against the last globally-agreed
  value, so ``n_accepted`` / ``n_dist_evals`` aggregate exactly once
  however many exchanges a run has.

Failure semantics: every gather runs under ``sync_timeout_s``.  A rank that
misses a window (killed, hung, partitioned) surfaces on its peers as a
typed :class:`repro.engine.faults.HostDead` — never a hang — carrying the
surviving rank's exact chunk accounting.  Under ``competitive`` sync there
are no mid-run barriers at all: a straggler host just loses the final
argmin (the race-window tolerance of the competitive scheduler, for free).

Checkpointing is rank-0-only (the PR-6 digest scheme unchanged); restore
broadcasts ``(state, key, step)`` to every rank at start, and the saved
step is the *global* chunk frontier so every rank resumes the same window.
"""
from __future__ import annotations

import base64
import io
import itertools
import json
import os
import socket
import subprocess
import sys
import time
from typing import NamedTuple

import numpy as np

from repro.core import bigmeans
from repro.engine import faults
from repro.engine import middleware as mw
from repro.engine import scheduler as sched_lib
from repro.engine import sync as sync_lib

ENV_COORD = "REPRO_COORD"
ENV_NUM_HOSTS = "REPRO_NUM_HOSTS"
ENV_RANK = "REPRO_HOST_RANK"

_BOOTSTRAPPED: tuple[int, int] | None = None
_RUN_SEQ = itertools.count()


def bootstrap(spec) -> tuple[int, int]:
    """Join (or create) the process group a :class:`TopologySpec` names.

    Explicit ``hosts``/``coordinator``/``rank`` fields win; otherwise the
    ``REPRO_NUM_HOSTS`` / ``REPRO_COORD`` / ``REPRO_HOST_RANK`` environment
    (the :func:`launch_local` contract).  ``hosts=1`` (or nothing set) is
    the degenerate single-process group: no service is started, so a
    ``topology='host_mesh'`` config runs anywhere.  Idempotent: a second
    call with the same shape reuses the initialized group.
    """
    global _BOOTSTRAPPED
    num = spec.hosts if spec.hosts is not None else int(
        os.environ.get(ENV_NUM_HOSTS, "1"))
    rank = spec.rank if spec.rank is not None else int(
        os.environ.get(ENV_RANK, "0"))
    if num <= 1:
        return 1, 0
    if rank >= num:
        raise ValueError(f"rank {rank} out of range for {num} hosts")
    if _BOOTSTRAPPED is not None:
        if _BOOTSTRAPPED != (num, rank):
            raise ValueError(
                f"jax.distributed already initialized as rank "
                f"{_BOOTSTRAPPED[1]}/{_BOOTSTRAPPED[0]}; cannot re-join as "
                f"{rank}/{num}")
        return _BOOTSTRAPPED
    coord = spec.coordinator or os.environ.get(ENV_COORD)
    if not coord:
        raise ValueError(
            f"host_mesh with {num} hosts needs a coordinator address "
            f"(TopologySpec.coordinator or ${ENV_COORD})")
    import jax

    jax.distributed.initialize(
        coordinator_address=coord, num_processes=num, process_id=rank,
        initialization_timeout=max(int(spec.sync_timeout_s), 10))
    _BOOTSTRAPPED = (num, rank)
    return num, rank


def _client():
    from jax._src.distributed import global_state

    client = getattr(global_state, "client", None)
    if client is None:
        raise RuntimeError(
            "no jax.distributed coordination client; bootstrap() first")
    return client


def _pack(payload: dict) -> str:
    """dict of ndarrays -> base64 npz string (the KV store takes strings).
    Arrays round-trip bit-exactly — the parity guarantee rides on this."""
    buf = io.BytesIO()
    np.savez(buf, **{k: np.asarray(v) for k, v in payload.items()})
    return base64.b64encode(buf.getvalue()).decode("ascii")


def _unpack(blob: str) -> dict:
    with np.load(io.BytesIO(base64.b64decode(blob))) as z:
        return {k: z[k] for k in z.files}


def _json_arr(obj) -> np.ndarray:
    return np.frombuffer(json.dumps(obj).encode("utf-8"), dtype=np.uint8)


def _arr_json(arr):
    return json.loads(np.asarray(arr, dtype=np.uint8).tobytes().decode("utf-8"))


class HostRuntime:
    """Keyed all-gathers and barriers over the coordination service, with
    timeouts that surface as :class:`~repro.engine.faults.HostDead`."""

    def __init__(self, processes: int, rank: int, *,
                 timeout_s: float = 60.0, prefix: str = "hm"):
        self.processes = processes
        self.rank = rank
        self.timeout_s = timeout_s
        self.prefix = prefix
        self._kv = _client() if processes > 1 else None

    def allgather(self, tag: str, payload: dict) -> list[dict]:
        """Publish ``payload`` under ``(prefix, tag, rank)`` and collect
        every rank's, in rank order.  One shared ``timeout_s`` deadline
        covers the whole gather; a missing peer raises ``HostDead``."""
        if self.processes == 1:
            return [payload]
        self._kv.key_value_set(f"{self.prefix}/{tag}/{self.rank}",
                               _pack(payload))
        deadline = time.monotonic() + self.timeout_s
        out: list[dict] = []
        for r in range(self.processes):
            if r == self.rank:
                out.append({k: np.asarray(v) for k, v in payload.items()})
                continue
            wait_ms = max(int((deadline - time.monotonic()) * 1000), 1)
            try:
                blob = self._kv.blocking_key_value_get(
                    f"{self.prefix}/{tag}/{r}", wait_ms)
            except Exception as exc:
                raise faults.HostDead(
                    f"rank {r} missed exchange {tag!r} within "
                    f"{self.timeout_s:.3g}s ({type(exc).__name__})",
                    rank=self.rank) from exc
            out.append(_unpack(blob))
        return out

    def barrier(self, tag: str) -> None:
        if self.processes == 1:
            return
        try:
            self._kv.wait_at_barrier(f"{self.prefix}/{tag}",
                                     int(self.timeout_s * 1000))
        except Exception as exc:
            raise faults.HostDead(
                f"a rank missed barrier {tag!r} within "
                f"{self.timeout_s:.3g}s ({type(exc).__name__})",
                rank=self.rank) from exc


def health_dict(metrics) -> dict:
    """One rank's reconciliation record:
    ``done + failed + dropped + quarantined == fetched``."""
    return {
        "chunks_done": metrics.chunks_done,
        "chunks_failed": metrics.chunks_failed,
        "chunks_dropped": metrics.chunks_dropped,
        "chunks_quarantined": metrics.chunks_quarantined,
        "chunks_fetched": (metrics.chunks_done + metrics.chunks_failed
                           + metrics.chunks_dropped
                           + metrics.chunks_quarantined),
    }


class HostExchanger:
    """The stream loop's cross-host hooks (``host=`` in ``run_stream``).

    Owns the window counter, the global chunk frontier (``global_step``),
    and the counter baselines for delta aggregation.  All methods are
    collective: every live rank calls them in the same order with the same
    window index (the shard assignment guarantees this as long as no rank
    loses a whole sync window's chunks to fetch failures — a desync
    surfaces as ``HostDead`` at the next gather, never a hang).
    """

    def __init__(self, runtime: HostRuntime, cfg, *,
                 straggler_s: float = 5.0, clock=time.monotonic):
        self.rt = runtime
        self.cfg = cfg                      # the GLOBAL config (batch = B)
        self.sync = sync_lib.from_config(cfg)
        self.R = runtime.processes
        self.rank = runtime.rank
        self.B = cfg.batch
        self.b_local = self.B // self.R
        self.straggler_s = straggler_s
        self.clock = clock
        self.window = 0
        self.global_step = 0
        self._counters = (0, 0.0)           # last globally-agreed (acc, nd)
        self._ctx = None

    # -- plumbing -----------------------------------------------------------

    def _gather(self, ctx, tag, payload, window):
        t0 = self.clock()
        try:
            got = self.rt.allgather(tag, payload)
        except faults.HostDead as exc:
            exc.window = window
            exc.health = health_dict(ctx.metrics)
            ctx.metrics.trace.append(("host_dead", window, str(exc)))
            raise
        waited = self.clock() - t0
        if waited > self.straggler_s:
            ctx.metrics.trace.append(
                ("host_straggler", window, round(waited, 3)))
        return got

    def _merge_counters(self, gathered):
        """Delta aggregation: every rank ships its *totals*; the new global
        value is the old agreed value plus each rank's progress since then.
        Counter values are integer-valued, so float64 summation is exact."""
        acc0, nd0 = self._counters
        acc = acc0 + sum(int(g["acc"]) - acc0 for g in gathered)
        nd = nd0 + sum(float(g["nd"]) - nd0 for g in gathered)
        self._counters = (acc, nd)
        return acc, nd

    def _payload(self, state, f, size):
        import jax.numpy as jnp  # noqa: F401  (state leaves are jax arrays)

        return {
            "f": np.asarray(f),
            "size": np.int64(size),
            "C": np.asarray(state.centroids if state.centroids.ndim == 2
                            else state.centroids),
            "d": np.asarray(state.degenerate),
            "acc": np.int64(np.asarray(state.n_accepted)),
            "nd": np.float64(np.asarray(state.n_dist_evals)),
        }

    @staticmethod
    def _winner(gathered) -> int:
        """Cross-host argmin of per-point ``f_best``; ``np.argmin``'s
        first-index rule breaks ties toward the lowest rank, which (shard
        order) is the lowest global stream index — the same winner the
        single-process ``jnp.argmin`` over all B streams picks."""
        per_point = np.asarray(
            [float(g["f"]) / max(float(g["size"]), 1.0) for g in gathered],
            dtype=np.float64)
        return int(np.argmin(per_point))

    def _winner_f(self, gathered, w, size) -> np.ndarray:
        """The winner's ``f_best`` on a ``size``-point chunk: the raw bits
        when the sizes already match (the uniform-s case — exact), rescaled
        per-point otherwise."""
        if int(gathered[w]["size"]) == int(size):
            return gathered[w]["f"]
        per_point = float(gathered[w]["f"]) / float(gathered[w]["size"])
        return np.float32(per_point * float(size))

    # -- stream-loop hooks --------------------------------------------------

    def sync_start(self, ctx, state, key):
        """Collective start: adopt rank 0's restored ``(state, key, step)``
        so every rank resumes the same global window (rank 0 is the only
        checkpoint writer)."""
        import jax.numpy as jnp

        self._ctx = ctx
        if self.R > 1:
            mine = self._payload(state, state.f_best, max(ctx.last_s, 1))
            mine["step"] = np.int64(ctx.start_step)
            mine["key"] = np.asarray(key)
            root = self._gather(ctx, "start", mine, "start")[0]
            state = bigmeans.BigMeansState(
                centroids=jnp.asarray(root["C"]),
                degenerate=jnp.asarray(root["d"]),
                f_best=jnp.asarray(root["f"]),
                n_accepted=jnp.int32(int(root["acc"])),
                n_dist_evals=jnp.float32(float(root["nd"])),
            )
            key = jnp.asarray(root["key"])
            ctx.step = ctx.start_step = int(root["step"])
            ctx.last_s = max(int(root["size"]), 1)
        start = ctx.start_step
        self.window = start // self.B
        self.global_step = start
        self._counters = (int(np.asarray(state.n_accepted)),
                          float(np.asarray(state.n_dist_evals)))
        ctx.state, ctx.key = state, key
        return state, key, start

    def chunk_ids(self, start: int = 0):
        """This rank's shard of the id stream, in global window order."""
        B, b, n = self.B, self.b_local, self.cfg.n_chunks
        lo = self.rank * b
        for w in range(start // B, -(-n // B)):
            for j in range(b):
                cid = w * B + lo + j
                if start <= cid < n:
                    yield cid

    def fold_boundary(self, ctx, state):
        """Per-window hook in fold mode: advance the global frontier and,
        at sync boundaries, run the cross-host argmin exchange."""
        w = self.window
        self.window += 1
        self.global_step = min(self.window * self.B, self.cfg.n_chunks)
        if self.R > 1 and not self.sync.final_only and self.sync.boundary(w):
            state = self._exchange_fold(ctx, state, w)
        return state

    def _exchange_fold(self, ctx, state, w):
        import jax.numpy as jnp

        size = max(int(ctx.last_s), 1)
        gathered = self._gather(
            ctx, f"x{w}", self._payload(state, state.f_best, size), w)
        winner = self._winner(gathered)
        acc, nd = self._merge_counters(gathered)
        if winner != self.rank:
            g = gathered[winner]
            state = state._replace(
                centroids=jnp.asarray(g["C"]),
                degenerate=jnp.asarray(g["d"]),
                f_best=jnp.asarray(self._winner_f(gathered, winner, size)),
            )
        state = state._replace(n_accepted=jnp.int32(acc),
                               n_dist_evals=jnp.float32(nd))
        f_pp = float(gathered[winner]["f"]) / float(gathered[winner]["size"])
        ctx.metrics.trace.append(("host_sync", w, winner, f_pp))
        return state

    def persistent_boundary(self, ctx, states, sizes):
        """Per-round hook in persistent mode (after the local exchange):
        broadcast the global winner into every local stream at sync
        boundaries.  Counters stay per-stream (the final reduce sums them;
        :meth:`finalize` merges across ranks)."""
        import jax.numpy as jnp

        w = self.window
        self.window += 1
        self.global_step = min(self.window * self.B, self.cfg.n_chunks)
        if self.R == 1 or self.sync.final_only or not self.sync.boundary(w):
            return states
        f = np.asarray(states.f_best, dtype=np.float64)
        szs = np.asarray(sizes, dtype=np.float64)
        lw = int(np.argmin(f / szs))
        payload = {
            "f": np.asarray(states.f_best[lw]),
            "size": np.int64(sizes[lw]),
            "C": np.asarray(states.centroids[lw]),
            "d": np.asarray(states.degenerate[lw]),
            # per-stream counters are not exchanged mid-run
            "acc": np.int64(0), "nd": np.float64(0.0),
        }
        gathered = self._gather(ctx, f"x{w}", payload, w)
        winner = self._winner(gathered)
        g = gathered[winner]
        batch = int(states.f_best.shape[0])
        f_new = jnp.asarray(np.asarray(
            [self._winner_f(gathered, winner, s_b) for s_b in sizes],
            dtype=np.float32))
        states = states._replace(
            centroids=jnp.broadcast_to(
                jnp.asarray(g["C"]), (batch,) + tuple(g["C"].shape)),
            degenerate=jnp.broadcast_to(
                jnp.asarray(g["d"]), (batch,) + tuple(g["d"].shape)),
            f_best=f_new,
        )
        f_pp = float(g["f"]) / max(float(g["size"]), 1.0)
        ctx.metrics.trace.append(("host_sync", w, winner, f_pp))
        return states

    def finalize(self, ctx, state):
        """The final cross-host argmin-reduce + counter merge + per-rank
        health gather.  Always runs (competitive mode's only exchange)."""
        import jax.numpy as jnp

        if self.R == 1:
            ctx.metrics.host = {
                "rank": 0, "processes": 1, "winner_rank": 0,
                "per_rank": [dict(health_dict(ctx.metrics), rank=0)],
            }
            return state
        size = int(ctx.extras.get("winner_s") or max(ctx.last_s, 1))
        payload = self._payload(state, state.f_best, size)
        payload["health"] = _json_arr(dict(
            health_dict(ctx.metrics), rank=self.rank,
            lloyd_iters=ctx.metrics.lloyd_iters))
        gathered = self._gather(ctx, "final", payload, "final")
        winner = self._winner(gathered)
        acc, nd = self._merge_counters(gathered)
        if winner != self.rank:
            g = gathered[winner]
            state = state._replace(
                centroids=jnp.asarray(g["C"]),
                degenerate=jnp.asarray(g["d"]),
                f_best=jnp.asarray(self._winner_f(gathered, winner, size)),
            )
        state = state._replace(n_accepted=jnp.int32(acc),
                               n_dist_evals=jnp.float32(nd))
        f_pp = float(gathered[winner]["f"]) / max(
            float(gathered[winner]["size"]), 1.0)
        ctx.metrics.trace.append(("host_sync", "final", winner, f_pp))
        per_rank = [_arr_json(g["health"]) for g in gathered]
        # run-level totals go global (single-process-equivalent reporting);
        # the per-rank breakdown stays in the health gather
        ctx.metrics.accepted = acc
        ctx.metrics.lloyd_iters = sum(
            h.get("lloyd_iters", 0) for h in per_rank)
        ctx.metrics.host = {
            "rank": self.rank,
            "processes": self.R,
            "winner_rank": winner,
            "per_rank": per_rank,
        }
        return state


def _host_stack(cfg, cfg_local, rank: int) -> mw.MiddlewareStack:
    """The default middleware stack, made rank-aware: rank 0 is the only
    checkpoint writer, and its steps index the *global* chunk frontier."""
    stack = mw.default_stack(cfg_local)
    mws = [m for m in stack.middlewares if not isinstance(m, mw.Checkpoint)]
    if rank == 0 and cfg.ckpt_dir:
        ckpt = mw.Checkpoint(cfg.ckpt_dir, cfg.ckpt_every, cfg.batch,
                             step_from="step")
        # keep default_stack's ordering: checkpoint before the stop/guard tail
        tail = [m for m in mws
                if isinstance(m, (mw.TimeBudget, mw.InvariantGuard))]
        head = [m for m in mws if m not in tail]
        mws = head + [ckpt] + tail
    return mw.MiddlewareStack(mws)


def run_host_stream(provider, cfg, *, topology, n_features: int,
                    resume: bool = True, key=None, fault_injector=None,
                    middlewares=None):
    """One rank's share of a multi-host streaming fit.

    Validates the host-shardable composition, builds the rank-local config
    (``batch = B / hosts``) and scheduler, and runs the ordinary
    :func:`repro.engine.stream.run_stream` with the exchanger's hooks
    plugged in.  Returns ``(state, metrics)`` exactly like ``run_stream``;
    ``metrics.host`` carries the per-rank health gather.  A dead peer
    propagates as :class:`~repro.engine.faults.HostDead`.
    """
    from repro.engine import stream as engine_stream
    from repro.engine import topology as topo_lib

    R, rank = topology.processes, topology.rank
    if cfg.batch % R:
        raise ValueError(
            f"host_mesh needs hosts ({R}) to divide the global batch "
            f"({cfg.batch})")
    if cfg.n_chunks % cfg.batch:
        raise ValueError(
            f"host_mesh needs batch ({cfg.batch}) to divide n_chunks "
            f"({cfg.n_chunks}): ranks must agree on the window count")
    if cfg.vns_ladder:
        raise ValueError(
            "vns_ladder is rank-local ladder state; not supported on "
            "host_mesh")
    if cfg.time_budget_s is not None:
        raise ValueError(
            "time_budget_s stops ranks at different windows and desyncs "
            "the exchange; use the n_chunks budget on host_mesh")
    b_local = cfg.batch // R
    scheduler = None
    if cfg.scheduler == "competitive_s":
        if b_local < 2:
            raise ValueError(
                f"competitive_s on host_mesh needs batch/hosts >= 2 local "
                f"streams, got {b_local}")
        ladder = tuple(cfg.competitive_ladder) or sched_lib.default_ladder(
            cfg.k, cfg.s)
        scheduler = sched_lib.CompetitiveS(
            ladder=ladder, batch=b_local, stream_offset=rank * b_local)
    cfg_local = cfg.replace(batch=b_local) if b_local != cfg.batch else cfg

    runtime = HostRuntime(
        R, rank, timeout_s=topology.sync_timeout_s,
        prefix=f"bm{next(_RUN_SEQ)}-{cfg.seed}")
    exchanger = HostExchanger(runtime, cfg,
                              straggler_s=topology.straggler_s)
    stack = middlewares
    if stack is None:
        stack = _host_stack(cfg, cfg_local, rank)
    return engine_stream.run_stream(
        provider, cfg_local, n_features=n_features, resume=resume,
        fault_injector=fault_injector, key=key, middlewares=stack,
        topology=topo_lib.SingleDevice(), scheduler=scheduler,
        host=exchanger)


# ---------------------------------------------------------------------------
# local multi-process launcher (tests, evalsuite, CI)
# ---------------------------------------------------------------------------


class HostProc(NamedTuple):
    rank: int
    returncode: int
    output: str


def _free_port() -> int:
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def launch_local(cmd, num_hosts: int, *, timeout_s: float = 300.0,
                 env_extra: dict | None = None) -> list[HostProc]:
    """Spawn ``num_hosts`` processes of ``cmd`` on this machine with the
    ``REPRO_COORD`` / ``REPRO_NUM_HOSTS`` / ``REPRO_HOST_RANK`` bootstrap
    environment set (coordinator on a fresh localhost port).

    ``cmd`` is an argv list, or a callable ``rank -> argv list``.  Output
    (stdout+stderr, merged) is captured per rank; processes still running
    after ``timeout_s`` are killed and reported with returncode -9.
    """
    coord = f"127.0.0.1:{_free_port()}"
    procs = []
    for r in range(num_hosts):
        env = dict(os.environ)
        env.update(env_extra or {})
        env[ENV_COORD] = coord
        env[ENV_NUM_HOSTS] = str(num_hosts)
        env[ENV_RANK] = str(r)
        env.setdefault("JAX_PLATFORMS", "cpu")
        argv = cmd(r) if callable(cmd) else list(cmd)
        procs.append(subprocess.Popen(
            argv, env=env, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True))
    deadline = time.monotonic() + timeout_s
    results = []
    for r, p in enumerate(procs):
        try:
            out, _ = p.communicate(
                timeout=max(deadline - time.monotonic(), 0.1))
        except subprocess.TimeoutExpired:
            p.kill()
            out, _ = p.communicate()
            out = (out or "") + "\n[launch_local] killed after timeout"
        results.append(HostProc(r, p.returncode, out or ""))
    return results


def main(argv=None):
    """``python -m repro.engine.hostmesh RANK_SCRIPT.py`` — reserved for
    future CLI wiring; tests and the evalsuite drive :func:`launch_local`
    with their own rank scripts."""
    raise SystemExit(
        "repro.engine.hostmesh has no CLI; use launch_local() or "
        "repro.evalsuite.hostcell")


if __name__ == "__main__":
    main(sys.argv[1:])
