"""Fault taxonomy, retry policy, watchdog and injection harness.

Long-lived streaming runs (hours of chunks from a flaky provider — the
regimes of arXiv:2311.04517 / 2410.14548) must *degrade* under faults, not
die or silently corrupt.  This module is the engine's one vocabulary for
that:

* **taxonomy** — :class:`TransientFault` / :class:`PermanentFault` and
  :func:`classify`: transient errors (timeouts, I/O, lost nodes) are worth
  retrying; permanent ones (malformed data, contract violations) never are.
* **RetryPolicy** — bounded retries with exponential backoff; the jitter is
  derived deterministically from ``(seed, chunk_id, attempt)`` so two runs
  of the same config back off identically (no wall-clock randomness).
* **watchdog** — :func:`call_with_timeout` turns a *hung* provider into a
  raisable :class:`FetchTimeout` (a transient fault): the blocked call is
  abandoned on a daemon thread and the fetch pipeline moves on, so
  ``_Prefetcher.close()`` always reclaims its worker.
* **FaultPlan** — a deterministic, seedable injection harness generalizing
  the ``fault_injector`` hook: transient/permanent fetch errors, corrupted
  chunks (NaN / Inf / wrong shape), provider stalls, plus helpers to
  corrupt checkpoints and fail kernel dispatches.  The same plan replayed
  against the same run injects the identical fault sequence — which is what
  makes chaos runs regression-testable (``benchmarks/chaos.py``).

Quarantine vs. failure: a chunk whose *fetch* raised is ``chunks_failed``
(``("fetch_error", cid, err)``); a chunk that arrived but carries bad data
is ``chunks_quarantined`` (``("quarantine", cid, reason)``, raised by the
sanitizer middleware as :class:`ChunkQuarantined`).  Both reconcile into
``done + failed + dropped + quarantined == fetched``.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time

import numpy as np

TRANSIENT = "transient"
PERMANENT = "permanent"


class TransientFault(Exception):
    """An error worth retrying: the next attempt may succeed (lost node,
    throttled provider, timeout)."""


class PermanentFault(Exception):
    """An error retries cannot fix (malformed request, contract violation):
    fail the chunk immediately, never burn retry budget on it."""


class FetchTimeout(TransientFault):
    """A provider call exceeded the watchdog timeout (hung fetch)."""


class HostDead(PermanentFault):
    """A peer process missed a cross-host exchange window (crashed, hung,
    or partitioned): the multi-host run fails *loudly* at the sync barrier
    instead of hanging.  Raised by :mod:`repro.engine.hostmesh` with the
    local ``rank``, the exchange ``window`` that timed out, and this rank's
    ``health`` accounting (``done+failed+dropped+quarantined == fetched``)
    attached — so a surviving rank can report exactly what it completed."""

    def __init__(self, message: str, *, rank: int | None = None,
                 window=None, health: dict | None = None):
        super().__init__(message)
        self.rank = rank
        self.window = window
        self.health = health


class ChunkQuarantined(Exception):
    """Raised by the chunk sanitizer: the chunk arrived but its *data* is
    unusable (non-finite values, wrong shape).  Carries the reason string
    recorded in the ``("quarantine", cid, reason)`` trace event."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


class InvariantViolation(RuntimeError):
    """A post-accept invariant broke (non-finite or increasing ``f_best``):
    the run is corrupt and must fail loudly, not stream on."""


# Exception types that retrying can never fix: data/contract errors.  An
# unrecognized exception defaults to transient — the retry budget is
# bounded, so optimism costs at most ``retries`` extra attempts, while
# misclassifying a recoverable blip as permanent loses the chunk forever.
_PERMANENT_TYPES = (
    PermanentFault,
    ValueError,
    TypeError,
    KeyError,
    IndexError,
    AttributeError,
    AssertionError,
    NotImplementedError,
    ZeroDivisionError,
)


def classify(exc: BaseException) -> str:
    """``TRANSIENT`` or ``PERMANENT`` for a provider exception."""
    if isinstance(exc, TransientFault):
        return TRANSIENT
    if isinstance(exc, _PERMANENT_TYPES):
        return PERMANENT
    return TRANSIENT


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries + exponential backoff with deterministic jitter.

    ``retries`` is the number of *re*-attempts after the first failure
    (0 = today's drop-the-chunk behaviour).  The jitter factor for
    ``(chunk_id, attempt)`` comes from a PRNG seeded with
    ``(seed, chunk_id, attempt)`` — no global randomness, so a replayed
    run backs off identically.
    """

    retries: int = 0
    backoff_s: float = 0.05
    backoff_max_s: float = 2.0
    seed: int = 0

    def delay(self, chunk_id: int, attempt: int) -> float:
        """Seconds to wait before re-attempt ``attempt`` (0-based)."""
        base = min(self.backoff_s * (2.0 ** attempt), self.backoff_max_s)
        rng = np.random.default_rng((self.seed, 0x5E77, chunk_id, attempt))
        return base * (0.5 + 0.5 * float(rng.random()))

    @classmethod
    def from_config(cls, cfg) -> "RetryPolicy":
        return cls(
            retries=getattr(cfg, "retries", 0),
            backoff_s=getattr(cfg, "retry_backoff_s", 0.05),
            seed=getattr(cfg, "seed", 0),
        )


def call_with_timeout(fn, timeout: float | None, *, name: str = "watchdog"):
    """Run ``fn()`` with a wall-clock bound.

    ``timeout=None`` calls inline.  Otherwise ``fn`` runs on a daemon
    thread; if it has not finished after ``timeout`` seconds a
    :class:`FetchTimeout` is raised and the hung call is *abandoned* (its
    daemon thread cannot block interpreter exit).  The caller's thread —
    the prefetch worker — is therefore always reclaimable, whatever the
    provider does.
    """
    if timeout is None:
        return fn()
    box: dict = {}
    done = threading.Event()

    def target():
        try:
            box["value"] = fn()
        except BaseException as exc:  # noqa: BLE001 — relayed to caller
            box["error"] = exc
        finally:
            done.set()

    thread = threading.Thread(target=target, daemon=True, name=name)
    thread.start()
    if not done.wait(timeout):
        raise FetchTimeout(
            f"provider call exceeded the {timeout:.3g}s watchdog timeout")
    if "error" in box:
        raise box["error"]
    return box["value"]


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, replayable schedule of injected faults.

    * ``transient_rate`` — fraction of chunk ids whose fetch raises a
      :class:`TransientFault` for the first ``transient_attempts`` attempts
      (then succeeds — so a retrying run recovers the chunk, a
      ``retries=0`` run drops it).  Which ids fault is a pure function of
      ``(seed, chunk_id)``.
    * ``permanent_ids`` — fetches that always raise :class:`PermanentFault`.
    * ``nan_ids`` / ``inf_ids`` / ``shape_ids`` — chunks delivered with
      NaN-poisoned / Inf-poisoned / wrong-shape data (sanitizer fodder).
    * ``stall_ids`` — fetches that sleep ``stall_s`` before returning
      (hung-provider simulation; pair with a ``fetch_timeout_s`` watchdog).

    Serve-side faults (wired via :meth:`wrap_launch` around a
    ``ModelEntry.launch``):

    * ``launch_transient_rate`` — fraction of launch *indices* that raise
      a :class:`TransientFault` (the batcher recovers them on the ref
      path); a pure function of ``(seed, launch_index)``.
    * ``launch_outage_after`` / ``launch_outage_len`` — a window of
      consecutive launches that all raise :class:`PermanentFault` (a dead
      model: bisection finds no healthy requests, the circuit breaker
      trips).
    * :meth:`wrap_launch` also fails any launch whose payload carries
      non-finite values with a :class:`PermanentFault` — the "poisoned
      request" a real kernel would choke on, isolatable only by bisection.
    """

    seed: int = 0
    transient_rate: float = 0.0
    transient_attempts: int = 1
    permanent_ids: tuple = ()
    nan_ids: tuple = ()
    inf_ids: tuple = ()
    shape_ids: tuple = ()
    stall_ids: tuple = ()
    stall_s: float = 30.0
    launch_transient_rate: float = 0.0
    launch_outage_after: int | None = None
    launch_outage_len: int = 0

    def is_transient(self, chunk_id: int) -> bool:
        if self.transient_rate <= 0.0:
            return False
        rng = np.random.default_rng((self.seed, 0xFA17, chunk_id))
        return bool(rng.random() < self.transient_rate)

    def transient_ids(self, n_chunks: int) -> list[int]:
        """The chunk ids in ``range(n_chunks)`` this plan faults."""
        return [cid for cid in range(n_chunks) if self.is_transient(cid)]

    def wrap(self, provider):
        """A provider with this plan's faults injected around ``provider``.

        Attempt counts are tracked per chunk id (exposed as
        ``wrapped.attempts``, a Counter) so transient faults clear after
        ``transient_attempts`` failures and tests can reconcile fetch
        accounting against actual provider traffic.
        """
        attempts: collections.Counter = collections.Counter()
        lock = threading.Lock()

        def fetch(chunk_id: int):
            with lock:
                attempts[chunk_id] += 1
                attempt = attempts[chunk_id]
            if chunk_id in self.stall_ids:
                time.sleep(self.stall_s)
            if chunk_id in self.permanent_ids:
                raise PermanentFault(
                    f"injected permanent fault on chunk {chunk_id}")
            if self.is_transient(chunk_id) \
                    and attempt <= self.transient_attempts:
                raise TransientFault(
                    f"injected transient fault on chunk {chunk_id} "
                    f"(attempt {attempt})")
            chunk = np.array(provider(chunk_id))  # copy: never poison source
            if chunk_id in self.nan_ids:
                chunk[::7] = np.nan
            if chunk_id in self.inf_ids:
                chunk[::11] = np.inf
            if chunk_id in self.shape_ids:
                chunk = chunk[:, : max(1, chunk.shape[1] // 2)]
            return chunk

        fetch.attempts = attempts
        return fetch

    def injector(self):
        """This plan's fetch-error faults as a legacy ``fault_injector``
        hook (``injector(cid)`` raises; data corruption and stalls need
        :meth:`wrap`, which owns the returned chunk)."""
        wrapped = self.wrap(lambda cid: np.zeros((1, 1), dtype=np.float32))

        def inject(chunk_id: int) -> None:
            wrapped(chunk_id)

        inject.attempts = wrapped.attempts
        return inject

    # -- serve-side injection ------------------------------------------------
    def is_launch_transient(self, launch_index: int) -> bool:
        if self.launch_transient_rate <= 0.0:
            return False
        rng = np.random.default_rng((self.seed, 0x1A47, launch_index))
        return bool(rng.random() < self.launch_transient_rate)

    def in_outage(self, launch_index: int) -> bool:
        if self.launch_outage_after is None or self.launch_outage_len <= 0:
            return False
        return (self.launch_outage_after <= launch_index
                < self.launch_outage_after + self.launch_outage_len)

    def wrap_launch(self, launch):
        """A ``(q, snapshot) -> (ids, dists)`` launch with faults injected.

        Wrap a ``ModelEntry.launch`` with it (``entry.launch =
        plan.wrap_launch(entry.launch)``) to chaos-test the serving path:
        non-finite payloads fail permanently (the poisoned-request case
        that only batch bisection can isolate), outage-window launches
        fail permanently (a dead model — breaker fodder), and
        ``launch_transient_rate`` launches fail transiently (ref-retry
        fodder).  ``wrapped.calls`` counts invocations; which launches
        fault is a pure function of ``(seed, launch_index)``.
        """
        calls: collections.Counter = collections.Counter()
        lock = threading.Lock()

        def wrapped(q, snapshot):
            with lock:
                idx = calls["n"]
                calls["n"] += 1
            if not bool(np.isfinite(np.asarray(q)).all()):
                raise PermanentFault(
                    f"injected: non-finite payload in launch {idx}")
            if self.in_outage(idx):
                raise PermanentFault(
                    f"injected launch outage (launch {idx})")
            if self.is_launch_transient(idx):
                raise TransientFault(
                    f"injected transient launch fault (launch {idx})")
            return launch(q, snapshot)

        wrapped.calls = calls
        return wrapped


def corrupt_checkpoint(directory: str, *, step: int | None = None,
                       keep_bytes: int = 64) -> str:
    """Truncate a checkpoint's ``arrays.npz`` to ``keep_bytes`` (a crashed /
    torn write), defaulting to the newest step.  Returns the mangled path —
    restore must now fall back to the previous intact step."""
    import os

    from repro.cluster import checkpoint as ckpt_lib

    if step is None:
        step = ckpt_lib.latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:012d}", "arrays.npz")
    with open(path, "rb") as f:
        head = f.read(keep_bytes)
    with open(path, "wb") as f:
        f.write(head)
    return path


@contextlib.contextmanager
def kernel_failure(op: str = "fused", exc: Exception | None = None):
    """Monkeypatch one Pallas kernel entry point to raise for the duration.

    ``op`` is one of ``assign`` / ``update`` / ``fused`` / ``fused_batched``.
    Used to exercise :mod:`repro.kernels.ops`'s graceful degradation: inside
    this context a Pallas dispatch fails, the op demotes that shape to the
    ref path once per process, and the run continues.
    """
    from repro.kernels import fused_step as fused_mod
    from repro.kernels import ops

    targets = {
        "assign": (ops, "assign_pallas"),
        "update": (ops, "update_pallas"),
        "fused": (fused_mod, "fused_step_pallas"),
        "fused_batched": (fused_mod, "fused_step_batched_pallas"),
    }
    if op not in targets:
        raise KeyError(f"unknown kernel op {op!r}; known: {sorted(targets)}")
    mod, name = targets[op]
    original = getattr(mod, name)
    failure = exc or RuntimeError(f"injected {op} kernel failure")

    def boom(*args, **kwargs):
        raise failure

    setattr(mod, name, boom)
    try:
        yield
    finally:
        setattr(mod, name, original)


@contextlib.contextmanager
def hung_restore(stall_s: float | None = None):
    """Monkeypatch checkpoint restore to *hang* for the duration.

    Simulates an NFS-stalled checkpoint load against the serving
    :class:`repro.serve.CheckpointWatcher`: inside the context every
    ``checkpoint.restore`` call blocks (``stall_s`` seconds, or until the
    context exits when ``None``) before proceeding, so a watcher poll that
    reaches the load hangs and its ``poll_timeout_s`` watchdog must abandon
    it.  Yields the release :class:`threading.Event` — set it early to
    un-stall mid-test.  Exiting the context releases stalled calls (they
    then complete normally, like a filesystem coming back).
    """
    from repro.cluster import checkpoint as ckpt_lib

    original = ckpt_lib.restore
    release = threading.Event()

    def stalled(*args, **kwargs):
        release.wait(stall_s)
        return original(*args, **kwargs)

    ckpt_lib.restore = stalled
    try:
        yield release
    finally:
        release.set()
        ckpt_lib.restore = original
