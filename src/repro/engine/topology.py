"""Topology — *where* the chunk streams advance.

A topology is a small frozen descriptor the engine dispatches on; the jitted
``chunk_step`` / ``chunk_step_batched`` kernels are reused unchanged in every
placement:

* :class:`SingleDevice` — all streams on one device (batched or scalar).
* :class:`StreamMesh` — the B-stream batch axis sharded over a 1-axis device
  mesh; incumbent exchange is an argmin-all-gather.  Works for both the
  in-core batched driver and (new) the out-of-core host loop, where the
  prefetcher feeds device-sharded chunk stacks.
* :class:`WorkerMesh` — one independent chunk stream per worker group of a
  mesh (the multi-worker driver); exchange is a tiny argmin-all-reduce.

Descriptors are hashable so they can ride through ``jax.jit`` static
arguments exactly like the raw ``mesh`` objects did.
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class SingleDevice:
    name: str = dataclasses.field(default="single", init=False)

    @property
    def devices(self) -> int:
        return 1


@dataclasses.dataclass(frozen=True)
class StreamMesh:
    """Shard the stream (batch) axis of the batched step over ``mesh``."""

    mesh: Any
    axis: str = "streams"
    name: str = dataclasses.field(default="stream_mesh", init=False)

    @property
    def devices(self) -> int:
        return int(self.mesh.shape[self.axis])


@dataclasses.dataclass(frozen=True)
class WorkerMesh:
    """One chunk stream per group of the ``axes`` mesh axes."""

    mesh: Any
    axes: tuple = ("data",)
    name: str = dataclasses.field(default="worker_mesh", init=False)

    @property
    def devices(self) -> int:
        w = 1
        for a in self.axes:
            w *= int(self.mesh.shape[a])
        return w


Topology = SingleDevice | StreamMesh | WorkerMesh


def for_streams(cfg) -> Topology:
    """Stream-parallel topology from a config: ``cfg.mesh`` shards the
    stream axis, otherwise everything stays on one device."""
    if cfg.mesh is not None:
        return StreamMesh(cfg.mesh, cfg.stream_axis)
    return SingleDevice()


def for_workers(cfg, mesh=None) -> WorkerMesh:
    mesh = mesh if mesh is not None else cfg.mesh
    if mesh is None:
        raise ValueError("worker topology needs a device mesh")
    return WorkerMesh(mesh, tuple(mesh.axis_names))
