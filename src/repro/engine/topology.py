"""Topology — *where* the chunk streams advance.

A topology is a small frozen descriptor the engine dispatches on; the jitted
``chunk_step`` / ``chunk_step_batched`` kernels are reused unchanged in every
placement:

* :class:`SingleDevice` — all streams on one device (batched or scalar).
* :class:`StreamMesh` — the B-stream batch axis sharded over a 1-axis device
  mesh; incumbent exchange is an argmin-all-gather.  Works for both the
  in-core batched driver and the out-of-core host loop, where the
  prefetcher feeds device-sharded chunk stacks.
* :class:`WorkerMesh` — one independent chunk stream per worker group of a
  mesh (the multi-worker driver); exchange is a tiny argmin-all-reduce.
* :class:`HostMesh` — one process per host (``jax.distributed``), each
  owning a disjoint shard of the chunk-id stream; incumbent exchange rides
  the coordination service at sync windows (:mod:`repro.engine.hostmesh`).

Descriptors are hashable so they can ride through ``jax.jit`` static
arguments exactly like the raw ``mesh`` objects did.

Callers no longer hand-build meshes: a declarative :class:`TopologySpec`
(``BigMeansConfig.topology``) names the placement and :func:`resolve` — the
single place device meshes get constructed — turns it into a descriptor.
Raw ``cfg.mesh`` objects keep working through :func:`from_config`'s
deprecation shim, bit-identically.
"""
from __future__ import annotations

import dataclasses
from typing import Any

KINDS = ("auto", "single", "stream_mesh", "worker_mesh", "host_mesh")


def check_axes(mesh, axes) -> None:
    """Every name in ``axes`` must be an axis of ``mesh`` — validated at
    descriptor construction, so a typo fails here with the mesh's real axis
    names instead of deep inside jit as an opaque ``KeyError``."""
    known = tuple(mesh.axis_names)
    missing = [a for a in axes if a not in known]
    if missing:
        raise ValueError(
            f"axes {missing} not in mesh (mesh axes: {known})")


@dataclasses.dataclass(frozen=True)
class SingleDevice:
    name: str = dataclasses.field(default="single", init=False)

    @property
    def devices(self) -> int:
        return 1


@dataclasses.dataclass(frozen=True)
class StreamMesh:
    """Shard the stream (batch) axis of the batched step over ``mesh``."""

    mesh: Any
    axis: str = "streams"
    name: str = dataclasses.field(default="stream_mesh", init=False)

    def __post_init__(self):
        check_axes(self.mesh, (self.axis,))

    @property
    def devices(self) -> int:
        return int(self.mesh.shape[self.axis])


@dataclasses.dataclass(frozen=True)
class WorkerMesh:
    """One chunk stream per group of the ``axes`` mesh axes."""

    mesh: Any
    axes: tuple = ("data",)
    name: str = dataclasses.field(default="worker_mesh", init=False)

    def __post_init__(self):
        object.__setattr__(self, "axes", tuple(self.axes))
        if not self.axes:
            raise ValueError("WorkerMesh needs at least one mesh axis")
        check_axes(self.mesh, self.axes)

    @property
    def devices(self) -> int:
        w = 1
        for a in self.axes:
            w *= int(self.mesh.shape[a])
        return w


@dataclasses.dataclass(frozen=True)
class HostMesh:
    """One process per host over ``jax.distributed``; each rank owns a
    disjoint shard of the chunk-id stream and exchanges incumbents at sync
    windows (see :mod:`repro.engine.hostmesh`)."""

    processes: int
    rank: int
    sync_timeout_s: float = 60.0
    straggler_s: float = 5.0
    name: str = dataclasses.field(default="host_mesh", init=False)

    def __post_init__(self):
        if self.processes < 1:
            raise ValueError(f"processes must be >= 1, got {self.processes}")
        if not 0 <= self.rank < self.processes:
            raise ValueError(
                f"rank {self.rank} out of range for {self.processes} "
                "processes")

    @property
    def devices(self) -> int:
        return self.processes


Topology = SingleDevice | StreamMesh | WorkerMesh | HostMesh


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """Declarative placement: *what* topology, not *how* to build it.

    ``BigMeansConfig.topology`` accepts a kind string or one of these;
    :func:`resolve` is the single place the named meshes/processes become
    concrete descriptors.

    * ``kind`` — ``'auto'`` (strategy picks), ``'single'``,
      ``'stream_mesh'``, ``'worker_mesh'``, ``'host_mesh'``.
    * ``devices`` — local device count (int; 1-axis meshes) or a full mesh
      shape tuple (``worker_mesh`` multi-axis); ``None`` = all local devices.
    * ``axes`` — mesh axis names; defaults: ``('streams',)`` for
      ``stream_mesh``, ``('data',)`` for ``worker_mesh``.
    * ``hosts`` / ``coordinator`` / ``rank`` — ``host_mesh`` bootstrap
      (``None`` reads the ``REPRO_NUM_HOSTS`` / ``REPRO_COORD`` /
      ``REPRO_HOST_RANK`` environment, the launcher contract of
      :func:`repro.engine.hostmesh.launch_local`).
    * ``sync_timeout_s`` — how long a rank waits at an exchange window for
      its peers before the run fails with a typed
      :class:`repro.engine.faults.HostDead` (never a hang).
    * ``straggler_s`` — gather wall time above this emits a
      ``('host_straggler', window, seconds)`` trace event.
    """

    kind: str = "auto"
    devices: Any = None
    axes: tuple = ()
    hosts: int | None = None
    coordinator: str | None = None
    rank: int | None = None
    sync_timeout_s: float = 60.0
    straggler_s: float = 5.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown topology kind {self.kind!r}; known: {KINDS}")
        object.__setattr__(self, "axes", tuple(self.axes))
        for a in self.axes:
            if not isinstance(a, str) or not a:
                raise ValueError(
                    f"axes must be non-empty strings, got {a!r}")
        d = self.devices
        if d is not None:
            if isinstance(d, int) and not isinstance(d, bool):
                if d < 1:
                    raise ValueError(f"devices must be >= 1, got {d}")
            elif isinstance(d, (tuple, list)):
                object.__setattr__(self, "devices", tuple(int(x) for x in d))
                if not self.devices or any(x < 1 for x in self.devices):
                    raise ValueError(
                        f"devices shape must be positive ints, got {d!r}")
                if self.axes and len(self.devices) != len(self.axes):
                    raise ValueError(
                        f"devices shape {self.devices} and axes {self.axes} "
                        "must have the same length")
            else:
                raise ValueError(
                    f"devices must be an int, a shape tuple or None, "
                    f"got {d!r}")
        if self.hosts is not None and (
                not isinstance(self.hosts, int) or self.hosts < 1):
            raise ValueError(f"hosts must be a positive int, got {self.hosts!r}")
        if self.rank is not None and (
                not isinstance(self.rank, int) or self.rank < 0):
            raise ValueError(f"rank must be an int >= 0, got {self.rank!r}")
        if self.sync_timeout_s <= 0 or self.straggler_s <= 0:
            raise ValueError("sync_timeout_s and straggler_s must be positive")
        if self.kind != "host_mesh" and (
                self.hosts is not None or self.coordinator is not None
                or self.rank is not None):
            raise ValueError(
                "hosts/coordinator/rank only apply to kind='host_mesh', "
                f"got kind={self.kind!r}")
        if self.kind in ("single", "host_mesh") and self.devices is not None:
            raise ValueError(
                f"kind={self.kind!r} takes no devices field (use hosts for "
                "host_mesh)")


def as_spec(value) -> TopologySpec:
    """Coerce ``'single'``-style kind strings to a :class:`TopologySpec`."""
    if isinstance(value, TopologySpec):
        return value
    if isinstance(value, str):
        return TopologySpec(kind=value)
    raise TypeError(
        f"topology must be a kind string {KINDS} or a TopologySpec, "
        f"got {type(value).__name__}")


def _local_device_count() -> int:
    import jax

    # jax.devices(), not local_devices(): identical in every single-process
    # setup, and the legacy strategies counted jax.devices() — host_mesh is
    # the only multi-process path and never auto-sizes a device mesh.
    return len(jax.devices())


def _build_mesh(shape, axes):
    from repro.launch.mesh import make_mesh

    return make_mesh(tuple(shape), tuple(axes))


def resolve(spec, *, role: str = "stream") -> Topology:
    """The single place topology specs become concrete descriptors (and the
    single place device meshes get constructed).

    ``role`` disambiguates ``'auto'``: the stream loop defaults to one
    device (bit-identical to the historical no-mesh path), the sharded
    driver to a worker mesh over every local device.
    """
    spec = as_spec(spec)
    kind = spec.kind
    if kind == "auto":
        kind = "worker_mesh" if role == "worker" else "single"
    if kind == "single":
        if role == "worker":    # sharded strategy forced onto one device
            return WorkerMesh(_build_mesh((1,), spec.axes or ("data",)),
                              spec.axes or ("data",))
        return SingleDevice()
    if kind == "stream_mesh":
        axis = spec.axes[0] if spec.axes else "streams"
        ndev = spec.devices if isinstance(spec.devices, int) \
            else _local_device_count()
        return StreamMesh(_build_mesh((ndev,), (axis,)), axis)
    if kind == "worker_mesh":
        axes = spec.axes or ("data",)
        if isinstance(spec.devices, tuple):
            shape = spec.devices
        else:
            ndev = spec.devices if isinstance(spec.devices, int) \
                else _local_device_count()
            if len(axes) > 1:
                raise ValueError(
                    f"worker_mesh with axes {axes} needs devices as a "
                    "matching shape tuple")
            shape = (ndev,)
        return WorkerMesh(_build_mesh(shape, axes), axes)
    # host_mesh: bootstrap (or join) the jax.distributed process group
    from repro.engine import hostmesh

    processes, rank = hostmesh.bootstrap(spec)
    return HostMesh(processes=processes, rank=rank,
                    sync_timeout_s=spec.sync_timeout_s,
                    straggler_s=spec.straggler_s)


def requested_kind(cfg) -> str:
    """The placement a config asks for, without constructing anything.

    ``'legacy_mesh'`` when a raw ``cfg.mesh`` is set (the deprecated path);
    otherwise the spec's kind verbatim (``'auto'`` included).
    """
    if getattr(cfg, "mesh", None) is not None:
        return "legacy_mesh"
    return as_spec(getattr(cfg, "topology", "auto")).kind


def worker_count(cfg) -> int:
    """How many workers a sharded run of this config would use — from the
    legacy mesh, the spec's devices field, or the local device count."""
    mesh = getattr(cfg, "mesh", None)
    if mesh is not None:
        return int(mesh.devices.size)
    spec = as_spec(getattr(cfg, "topology", "auto"))
    if isinstance(spec.devices, int):
        return spec.devices
    if isinstance(spec.devices, tuple):
        w = 1
        for x in spec.devices:
            w *= x
        return w
    return _local_device_count()


def from_config(cfg, role: str = "stream") -> Topology:
    """Topology for a config: the spec path through :func:`resolve`, or the
    raw-mesh deprecation shim (``cfg.mesh`` wrapped exactly as the legacy
    strategies did — ``StreamMesh(mesh, cfg.stream_axis)`` for the stream
    loop, ``WorkerMesh(mesh, mesh.axis_names)`` for the sharded driver —
    so shimmed runs are bit-identical to spec runs on the same mesh)."""
    mesh = getattr(cfg, "mesh", None)
    if mesh is not None:
        if role == "worker":
            return WorkerMesh(mesh, tuple(mesh.axis_names))
        return StreamMesh(mesh, getattr(cfg, "stream_axis", "streams"))
    return resolve(getattr(cfg, "topology", "auto"), role=role)


def for_streams(cfg) -> Topology:
    """Stream-parallel topology from a config (the stream loop's default)."""
    return from_config(cfg, role="stream")


def for_workers(cfg, mesh=None) -> WorkerMesh:
    if mesh is not None:
        return WorkerMesh(mesh, tuple(mesh.axis_names))
    topo = from_config(cfg, role="worker")
    if not isinstance(topo, WorkerMesh):
        raise ValueError(
            f"worker topology needs a device mesh, got {topo.name}")
    return topo
