"""Multi-host streaming: the N=2 process single-machine suite.

Real two-process runs go through :func:`repro.engine.hostmesh.launch_local`
(fresh coordinator port, ``REPRO_*`` bootstrap env); each rank is a small
script that prints a ``RESULT`` JSON line and exits via ``os._exit`` so the
``jax.distributed`` atexit shutdown cannot turn an intentionally-killed-peer
test into a spurious abort.  Exchanger mechanics (shard math, argmin
tie-break, counter deltas, straggler/dead events) are unit-tested in-process
against a fake runtime.
"""
import json
import os
import sys
import types

import numpy as np
import pytest

from repro.api import BigMeansConfig
from repro.engine import hostmesh
from repro.engine.faults import HostDead
from repro.engine.stream import RunnerMetrics
from repro.engine.topology import HostMesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CFG = BigMeansConfig(k=4, s=64, n_chunks=8, batch=4, log_every=0,
                     impl="ref", prefetch=2)


def _provider(cid):
    """Pure in chunk id — every process regenerates identical chunks."""
    rng = np.random.default_rng((11, cid))
    return rng.normal(size=(64, 5)).astype(np.float32)


# ---------------------------------------------------------------------------
# exchanger unit tests (fake runtime, no jax.distributed)
# ---------------------------------------------------------------------------


class _FakeRuntime:
    def __init__(self, processes=2, rank=0, gathered=None, raise_dead=False):
        self.processes = processes
        self.rank = rank
        self._gathered = gathered
        self._raise = raise_dead

    def allgather(self, tag, payload):
        if self._raise:
            raise HostDead("rank 1 missed exchange", rank=self.rank)
        return self._gathered


def _ctx():
    return types.SimpleNamespace(metrics=RunnerMetrics(), last_s=64,
                                 extras={})


def test_chunk_id_sharding_preserves_global_window_order():
    ex0 = hostmesh.HostExchanger(_FakeRuntime(2, 0), CFG)
    ex1 = hostmesh.HostExchanger(_FakeRuntime(2, 1), CFG)
    assert list(ex0.chunk_ids(0)) == [0, 1, 4, 5]
    assert list(ex1.chunk_ids(0)) == [2, 3, 6, 7]
    # the union per window is contiguous: window w covers w*B..w*B+B-1
    assert sorted(list(ex0.chunk_ids(0)) + list(ex1.chunk_ids(0))) == \
        list(range(8))
    # resume from a window frontier drops exactly the finished windows
    assert list(ex0.chunk_ids(4)) == [4, 5]
    assert list(ex1.chunk_ids(4)) == [6, 7]


def test_winner_argmin_breaks_ties_toward_lowest_rank():
    g = [{"f": np.float32(8.0), "size": np.int64(64)},
         {"f": np.float32(8.0), "size": np.int64(64)}]
    assert hostmesh.HostExchanger._winner(g) == 0
    g[1]["f"] = np.float32(7.0)
    assert hostmesh.HostExchanger._winner(g) == 1
    # per-point comparison: a smaller raw f on a smaller chunk can lose
    g = [{"f": np.float32(10.0), "size": np.int64(100)},
         {"f": np.float32(6.0), "size": np.int64(50)}]
    assert hostmesh.HostExchanger._winner(g) == 0


def test_counter_delta_merge_is_exactly_once():
    ex = hostmesh.HostExchanger(_FakeRuntime(2, 0), CFG)
    ex._counters = (10, 100.0)
    acc, nd = ex._merge_counters([
        {"acc": np.int64(14), "nd": np.float64(130.0)},
        {"acc": np.int64(13), "nd": np.float64(120.0)},
    ])
    assert (acc, nd) == (17, 150.0)
    assert ex._counters == (17, 150.0)


def test_straggler_gather_is_traced():
    ticks = iter([0.0, 9.0])
    ex = hostmesh.HostExchanger(
        _FakeRuntime(2, 0, gathered=[{}, {}]), CFG,
        straggler_s=5.0, clock=lambda: next(ticks))
    ctx = _ctx()
    ex._gather(ctx, "x0", {}, 0)
    assert ("host_straggler", 0, 9.0) in ctx.metrics.trace


def test_dead_peer_enriches_typed_fault():
    ex = hostmesh.HostExchanger(_FakeRuntime(2, 0, raise_dead=True), CFG)
    ctx = _ctx()
    ctx.metrics.chunks_done = 2
    with pytest.raises(HostDead) as ei:
        ex._gather(ctx, "x3", {}, 3)
    assert ei.value.window == 3
    assert ei.value.health["chunks_done"] == 2
    assert ei.value.health["chunks_fetched"] == 2
    assert any(t[0] == "host_dead" and t[1] == 3
               for t in ctx.metrics.trace)


def test_run_host_stream_validates_composition():
    topo2 = HostMesh(processes=2, rank=0)
    with pytest.raises(ValueError, match="divide the global batch"):
        hostmesh.run_host_stream(_provider, CFG.replace(batch=3, n_chunks=9),
                                 topology=topo2, n_features=5)
    with pytest.raises(ValueError, match="divide n_chunks"):
        hostmesh.run_host_stream(_provider, CFG.replace(n_chunks=10),
                                 topology=topo2, n_features=5)
    with pytest.raises(ValueError, match="vns_ladder"):
        hostmesh.run_host_stream(_provider, CFG.replace(vns_ladder=(64,)),
                                 topology=topo2, n_features=5)
    with pytest.raises(ValueError, match="time_budget_s"):
        hostmesh.run_host_stream(_provider, CFG.replace(time_budget_s=5.0),
                                 topology=topo2, n_features=5)
    with pytest.raises(ValueError, match="competitive_s"):
        hostmesh.run_host_stream(
            _provider,
            CFG.replace(batch=2, scheduler="competitive_s", sync_every=4,
                        n_chunks=8),
            topology=topo2, n_features=5)


def test_launch_local_env_contract():
    script = ("import os, json; "
              "print('RESULT ' + json.dumps({"
              "'rank': os.environ['REPRO_HOST_RANK'], "
              "'hosts': os.environ['REPRO_NUM_HOSTS'], "
              "'coord': os.environ['REPRO_COORD']}))")
    procs = hostmesh.launch_local([sys.executable, "-c", script], 2,
                                  timeout_s=60)
    assert [p.returncode for p in procs] == [0, 0]
    outs = [json.loads(p.output.splitlines()[-1][len("RESULT "):])
            for p in procs]
    assert [o["rank"] for o in outs] == ["0", "1"]
    assert outs[0]["hosts"] == "2"
    assert outs[0]["coord"] == outs[1]["coord"]
    assert outs[0]["coord"].startswith("127.0.0.1:")


# ---------------------------------------------------------------------------
# real 2-process runs
# ---------------------------------------------------------------------------

_RANK_SCRIPT = r"""
import os, json
import numpy as np

import jax
from repro.api import BigMeansConfig, TopologySpec, fit

def provider(cid):
    rng = np.random.default_rng((11, cid))
    return rng.normal(size=(64, 5)).astype(np.float32)

spec = TopologySpec(kind="host_mesh", sync_timeout_s=20.0)
base = dict(k=4, s=64, n_chunks=8, batch=4, log_every=0, impl="ref",
            prefetch=2, topology=spec)

out = {}
# fold mode: collective sync (sync_every=1)
r = fit(provider, BigMeansConfig(**base), method="streaming", n_features=5)
out["fold"] = {
    "f": float(r.objective),
    "C": np.asarray(r.centroids).tolist(),
    "accepted": int(r.n_accepted),
    "host": r.extras["host"],
    "ranks": r.extras["health"]["ranks"],
    "host_sync_windows": [t[1] for t in r.trace if t[0] == "host_sync"],
}
# persistent mode: periodic sync (sync_every=2 over 2 local streams)
r2 = fit(provider, BigMeansConfig(**dict(base, sync_every=2)),
         method="streaming", n_features=5)
out["persistent"] = {
    "f": float(r2.objective),
    "C": np.asarray(r2.centroids).tolist(),
    "accepted": int(r2.n_accepted),
}
print("RESULT " + json.dumps(out), flush=True)
os._exit(0)   # skip the jax.distributed atexit teardown race
"""

_KILLED_SCRIPT = r"""
import os, json
import numpy as np

import jax
from repro.api import BigMeansConfig, TopologySpec, fit
from repro.engine.faults import HostDead

rank = int(os.environ["REPRO_HOST_RANK"])

def provider(cid):
    # rank 1 dies on its first own chunk (after the collective start), so
    # rank 0 completes its window-0 chunks and then times out at the
    # exchange -- exercising the typed-fault path with non-zero accounting
    if rank == 1 and cid in (2, 3):
        os._exit(3)
    rng = np.random.default_rng((11, cid))
    return rng.normal(size=(64, 5)).astype(np.float32)

spec = TopologySpec(kind="host_mesh", sync_timeout_s=8.0)
cfg = BigMeansConfig(k=4, s=64, n_chunks=8, batch=4, log_every=0,
                     impl="ref", prefetch=0, topology=spec)
try:
    fit(provider, cfg, method="streaming", n_features=5)
    out = {"host_dead": False}
except HostDead as e:
    out = {"host_dead": True, "rank": e.rank, "window": e.window,
           "health": e.health}
print("RESULT " + json.dumps(out), flush=True)
os._exit(0)   # the surviving rank must report cleanly, not abort at exit
"""


def _parse(proc):
    lines = [l for l in proc.output.splitlines() if l.startswith("RESULT ")]
    assert lines, (proc.rank, proc.returncode, proc.output[-3000:])
    return json.loads(lines[-1][len("RESULT "):])


@pytest.fixture(scope="module")
def two_proc():
    env = {"PYTHONPATH": os.path.join(REPO, "src")}
    procs = hostmesh.launch_local(
        [sys.executable, "-c", _RANK_SCRIPT], 2, timeout_s=540,
        env_extra=env)
    for p in procs:
        assert p.returncode == 0, (p.rank, p.output[-3000:])
    return [_parse(p) for p in procs]


@pytest.fixture(scope="module")
def reference():
    """The single-process runs at the same global chunk budget."""
    from repro.api import fit

    outs = {}
    r = fit(_provider, CFG, method="streaming", n_features=5)
    outs["fold"] = r
    r2 = fit(_provider, CFG.replace(sync_every=2), method="streaming",
             n_features=5)
    outs["persistent"] = r2
    return outs


def test_two_process_fold_bit_identical_to_single(two_proc, reference):
    ref = reference["fold"]
    for rank_out in two_proc:
        assert rank_out["fold"]["f"] == float(ref.objective)
        np.testing.assert_array_equal(
            np.asarray(rank_out["fold"]["C"], dtype=np.float32),
            np.asarray(ref.centroids))
        assert rank_out["fold"]["accepted"] == int(ref.n_accepted)


def test_two_process_persistent_bit_identical_to_single(two_proc, reference):
    ref = reference["persistent"]
    for rank_out in two_proc:
        assert rank_out["persistent"]["f"] == float(ref.objective)
        np.testing.assert_array_equal(
            np.asarray(rank_out["persistent"]["C"], dtype=np.float32),
            np.asarray(ref.centroids))
        assert rank_out["persistent"]["accepted"] == int(ref.n_accepted)


def test_two_process_health_and_sync_events(two_proc):
    for rank, out in enumerate(two_proc):
        assert out["fold"]["host"]["processes"] == 2
        assert out["fold"]["host"]["rank"] == rank
        ranks = out["fold"]["ranks"]
        assert [h["rank"] for h in ranks] == [0, 1]
        for h in ranks:
            assert h["chunks_done"] == 4            # 8 chunks over 2 ranks
            assert (h["chunks_done"] + h["chunks_failed"]
                    + h["chunks_dropped"] + h["chunks_quarantined"]
                    == h["chunks_fetched"])
        # collective sync: an exchange per window plus the final reduce
        assert out["fold"]["host_sync_windows"] == [0, 1, "final"]


def test_killed_process_fails_fast_with_typed_fault():
    env = {"PYTHONPATH": os.path.join(REPO, "src")}
    procs = hostmesh.launch_local(
        [sys.executable, "-c", _KILLED_SCRIPT], 2, timeout_s=540,
        env_extra=env)
    dead = procs[1]
    assert dead.returncode == 3                 # rank 1 killed itself
    survivor = _parse(procs[0])
    assert survivor["host_dead"] is True
    assert survivor["rank"] == 0
    assert survivor["window"] == 0              # the first exchange window
    h = survivor["health"]
    assert h["chunks_done"] == 2                # rank 0's window-0 chunks
    assert (h["chunks_done"] + h["chunks_failed"] + h["chunks_dropped"]
            + h["chunks_quarantined"]) == h["chunks_fetched"]
