import os

# Keep the default single-device CPU view for tests (the dry-run sets its own
# 512-device flag in its own process; per the launch spec it must NOT leak
# here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
