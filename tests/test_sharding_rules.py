"""Unit tests for the sharding rule tables (pure logic, fabricated meshes)."""
import types

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import flags
from repro.train import sharding as sh

MESH = types.SimpleNamespace(axis_names=("data", "model"),
                             devices=np.zeros((16, 16)))
POD_MESH = types.SimpleNamespace(axis_names=("pod", "data", "model"),
                                 devices=np.zeros((2, 16, 16)))


def test_batch_axes_adapt_to_pod():
    assert sh.physical_axes(MESH, "batch") == ("data",)
    assert sh.physical_axes(POD_MESH, "batch") == ("pod", "data")
    assert sh.physical_axes(POD_MESH, "fsdp") == ("pod", "data")


def test_kv_cache_heads_sharded_when_divisible():
    # phi3/deepseek-style: KV=16 divides the 16-way model axis
    logical = sh.kv_cache_logical(MESH, (32, 128, 32768, 16, 128))
    assert logical == (None, "batch", None, "model", None)


def test_kv_cache_seq_fallback_for_gqa():
    # llama-style: KV=8 does not divide 16 -> sequence over model
    flags.KV_SHARD_SEQ = True
    logical = sh.kv_cache_logical(MESH, (16, 128, 32768, 8, 64))
    assert logical == (None, "batch", "seqtp", None, None)
    flags.KV_SHARD_SEQ = False
    logical = sh.kv_cache_logical(MESH, (16, 128, 32768, 8, 64))
    assert logical == (None, "batch", None, None, None)   # pre-fix baseline
    flags.KV_SHARD_SEQ = True


def test_kv_cache_batch1_long_context():
    # long_500k: B=1 -> sequence over the data axes
    logical = sh.kv_cache_logical(MESH, (26, 1, 524288, 4, 256))
    assert logical[1] is None
    assert logical[2] == "seq"


def test_param_rules_expert_weights():
    spec = sh.param_pspec(
        (types.SimpleNamespace(key="layers"), types.SimpleNamespace(key="moe"),
         types.SimpleNamespace(key="e_gate")),
        (94, 128, 4096, 1536))
    assert spec == (None, "expert", "fsdp", None)


def test_param_rules_unknown_replicated():
    spec = sh.param_pspec((types.SimpleNamespace(key="mystery"),), (3, 4))
    assert spec == (None, None)


def test_spec_divisibility_guard():
    # mamba2 vocab 50280 is not divisible by 16: embedding vocab replicated
    s = sh.spec(MESH, "model", "fsdp", shape=(50280, 2560))
    assert s[0] is None and s[1] == "data"
    # qwen3 vocab divides: sharded
    s = sh.spec(MESH, "model", "fsdp", shape=(151936, 4096))
    assert s == P("model", "data")
