"""Serving correctness: prefill + single-token decode must reproduce the
full-sequence forward logits (exactly for attention families; small bf16
tolerance for SSD whose chunked/recurrent forms differ in summation order)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.models import transformer as T
from repro.models.registry import get_config, model_fns

# Full-model prefill/decode replays: the slowest block of the suite.
pytestmark = pytest.mark.slow

B, S, S0 = 2, 32, 24
KEY = jax.random.PRNGKey(1)

CASES = [
    ("seamless-m4t-medium", 1e-3),
    ("deepseek-moe-16b", 1e-3),
    ("hymba-1.5b", 0.15),
]


@pytest.mark.parametrize("arch,tol", CASES)
def test_decode_matches_forward(arch, tol):
    cfg = get_config(arch).reduced()
    if cfg.moe:
        # align train/decode capacity handling: no token drops in either
        cfg = dataclasses.replace(
            cfg, capacity_factor=cfg.num_experts / cfg.top_k)
    mod = model_fns(cfg)
    params = T.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)

    if cfg.family == "encdec":
        frames = jax.random.normal(KEY, (B, 16, cfg.frontend_dim))
        logits_full, _ = mod.forward(cfg, params, tokens, frames)
        _, cache = mod.prefill(cfg, params, tokens[:, :S0], frames, S)
        offset = 0
    elif cfg.family == "vlm":
        frames = jax.random.normal(KEY, (B, cfg.frontend_len, cfg.frontend_dim))
        logits_full, _ = mod.forward(cfg, params, tokens, frontend=frames)
        _, cache = mod.prefill(cfg, params, tokens[:, :S0],
                               S + cfg.frontend_len, frontend=frames)
        offset = cfg.frontend_len
    else:
        logits_full, _ = mod.forward(cfg, params, tokens)
        _, cache = mod.prefill(cfg, params, tokens[:, :S0], S)
        offset = 0

    for t in range(S0, S):
        lg, cache = mod.decode_step(cfg, params, cache, tokens[:, t:t + 1],
                                    jnp.int32(t + offset))
        err = float(jnp.max(jnp.abs(lg - logits_full[:, t + offset])))
        assert err < tol, (t, err)
