"""The declarative topology API: spec validation, the single resolve()
construction point, the raw-mesh deprecation shim (bit-identical), and the
degenerate single-process host_mesh path."""
import warnings

import jax
import numpy as np
import pytest

from repro.api import BigMeansConfig, TopologySpec, fit
from repro.data.synthetic import GMMSpec, gmm_dataset
from repro.engine import topology as topo
from repro.launch.mesh import make_mesh

X = gmm_dataset(GMMSpec(m=2000, n=5, components=4, seed=3))
CFG = BigMeansConfig(k=4, s=64, n_chunks=8, log_every=0, impl="ref")


# ---------------------------------------------------------------------------
# TopologySpec validation
# ---------------------------------------------------------------------------


def test_spec_defaults_and_kinds():
    assert TopologySpec().kind == "auto"
    for kind in topo.KINDS:
        if kind == "host_mesh":
            assert TopologySpec(kind=kind, hosts=2, rank=0).hosts == 2
        elif kind in ("auto", "single"):
            TopologySpec(kind=kind)
        else:
            TopologySpec(kind=kind, devices=1)


@pytest.mark.parametrize("bad", [
    dict(kind="bogus"),
    dict(kind="single", devices=2),
    dict(kind="host_mesh", devices=2),
    dict(kind="worker_mesh", devices=0),
    dict(kind="worker_mesh", devices=(2, 2), axes=("data",)),
    dict(kind="worker_mesh", axes=("",)),
    dict(kind="stream_mesh", hosts=2),
    dict(kind="single", coordinator="h:1"),
    dict(kind="host_mesh", hosts=0),
    dict(kind="host_mesh", rank=-1),
    dict(kind="host_mesh", sync_timeout_s=0),
])
def test_spec_rejects(bad):
    with pytest.raises(ValueError):
        TopologySpec(**bad)


def test_as_spec_coercion():
    assert topo.as_spec("single").kind == "single"
    spec = TopologySpec(kind="stream_mesh", devices=1)
    assert topo.as_spec(spec) is spec
    with pytest.raises(TypeError):
        topo.as_spec(42)
    with pytest.raises(ValueError):
        topo.as_spec("not_a_kind")


# ---------------------------------------------------------------------------
# resolve(): the one mesh construction point
# ---------------------------------------------------------------------------


def test_resolve_kinds():
    assert isinstance(topo.resolve("single"), topo.SingleDevice)
    assert isinstance(topo.resolve("auto"), topo.SingleDevice)
    sm = topo.resolve(TopologySpec(kind="stream_mesh", devices=1))
    assert isinstance(sm, topo.StreamMesh) and sm.axis == "streams"
    wm = topo.resolve(TopologySpec(kind="worker_mesh", devices=1),
                      role="worker")
    assert isinstance(wm, topo.WorkerMesh) and wm.axes == ("data",)
    auto_w = topo.resolve("auto", role="worker")
    assert isinstance(auto_w, topo.WorkerMesh)
    assert auto_w.devices == len(jax.devices())


def test_resolve_host_mesh_degenerate(monkeypatch):
    """hosts=1 (or nothing set) is the no-bootstrap single-process group."""
    monkeypatch.delenv("REPRO_NUM_HOSTS", raising=False)
    hm = topo.resolve("host_mesh")
    assert isinstance(hm, topo.HostMesh)
    assert (hm.processes, hm.rank) == (1, 0)


def test_worker_mesh_validates_axes_at_construction():
    mesh = make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="'bogus'.*data"):
        topo.WorkerMesh(mesh, ("bogus",))
    with pytest.raises(ValueError, match="at least one"):
        topo.WorkerMesh(mesh, ())
    with pytest.raises(ValueError, match="'nope'"):
        topo.StreamMesh(mesh, "nope")
    # valid axes still construct
    assert topo.WorkerMesh(mesh, ("data",)).devices == 1


def test_host_mesh_descriptor_validation():
    with pytest.raises(ValueError):
        topo.HostMesh(processes=0, rank=0)
    with pytest.raises(ValueError):
        topo.HostMesh(processes=2, rank=2)
    assert topo.HostMesh(processes=2, rank=1).devices == 2


def test_requested_kind_and_worker_count():
    assert topo.requested_kind(CFG) == "auto"
    cfg = CFG.replace(topology=TopologySpec(kind="worker_mesh", devices=3))
    assert topo.requested_kind(cfg) == "worker_mesh"
    assert topo.worker_count(cfg) == 3
    cfg = CFG.replace(topology=TopologySpec(kind="worker_mesh",
                                            devices=(2, 2),
                                            axes=("data", "model")))
    assert topo.worker_count(cfg) == 4
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = CFG.replace(mesh=make_mesh((1,), ("data",)))
    assert topo.requested_kind(legacy) == "legacy_mesh"
    assert topo.worker_count(legacy) == 1


# ---------------------------------------------------------------------------
# config integration: the primary path is declarative, raw mesh is shimmed
# ---------------------------------------------------------------------------


def test_config_normalizes_topology_to_spec():
    cfg = CFG.replace(topology="host_mesh")
    assert isinstance(cfg.topology, TopologySpec)
    assert cfg.topology.kind == "host_mesh"
    with pytest.raises(ValueError):
        CFG.replace(topology="bogus")
    with pytest.raises(TypeError):
        CFG.replace(topology=7)


def test_raw_mesh_deprecated_but_working():
    mesh = make_mesh((1,), ("streams",))
    with pytest.warns(DeprecationWarning, match="topology"):
        cfg = CFG.replace(mesh=mesh, stream_axis="streams")
    assert cfg.mesh is mesh                     # shim: still carried through


def test_raw_mesh_conflicts_with_explicit_topology():
    mesh = make_mesh((1,), ("streams",))
    with pytest.raises(ValueError, match="mutually exclusive"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            CFG.replace(mesh=mesh, topology="stream_mesh")


def test_shim_and_spec_bit_identical_streaming():
    """A raw cfg.mesh and the equivalent declarative spec must produce the
    same fit, bit for bit."""
    mesh = make_mesh((1,), ("streams",))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = CFG.replace(mesh=mesh, stream_axis="streams", batch=2)
    spec = CFG.replace(batch=2, topology=TopologySpec(
        kind="stream_mesh", devices=1, axes=("streams",)))
    r_legacy = fit(X, legacy, method="streaming")
    r_spec = fit(X, spec, method="streaming")
    assert r_legacy.objective == r_spec.objective
    np.testing.assert_array_equal(np.asarray(r_legacy.centroids),
                                  np.asarray(r_spec.centroids))
    assert r_legacy.n_accepted == r_spec.n_accepted


def test_shim_and_spec_bit_identical_batched():
    mesh = make_mesh((1,), ("streams",))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = CFG.replace(mesh=mesh, stream_axis="streams", batch=4)
    spec = CFG.replace(batch=4, topology=TopologySpec(
        kind="stream_mesh", devices=1, axes=("streams",)))
    r_legacy = fit(X, legacy, method="batched")
    r_spec = fit(X, spec, method="batched")
    assert r_legacy.objective == r_spec.objective
    np.testing.assert_array_equal(np.asarray(r_legacy.centroids),
                                  np.asarray(r_spec.centroids))


def test_batched_rejects_worker_topology():
    cfg = CFG.replace(topology=TopologySpec(kind="worker_mesh", devices=1),
                      batch=2)
    with pytest.raises(ValueError, match="batched"):
        fit(X, cfg, method="batched")


def test_sharded_consumes_spec():
    cfg = CFG.replace(topology=TopologySpec(kind="worker_mesh", devices=1))
    r = fit(X, cfg, method="sharded")
    assert r.extras["workers"] == 1


def test_auto_routes_host_mesh_to_streaming(monkeypatch):
    from repro.api import strategies as S
    from repro.api.sources import as_source

    monkeypatch.delenv("REPRO_NUM_HOSTS", raising=False)
    cfg = CFG.replace(topology="host_mesh")
    assert S.resolve_auto(cfg, as_source(X)) == "streaming"


def test_single_process_host_mesh_matches_plain_streaming(monkeypatch):
    """topology='host_mesh' with hosts=1 is the degenerate group: no
    coordination service, and results bit-identical to plain streaming."""
    monkeypatch.delenv("REPRO_NUM_HOSTS", raising=False)
    cfg = CFG.replace(batch=4)
    r_plain = fit(X, cfg, method="streaming")
    r_host = fit(X, cfg.replace(topology="host_mesh"), method="streaming")
    assert r_plain.objective == r_host.objective
    np.testing.assert_array_equal(np.asarray(r_plain.centroids),
                                  np.asarray(r_host.centroids))
    assert r_plain.n_accepted == r_host.n_accepted
    assert r_host.extras["host"]["processes"] == 1
    ranks = r_host.extras["health"]["ranks"]
    assert len(ranks) == 1 and ranks[0]["rank"] == 0
