"""Batched chunk pipeline: vmap-able Lloyd, batched fused kernel, batched
driver, prefetching runner.

The load-bearing guarantees:

* ``big_means_batched(batch=1)`` IS the sequential algorithm (same key
  schedule, same incumbent trajectory);
* ``lloyd_batched`` matches B independent ``lloyd`` calls, including the
  per-stream iteration counts the paper's n_d accounting needs;
* the batched fused Pallas kernel agrees with the two-pass oracle *beyond*
  the single-chunk kernel's k<=128 / n<=1024 envelope;
* the prefetching / batched runner preserves the host-loop semantics
  (counts, failures, resume).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import BigMeansConfig
from repro.core import (
    big_means, big_means_batched, broadcast_state, chunk_step_batched,
    init_state, kmeanspp, lloyd, lloyd_batched, reduce_state,
)
from repro.core.kmeanspp import seed, seed_batched
from repro.data.synthetic import GMMSpec, gmm_dataset
from repro.kernels import ops
from repro.kernels.fused_step import (
    LEGACY_MAX_K, LEGACY_MAX_N, MAX_K, MAX_N, fits, fits_batched,
    fused_step_batched_pallas,
)

X = gmm_dataset(GMMSpec(m=8000, n=8, components=5, seed=21))


# ---------------------------------------------------------------------------
# lloyd: masked iteration, vmap-ability, explicit batching
# ---------------------------------------------------------------------------

def _stream_data(B, s, k, key=0):
    kx = jax.random.split(jax.random.PRNGKey(key), B)
    pts = jnp.stack([X[i * s:(i + 1) * s] for i in range(B)])
    cs = jnp.stack([kmeanspp(pts[i], kx[i], k) for i in range(B)])
    return pts, cs


def test_lloyd_batched_matches_independent_runs():
    B, s, k = 3, 1000, 5
    pts, cs = _stream_data(B, s, k)
    rb = lloyd_batched(pts, cs, impl="ref")
    for i in range(B):
        ri = lloyd(pts[i], cs[i], impl="ref")
        np.testing.assert_allclose(
            float(rb.objective[i]), float(ri.objective), rtol=1e-5)
        assert int(rb.iterations[i]) == int(ri.iterations)
        np.testing.assert_allclose(
            np.asarray(rb.centroids[i]), np.asarray(ri.centroids),
            rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(
            np.asarray(rb.counts[i]), np.asarray(ri.counts))


def test_lloyd_is_vmappable():
    """The masked-iteration scheme makes plain `lloyd` vmap-able: converged
    streams become no-ops instead of breaking the while_loop."""
    B, s, k = 3, 800, 4
    pts, cs = _stream_data(B, s, k, key=1)
    rv = jax.vmap(lambda p, c: lloyd(p, c, impl="ref"))(pts, cs)
    for i in range(B):
        ri = lloyd(pts[i], cs[i], impl="ref")
        np.testing.assert_allclose(
            float(rv.objective[i]), float(ri.objective), rtol=1e-5)
        assert int(rv.iterations[i]) == int(ri.iterations)


def test_lloyd_batched_respects_max_iters():
    B, s, k = 2, 500, 4
    pts, cs = _stream_data(B, s, k, key=2)
    rb = lloyd_batched(pts, cs, max_iters=3, tol=0.0, impl="ref")
    assert int(rb.iterations.max()) <= 3


# ---------------------------------------------------------------------------
# batched fused kernel: parity beyond the single-chunk envelope
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,m,n,k", [
    (2, 300, 28, 25),        # paper regime
    (3, 257, 64, 128),       # ragged m tile, envelope edge
    (1, 400, 20, 200),       # k > 128: beyond the single-chunk wall
    (2, 300, 1100, 40),      # n > 1024: beyond the single-chunk wall
    (1, 200, 1500, 256),     # both walls at once
])
def test_batched_fused_kernel_matches_two_pass(B, m, n, k):
    kx, kc = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (B, m, n))
    c = jax.random.normal(kc, (B, k, n))
    assert fits_batched(k, n)
    if k > LEGACY_MAX_K or n > LEGACY_MAX_N:
        # Beyond the historical single-chunk envelope — the k-tiled argmin
        # rewrite widened fits() to cover these shapes in one kernel too.
        assert fits(k, n)
    assert not fits(MAX_K + 1, n)        # the widened wall still exists
    assert not fits(k, MAX_N + 1)
    s_p, n_p, o_p = fused_step_batched_pallas(x, c, interpret=True)
    s_r, n_r, o_r = ops._fused_step_batched_ref(x, c)
    np.testing.assert_allclose(np.asarray(n_p), np.asarray(n_r), atol=0)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r),
                               rtol=2e-3, atol=2e-2)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r), rtol=2e-3)


def test_fused_step_batched_dispatch():
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 200, 16))
    c = jax.random.normal(jax.random.PRNGKey(2), (2, 5, 16))
    s1, n1, o1 = ops.fused_step_batched(x, c, impl="ref")
    s2, n2, o2 = ops.fused_step_batched(x, c, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n2))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5)


# ---------------------------------------------------------------------------
# vmap-safe seeding
# ---------------------------------------------------------------------------

def test_seed_batched_matches_per_stream():
    B, s, k = 3, 1000, 5
    pts = jnp.stack([X[i * s:(i + 1) * s] for i in range(B)])
    keys = jax.random.split(jax.random.PRNGKey(3), B)
    init = jnp.stack([pts[i, :k] for i in range(B)])
    deg = jnp.array([[False, True, False, True, False]] * B)
    out = seed_batched(pts, keys, k, init=init, degenerate=deg[0] * deg)
    for i in range(B):
        ref_i = seed(pts[i], keys[i], k, init=init[i],
                     degenerate=(deg[0] * deg)[i])
        np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(ref_i))


# ---------------------------------------------------------------------------
# batched driver: batch=1 equivalence, stream sync, state algebra
# ---------------------------------------------------------------------------

def test_big_means_batched_batch1_equals_sequential():
    key = jax.random.PRNGKey(7)
    st_seq, inf_seq = big_means(X, key, k=5, s=600, n_chunks=12, impl="ref")
    st_b1, inf_b1 = big_means_batched(
        X, key, k=5, s=600, batch=1, rounds=12, impl="ref")
    np.testing.assert_allclose(
        float(st_b1.f_best), float(st_seq.f_best), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(st_b1.centroids), np.asarray(st_seq.centroids),
        rtol=1e-5, atol=1e-5)
    assert int(st_b1.n_accepted) == int(st_seq.n_accepted)
    np.testing.assert_allclose(
        float(st_b1.n_dist_evals), float(st_seq.n_dist_evals), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(inf_b1.f_new), np.asarray(inf_seq.f_new), rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(inf_b1.accepted), np.asarray(inf_seq.accepted))


@pytest.mark.parametrize("sync_every", [1, 3])
def test_big_means_batched_multi_stream(sync_every):
    key = jax.random.PRNGKey(8)
    st, infos = big_means_batched(
        X, key, k=5, s=600, batch=4, rounds=6, sync_every=sync_every,
        impl="ref")
    assert infos.f_new.shape == (24,)
    assert np.isfinite(float(st.f_best))
    assert int(st.n_accepted) >= 1
    # the reduced incumbent is at least as good as every observed chunk f
    assert float(st.f_best) <= float(np.min(np.asarray(infos.f_new))) + 1e-3


def test_big_means_batched_quality_tracks_sequential():
    from repro.core import full_objective
    key = jax.random.PRNGKey(9)
    st_b, _ = big_means_batched(X, key, k=5, s=600, batch=4, rounds=6,
                                impl="ref")
    st_s, _ = big_means(X, key, k=5, s=600, n_chunks=24, impl="ref")
    f_b = float(full_objective(X, st_b.centroids)) / X.shape[0]
    f_s = float(full_objective(X, st_s.centroids)) / X.shape[0]
    assert f_b <= f_s * 1.15


def test_broadcast_reduce_state_roundtrip():
    state = init_state(4, 8)._replace(
        centroids=jnp.ones((4, 8)), degenerate=jnp.zeros((4,), bool),
        f_best=jnp.float32(5.0), n_accepted=jnp.int32(3),
        n_dist_evals=jnp.float32(100.0))
    bs = broadcast_state(state, 3)
    assert bs.centroids.shape == (3, 4, 8)
    assert int(jnp.sum(bs.n_accepted)) == 0      # counters zeroed per stream
    # pretend stream 1 improved
    bs = bs._replace(
        f_best=bs.f_best.at[1].set(2.0),
        n_accepted=bs.n_accepted.at[1].set(1),
        n_dist_evals=bs.n_dist_evals + 10.0)
    red = reduce_state(bs, base=state)
    assert float(red.f_best) == 2.0
    assert int(red.n_accepted) == 3 + 1
    assert float(red.n_dist_evals) == 100.0 + 30.0


def test_chunk_step_batched_keeps_best_per_stream():
    B, s, k = 3, 500, 5
    pts = jnp.stack([X[i * s:(i + 1) * s] for i in range(B)])
    keys = jax.random.split(jax.random.PRNGKey(10), B)
    states = broadcast_state(init_state(k, 8), B)
    states, info = chunk_step_batched(pts, states, keys, impl="ref")
    assert bool(jnp.all(info.accepted))          # first chunk always accepted
    np.testing.assert_allclose(
        np.asarray(states.f_best), np.asarray(info.f_new), rtol=1e-6)


# ---------------------------------------------------------------------------
# prefetching / batched runner
# ---------------------------------------------------------------------------

def _provider_spec():
    from repro.data.synthetic import gmm_chunk
    spec = GMMSpec(m=10**6, n=8, components=5, seed=3)

    def provider(cid):
        return np.asarray(gmm_chunk(spec, cid, 512))

    return provider


def test_runner_batched_end_to_end():
    from repro.cluster import runner
    provider = _provider_spec()
    cfg = BigMeansConfig(k=5, s=512, n_chunks=12, batch=4, seed=1)
    state, m = runner.run(provider, cfg, n_features=8)
    assert m.chunks_done == 12
    assert np.isfinite(m.f_best)


def test_runner_batched_partial_batch_and_failures():
    from repro.cluster import runner
    provider = _provider_spec()

    def bomb(cid):
        if cid in (2, 5):
            raise RuntimeError("node lost")

    cfg = BigMeansConfig(k=5, s=512, n_chunks=11, batch=4, seed=2)
    state, m = runner.run(provider, cfg, n_features=8, fault_injector=bomb)
    assert m.chunks_failed == 2
    assert m.chunks_done == 9          # 2 full batches + partial final batch


def test_runner_prefetch_matches_sync():
    """The prefetch thread must not change results: chunk keys are folded
    from ids, so pipelined and synchronous fetch produce identical runs."""
    from repro.cluster import runner
    provider = _provider_spec()
    cfg_pre = BigMeansConfig(k=5, s=512, n_chunks=8, prefetch=3, seed=4)
    cfg_syn = BigMeansConfig(k=5, s=512, n_chunks=8, prefetch=0, seed=4)
    st_p, m_p = runner.run(provider, cfg_pre, n_features=8)
    st_s, m_s = runner.run(provider, cfg_syn, n_features=8)
    assert m_p.chunks_done == m_s.chunks_done == 8
    np.testing.assert_allclose(m_p.f_best, m_s.f_best, rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(st_p.centroids), np.asarray(st_s.centroids),
        rtol=1e-5, atol=1e-5)
