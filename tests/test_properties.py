"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)",
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import chunk_step, init_state, lloyd
from repro.kernels import ops, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

dims = st.tuples(
    st.integers(4, 200),      # m
    st.integers(1, 40),       # n
    st.integers(1, 12),       # k
)


@given(dims, st.integers(0, 2**31 - 1))
def test_assign_invariants(mnk, seed):
    m, n, k = mnk
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, n))
    c = jax.random.normal(kc, (k, n))
    ids, d = ops.assign(x, c, impl="ref")
    assert (np.asarray(d) >= 0).all()
    assert (np.asarray(ids) >= 0).all() and (np.asarray(ids) < k).all()
    # the reported distance is the minimum over all centroids
    full = np.asarray(ref.pairwise_sqdist_ref(x, c))
    np.testing.assert_allclose(np.asarray(d), full.min(axis=1), rtol=1e-5,
                               atol=1e-5)


@given(dims, st.integers(0, 2**31 - 1))
def test_update_mass_conservation(mnk, seed):
    m, n, k = mnk
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, n))
    ids = jax.random.randint(kc, (m,), 0, k)
    sums, counts = ops.update(x, ids, k, impl="ref")
    np.testing.assert_allclose(float(jnp.sum(counts)), m)
    np.testing.assert_allclose(
        np.asarray(jnp.sum(sums, 0)), np.asarray(jnp.sum(x, 0)),
        rtol=1e-3, atol=1e-3)


@given(dims, st.integers(0, 2**31 - 1))
def test_pallas_interpret_matches_ref(mnk, seed):
    m, n, k = mnk
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, n))
    c = jax.random.normal(kc, (k, n))
    _, d_r = ops.assign(x, c, impl="ref")
    _, d_p = ops.assign(x, c, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_r),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(2, 6), st.integers(0, 2**31 - 1))
def test_lloyd_never_increases_objective(k, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (120, 6)) * 3
    c0 = x[:k]
    res0 = lloyd(x, c0, max_iters=1, tol=0.0)
    res5 = lloyd(x, c0, max_iters=8, tol=0.0)
    assert float(res5.objective) <= float(res0.objective) + 1e-3


@given(st.integers(0, 2**31 - 1))
def test_chunk_step_incumbent_monotone(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (400, 5))
    state = init_state(4, 5)
    prev = float("inf")
    for i in range(4):
        key, k1 = jax.random.split(key)
        state, _ = chunk_step(x, state, k1)
        assert float(state.f_best) <= prev + 1e-6
        prev = float(state.f_best)
