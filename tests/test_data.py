"""Data substrate: loaders, normalization, streaming determinism."""
import csv
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.data.loader import MemmapProvider, csv_to_npy, sharded_provider
from repro.data.normalize import minmax_normalize, streaming_minmax
from repro.data.synthetic import GMMSpec, gmm_chunk, gmm_dataset


def test_memmap_provider_deterministic(tmp_path):
    path = os.path.join(tmp_path, "x.npy")
    np.save(path, np.arange(1000.0 * 4).reshape(1000, 4).astype(np.float32))
    p = MemmapProvider(path, s=64, seed=3)
    a, b = p(7), p(7)
    np.testing.assert_array_equal(a, b)            # replayable
    c = p(8)
    assert not np.array_equal(a, c)                # distinct chunks
    assert a.shape == (64, 4) and a.dtype == np.float32


def test_csv_roundtrip(tmp_path):
    csv_path = os.path.join(tmp_path, "d.csv")
    npy_path = os.path.join(tmp_path, "d.npy")
    data = np.random.default_rng(0).normal(size=(137, 5)).astype(np.float32)
    with open(csv_path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow([f"c{i}" for i in range(5)])
        w.writerows(data.tolist())
    rows, cols = csv_to_npy(csv_path, npy_path)
    assert (rows, cols) == (137, 5)
    np.testing.assert_allclose(np.load(npy_path), data, rtol=1e-5)


def test_sharded_provider_disjoint(tmp_path):
    path = os.path.join(tmp_path, "x.npy")
    np.save(path, np.random.default_rng(1).normal(size=(500, 3)).astype(np.float32))
    base = MemmapProvider(path, s=16, seed=0)
    w0 = sharded_provider(base, 0, 4)
    w1 = sharded_provider(base, 1, 4)
    assert not np.array_equal(w0(0), w1(0))        # different chunk ids
    np.testing.assert_array_equal(w0(1), base(4))  # id mapping


def test_gmm_chunk_streaming_consistency():
    spec = GMMSpec(m=10000, n=6, components=4, seed=5)
    full = gmm_dataset(spec)
    # chunk 0 of the stream equals the first rows of the materialized set
    c0 = gmm_chunk(spec, 0, 1 << 16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(c0)[:10000],
                               rtol=1e-6)


def test_minmax_normalize_bounds():
    x = jnp.asarray(np.random.default_rng(2).normal(size=(100, 7)) * 9.0)
    z = minmax_normalize(x)
    assert float(z.min()) >= 0.0 and float(z.max()) <= 1.0


def test_streaming_minmax_matches_full():
    x = np.random.default_rng(3).normal(size=(300, 4)).astype(np.float32)
    lo, hi = streaming_minmax([jnp.asarray(x[:100]), jnp.asarray(x[100:])])
    np.testing.assert_allclose(np.asarray(lo), x.min(0), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(hi), x.max(0), rtol=1e-6)
