"""Distributed-runtime behaviour: checkpoint/restart, fault tolerance,
straggler bounds, elastic restore, optimizer."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import BigMeansConfig
from repro.cluster import checkpoint, runner
from repro.data.synthetic import GMMSpec, gmm_chunk
from repro.train.optimizer import adamw, warmup_cosine

SPEC = GMMSpec(m=10**6, n=8, components=5, seed=3)


def provider(cid):
    return np.asarray(gmm_chunk(SPEC, cid, 1024))


def test_runner_end_to_end(tmp_path):
    cfg = BigMeansConfig(k=5, s=1024, n_chunks=20,
                              ckpt_dir=str(tmp_path), ckpt_every=8, seed=1)
    state, m = runner.run(provider, cfg, n_features=8)
    assert m.chunks_done == 20
    assert np.isfinite(m.f_best)
    assert checkpoint.latest_step(str(tmp_path)) is not None


def test_runner_restart_resumes_not_restarts(tmp_path):
    cfg = BigMeansConfig(k=5, s=1024, n_chunks=10,
                              ckpt_dir=str(tmp_path), ckpt_every=5, seed=1)
    runner.run(provider, cfg, n_features=8)
    cfg2 = BigMeansConfig(k=5, s=1024, n_chunks=25,
                               ckpt_dir=str(tmp_path), ckpt_every=5, seed=1)
    _, m2 = runner.run(provider, cfg2, n_features=8)
    assert m2.chunks_done <= 16            # resumed past the first 10


def test_runner_survives_chunk_failures(tmp_path):
    def bomb(cid):
        if cid in (2, 3, 7):
            raise RuntimeError("node lost")

    cfg = BigMeansConfig(k=5, s=1024, n_chunks=12, seed=2)
    state, m = runner.run(provider, cfg, n_features=8, fault_injector=bomb)
    assert m.chunks_failed == 3
    assert m.chunks_done == 9
    assert np.isfinite(m.f_best)


def test_runner_straggler_budget():
    # A straggling chunk is bounded by max_iters (compile-time constant):
    cfg = BigMeansConfig(k=5, s=1024, n_chunks=3, max_iters=2, seed=4)
    state, m = runner.run(provider, cfg, n_features=8)
    assert m.chunks_done == 3


@pytest.mark.slow
def test_runner_time_budget():
    cfg = BigMeansConfig(k=5, s=1024, n_chunks=10**6,
                              time_budget_s=2.0, seed=5)
    state, m = runner.run(provider, cfg, n_features=8)
    assert m.wall_time_s < 20.0
    assert m.chunks_done >= 1


def test_checkpoint_roundtrip_and_keep(tmp_path):
    tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3))}}
    for step in (1, 2, 3, 4, 5):
        checkpoint.save(str(tmp_path), step, tree, keep=2)
    assert checkpoint.latest_step(str(tmp_path)) == 5
    restored, step = checkpoint.restore(str(tmp_path), tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(5.0))
    import os
    kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(kept) == 2                   # keep-last-N enforced


def test_checkpoint_elastic_resharding(tmp_path):
    """Restore onto a different 'topology' (here: a different sharding) —
    arrays are stored as full logical values, so any target works."""
    tree = {"c": jnp.ones((8, 4))}
    checkpoint.save(str(tmp_path), 1, tree)
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    restored, _ = checkpoint.restore(str(tmp_path), tree, shardings=sharding)
    assert restored["c"].sharding == sharding


def test_adamw_decreases_quadratic():
    opt = adamw(0.1, weight_decay=0.0)
    p = {"w": jnp.array([3.0, -2.0])}
    s = opt.init(p)
    for _ in range(50):
        g = {"w": 2 * p["w"]}
        p, s = opt.update(g, s, p)
    assert float(jnp.sum(p["w"] ** 2)) < 0.1


def test_warmup_cosine_schedule():
    sched = warmup_cosine(1.0, warmup=10, total=100)
    assert float(sched(jnp.int32(0))) == 0.0
    assert abs(float(sched(jnp.int32(10))) - 1.0) < 1e-6
    assert float(sched(jnp.int32(100))) < 1e-3


@pytest.mark.slow
def test_runner_vns_ladder():
    """Beyond-paper: VNS chunk-size shaking (the paper's §6 future work).
    Stalls escalate to smaller chunks; acceptances reset; quality is not
    hurt vs the fixed-size baseline."""
    cfg_base = BigMeansConfig(k=5, s=1024, n_chunks=25, seed=7)
    _, m_base = runner.run(provider, cfg_base, n_features=8)
    cfg_vns = BigMeansConfig(k=5, s=1024, n_chunks=25, seed=7,
                                  vns_ladder=(512, 256), vns_patience=3)
    _, m_vns = runner.run(provider, cfg_vns, n_features=8)
    assert np.isfinite(m_vns.f_best)
    # normalized per-point quality comparable (within 20%)
    assert m_vns.f_best / 256 <= (m_base.f_best / 1024) * 1.2 * 1024 / 256 \
        or m_vns.f_best <= m_base.f_best * 1.2
