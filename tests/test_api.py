"""`repro.api` facade: config validation, data-source adapters, strategy
registry, baseline registry, deprecation shims, impl resolver.

Load-bearing guarantees:

* one ``fit(data, config, method=...)`` signature covers all four driver
  strategies AND the §5 baselines, all returning a ``FitResult``;
* ``fit(strategy='batched', batch=1)`` is fp-identical to
  ``fit(strategy='sequential')`` on the reference path (the facade preserves
  the ``test_batched.py`` equivalence);
* every ``DataSource`` adapter over the same rows serves the same chunks;
* config mistakes fail fast with actionable ``ValueError``s, not deep in a
  driver.
"""
import warnings

import jax
import numpy as np
import pytest

import repro.api as api
from repro.api import (
    ArraySource, BigMeansConfig, FitResult, IteratorSource, MemmapSource,
    ProviderSource, as_source, evaluate, fit,
)
from repro.data.synthetic import GMMSpec, gmm_chunk, gmm_dataset
from repro.kernels import ops

X = gmm_dataset(GMMSpec(m=6000, n=8, components=5, seed=33))
CFG = BigMeansConfig(k=5, s=500, n_chunks=8, impl="ref", seed=3)


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(k=0, s=100),
    dict(k=-3, s=100),
    dict(k=5, s=0),
    dict(k=50, s=10),                       # s < k
    dict(k=5, s=100, batch=0),
    dict(k=5, s=100, n_chunks=0),
    dict(k=5, s=100, sync_every=0),
    dict(k=5, s=100, tol=-1.0),
    dict(k=5, s=100, prefetch=-1),
    dict(k=5, s=100, impl="cuda"),
    dict(k=5, s=100, time_budget_s=0.0),
    dict(k=5, s=100, vns_ladder=(3,)),      # rung < k
    dict(k=5, s=100, vns_patience=0),
])
def test_config_validation_rejects(bad):
    with pytest.raises(ValueError):
        BigMeansConfig(**bad)


def test_config_replace_revalidates():
    cfg = BigMeansConfig(k=5, s=100)
    assert cfg.replace(batch=4).batch == 4
    with pytest.raises(ValueError):
        cfg.replace(s=2)


def test_fit_requires_k_and_s_without_config():
    with pytest.raises(TypeError, match="k"):
        fit(X)


def test_batched_strategy_validates_divisibility():
    with pytest.raises(ValueError, match="divide n_chunks"):
        fit(X, CFG, method="batched", batch=3)       # 3 does not divide 8
    with pytest.raises(ValueError, match="sync_every"):
        fit(X, CFG, method="batched", batch=2, sync_every=3)


def test_unknown_method_lists_options():
    with pytest.raises(KeyError, match="sequential"):
        fit(X, CFG, method="nope")


# ---------------------------------------------------------------------------
# config truth: from_workload + deprecation shims
# ---------------------------------------------------------------------------

def test_from_workload_paper_config():
    from repro.configs.bigmeans_paper import CONFIG

    cfg = BigMeansConfig.from_workload(CONFIG)
    assert (cfg.k, cfg.s) == (CONFIG.k, CONFIG.s) == (25, 64_000)
    assert cfg.n_chunks == CONFIG.chunks_per_worker
    assert cfg.batch == CONFIG.batch
    cfg2 = BigMeansConfig.from_workload(CONFIG, batch=2)
    assert cfg2.batch == 2 and CONFIG.batch == 8    # override copies


def test_workload_legacy_kwargs_deprecated():
    from repro.configs.bigmeans_paper import BigMeansWorkload

    with pytest.deprecated_call():
        wl = BigMeansWorkload(k=30, chunks_per_worker=6)
    assert wl.k == 30 and wl.algo.k == 30
    assert wl.chunks_per_worker == 6 and wl.algo.n_chunks == 6
    with pytest.raises(TypeError):
        BigMeansWorkload(bogus_knob=1)


def test_runner_config_shim():
    from repro.cluster import runner

    with pytest.deprecated_call():
        cfg = runner.RunnerConfig(k=5, s=512, batch=2)
    assert isinstance(cfg, BigMeansConfig)
    assert cfg.n_chunks == 1_000_000        # the old "until budget" default


# ---------------------------------------------------------------------------
# impl resolver (kernels/ops dispatch cache)
# ---------------------------------------------------------------------------

def test_set_default_impl_none_restores_autodetect():
    assert ops.resolve_impl("auto") == "ref"         # CPU container
    try:
        ops.set_default_impl("ref_chunked")
        assert ops.resolve_impl("auto") == "ref_chunked"
        assert ops.resolve_impl(None) == "ref_chunked"
    finally:
        ops.set_default_impl(None)
    assert ops.resolve_impl("auto") == "ref"         # cache cleared


def test_resolve_impl_validates():
    assert ops.resolve_impl("pallas_interpret") == "pallas_interpret"
    with pytest.raises(ValueError):
        ops.resolve_impl("cuda")
    with pytest.raises(ValueError):
        ops.set_default_impl("cuda")


# ---------------------------------------------------------------------------
# data sources: every adapter round-trips the same chunks
# ---------------------------------------------------------------------------

def test_array_and_memmap_sources_serve_identical_chunks(tmp_path):
    rows = np.asarray(X, dtype=np.float32)
    path = tmp_path / "data.npy"
    np.save(path, rows)

    a = ArraySource(rows)
    m = MemmapSource(path)
    assert (a.n_rows, a.n_features) == (m.n_rows, m.n_features)
    pa, pm = a.provider(64, seed=9), m.provider(64, seed=9)
    for cid in (0, 1, 17):
        ca, cm = pa(cid), pm(cid)
        assert ca.shape == cm.shape == (64, 8)
        np.testing.assert_array_equal(ca, cm)
    # same (seed, chunk_id) -> same chunk on refetch
    np.testing.assert_array_equal(pa(0), a.provider(64, seed=9)(0))


def test_provider_and_iterator_sources_round_trip():
    chunks = [np.full((16, 4), float(i), np.float32) for i in range(6)]

    psrc = ProviderSource(lambda cid: chunks[cid])
    assert psrc.n_features == 4              # probed from chunk 0
    isrc = IteratorSource(iter(chunks), n_features=4)
    pf, itf = psrc.provider(16), isrc.provider(16)
    for cid in range(6):
        np.testing.assert_array_equal(pf(cid), itf(cid))
    assert not psrc.in_core
    with pytest.raises(TypeError, match="streaming"):
        psrc.as_array()


def test_as_source_dispatch(tmp_path):
    path = tmp_path / "d.npy"
    np.save(path, np.zeros((10, 3), np.float32))
    assert isinstance(as_source(np.zeros((4, 2))), ArraySource)
    assert isinstance(as_source(X), ArraySource)
    assert isinstance(as_source(str(path)), MemmapSource)
    assert isinstance(as_source(lambda cid: None), ProviderSource)
    assert isinstance(as_source(iter([])), IteratorSource)
    src = ArraySource(np.zeros((4, 2)))
    assert as_source(src) is src
    with pytest.raises(TypeError):
        as_source(object())


def test_in_core_strategy_rejects_stream_source():
    with pytest.raises(TypeError, match="streaming"):
        fit(lambda cid: np.zeros((8, 2), np.float32), CFG,
            method="sequential", n_features=2)


# ---------------------------------------------------------------------------
# strategies: unified contract + equivalence
# ---------------------------------------------------------------------------

def _check_result(r, strategy):
    assert isinstance(r, FitResult)
    assert r.centroids.shape == (5, 8)
    assert np.isfinite(r.objective)
    assert r.strategy == strategy
    assert r.algorithm == "big_means"
    assert r.n_chunks == 8
    assert r.config.k == 5


def test_all_four_strategies_same_signature():
    key = jax.random.PRNGKey(0)
    for strategy in api.list_strategies():
        r = fit(X, CFG, method=strategy, key=key)
        _check_result(r, strategy)


def test_batched_batch1_fp_identical_to_sequential():
    key = jax.random.PRNGKey(7)
    r_seq = fit(X, CFG, method="sequential", key=key)
    r_b1 = fit(X, CFG, method="batched", key=key, batch=1)
    assert float(r_b1.objective) == float(r_seq.objective)
    np.testing.assert_array_equal(np.asarray(r_b1.centroids),
                                  np.asarray(r_seq.centroids))
    assert r_b1.n_accepted == r_seq.n_accepted
    assert r_b1.n_iterations == r_seq.n_iterations
    assert r_b1.n_dist_evals == r_seq.n_dist_evals
    assert [t[:2] for t in r_b1.trace] == [t[:2] for t in r_seq.trace]


def test_auto_strategy_resolution():
    r = fit(X, CFG)
    assert r.extras.get("auto") is True
    assert r.strategy in api.list_strategies()
    # stream-shaped source -> streaming
    assert api.resolve_auto(CFG, as_source(lambda c: None, n_features=8)) \
        == "streaming"
    # runner-only features -> streaming even for in-core data
    assert api.resolve_auto(CFG.replace(time_budget_s=60.0),
                            as_source(X)) == "streaming"
    # batch knob -> batched
    assert api.resolve_auto(CFG.replace(batch=4), as_source(X)) == "batched"


def test_streaming_strategy_from_array_source(tmp_path):
    r = fit(X, CFG, method="streaming",
            ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=4)
    _check_result(r, "streaming")
    assert r.checkpoint_dir is not None
    from repro.cluster import checkpoint
    assert checkpoint.latest_step(r.checkpoint_dir) is not None


def test_fit_registry_is_extensible():
    calls = []

    @api.register_strategy("_test_echo")
    def _echo(cfg, source, key):
        calls.append(cfg.k)
        return FitResult(centroids=np.zeros((cfg.k, source.n_features)),
                         objective=0.0, strategy="_test_echo")

    try:
        r = fit(X, CFG, method="_test_echo")
        assert calls == [5] and r.strategy == "_test_echo"
    finally:
        api.strategies._STRATEGIES.pop("_test_echo")


# ---------------------------------------------------------------------------
# baselines through the same fit()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["forgy", "kmeanspp", "coreset", "da_mssc",
                                  "ward"])
def test_baselines_same_fit_signature(name):
    r = fit(X, CFG, method=name, key=jax.random.PRNGKey(1))
    assert isinstance(r, FitResult)
    assert r.algorithm == name and r.strategy is None
    assert r.centroids.shape == (5, 8)
    assert np.isfinite(r.objective)
    _, f_full = evaluate(r, X)
    assert np.isfinite(f_full)


def test_bigmeans_competitive_with_forgy_via_facade():
    key = jax.random.PRNGKey(2)
    r_bm = fit(X, CFG, key=key)
    r_fg = fit(X, CFG, method="forgy", key=key)
    _, f_bm = evaluate(r_bm, X)
    _, f_fg = evaluate(r_fg, X)
    assert f_bm <= f_fg * 1.5


# ---------------------------------------------------------------------------
# streaming failure hygiene: fetch errors land in the trace
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prefetch", [0, 2])
def test_fetch_failures_recorded_in_trace(prefetch):
    spec = GMMSpec(m=10**5, n=8, components=5, seed=3)

    def provider(cid):
        if cid == 2:
            raise RuntimeError("node lost")
        return np.asarray(gmm_chunk(spec, cid, 256))

    r = fit(provider, BigMeansConfig(k=5, s=256, n_chunks=6, seed=1,
                                     prefetch=prefetch),
            method="streaming", n_features=8)
    assert r.extras["chunks_failed"] == 1
    errors = [t for t in r.trace if t[0] == "fetch_error"]
    assert errors == [("fetch_error", 2, "RuntimeError: node lost")]


def test_iterator_exhaustion_ends_run_cleanly():
    """A finite chunk stream shorter than n_chunks is a clean end-of-stream,
    not a pile of phantom fetch failures."""
    chunks = (np.asarray(gmm_chunk(GMMSpec(m=10**4, n=8, components=5,
                                           seed=4), i, 256))
              for i in range(5))
    r = fit(chunks, BigMeansConfig(k=5, s=256, n_chunks=20, seed=0),
            method="streaming", n_features=8)
    assert r.n_chunks == 5
    assert r.extras["chunks_failed"] == 0
    assert not [t for t in r.trace if t[0] == "fetch_error"]


def test_streaming_honors_with_replacement():
    src = as_source(np.arange(40, dtype=np.float32).reshape(20, 2))
    chunk = src.provider(10, seed=0, with_replacement=False)(0)
    rows = {tuple(row) for row in chunk}
    assert len(rows) == 10                       # all rows distinct
    r = fit(src, BigMeansConfig(k=3, s=10, n_chunks=4, seed=0,
                                with_replacement=False), method="streaming")
    assert np.isfinite(r.objective)


def test_provider_probe_not_refetched():
    calls = []

    def provider(cid):
        calls.append(cid)
        return np.zeros((16, 4), np.float32) + cid

    src = as_source(provider)
    assert src.n_features == 4                   # probes chunk 0
    fetch = src.provider(16)
    np.testing.assert_array_equal(fetch(0), np.zeros((16, 4)))
    fetch(1)
    assert calls == [0, 1]                       # chunk 0 fetched exactly once


def test_auto_never_picks_invalid_sharded(monkeypatch):
    """On a multi-device host whose worker count does not divide n_chunks,
    auto must fall back instead of handing the config to a strategy that
    rejects it."""
    import repro.api.strategies as S

    monkeypatch.setattr(jax, "devices", lambda: [object()] * 3)
    cfg = CFG.replace(n_chunks=100)              # 100 % 3 != 0
    assert S.resolve_auto(cfg, as_source(X)) == "sequential"
    assert S.resolve_auto(cfg.replace(n_chunks=99), as_source(X)) == "sharded"


def test_facade_emits_no_warnings():
    """Documented usage must not trip the deprecation shims."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        fit(X, CFG, method="sequential")
