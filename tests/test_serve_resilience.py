"""repro.serve resilience: typed failure, isolation, supervision.

The contracts under test (this PR's acceptance criteria):

* no request future is ever stranded — a crashed worker fails its pending
  futures with `WorkerCrashed` and restarts (the regression for the
  exception-escaping-`_take_batch` bug that used to kill the worker
  silently while `submit` kept accepting);
* `assign(timeout=)` *cancels* its queued request on timeout — no launch
  slot is burned for a client that gave up, and its latency never enters
  the percentiles;
* a non-finite payload is a typed *client* error at submit time; with
  validation off, bisection isolates the poisoned request at launch time
  and its coalesced neighbors are served bitwise-identically to a
  fault-free run;
* deadlines shed expired requests from a saturated queue before they can
  waste a launch slot, in queue order, with trace events;
* the per-model circuit breaker trips on consecutive launch failures,
  fast-fails while open, probes half-open on a seeded backoff, and closes
  on recovery — observable end-to-end through `Server.health()`;
* per-tenant quotas bound one noisy tenant without starving others;
* transient launch faults recover on the ref fallback path with bitwise
  parity; repeated primary failures demote the bucket;
* a hung checkpoint load stalls one watcher poll (counted, abandoned),
  never the watcher thread.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import checkpoint
from repro.core import bigmeans
from repro.engine import faults
from repro.kernels import ops
from repro.serve import (
    CheckpointWatcher,
    CircuitBreaker,
    DeadlineExceeded,
    InvalidRequest,
    LaunchFault,
    ModelRegistry,
    ModelUnhealthy,
    QuotaExceeded,
    ServeConfig,
    WorkerCrashed,
    serve,
)
from repro.serve.resilience import CLOSED, HALF_OPEN, OPEN


def _centroids(k: int, n: int, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).standard_normal((k, n)).astype(
        np.float32) * 3.0


def _points(m: int, n: int, seed: int) -> np.ndarray:
    return np.random.default_rng(seed).standard_normal((m, n)).astype(
        np.float32)


_jit_ref = jax.jit(lambda q, c: ops.assign(q, c, impl="ref"))


def _oracle(points: np.ndarray, centroids: np.ndarray):
    ids, d = _jit_ref(jnp.asarray(points), jnp.asarray(centroids))
    return np.asarray(ids), np.asarray(d)


def _quick_cfg(**overrides) -> ServeConfig:
    base = dict(min_bucket=8, max_batch=64, max_linger_ms=2.0,
                queue_depth=64)
    base.update(overrides)
    return ServeConfig(**base)


def _gate_launch(entry):
    """Block the worker's launches on an Event (release with .set())."""
    gate = threading.Event()
    original = entry.launch

    def gated(q, snap):
        gate.wait(10.0)
        return original(q, snap)

    entry.launch = gated
    return gate


def _drain(batcher, timeout=5.0):
    t0 = time.monotonic()
    while batcher.queue_depth() and time.monotonic() - t0 < timeout:
        time.sleep(0.005)


# ---------------------------------------------------------------------------
# supervision: no stranded futures, ever


def test_worker_crash_fails_pending_futures_and_restarts():
    # The regression this PR exists for: before supervision, an exception
    # escaping the take/launch loop killed the worker thread silently —
    # every pending future hung forever while submit() kept accepting.
    C = _centroids(6, 4)
    with serve({"m": C}, _quick_cfg()) as srv:
        batcher = srv._batchers["m"]
        original = batcher._launch_batch

        def boom(batch):
            batcher._launch_batch = original       # crash exactly once
            raise RuntimeError("injected worker crash")

        batcher._launch_batch = boom
        fut = srv.submit("m", _points(3, 4, seed=1))
        with pytest.raises(WorkerCrashed):
            fut.result(timeout=5.0)
        # The supervisor restarted the loop: same worker thread, serving.
        resp = srv.assign("m", _points(5, 4, seed=2), timeout=5.0)
        ids, _ = _oracle(_points(5, 4, seed=2), C)
        assert np.array_equal(resp.ids, ids)
        assert batcher.worker_alive()
        assert batcher.stats.worker_restarts == 1
        assert any(e[0] == "worker_restart" and e[1] == "m"
                   for e in srv.trace)
        health = srv.health()
        assert health["models"]["m"]["worker_restarts"] == 1


def test_close_after_crash_still_clean():
    C = _centroids(4, 3)
    srv = serve({"m": C}, _quick_cfg())
    batcher = srv._batchers["m"]
    batcher._launch_batch = lambda batch: (_ for _ in ()).throw(
        RuntimeError("always crash"))
    with pytest.raises(WorkerCrashed):
        srv.submit("m", _points(2, 3, seed=0)).result(timeout=5.0)
    srv.close()
    assert not batcher.worker_alive()


# ---------------------------------------------------------------------------
# assign(timeout=): cancel, don't strand


def test_assign_timeout_cancels_queued_request():
    C = _centroids(5, 4)
    with serve({"m": C}, _quick_cfg()) as srv:
        entry = srv.registry.get("m")
        batcher = srv._batchers["m"]
        gate = _gate_launch(entry)
        blocker = srv.submit("m", _points(2, 4, seed=0))
        time.sleep(0.05)                          # worker now inside launch
        with pytest.raises(DeadlineExceeded):
            srv.assign("m", _points(2, 4, seed=1), timeout=0.05)
        # The timed-out request was withdrawn from the queue: nothing
        # pending but the blocker, and the cancellation was counted.
        assert batcher.queue_depth() == 0
        assert batcher.stats.n_cancelled == 1
        gate.set()
        blocker.result(timeout=5.0)
        _drain(batcher)
        # Cancelled requests never enter the latency percentiles.
        assert len(batcher.stats.latencies_ms) == 1


def test_cancelled_request_burns_no_launch(monkeypatch):
    C = _centroids(5, 4)
    with serve({"m": C}, _quick_cfg()) as srv:
        entry = srv.registry.get("m")
        gate = _gate_launch(entry)
        blocker = srv.submit("m", _points(2, 4, seed=0))
        time.sleep(0.05)
        fut = srv.submit("m", _points(2, 4, seed=1))
        assert srv._batchers["m"].cancel(fut)
        launches = []
        original = entry.launch

        def counting(q, snap):
            launches.append(int(q.shape[0]))
            return original(q, snap)

        entry.launch = counting
        gate.set()
        blocker.result(timeout=5.0)
        assert fut.cancelled()
        # Only the blocker launched; the cancelled request never did.
        assert len(launches) <= 1


# ---------------------------------------------------------------------------
# admission validation


def test_non_finite_request_rejected_at_submit():
    C = _centroids(4, 3)
    with serve({"m": C}, _quick_cfg()) as srv:
        bad = _points(4, 3, seed=0)
        bad[2, 1] = np.nan
        with pytest.raises(InvalidRequest):
            srv.submit("m", bad)
        inf = _points(4, 3, seed=1)
        inf[0, 0] = np.inf
        with pytest.raises(InvalidRequest):
            srv.assign("m", inf)
        assert srv.stats("m")["n_invalid"] == 2
        # Trusted-client override: admitted (the ref path tolerates NaN).
        resp = srv.assign("m", bad, validate=False, timeout=5.0)
        assert resp.ids.shape == (4,)


def test_deadline_must_be_positive():
    C = _centroids(4, 3)
    with serve({"m": C}, _quick_cfg()) as srv:
        with pytest.raises(ValueError):
            srv.submit("m", _points(2, 3, seed=0), deadline_ms=0)


# ---------------------------------------------------------------------------
# deadline shedding


def test_deadlines_shed_expired_requests_under_saturation():
    C = _centroids(5, 4)
    with serve({"m": C}, _quick_cfg()) as srv:
        entry = srv.registry.get("m")
        batcher = srv._batchers["m"]
        gate = _gate_launch(entry)
        blocker = srv.submit("m", _points(2, 4, seed=0))
        time.sleep(0.05)
        # Saturated queue: one request with a deadline that will expire
        # while blocked, one without any deadline.
        doomed = srv.submit("m", _points(2, 4, seed=1), deadline_ms=40.0)
        healthy = srv.submit("m", _points(2, 4, seed=2))
        time.sleep(0.12)                          # doomed is now expired
        gate.set()
        blocker.result(timeout=5.0)
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=5.0)
        resp = healthy.result(timeout=5.0)
        ids, _ = _oracle(_points(2, 4, seed=2), C)
        assert np.array_equal(resp.ids, ids)
        assert batcher.stats.n_deadline_shed == 1
        shed = [e for e in srv.trace if e[0] == "deadline_shed"]
        assert len(shed) == 1 and shed[0][1] == "m" and shed[0][2] > 0


def test_default_deadline_from_config():
    C = _centroids(5, 4)
    with serve({"m": C}, _quick_cfg(default_deadline_ms=40.0)) as srv:
        entry = srv.registry.get("m")
        gate = _gate_launch(entry)
        blocker = srv.submit("m", _points(2, 4, seed=0))
        time.sleep(0.05)
        doomed = srv.submit("m", _points(2, 4, seed=1))  # inherits 40ms
        time.sleep(0.12)
        gate.set()
        blocker.result(timeout=5.0)
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=5.0)


# ---------------------------------------------------------------------------
# per-tenant quotas


def test_tenant_quota_bounds_one_tenant_not_others():
    C = _centroids(4, 3)
    with serve({"m": C}, _quick_cfg(tenant_quota=2)) as srv:
        entry = srv.registry.get("m")
        gate = _gate_launch(entry)
        blocker = srv.submit("m", _points(2, 3, seed=0), tenant="noisy")
        time.sleep(0.05)
        # The blocker is in flight (not queued): tenant "noisy" may queue
        # two more, then hits its quota while "quiet" still admits.
        futs = [srv.submit("m", _points(2, 3, seed=i), tenant="noisy")
                for i in (1, 2)]
        with pytest.raises(QuotaExceeded):
            srv.submit("m", _points(2, 3, seed=3), tenant="noisy")
        quiet = srv.submit("m", _points(2, 3, seed=4), tenant="quiet")
        gate.set()
        for f in [blocker, quiet] + futs:
            f.result(timeout=5.0)
        assert srv.stats("m")["n_quota_rejected"] == 1
        # Quota freed after the queue drained: the tenant admits again.
        srv.assign("m", _points(2, 3, seed=5), tenant="noisy", timeout=5.0)


# ---------------------------------------------------------------------------
# circuit breaker


def test_breaker_state_machine_with_fake_clock():
    t = [0.0]
    events = []
    br = CircuitBreaker("m", threshold=3, backoff_s=1.0, backoff_max_s=8.0,
                        seed=7, clock=lambda: t[0], on_event=events.append)
    assert br.allow() and br.state == CLOSED
    br.record_failure("f1")
    br.record_failure("f2")
    assert br.allow()                             # still under threshold
    br.record_failure("f3")
    assert br.state == OPEN and not br.allow()
    assert 0.0 < br.retry_in_s() <= 1.0           # jittered in (0.5, 1.0]
    # Backoff expires: exactly one caller becomes the half-open probe.
    t[0] = 1.0
    assert br.allow() and br.state == HALF_OPEN
    assert not br.allow()                         # probe already in flight
    # Probe fails: re-open with doubled backoff.
    br.record_failure("probe failed")
    assert br.state == OPEN and br.trips == 2
    assert br.retry_in_s() <= 2.0
    t[0] = 3.0
    assert br.allow()
    br.record_success()
    assert br.state == CLOSED and br.failures == 0
    kinds = [e[0] for e in events]
    assert kinds == ["breaker_open", "breaker_probe", "breaker_open",
                     "breaker_probe", "breaker_close"]
    # Determinism: two identically seeded breakers probe at the same offsets.
    def trip_once(seed):
        b = CircuitBreaker("m", threshold=3, backoff_s=1.0,
                           backoff_max_s=8.0, seed=seed, clock=lambda: 0.0)
        for _ in range(3):
            b.record_failure()
        return b.retry_in_s()

    assert trip_once(7) == trip_once(7)
    assert trip_once(7) != trip_once(8)


def test_breaker_trips_fast_fails_and_recovers_end_to_end():
    C = _centroids(5, 4)
    cfg = _quick_cfg(breaker_threshold=3, breaker_backoff_s=0.05,
                     breaker_backoff_max_s=0.05, launch_retries=0)
    with serve({"m": C}, cfg) as srv:
        entry = srv.registry.get("m")
        original = entry.launch

        def dead(q, snap):
            raise faults.PermanentFault("injected model outage")

        entry.launch = dead
        entry.launch_fallback = dead
        for i in range(3):
            with pytest.raises(LaunchFault):
                srv.assign("m", _points(2, 4, seed=i), timeout=5.0)
        # Breaker is open: requests fast-fail without touching the queue.
        with pytest.raises(ModelUnhealthy) as exc_info:
            srv.submit("m", _points(2, 4, seed=9))
        assert exc_info.value.retry_in_s > 0
        health = srv.health()
        assert health["models"]["m"]["breaker"]["state"] == OPEN
        assert not health["ok"]
        # Model heals; the half-open probe succeeds and closes the breaker.
        entry.launch = original
        del entry.launch_fallback                 # restore class method
        time.sleep(0.08)
        resp = srv.assign("m", _points(3, 4, seed=10), timeout=5.0)
        ids, _ = _oracle(_points(3, 4, seed=10), C)
        assert np.array_equal(resp.ids, ids)
        health = srv.health()
        assert health["models"]["m"]["breaker"]["state"] == CLOSED
        assert health["ok"]
        kinds = [e[0] for e in srv.trace]
        assert "breaker_open" in kinds and "breaker_probe" in kinds \
            and "breaker_close" in kinds
        assert srv.stats("m")["n_breaker_rejected"] == 1


# ---------------------------------------------------------------------------
# fault-isolated launches


def test_bisection_isolates_poisoned_request_bitwise():
    C = _centroids(6, 4)
    # Generous linger so all requests coalesce into one launch behind the
    # blocked worker; the injected launch wrapper fails any payload that
    # carries non-finite values (a kernel choking on a poisoned request).
    cfg = _quick_cfg(max_linger_ms=100.0, launch_retries=0)
    with serve({"m": C}, cfg) as srv:
        entry = srv.registry.get("m")
        plan = faults.FaultPlan(seed=3)
        entry.launch = plan.wrap_launch(entry.launch)
        gate = _gate_launch(entry)                # gates the wrapped launch
        blocker = srv.submit("m", _points(2, 4, seed=0))
        time.sleep(0.05)
        healthy_pts = [_points(3, 4, seed=10 + i) for i in range(4)]
        poison = _points(3, 4, seed=99)
        poison[1, 2] = np.nan
        futs = [srv.submit("m", p) for p in healthy_pts[:2]]
        poisoned = srv.submit("m", poison, validate=False)
        futs += [srv.submit("m", p) for p in healthy_pts[2:]]
        gate.set()
        blocker.result(timeout=5.0)
        # Only the poisoned request fails, and with the typed exception.
        with pytest.raises(LaunchFault):
            poisoned.result(timeout=10.0)
        for pts, fut in zip(healthy_pts, futs):
            resp = fut.result(timeout=10.0)
            ids, dists = _oracle(pts, C)
            assert np.array_equal(resp.ids, ids)
            assert np.array_equal(resp.dists, dists)
        assert srv.stats("m")["n_failed"] == 1
        assert any(e[0] == "launch_fault" for e in srv.trace)
        # One poisoned request among healthy traffic must not trip the
        # breaker: healthy sub-launches reset the consecutive count.
        assert srv.health()["models"]["m"]["breaker"]["state"] == CLOSED


def test_transient_launch_faults_recover_on_ref_path_bitwise():
    C = _centroids(5, 4)
    with serve({"m": C}, _quick_cfg(launch_retries=1, demote_after=0)) as srv:
        entry = srv.registry.get("m")
        # Every primary launch fails transiently; the ref fallback serves.
        plan = faults.FaultPlan(seed=0, launch_transient_rate=1.0)
        entry.launch = plan.wrap_launch(entry.launch)
        for i in range(4):
            pts = _points(6, 4, seed=i)
            resp = srv.assign("m", pts, timeout=5.0)
            ids, dists = _oracle(pts, C)
            assert np.array_equal(resp.ids, ids)
            assert np.array_equal(resp.dists, dists)
        stats = srv.stats("m")
        assert stats["n_ref_retries"] == 4
        assert stats["n_failed"] == 0
        assert srv.health()["models"]["m"]["breaker"]["state"] == CLOSED


def test_repeated_primary_failures_demote_bucket():
    C = _centroids(5, 4)
    cfg = _quick_cfg(launch_retries=1, demote_after=2)
    with serve({"m": C}, cfg) as srv:
        entry = srv.registry.get("m")
        plan = faults.FaultPlan(seed=0, launch_transient_rate=1.0)
        entry.launch = plan.wrap_launch(entry.launch)
        for i in range(3):
            srv.assign("m", _points(6, 4, seed=i), timeout=5.0)
        # After demote_after consecutive primary failures at the 8-bucket,
        # the batcher pinned it to the ref path...
        assert entry.demoted_buckets == (8,)
        assert srv.health()["models"]["m"]["demoted_buckets"] == [8]
        # ...so later launches at that bucket bypass the failing primary
        # entirely: the wrapped launch is not called again.
        calls_before = entry.launch.calls["n"]
        resp = srv.assign("m", _points(6, 4, seed=9), timeout=5.0)
        ids, _ = _oracle(_points(6, 4, seed=9), C)
        assert np.array_equal(resp.ids, ids)
        assert entry.launch.calls["n"] == calls_before


# ---------------------------------------------------------------------------
# watcher supervision


def _save_engine_ckpt(directory: str, step: int, centroids: np.ndarray):
    k, n = centroids.shape
    state = bigmeans.init_state(k, n)._replace(
        centroids=jnp.asarray(centroids),
        f_best=jnp.float32(1.0))
    aux = np.asarray([0, 0, 0], dtype=np.int64)
    checkpoint.save(directory, step, ((state, jnp.zeros(2, jnp.uint32)), aux))


def test_watcher_survives_poll_exceptions(monkeypatch):
    registry = ModelRegistry()
    C = _centroids(4, 3)
    registry.register("m", C)
    w = CheckpointWatcher(registry, "m", "/nonexistent",
                          poll_interval_s=0.01, poll_timeout_s=None)

    def explode(_):
        raise OSError("injected scan failure")

    monkeypatch.setattr(checkpoint, "latest_intact_step", explode)
    w.start()
    time.sleep(0.1)
    assert w.alive()                              # the scan error didn't
    assert w.n_errors > 0                         # kill the thread
    assert "injected scan failure" in w.last_error
    w.stop()
    d = w.describe()
    assert d["n_errors"] == w.n_errors and d["model_id"] == "m"


def test_watcher_watchdog_abandons_hung_poll(tmp_path):
    d = str(tmp_path / "ckpt")
    C = _centroids(4, 3)
    C2 = _centroids(4, 3, seed=1)
    _save_engine_ckpt(d, 1, C)
    registry = ModelRegistry()
    registry.register("m", C)
    w = CheckpointWatcher(registry, "m", d, poll_interval_s=0.02,
                          poll_timeout_s=0.1)
    with faults.hung_restore():                   # loads hang until exit
        w.start()
        _save_engine_ckpt(d, 2, C2)               # a new step appears...
        t0 = time.monotonic()
        while w.stalled_polls == 0 and time.monotonic() - t0 < 5.0:
            time.sleep(0.01)
        # ...but its load hangs: the watchdog abandoned the poll instead
        # of freezing the watcher thread, and no swap happened.
        assert w.stalled_polls >= 1
        assert w.alive()
        assert w.n_swaps == 0
        assert "stalled" in w.last_error
        assert any(e[0] == "watcher_stall" for e in registry.trace)
    # Filesystem recovers: the abandoned poll completes (possibly at the
    # older step it had already chosen) and a fresh poll converges the
    # watcher forward to the newest intact step.
    t0 = time.monotonic()
    while w.last_step != 2 and time.monotonic() - t0 < 5.0:
        time.sleep(0.02)
    assert w.n_swaps >= 1 and w.last_step == 2
    assert np.array_equal(
        np.asarray(registry.get("m").snapshot().centroids), C2)
    w.stop()


# ---------------------------------------------------------------------------
# health aggregation


def test_health_shape_and_ok():
    C = _centroids(4, 3)
    with serve({"a": C, "b": _centroids(5, 3, seed=2)},
               _quick_cfg()) as srv:
        srv.assign("a", _points(3, 3, seed=0), timeout=5.0)
        health = srv.health()
        assert health["ok"] is True
        assert set(health["models"]) == {"a", "b"}
        m = health["models"]["a"]
        assert m["queue_depth"] == 0
        assert m["worker_alive"] is True
        assert m["worker_restarts"] == 0
        assert m["breaker"]["state"] == CLOSED
        assert m["demoted_buckets"] == []
        assert m["last_swap_age_s"] >= 0
        assert health["watchers"] == []
        # health() is JSON-serializable (the ops endpoint contract).
        import json

        json.dumps(health)
