"""repro.serve: batching frontend, multi-model tenancy, hot-swap.

The contracts under test (the serving subsystem's acceptance criteria):

* coalescing is invisible — a request's results are bitwise-identical
  whether it rode a coalesced launch or its own, across bucket boundaries;
* after bucket warmup the jitted serving call never recompiles, whatever
  request sizes traffic throws at it (exact trace counter);
* hot-swap under concurrent traffic loses no request and never mixes old
  and new centroids within one response;
* tenants are isolated: two resident models serve concurrently, each
  bitwise-correct against its own centroids, with separate accounting;
* a full queue rejects loudly and immediately (never a hang), and the
  rejected client can retry once the queue drains;
* serving-shaped Pallas failures demote per-shape at warmup through
  `ops.warm_assign` — the request path then runs the ref fallback.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import checkpoint
from repro.core import bigmeans
from repro.engine import faults
from repro.kernels import ops
from repro.serve import (
    CheckpointWatcher,
    ModelRegistry,
    QueueFull,
    ServeConfig,
    Server,
    ServerClosed,
    load_centroids,
    serve,
    swap_from_checkpoint,
)

RNG = np.random.default_rng(7)


def _centroids(k: int, n: int, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).standard_normal((k, n)).astype(
        np.float32) * 3.0


def _points(m: int, n: int, seed: int) -> np.ndarray:
    return np.random.default_rng(seed).standard_normal((m, n)).astype(
        np.float32)


# The serving path always runs under jit; XLA fuses the distance expression
# differently eager vs jitted (1-ULP dist differences), so the bitwise
# oracle must be jitted too.  Padding/bucket row-independence is what the
# tests then actually measure: the oracle runs at the request's own shape,
# serving runs at the padded bucket shape.
_jit_ref = jax.jit(lambda q, c: ops.assign(q, c, impl="ref"))


def _oracle(points: np.ndarray, centroids: np.ndarray):
    ids, d = _jit_ref(jnp.asarray(points), jnp.asarray(centroids))
    return np.asarray(ids), np.asarray(d)


def _quick_cfg(**overrides) -> ServeConfig:
    base = dict(min_bucket=8, max_batch=64, max_linger_ms=2.0,
                queue_depth=64)
    base.update(overrides)
    return ServeConfig(**base)


# ---------------------------------------------------------------------------
# config contract


def test_config_validation():
    assert ServeConfig().buckets()[-1] == 4096
    assert ServeConfig(min_bucket=8, max_batch=64).buckets() == (8, 16, 32, 64)
    # non-power-of-two knobs round up, bucket chain stays power-of-two
    assert ServeConfig(min_bucket=6, max_batch=48).buckets() == (8, 16, 32, 64)
    with pytest.raises(ValueError):
        ServeConfig(max_batch=0)
    with pytest.raises(ValueError):
        ServeConfig(min_bucket=128, max_batch=64)
    with pytest.raises(ValueError):
        ServeConfig(max_linger_ms=-1.0)
    with pytest.raises(ValueError):
        ServeConfig(impl="nope")
    with pytest.raises(ValueError):
        ServeConfig(precision="f64")
    with pytest.raises(ValueError):
        ServeConfig(donate="maybe")


def test_submit_validation():
    C = _centroids(5, 4)
    with serve({"m": C}, _quick_cfg()) as srv:
        with pytest.raises(ValueError):          # wrong feature count
            srv.assign("m", _points(3, 7, 0))
        with pytest.raises(ValueError):          # oversized request
            srv.assign("m", _points(65, 4, 0))
        with pytest.raises(ValueError):          # empty request
            srv.assign("m", np.zeros((0, 4), np.float32))
        with pytest.raises(KeyError):
            srv.assign("ghost", _points(3, 4, 0))
        # a 1-D query is promoted to one row
        resp = srv.assign("m", _points(1, 4, 0)[0])
        assert resp.ids.shape == (1,)


# ---------------------------------------------------------------------------
# coalescing correctness


def test_coalesced_bitwise_equal_per_request_across_buckets():
    """Concurrent (coalesced) and serial (one-per-launch) serving return
    bitwise-identical ids AND distances, for request sizes straddling
    every bucket boundary."""
    C = _centroids(10, 12)
    sizes = [3, 8, 9, 16, 5, 1, 31, 64]          # crosses 8/16/32/64
    reqs = [_points(m, 12, seed=100 + i) for i, m in enumerate(sizes)]

    # serial: linger 0 and one request in flight at a time
    with serve({"m": C}, _quick_cfg(max_linger_ms=0.0)) as srv:
        serial = [srv.assign("m", p) for p in reqs]
    assert all(r.n_coalesced == 1 for r in serial)

    # concurrent: long linger, submit everything before reading results
    with serve({"m": C}, _quick_cfg(max_linger_ms=100.0)) as srv:
        futures = [srv.submit("m", p) for p in reqs]
        coalesced = [f.result(timeout=30) for f in futures]
    assert any(r.n_coalesced > 1 for r in coalesced), \
        "expected at least one coalesced launch"

    for p, rs, rc in zip(reqs, serial, coalesced):
        oid, od = _oracle(p, C)
        for r in (rs, rc):
            assert np.array_equal(r.ids, oid)
            assert np.array_equal(r.dists, od)
        assert np.array_equal(rs.ids, rc.ids)
        assert np.array_equal(rs.dists, rc.dists)


def test_requests_never_split_across_launches():
    """A request's rows always come from exactly one launch (and one
    snapshot): coalescing stops before max_batch would be exceeded."""
    C = _centroids(6, 4)
    with serve({"m": C}, _quick_cfg(max_batch=32, max_linger_ms=100.0)) as srv:
        futures = [srv.submit("m", _points(20, 4, seed=i)) for i in range(3)]
        resps = [f.result(timeout=30) for f in futures]
    for r in resps:
        assert r.batch_rows <= 32
    # 20 + 20 > 32: no launch carried more than one of these requests
    assert all(r.n_coalesced == 1 for r in resps)


# ---------------------------------------------------------------------------
# recompile counter


def test_zero_recompiles_after_bucket_warmup():
    C = _centroids(10, 12)
    cfg = _quick_cfg()
    with serve({"m": C}, cfg) as srv:
        warm = srv.recompiles("m")
        assert warm == len(cfg.buckets())        # one trace per bucket
        # traffic at many distinct request sizes, serial and concurrent
        for i, m in enumerate([1, 2, 3, 5, 7, 8, 9, 15, 33, 64, 40, 12]):
            srv.assign("m", _points(m, 12, seed=i))
        futures = [srv.submit("m", _points(m, 12, seed=50 + m))
                   for m in (4, 6, 10, 14, 22)]
        for f in futures:
            f.result(timeout=30)
        assert srv.recompiles("m") == warm, \
            "serving recompiled after bucket warmup"


# ---------------------------------------------------------------------------
# hot-swap


def test_hot_swap_under_concurrent_traffic():
    """Swap mid-traffic: every request completes, each response is
    bitwise-consistent with exactly one centroid generation, and both
    generations are observed."""
    k, n = 8, 6
    C0 = _centroids(k, n, seed=1)
    perm = np.roll(np.arange(k), 1)
    C1 = C0[perm]                                # every id changes
    gens = [C0, C1]

    results: list = []
    errors: list = []
    lock = threading.Lock()

    with serve({"m": C0}, _quick_cfg(max_linger_ms=1.0,
                                     queue_depth=512)) as srv:
        stop = threading.Event()

        def client(cid: int):
            i = 0
            while not stop.is_set():
                p = _points(5 + (i % 11), n, seed=cid * 1000 + i)
                try:
                    r = srv.submit("m", p).result(timeout=30)
                except Exception as exc:          # pragma: no cover
                    with lock:
                        errors.append(exc)
                    return
                with lock:
                    results.append((p, r))
                i += 1

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(4)]
        for t in threads:
            t.start()
        # let generation-0 traffic flow, then swap under load
        while True:
            with lock:
                if len(results) >= 20:
                    break
            time.sleep(0.005)
        srv.swap("m", C1, step=123)
        n_at_swap = len(results)
        while True:
            with lock:
                if len(results) >= n_at_swap + 20:
                    break
            time.sleep(0.005)
        stop.set()
        for t in threads:
            t.join(timeout=30)

        assert not errors
        assert ("swap", "m", 123) in srv.trace

    versions = {r.version for _, r in results}
    assert versions == {0, 1}, f"expected both generations, saw {versions}"
    for p, r in results:
        oid, od = _oracle(p, gens[r.version])
        assert np.array_equal(r.ids, oid), \
            "response mixed centroid generations"
        assert np.array_equal(r.dists, od)


def test_swap_shape_mismatch_rejected():
    C = _centroids(5, 4)
    with serve({"m": C}, _quick_cfg()) as srv:
        with pytest.raises(ValueError):
            srv.swap("m", _centroids(6, 4))
        with pytest.raises(ValueError):
            srv.swap("m", np.full((5, 4), np.nan, np.float32))
        assert srv.stats("m")["version"] == 0    # nothing swapped


def test_swap_does_not_recompile():
    C = _centroids(5, 4)
    with serve({"m": C}, _quick_cfg()) as srv:
        warm = srv.recompiles("m")
        srv.assign("m", _points(3, 4, 0))
        for i in range(3):
            srv.swap("m", _centroids(5, 4, seed=i + 10))
            srv.assign("m", _points(3, 4, seed=i))
        assert srv.recompiles("m") == warm


# ---------------------------------------------------------------------------
# tenancy


def test_multi_model_tenancy_isolation():
    """Two resident (k, n) models serve interleaved concurrent traffic;
    each response is bitwise-correct for its own model and the per-model
    accounting never bleeds across tenants."""
    Ca = _centroids(7, 5, seed=1)
    Cb = _centroids(13, 5, seed=2)
    with serve({"a": Ca, "b": Cb}, _quick_cfg(max_linger_ms=1.0)) as srv:
        futures = []
        for i in range(30):
            mid = "a" if i % 2 == 0 else "b"
            p = _points(4 + (i % 9), 5, seed=i)
            futures.append((mid, p, srv.submit(mid, p)))
        for mid, p, f in futures:
            r = f.result(timeout=30)
            assert r.model_id == mid
            oid, od = _oracle(p, Ca if mid == "a" else Cb)
            assert np.array_equal(r.ids, oid)
            assert np.array_equal(r.dists, od)
            assert r.ids.max() < (7 if mid == "a" else 13)
        stats = srv.stats()
        assert stats["a"]["n_requests"] == 15
        assert stats["b"]["n_requests"] == 15
        assert stats["a"]["k"] == 7 and stats["b"]["k"] == 13
        # swapping one tenant leaves the other untouched
        srv.swap("a", _centroids(7, 5, seed=9))
        assert srv.stats("a")["version"] == 1
        assert srv.stats("b")["version"] == 0


# ---------------------------------------------------------------------------
# admission control


def test_queue_full_rejects_immediately_not_a_hang():
    C = _centroids(5, 4)
    cfg = _quick_cfg(queue_depth=4, max_linger_ms=0.0)
    with serve({"m": C}, cfg) as srv:
        entry = srv.registry.get("m")
        in_launch = threading.Event()
        release = threading.Event()
        orig = entry.launch

        def slow_launch(q, snap):
            in_launch.set()
            release.wait(timeout=30)
            return orig(q, snap)

        entry.launch = slow_launch
        try:
            # occupy the worker, then fill the queue to queue_depth
            first = srv.submit("m", _points(2, 4, 0))
            assert in_launch.wait(timeout=10)
            queued = [srv.submit("m", _points(2, 4, i + 1)) for i in range(4)]
            t0 = time.monotonic()
            with pytest.raises(QueueFull):
                srv.submit("m", _points(2, 4, 99))
            assert time.monotonic() - t0 < 1.0, "rejection must not block"
            assert srv.stats("m")["n_rejected"] == 1
        finally:
            release.set()
            entry.launch = orig
        # the queue drains and the rejected client can retry successfully
        for f in [first] + queued:
            f.result(timeout=30)
        retry = srv.assign("m", _points(2, 4, 99))
        oid, _ = _oracle(_points(2, 4, 99), C)
        assert np.array_equal(retry.ids, oid)


def test_closed_server_rejects_and_drains():
    C = _centroids(5, 4)
    srv = serve({"m": C}, _quick_cfg())
    f = srv.submit("m", _points(3, 4, 0))
    srv.close()                                   # drains pending work
    assert f.result(timeout=30).ids.shape == (3,)
    with pytest.raises(ServerClosed):
        srv.submit("m", _points(3, 4, 1))


# ---------------------------------------------------------------------------
# checkpoint hot-swap + watcher


def _save_engine_ckpt(directory: str, step: int, centroids: np.ndarray):
    """Write a checkpoint in the engine's ((state, key), aux) layout."""
    k, n = centroids.shape
    state = bigmeans.init_state(k, n)._replace(
        centroids=jnp.asarray(centroids),
        f_best=jnp.float32(1.0))
    aux = np.asarray([0, 0, 0], dtype=np.int64)
    checkpoint.save(directory, step, ((state, jnp.zeros(2, jnp.uint32)), aux))


def test_load_centroids_verified_and_batched(tmp_path):
    d = str(tmp_path / "ckpt")
    C5 = _centroids(4, 3, seed=5)
    _save_engine_ckpt(d, 5, C5)
    got, step = load_centroids(d)
    assert step == 5 and np.array_equal(got, C5)

    # newest step torn -> verified load falls back to the intact one
    C9 = _centroids(4, 3, seed=9)
    _save_engine_ckpt(d, 9, C9)
    bad = tmp_path / "ckpt" / "step_000000000009" / "arrays.npz"
    bad.write_bytes(bad.read_bytes()[:64])
    got, step = load_centroids(d)
    assert step == 5 and np.array_equal(got, C5)

    # batched state: the best finite f_best stream is served
    B, k, n = 3, 4, 3
    Cs = np.stack([_centroids(k, n, seed=20 + b) for b in range(B)])
    state = bigmeans.init_state(k, n)._replace(
        centroids=jnp.asarray(Cs),
        f_best=jnp.asarray([np.inf, 2.0, 5.0], np.float32))
    aux = np.asarray([0, 0, 0], dtype=np.int64)
    d2 = str(tmp_path / "ckpt_b")
    checkpoint.save(d2, 1, ((state, jnp.zeros(2, jnp.uint32)), aux))
    got, _ = load_centroids(d2)
    assert np.array_equal(got, Cs[1])


def test_swap_from_checkpoint_records_step(tmp_path):
    d = str(tmp_path / "ckpt")
    C = _centroids(6, 4, seed=3)
    _save_engine_ckpt(d, 7, C)
    reg = ModelRegistry()
    reg.register("m", _centroids(6, 4, seed=0))
    snap = swap_from_checkpoint(reg, "m", d)
    assert snap.step == 7 and snap.version == 1
    assert ("swap", "m", 7) in reg.trace
    assert np.array_equal(np.asarray(snap.centroids), C)


def test_checkpoint_watcher_swaps_under_traffic(tmp_path):
    d = str(tmp_path / "ckpt")
    C0 = _centroids(5, 4, seed=0)
    C1 = _centroids(5, 4, seed=1)
    _save_engine_ckpt(d, 1, C0)
    with serve({"m": C0}, _quick_cfg()) as srv:
        watcher = srv.watch("m", d, poll_interval_s=0.02)
        time.sleep(0.1)
        assert watcher.n_swaps <= 1               # step 1 may apply once
        base = srv.stats("m")["version"]
        _save_engine_ckpt(d, 2, C1)               # "training" publishes
        deadline = time.monotonic() + 10
        while srv.stats("m")["version"] == base:
            srv.assign("m", _points(3, 4, 0))     # traffic keeps flowing
            if time.monotonic() > deadline:
                pytest.fail("watcher never swapped the new checkpoint")
            time.sleep(0.02)
        assert watcher.last_step == 2
        r = srv.assign("m", _points(3, 4, 1))
        oid, _ = _oracle(_points(3, 4, 1), C1)
        assert np.array_equal(r.ids, oid)


# ---------------------------------------------------------------------------
# kernel dispatch: serving shapes consult autotune + demotion (satellite)


@pytest.fixture
def clean_demotions():
    ops.reset_kernel_demotions()
    yield ops
    ops.reset_kernel_demotions()


def test_warm_assign_demotes_serving_shape(clean_demotions):
    """A Pallas failure at a serving shape (small m, large k) demotes that
    exact shape during warmup — the same pre-tune path fused_step gets
    from fit() — and returns the fallback impl."""
    with faults.kernel_failure("assign"):
        got = ops.warm_assign(32, 256, 16, impl="pallas_interpret")
    assert got == "ref"
    demos = ops.kernel_demotions()
    assert [d for d in demos
            if d["op"] == "assign" and d["shape"] == (1, 32, 256, 16)]
    # the demoted shape now serves through the ref path, correctly
    x = _points(32, 16, seed=0)
    c = _centroids(256, 16, seed=1)
    ids, d = ops.assign(jnp.asarray(x), jnp.asarray(c),
                        impl="pallas_interpret")
    oid, od = _oracle(x, c)
    assert np.array_equal(np.asarray(ids), oid)


def test_warm_assign_healthy_path(clean_demotions):
    assert ops.warm_assign(16, 8, 4, impl="ref") == "ref"
    assert ops.warm_assign(16, 8, 4, impl="pallas_interpret") == \
        "pallas_interpret"
    assert not ops.kernel_demotions()


def test_server_warmup_demotes_failing_pallas_end_to_end(clean_demotions):
    """Register under an injected Pallas failure: warmup demotes every
    bucket shape, and traffic then serves bitwise-correct ref results."""
    C = _centroids(10, 12)
    cfg = _quick_cfg(impl="pallas_interpret")
    with faults.kernel_failure("assign"):
        srv = serve({"m": C}, cfg)
    try:
        shapes = {d["shape"] for d in ops.kernel_demotions()
                  if d["op"] == "assign"}
        assert {(1, b, 10, 12) for b in cfg.buckets()} <= shapes
        p = _points(9, 12, seed=4)
        r = srv.assign("m", p)
        oid, od = _oracle(p, C)
        assert np.array_equal(r.ids, oid)
        assert np.array_equal(r.dists, od)
    finally:
        srv.close()
