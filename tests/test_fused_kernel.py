"""Fused Lloyd-step kernel vs the two-pass oracle (shape/dtype sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.fused_step import fits, fused_step_pallas

SHAPES = [
    (100, 7, 3),
    (300, 28, 25),       # HEPMASS-like paper regime
    (512, 768, 25),      # CORD-19-like
    (1000, 68, 100),
    (257, 1024, 128),    # envelope edges
]


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_matches_two_pass(m, n, k, dtype):
    kx, kc = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (m, n), jnp.float32).astype(dtype)
    c = jax.random.normal(kc, (k, n), jnp.float32)
    assert fits(k, n)
    sums_p, counts_p, obj_p = fused_step_pallas(x, c, interpret=True)

    # the kernel upcasts to fp32 before the distance matmul; give the oracle
    # the same view so near-tie assignments agree
    x = x.astype(jnp.float32)
    ids, d = ops.assign(x, c, impl="ref")
    sums_r, counts_r = ops.update(x, ids, k, impl="ref")
    obj_r = float(jnp.sum(d))

    np.testing.assert_allclose(counts_p, counts_r, atol=0)
    np.testing.assert_allclose(sums_p, sums_r, rtol=2e-3, atol=2e-2)
    np.testing.assert_allclose(float(obj_p), obj_r, rtol=2e-3)


def test_ops_fused_step_dispatch():
    x = jax.random.normal(jax.random.PRNGKey(1), (200, 16))
    c = jax.random.normal(jax.random.PRNGKey(2), (5, 16))
    s1, n1, o1 = ops.fused_step(x, c, impl="ref")
    s2, n2, o2 = ops.fused_step(x, c, impl="pallas_interpret")
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(n1, n2)
    np.testing.assert_allclose(float(o1), float(o2), rtol=1e-5)


def test_fused_step_weighted_falls_back():
    x = jax.random.normal(jax.random.PRNGKey(1), (100, 8))
    c = jax.random.normal(jax.random.PRNGKey(2), (4, 8))
    w = jax.random.uniform(jax.random.PRNGKey(3), (100,))
    sums, counts, obj = ops.fused_step(x, c, weights=w, impl="ref")
    np.testing.assert_allclose(float(jnp.sum(counts)), float(jnp.sum(w)),
                               rtol=1e-5)


def test_lloyd_uses_fused_consistently():
    from repro.core import kmeans
    from repro.core.kmeanspp import kmeanspp
    x = jax.random.normal(jax.random.PRNGKey(4), (2000, 12)) * 3
    c0 = kmeanspp(x, jax.random.PRNGKey(5), 6)
    res_ref = kmeans.lloyd(x, c0, impl="ref")
    res_pal = kmeans.lloyd(x, c0, impl="pallas_interpret")
    np.testing.assert_allclose(float(res_pal.objective),
                               float(res_ref.objective), rtol=1e-3)


@pytest.mark.parametrize("m,n,L", [(100, 7, 3), (513, 28, 3), (300, 768, 8),
                                   (1000, 68, 128)])
def test_kpp_probe_matches_oracle(m, n, L):
    from repro.kernels.kpp_probe import fits as kpp_fits, kpp_probe_pallas
    kx, kc, kd = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(kx, (m, n))
    cands = jax.random.normal(kc, (L, n))
    d = jax.random.uniform(kd, (m,)) * 5.0
    assert kpp_fits(L, n)
    newd_p, pot_p = kpp_probe_pallas(x, cands, d, interpret=True)

    dc = ref.pairwise_sqdist_ref(x, cands)
    newd_r = jnp.minimum(d[:, None], dc)
    np.testing.assert_allclose(newd_p, newd_r, rtol=2e-4, atol=1e-3)
    np.testing.assert_allclose(pot_p, jnp.sum(newd_r, axis=0),
                               rtol=2e-4, atol=1e-2)
