"""Competitor algorithms: sanity + the paper's qualitative ordering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import big_means, full_objective
from repro.core.baselines import (
    da_mssc, forgy_kmeans, kmeans_parallel, lightweight_coreset_kmeans,
    multistart_kmeans, ward,
)
from repro.data.synthetic import GMMSpec, gmm_dataset

X = gmm_dataset(GMMSpec(m=5000, n=10, components=6, seed=21))
KEY = jax.random.PRNGKey(0)


def _fpp(centroids):
    return float(full_objective(X, centroids)) / X.shape[0]


@pytest.mark.parametrize("fn,kwargs", [
    (forgy_kmeans, {}),
    (multistart_kmeans, {"n_init": 2}),
    (kmeans_parallel, {"rounds": 3}),
    (lightweight_coreset_kmeans, {"s": 800}),
    (da_mssc, {"s": 800, "q": 4}),
])
def test_baseline_runs_and_is_sane(fn, kwargs):
    res = fn(X, KEY, k=6, **kwargs)
    assert res.centroids.shape == (6, 10)
    assert np.isfinite(float(res.objective))
    # against a trivial 1-cluster solution
    trivial = float(full_objective(X, jnp.mean(X, 0, keepdims=True)))
    assert _fpp(res.centroids) * X.shape[0] < trivial


def test_ward_small_data():
    c, labels = ward(np.asarray(X[:800]), 6)
    assert c.shape == (6, 10)
    assert len(np.unique(labels)) == 6
    # ward should beat the trivial solution comfortably
    f_w = float(full_objective(X[:800], jnp.asarray(c))) / 800
    f_triv = float(full_objective(X[:800], jnp.mean(X[:800], 0,
                                                    keepdims=True))) / 800
    assert f_w < 0.5 * f_triv


def test_ward_refuses_big_data():
    with pytest.raises(MemoryError):
        ward(np.zeros((30000, 2)), 3)


def test_quality_ordering_bigmeans_vs_informed_inits():
    """The paper's headline: Big-means matches the strong baselines while
    only ever touching small chunks."""
    st, _ = big_means(X, KEY, k=6, s=800, n_chunks=25)
    f_bm = _fpp(st.centroids)
    f_pp = _fpp(multistart_kmeans(X, KEY, k=6, n_init=3).centroids)
    assert f_bm <= f_pp * 1.10
