"""End-to-end behaviour of the paper's system (integration tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import big_means, full_assignment, full_objective
from repro.core.baselines import forgy_kmeans
from repro.data.synthetic import GMMSpec, gmm_dataset


def test_bigmeans_recovers_gmm_structure():
    """With well-separated components, Big-means must recover the k true
    means while clustering only a fraction of the data per chunk."""
    spec = GMMSpec(m=20000, n=6, components=5, spread=10.0, seed=33)
    X = gmm_dataset(spec)
    state, infos = big_means(X, jax.random.PRNGKey(0), k=5, s=1000,
                             n_chunks=5)
    ids, f = full_assignment(X, state.centroids)
    # every cluster populated, objective near the noise floor (n per point)
    counts = np.bincount(np.asarray(ids), minlength=5)
    assert (counts > 0).all()
    f_per_point = float(f) / X.shape[0]
    assert f_per_point < 1.5 * spec.n          # ~n for a perfect fit


@pytest.mark.slow
def test_bigmeans_improves_with_more_chunks():
    X = gmm_dataset(GMMSpec(m=30000, n=10, components=12, spread=3.0, seed=5))
    key = jax.random.PRNGKey(1)
    st_few, _ = big_means(X, key, k=12, s=500, n_chunks=2)
    st_many, _ = big_means(X, key, k=12, s=500, n_chunks=40)
    f_few = float(full_objective(X, st_few.centroids))
    f_many = float(full_objective(X, st_many.centroids))
    assert f_many <= f_few * 1.001             # more data -> no worse (§2.2 p3)


@pytest.mark.slow
def test_bigmeans_beats_forgy_on_hard_instance():
    """Forgy K-means is prone to bad local minima on many-component data;
    the decomposition's natural shaking escapes them (paper Tables 3-4)."""
    X = gmm_dataset(GMMSpec(m=20000, n=8, components=20, spread=8.0, seed=8))
    f_bm, f_fg = [], []
    for i in range(3):
        key = jax.random.PRNGKey(100 + i)
        st, _ = big_means(X, key, k=20, s=1500, n_chunks=30)
        f_bm.append(float(full_objective(X, st.centroids)))
        res = forgy_kmeans(X, key, k=20)
        f_fg.append(float(res.objective))
    assert np.mean(f_bm) <= np.mean(f_fg)


def test_final_assignment_pass():
    X = gmm_dataset(GMMSpec(m=5000, n=4, components=3, seed=9))
    state, _ = big_means(X, jax.random.PRNGKey(3), k=3, s=500, n_chunks=10)
    ids, f = full_assignment(X, state.centroids)
    assert ids.shape == (5000,)
    np.testing.assert_allclose(
        float(f), float(full_objective(X, state.centroids)), rtol=1e-6)
