"""Grouped MoE dispatch (§Perf adopted optimization) vs the global path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import flags
from repro.models import transformer as T
from repro.models.registry import get_config, model_fns

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _reset_flags():
    yield
    flags.MOE_GROUPED_DISPATCH = 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["deepseek-moe-16b", "qwen3-moe-235b-a22b"])
def test_grouped_equals_global_at_full_capacity(arch):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg,
                              capacity_factor=cfg.num_experts / cfg.top_k)
    mod = model_fns(cfg)
    params = T.init_params(cfg, KEY)
    batch = {
        "tokens": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size),
    }
    base = float(mod.loss_fn(cfg, params, batch))
    flags.MOE_GROUPED_DISPATCH = 4
    grouped = float(mod.loss_fn(cfg, params, batch))
    assert abs(base - grouped) < 1e-6


@pytest.mark.slow
def test_grouped_gradients_finite():
    cfg = get_config("deepseek-moe-16b").reduced()
    mod = model_fns(cfg)
    params = T.init_params(cfg, KEY)
    batch = {
        "tokens": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size),
    }
    flags.MOE_GROUPED_DISPATCH = 4
    g = jax.grad(lambda p: mod.loss_fn(cfg, p, batch))(params)
    assert all(bool(jnp.isfinite(x.astype(jnp.float32)).all())
               for x in jax.tree.leaves(g))


def test_grouped_capacity_drops_are_bounded():
    """At cf=1.0 per-group capacity, drops exist under skew but the output
    stays close to the no-drop result (sanity on the trade-off)."""
    cfg = get_config("deepseek-moe-16b").reduced()
    mod = model_fns(cfg)
    params = T.init_params(cfg, KEY)
    batch = {
        "tokens": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size),
    }
    nodrop_cfg = dataclasses.replace(
        cfg, capacity_factor=cfg.num_experts / cfg.top_k)
    ref = float(mod.loss_fn(nodrop_cfg, params, batch))
    flags.MOE_GROUPED_DISPATCH = 4
    dropped = float(mod.loss_fn(cfg, params, batch))
    assert abs(dropped - ref) / ref < 0.25
