"""Multi-device behaviour (8 forced host devices, separate process so the
main test process keeps its single-device view, per the launch spec)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.core import big_means, big_means_batched, big_means_sharded, full_objective
from repro.data.synthetic import GMMSpec, gmm_dataset
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
X = gmm_dataset(GMMSpec(m=16000, n=8, components=5, seed=2))
key = jax.random.PRNGKey(0)

out = {}
st, infos = big_means_sharded(
    X, key, mesh=mesh, k=5, s=800, chunks_per_worker=6, sync_every=2,
    axes=("data",))
out["f_sharded"] = float(full_objective(X, st.centroids)) / X.shape[0]
out["accepted"] = int(st.n_accepted)
out["n_infos"] = int(infos.f_new.shape[0])

# all-workers variant: every device is a worker
st2, _ = big_means_sharded(
    X, key, mesh=mesh, k=5, s=800, chunks_per_worker=4, sync_every=4,
    axes=("data", "model"))
out["f_allworkers"] = float(full_objective(X, st2.centroids)) / X.shape[0]

# sequential reference
st3, _ = big_means(X, key, k=5, s=800, n_chunks=24)
out["f_seq"] = float(full_objective(X, st3.centroids)) / X.shape[0]

# stream-mesh batched driver: sharding the stream axis over devices must
# reproduce the single-device batched result exactly (same key schedule)
smesh = make_mesh((4,), ("streams",))
stb, _ = big_means_batched(X, key, k=5, s=800, batch=8, rounds=3, impl="ref")
stm, _ = big_means_batched(X, key, k=5, s=800, batch=8, rounds=3, impl="ref",
                           mesh=smesh)
out["batched_mesh_matches"] = bool(
    np.allclose(float(stb.f_best), float(stm.f_best), rtol=1e-5)
    and np.allclose(np.asarray(stb.centroids), np.asarray(stm.centroids),
                    rtol=1e-4, atol=1e-4)
    and int(stb.n_accepted) == int(stm.n_accepted))
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_sharded_quality_matches_sequential(result):
    assert result["f_sharded"] <= result["f_seq"] * 1.15
    assert result["f_allworkers"] <= result["f_seq"] * 1.15


def test_sharded_progress(result):
    assert result["accepted"] >= 1
    # per-worker chunk traces concatenated over the 4 data-axis workers
    assert result["n_infos"] == 4 * 6


def test_batched_stream_mesh_matches_local(result):
    assert result["batched_mesh_matches"]
