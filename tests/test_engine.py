"""Execution-engine parity and composition tests.

The load-bearing guarantees of the scheduler/topology/sync-policy refactor:

* the legacy drivers are thin engine assemblies with **bit-identical**
  trajectories on the reference path (sequential / batched / sharded);
* the host-orchestrated sharded windows (`worker_sharded_rounds`) replay
  the one-shot sharded driver exactly, and compose with checkpoint/resume
  and stop conditions — the previously-impossible "sharded + checkpoints";
* the streaming loop's checkpoint carries the *full* loop state (VNS rung /
  stall / last chunk size), so an interrupted+resumed run equals an
  uninterrupted one bit-for-bit;
* budget stops account for fetched-but-unstepped chunks
  (``done + failed + dropped == fetched``);
* ``competitive_s`` races per-stream sample sizes and reallocates toward
  the winner (arXiv:2403.18766);
* streaming + stream-mesh (out-of-core data on a multi-device mesh) matches
  single-device streaming to fp tolerance — exercised in a forced-4-device
  subprocess.
"""
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

import jax

from repro.api import BigMeansConfig, fit
from repro.cluster import checkpoint, runner
from repro.core import big_means, big_means_batched, big_means_sharded
from repro.data.synthetic import GMMSpec, gmm_chunk, gmm_dataset
from repro.engine import (
    CompetitiveS,
    Checkpoint,
    Middleware,
    TimeBudget,
    get_scheduler,
    incore,
    list_schedulers,
    load_loop_state,
    periodic,
    competitive,
)
from repro.launch.mesh import make_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
X = gmm_dataset(GMMSpec(m=8000, n=8, components=5, seed=21))
SPEC = GMMSpec(m=10**6, n=8, components=5, seed=3)


def provider(cid):
    return np.asarray(gmm_chunk(SPEC, cid, 1024))


# ---------------------------------------------------------------------------
# engine <-> legacy-driver parity (bit-identical on the ref path)
# ---------------------------------------------------------------------------


def test_engine_sequential_parity():
    key = jax.random.PRNGKey(3)
    st_l, inf_l = big_means(X, key, k=5, s=600, n_chunks=8, impl="ref")
    st_e, inf_e = incore.sequential(X, key, k=5, s=600, n_chunks=8,
                                    impl="ref")
    np.testing.assert_array_equal(np.asarray(st_l.centroids),
                                  np.asarray(st_e.centroids))
    assert float(st_l.f_best) == float(st_e.f_best)
    np.testing.assert_array_equal(np.asarray(inf_l.f_new),
                                  np.asarray(inf_e.f_new))


def test_engine_batched_parity():
    key = jax.random.PRNGKey(4)
    st_l, inf_l = big_means_batched(X, key, k=5, s=600, batch=4, rounds=4,
                                    sync_every=2, impl="ref")
    st_e, inf_e = incore.batched_local(
        X, key, k=5, s=600, batch=4, rounds=4, sync_every=2, max_iters=300,
        tol=1e-4, candidates=3, impl="ref", with_replacement=True)
    np.testing.assert_array_equal(np.asarray(st_l.centroids),
                                  np.asarray(st_e.centroids))
    assert float(st_l.f_best) == float(st_e.f_best)
    np.testing.assert_array_equal(np.asarray(inf_l.accepted),
                                  np.asarray(inf_e.accepted))


def test_engine_facade_parity():
    """The api strategies are engine assemblies: `fit` == direct driver."""
    cfg = BigMeansConfig(k=5, s=600, n_chunks=8, impl="ref", seed=5)
    r = fit(X, cfg, method="sequential")
    st, _ = big_means(X, jax.random.PRNGKey(5), k=5, s=600, n_chunks=8,
                      impl="ref")
    np.testing.assert_array_equal(np.asarray(r.centroids),
                                  np.asarray(st.centroids))
    assert r.objective == float(st.f_best)


def test_sharded_rounds_parity_single_device_mesh():
    """Host-orchestrated sync windows replay the one-shot jitted sharded
    driver bit-for-bit (worker mesh of this host's devices)."""
    mesh = make_mesh((1,), ("data",))
    key = jax.random.PRNGKey(0)
    st1, inf1 = big_means_sharded(
        X, key, mesh=mesh, k=5, s=500, chunks_per_worker=8, sync_every=2,
        impl="ref")
    st2, inf2, ctx = incore.worker_sharded_rounds(
        X, key, mesh=mesh, k=5, s=500, chunks_per_worker=8, sync_every=2,
        impl="ref")
    assert ctx.step == 4
    np.testing.assert_array_equal(np.asarray(st1.centroids),
                                  np.asarray(st2.centroids))
    assert float(st1.f_best) == float(st2.f_best)
    np.testing.assert_array_equal(np.asarray(inf1.f_new),
                                  np.asarray(inf2.f_new))
    np.testing.assert_allclose(float(st1.n_dist_evals),
                               float(st2.n_dist_evals), rtol=1e-6)


# ---------------------------------------------------------------------------
# sharded + checkpoint/resume (previously impossible)
# ---------------------------------------------------------------------------


class _StopAfter(Middleware):
    def __init__(self, n_rounds):
        self.n = n_rounds

    def should_stop(self, ctx):
        return ctx.step >= self.n


def test_sharded_checkpoint_resume_bitwise(tmp_path):
    mesh = make_mesh((1,), ("data",))
    key = jax.random.PRNGKey(0)
    kwargs = dict(mesh=mesh, k=5, s=500, chunks_per_worker=8, sync_every=2,
                  impl="ref")
    st_ref, _ = big_means_sharded(X, key, **kwargs)

    d = str(tmp_path)
    mws = [Checkpoint(d, 1, 2, step_from="step"), _StopAfter(2)]
    _, _, ctx_a = incore.worker_sharded_rounds(
        X, key, middlewares=mws, **kwargs)
    assert ctx_a.step == 2                      # interrupted mid-run
    st_b, inf_b, ctx_b = incore.worker_sharded_rounds(
        X, key, middlewares=[Checkpoint(d, 1, 2, step_from="step")], **kwargs)
    assert ctx_b.start_step == 2                # resumed, not restarted
    assert ctx_b.step == 4
    # the resumed process ran windows 2-3 only: 2 windows x sync_every chunks
    assert int(np.asarray(inf_b.f_new).size) == 4
    np.testing.assert_array_equal(np.asarray(st_b.centroids),
                                  np.asarray(st_ref.centroids))
    assert float(st_b.f_best) == float(st_ref.f_best)


def test_sharded_strategy_with_checkpoint(tmp_path):
    """The facade composition: method='sharded' + ckpt_dir runs the
    host-orchestrated windows and leaves a resumable checkpoint."""
    workers = len(jax.devices())     # the strategy meshes over all devices
    cfg = BigMeansConfig(k=5, s=500, n_chunks=8 * workers, sync_every=2,
                         impl="ref", ckpt_dir=str(tmp_path), ckpt_every=1,
                         seed=0)
    r = fit(X, cfg, method="sharded")
    assert r.strategy == "sharded"
    assert r.extras["rounds_done"] >= 1
    assert checkpoint.latest_step(str(tmp_path)) is not None
    st_ref, _ = big_means_sharded(
        X, jax.random.PRNGKey(0), mesh=make_mesh((workers,), ("data",)),
        k=5, s=500, chunks_per_worker=8, sync_every=2, impl="ref")
    np.testing.assert_array_equal(np.asarray(r.centroids),
                                  np.asarray(st_ref.centroids))


# ---------------------------------------------------------------------------
# streaming checkpoint: full loop state (VNS rung/stall, last_s)
# ---------------------------------------------------------------------------


def _fixed_provider():
    fixed = np.asarray(gmm_chunk(SPEC, 0, 1024))
    return lambda cid: fixed        # identical chunks: acceptance stalls


def test_streaming_resume_preserves_vns_state(tmp_path):
    prov = _fixed_provider()
    base = dict(k=5, s=1024, vns_ladder=(512, 256), vns_patience=3, seed=7,
                prefetch=0, log_every=0, ckpt_every=100)
    d_full, d_res = str(tmp_path / "full"), str(tmp_path / "res")

    st_full, _ = runner.run(
        prov, BigMeansConfig(n_chunks=14, ckpt_dir=d_full, **base),
        n_features=8)
    aux_full = load_loop_state(d_full)

    runner.run(prov, BigMeansConfig(n_chunks=7, ckpt_dir=d_res, **base),
               n_features=8)
    aux_mid = load_loop_state(d_res)
    assert aux_mid is not None      # rung/stall/last_s persisted
    st_res, _ = runner.run(
        prov, BigMeansConfig(n_chunks=14, ckpt_dir=d_res, **base),
        n_features=8)

    # interrupted + resumed == uninterrupted, ladder state included
    np.testing.assert_array_equal(np.asarray(st_full.centroids),
                                  np.asarray(st_res.centroids))
    assert float(st_full.f_best) == float(st_res.f_best)
    assert load_loop_state(d_res) == aux_full


def test_streaming_resume_accepts_legacy_checkpoints(tmp_path):
    """Checkpoints written before the aux payload (plain (state, key))
    still restore — with ladder state reset, not a crash."""
    from repro.core import bigmeans

    d = str(tmp_path)
    cfg = BigMeansConfig(k=5, s=1024, n_chunks=6, ckpt_dir=d, seed=1,
                         prefetch=0)
    state = bigmeans.init_state(5, 8)
    key = jax.random.PRNGKey(1)
    checkpoint.save(d, 3, (state, key))         # legacy 6-leaf payload
    st, m = runner.run(provider, cfg, n_features=8)
    assert m.chunks_done == 3                   # resumed from chunk 3
    assert np.isfinite(m.f_best)


# ---------------------------------------------------------------------------
# budget-stop accounting (done + failed + dropped == fetched)
# ---------------------------------------------------------------------------


def test_budget_stop_accounts_dropped_chunks():
    data = np.asarray(gmm_chunk(SPEC, 0, 512))
    fetched = []

    def slow_provider(cid):
        fetched.append(cid)
        if cid == 2:
            time.sleep(0.6)
        return data

    cfg = BigMeansConfig(k=5, s=512, n_chunks=10, batch=3,
                         time_budget_s=0.3, prefetch=0, seed=1)
    # warm the jitted path so compile time cannot eat the budget first
    fit(data, BigMeansConfig(k=5, s=512, n_chunks=1, seed=1),
        method="sequential")
    _, m = runner.run(slow_provider, cfg, n_features=8)
    drops = [t for t in m.trace if t[0] == "budget_drop"]
    assert m.chunks_dropped == sum(len(t[1]) for t in drops)
    # with prefetch=0 the provider is called exactly once per consumed
    # chunk, so the reconciliation invariant is exact
    assert m.chunks_done + m.chunks_failed + m.chunks_dropped == len(fetched)
    if m.chunks_dropped:                        # the budget fired mid-batch
        assert drops and isinstance(drops[0][1], tuple)


def test_persistent_streams_skip_short_tail_chunk():
    """A ragged tail chunk in persistent-stream mode is skipped with
    accounting (trace + chunks_dropped), not a crash."""
    data = np.asarray(gmm_chunk(SPEC, 0, 1024))

    def provider_short_tail(cid):
        return data[:100] if cid == 7 else data

    cfg = BigMeansConfig(k=5, s=1024, n_chunks=8, batch=2, sync_every=2,
                         prefetch=0, seed=1)
    st, m = runner.run(provider_short_tail, cfg, n_features=8)
    assert m.chunks_done == 7
    assert m.chunks_dropped == 1
    assert ("short_chunk", 7, 100, 1024) in m.trace
    assert m.chunks_done + m.chunks_failed + m.chunks_dropped == 8
    assert np.isfinite(float(st.f_best))


def test_worker_scheduler_streams_like_uniform():
    """Every registered scheduler exposes the full stream-loop interface;
    'worker' (the sharded drivers' descriptor) behaves like 'uniform'."""
    cfg = BigMeansConfig(k=5, s=1024, n_chunks=8, batch=2, sync_every=2,
                         scheduler="worker", prefetch=0, seed=1)
    r = fit(provider, cfg, method="streaming", n_features=8)
    assert r.n_chunks == 8
    assert np.isfinite(r.objective)


# ---------------------------------------------------------------------------
# sync policies & persistent streams
# ---------------------------------------------------------------------------


def test_sync_policy_resolution():
    assert periodic(3).resolve(12) == 3
    assert competitive().resolve(12) == 12
    assert periodic(1).boundary(0) and periodic(2).boundary(1)
    assert not periodic(2).boundary(0)
    assert not competitive().boundary(10**6)


def test_streaming_persistent_streams_runs():
    """batch > 1 with periodic/competitive sync keeps per-stream incumbents
    across batches (out-of-core competitive mode, previously impossible)."""
    cfg = BigMeansConfig(k=5, s=1024, n_chunks=16, batch=4, sync_every=2,
                         seed=1)
    r = fit(provider, cfg, method="streaming", n_features=8)
    assert r.n_chunks == 16
    assert np.isfinite(r.objective)
    r2 = fit(provider, cfg.replace(sync="competitive"), method="streaming",
             n_features=8)
    assert r2.n_chunks == 16
    assert np.isfinite(r2.objective)


def test_streaming_surfaces_lloyd_iterations():
    cfg = BigMeansConfig(k=5, s=1024, n_chunks=6, seed=2)
    r = fit(provider, cfg, method="streaming", n_features=8)
    assert r.n_iterations > 0                   # no longer hard-coded 0


# ---------------------------------------------------------------------------
# competitive_s scheduler (arXiv:2403.18766)
# ---------------------------------------------------------------------------


def test_competitive_s_registered():
    assert "competitive_s" in list_schedulers()
    sched = get_scheduler(
        "competitive_s",
        BigMeansConfig(k=5, s=1024, batch=4, scheduler="competitive_s"))
    assert isinstance(sched, CompetitiveS)
    assert sched.fetch_s == max(sched.ladder)


def test_competitive_s_reallocates_toward_winner():
    sched = CompetitiveS(ladder=(256, 512, 1024), batch=6)
    sizes = sched.sizes(6)
    # common-eval scores: 512 the clear winner, 1024 the loser
    f = [1.0 if s == 512 else (3.0 if s == 1024 else 2.0) for s in sizes]
    moves = sched.observe_window(f, sizes)
    assert len(moves) == 1
    b, new_s, clone_from = moves[0]
    assert new_s == 512 and sizes[b] == 1024 and sizes[clone_from] == 512
    assert sched.s_of.count(512) == sizes.count(512) + 1


def test_competitive_s_end_to_end():
    # array source: the engine fetches at max(ladder) and slices per stream
    cfg = BigMeansConfig(k=5, s=1024, n_chunks=24, batch=4, sync_every=2,
                         scheduler="competitive_s",
                         competitive_ladder=(512, 1024, 2048), seed=1)
    r = fit(X, cfg, method="streaming")
    assert r.n_chunks == 24
    info = r.extras["competitive_s"]
    assert info["ladder"] == (512, 1024, 2048)
    assert info["windows"] >= 1
    assert len(info["final_sizes"]) == 4
    assert np.isfinite(r.objective)


def test_competitive_s_validation():
    with pytest.raises(ValueError, match="batch >= 2"):
        BigMeansConfig(k=5, s=1024, batch=1, scheduler="competitive_s")
    with pytest.raises(ValueError, match="unknown scheduler"):
        BigMeansConfig(k=5, s=1024, scheduler="nope")


# ---------------------------------------------------------------------------
# auto strategy: compatible sync_every derivation
# ---------------------------------------------------------------------------


def test_auto_derives_compatible_sync_every(monkeypatch):
    import repro.api.strategies as S

    calls = {}

    def spy(cfg, source, key):
        calls["sync_every"] = cfg.sync_every
        return fit(X, cfg.replace(mesh=None), method="sequential")

    monkeypatch.setattr(jax, "devices", lambda: [object()] * 4)
    monkeypatch.setitem(S._STRATEGIES, "sharded", spy)
    cfg = BigMeansConfig(k=5, s=600, n_chunks=8, sync_every=3, impl="ref")
    # 4 workers -> 2 chunks/worker; sync_every=3 does not divide 2:
    # auto derives the largest divisor <= 3 instead of downgrading
    assert S.resolve_auto(cfg, __import__(
        "repro.api.sources", fromlist=["as_source"]).as_source(X)) == "sharded"
    r = S._fit_auto(cfg, __import__(
        "repro.api.sources", fromlist=["as_source"]).as_source(X),
        jax.random.PRNGKey(0))
    assert calls["sync_every"] == 2
    assert r.extras["sync_every_adjusted"] == {"requested": 3, "used": 2}


# ---------------------------------------------------------------------------
# multi-device compositions (forced 4 host devices, separate process)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
from repro.api import BigMeansConfig, fit
from repro.core import big_means_sharded
from repro.engine import incore
from repro.launch.mesh import make_mesh
from repro.data.synthetic import GMMSpec, gmm_chunk, gmm_dataset

SPEC = GMMSpec(m=10**6, n=8, components=5, seed=3)
def provider(cid):
    return np.asarray(gmm_chunk(SPEC, cid, 1024))

out = {"n_devices": len(jax.devices())}

# streaming + stream mesh == streaming single-device (fp tolerance)
mesh = make_mesh((4,), ("streams",))
cfg1 = BigMeansConfig(k=5, s=1024, n_chunks=16, batch=4, seed=1, impl="ref")
r1 = fit(provider, cfg1, method="streaming", n_features=8)
r2 = fit(provider, cfg1.replace(mesh=mesh), method="streaming", n_features=8)
out["stream_mesh_matches"] = bool(
    np.allclose(r1.objective, r2.objective, rtol=1e-5)
    and np.allclose(np.asarray(r1.centroids), np.asarray(r2.centroids),
                    rtol=1e-4, atol=1e-4))

# persistent streams over the mesh too
cfg2 = cfg1.replace(sync_every=2)
r3 = fit(provider, cfg2, method="streaming", n_features=8)
r4 = fit(provider, cfg2.replace(mesh=mesh), method="streaming", n_features=8)
out["stream_mesh_persistent_matches"] = bool(
    np.allclose(r3.objective, r4.objective, rtol=1e-5))

# sharded rounds parity on a real 4-worker mesh
X = gmm_dataset(GMMSpec(m=16000, n=8, components=5, seed=2))
wmesh = make_mesh((4,), ("data",))
key = jax.random.PRNGKey(0)
st1, inf1 = big_means_sharded(X, key, mesh=wmesh, k=5, s=800,
                              chunks_per_worker=6, sync_every=2, impl="ref")
st2, inf2, ctx = incore.worker_sharded_rounds(
    X, key, mesh=wmesh, k=5, s=800, chunks_per_worker=6, sync_every=2,
    impl="ref")
out["sharded_rounds_match"] = bool(
    float(st1.f_best) == float(st2.f_best)
    and np.array_equal(np.asarray(st1.centroids), np.asarray(st2.centroids))
    and np.array_equal(np.asarray(inf1.f_new), np.asarray(inf2.f_new)))
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def mesh_result():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
def test_streaming_mesh_matches_single_device(mesh_result):
    assert mesh_result["n_devices"] == 4
    assert mesh_result["stream_mesh_matches"]
    assert mesh_result["stream_mesh_persistent_matches"]


@pytest.mark.slow
def test_sharded_rounds_parity_multi_device(mesh_result):
    assert mesh_result["sharded_rounds_match"]
