"""Behavioural tests for the paper core: Lloyd, K-means++, Big-means."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    big_means, chunk_step, full_objective, init_state, kmeanspp, lloyd,
    sample_chunk, seed,
)
from repro.data.synthetic import GMMSpec, gmm_dataset

X = gmm_dataset(GMMSpec(m=6000, n=8, components=5, seed=11))


def test_lloyd_monotone_objective():
    c0 = kmeanspp(X, jax.random.PRNGKey(0), 5)
    f_init = float(full_objective(X, c0))
    res = lloyd(X, c0)
    assert float(res.objective) <= f_init + 1e-3
    assert int(res.iterations) >= 1
    # objective equals independent evaluation of the final centroids
    np.testing.assert_allclose(
        float(res.objective), float(full_objective(X, res.centroids)),
        rtol=1e-5)


def test_lloyd_counts_and_assignments():
    c0 = kmeanspp(X, jax.random.PRNGKey(1), 5)
    res = lloyd(X, c0)
    assert res.assignments.shape == (X.shape[0],)
    assert int(res.assignments.min()) >= 0
    assert int(res.assignments.max()) < 5
    assert float(jnp.sum(res.counts)) == X.shape[0]
    np.testing.assert_array_equal(
        np.asarray(res.degenerate), np.asarray(res.counts) == 0)


def test_lloyd_respects_max_iters():
    c0 = kmeanspp(X, jax.random.PRNGKey(2), 5)
    res = lloyd(X, c0, max_iters=3, tol=0.0)
    assert int(res.iterations) <= 3


def test_kmeanspp_seeds_are_data_points():
    c = kmeanspp(X, jax.random.PRNGKey(3), 7, candidates=1)
    d = np.asarray(
        jnp.min(jnp.sum((X[None] - c[:, None]) ** 2, -1), axis=1))
    assert d.max() < 1e-6      # every seed coincides with a dataset point


def test_kmeanspp_deterministic():
    a = kmeanspp(X, jax.random.PRNGKey(4), 5)
    b = kmeanspp(X, jax.random.PRNGKey(4), 5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_seed_keeps_nondegenerate_rows():
    init = jnp.stack([X[0], X[1], jnp.zeros(8), X[3]])
    degenerate = jnp.array([False, False, True, False])
    out = seed(X, jax.random.PRNGKey(5), 4, init=init, degenerate=degenerate)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(init[0]))
    np.testing.assert_array_equal(np.asarray(out[3]), np.asarray(init[3]))
    assert not np.allclose(np.asarray(out[2]), 0.0)   # reseeded


def test_chunk_step_keep_the_best():
    state = init_state(5, 8)
    key = jax.random.PRNGKey(6)
    fs = []
    for i in range(8):
        k1, k2, key = jax.random.split(key, 3)
        chunk = sample_chunk(X, k1, 512)
        state, info = chunk_step(chunk, state, k2)
        fs.append(float(state.f_best))
    assert all(b <= a + 1e-6 for a, b in zip(fs, fs[1:]))   # monotone
    assert int(state.n_accepted) >= 1
    assert np.isfinite(fs[-1])


def test_big_means_close_to_full_kmeans():
    key = jax.random.PRNGKey(7)
    state, infos = big_means(X, key, k=5, s=600, n_chunks=25)
    f_bm = float(full_objective(X, state.centroids)) / X.shape[0]
    c0 = kmeanspp(X, jax.random.PRNGKey(8), 5)
    f_full = float(lloyd(X, c0).objective) / X.shape[0]
    # decomposition search should be within 10% of full-data K-means
    assert f_bm <= f_full * 1.10
    assert infos.f_new.shape == (25,)


def test_big_means_order_independence():
    """Property 8 (§2.2): results do not depend on dataset row order in
    distribution — a row permutation with the same key gives a solution of
    statistically equal quality (identical sampling law)."""
    key = jax.random.PRNGKey(9)
    perm = jax.random.permutation(jax.random.PRNGKey(10), X.shape[0])
    s1, _ = big_means(X, key, k=5, s=600, n_chunks=20)
    s2, _ = big_means(X[perm], key, k=5, s=600, n_chunks=20)
    f1 = float(full_objective(X, s1.centroids)) / X.shape[0]
    f2 = float(full_objective(X, s2.centroids)) / X.shape[0]
    assert abs(f1 - f2) / f1 < 0.1


def test_sample_chunk_without_replacement_unique():
    idx_free = sample_chunk(jnp.arange(1000.0)[:, None],
                            jax.random.PRNGKey(11), 64,
                            with_replacement=False)
    vals = np.asarray(idx_free).ravel()
    assert len(np.unique(vals)) == 64
