"""Fault-tolerance suite: injection harness, retry/quarantine, watchdog,
checkpoint self-healing, kernel degradation, and the seeded chaos e2e.

The load-bearing contracts:

* accounting reconciles exactly — ``done + failed + dropped + quarantined
  == fetched`` — at every prefetch depth, fault or no fault;
* ``retries=0`` (the default) reproduces the legacy drop-the-chunk
  behaviour bit-for-bit;
* a recovered transient fault leaves the trajectory bitwise identical to
  the fault-free run (per-chunk keys come from ``fold_in(key, cid)``);
* a hung provider never leaks the prefetch worker thread.
"""
import json
import os
import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import BigMeansConfig, fit
from repro.cluster import checkpoint, runner
from repro.data.synthetic import GMMSpec, gmm_chunk
from repro.engine import faults, middleware, stream

SPEC = GMMSpec(m=10**5, n=8, components=5, seed=3)


def provider(cid):
    return np.asarray(gmm_chunk(SPEC, cid, 512))


def cfg_for(**kw):
    base = dict(k=5, s=512, n_chunks=8, prefetch=0, seed=1)
    base.update(kw)
    return BigMeansConfig(**base)


def reconcile(m, fetched):
    assert (m.chunks_done + m.chunks_failed + m.chunks_dropped
            + m.chunks_quarantined) == fetched, m


# ---------------------------------------------------------------------------
# harness determinism


def test_fault_plan_is_deterministic():
    plan = faults.FaultPlan(seed=11, transient_rate=0.3)
    again = faults.FaultPlan(seed=11, transient_rate=0.3)
    assert plan.transient_ids(64) == again.transient_ids(64)
    assert plan.transient_ids(64)  # a 0.3 rate over 64 ids must hit some
    other = faults.FaultPlan(seed=12, transient_rate=0.3)
    assert plan.transient_ids(256) != other.transient_ids(256)


def test_retry_policy_deterministic_bounded_backoff():
    pol = faults.RetryPolicy(retries=3, backoff_s=0.05, backoff_max_s=0.4,
                             seed=7)
    delays = [pol.delay(5, a) for a in range(6)]
    assert delays == [pol.delay(5, a) for a in range(6)]  # replay identical
    assert all(0.0 < d <= 0.4 for d in delays)            # capped
    assert pol.delay(5, 0) != pol.delay(6, 0)             # jitter per chunk


def test_classify_taxonomy():
    assert faults.classify(RuntimeError("node lost")) == faults.TRANSIENT
    assert faults.classify(faults.FetchTimeout("hung")) == faults.TRANSIENT
    assert faults.classify(OSError("io")) == faults.TRANSIENT
    assert faults.classify(ValueError("bad")) == faults.PERMANENT
    assert faults.classify(KeyError("k")) == faults.PERMANENT
    assert faults.classify(NotImplementedError()) == faults.PERMANENT


# ---------------------------------------------------------------------------
# retry / quarantine semantics through the real streaming loop


def test_retry_recovers_transients_bitwise():
    plan = faults.FaultPlan(seed=5, transient_rate=0.4, transient_attempts=1)
    hit = plan.transient_ids(8)
    assert hit  # the plan must actually fault something
    wrapped = plan.wrap(provider)
    cfg = cfg_for(retries=2, retry_backoff_s=0.0)
    st, m = runner.run(wrapped, cfg, n_features=8)
    clean_st, clean_m = runner.run(provider, cfg_for(), n_features=8)

    assert m.chunks_done == 8 and m.chunks_failed == 0
    reconcile(m, 8)
    # every faulted chunk burned exactly one extra provider attempt
    assert sum(wrapped.attempts.values()) == 8 + len(hit)
    # recovered run is indistinguishable from the fault-free run
    np.testing.assert_array_equal(np.asarray(st.centroids),
                                  np.asarray(clean_st.centroids))
    assert float(st.f_best) == float(clean_st.f_best)


def test_retries_zero_matches_legacy_drop_bitwise():
    """The default config must reproduce today's behaviour exactly: a
    failing fetch is dropped with ``chunks_failed`` + ``fetch_error``."""
    bad = {2, 5}

    def flaky(cid):
        if cid in bad:
            raise RuntimeError(f"node lost {cid}")
        return provider(cid)

    def legacy_injector(cid):
        if cid in bad:
            raise RuntimeError(f"node lost {cid}")

    st, m = runner.run(flaky, cfg_for(), n_features=8)
    st_legacy, m_legacy = runner.run(
        provider, cfg_for(), n_features=8, fault_injector=legacy_injector)

    assert m.chunks_failed == len(bad) == m_legacy.chunks_failed
    assert sorted(t[1] for t in m.trace if t[0] == "fetch_error") == [2, 5]
    np.testing.assert_array_equal(np.asarray(st.centroids),
                                  np.asarray(st_legacy.centroids))
    assert float(st.f_best) == float(st_legacy.f_best)


def test_permanent_faults_are_never_retried():
    plan = faults.FaultPlan(seed=0, permanent_ids=(3,))
    wrapped = plan.wrap(provider)
    cfg = cfg_for(retries=3, retry_backoff_s=0.0)
    _, m = runner.run(wrapped, cfg, n_features=8)
    assert wrapped.attempts[3] == 1          # no retry budget burned
    assert m.chunks_failed == 1
    errs = [t for t in m.trace if t[0] == "fetch_error" and t[1] == 3]
    assert errs and "PermanentFault" in errs[0][2]
    reconcile(m, 8)


def test_corrupt_chunks_quarantined_with_accounting():
    plan = faults.FaultPlan(seed=0, nan_ids=(1,), inf_ids=(4,),
                            shape_ids=(6,))
    st, m = runner.run(plan.wrap(provider), cfg_for(), n_features=8)

    assert m.chunks_quarantined == 3 and m.chunks_failed == 0
    reconcile(m, 8)
    q = {t[1]: t[2] for t in m.trace if t[0] == "quarantine"}
    assert set(q) == {1, 4, 6}
    assert "non-finite" in q[1] and "non-finite" in q[4]
    assert "shape" in q[6]
    assert np.isfinite(float(st.f_best))

    # Quarantining a chunk is equivalent to its fetch having failed: the
    # surviving-chunk trajectory must be bitwise identical.
    def failing(cid):
        if cid in (1, 4, 6):
            raise RuntimeError("boom")
        return provider(cid)

    st_drop, m_drop = runner.run(failing, cfg_for(), n_features=8)
    assert m_drop.chunks_failed == 3
    np.testing.assert_array_equal(np.asarray(st.centroids),
                                  np.asarray(st_drop.centroids))
    assert float(st.f_best) == float(st_drop.f_best)


def test_quarantine_in_persistent_stream_mode():
    plan = faults.FaultPlan(seed=0, nan_ids=(3,))
    cfg = cfg_for(batch=2, sync_every=2)
    st, m = runner.run(plan.wrap(provider), cfg, n_features=8)
    assert m.chunks_quarantined == 1
    assert ("quarantine", 3, "non-finite values (NaN/Inf)") in m.trace
    reconcile(m, 8)
    assert np.isfinite(float(np.min(np.asarray(st.f_best))))


# ---------------------------------------------------------------------------
# watchdog: hung providers (satellite 1)


def test_watchdog_turns_hang_into_fault():
    never = threading.Event()

    def hung(cid):
        if cid == 2:
            never.wait(30.0)  # "never" returns within the test's horizon
        return provider(cid)

    cfg = cfg_for(fetch_timeout_s=0.25)
    t0 = time.monotonic()
    _, m = runner.run(hung, cfg, n_features=8)
    assert time.monotonic() - t0 < 15.0      # did not wait out the hang
    assert m.chunks_done == 7 and m.chunks_failed == 1
    errs = [t for t in m.trace if t[0] == "fetch_error" and t[1] == 2]
    assert errs and "FetchTimeout" in errs[0][2]
    reconcile(m, 8)
    never.set()


def test_prefetcher_close_reclaims_worker_with_hung_provider():
    """Regression: close() must not deadlock or leak the worker thread when
    the provider never returns."""
    never = threading.Event()

    def hung(cid):
        never.wait(30.0)
        return provider(cid)

    p = stream._Prefetcher(hung, range(100), depth=2, timeout=0.2)
    it = iter(p)
    cid, item = next(it)
    assert cid == 0 and isinstance(item, stream._FetchFailure)
    assert "FetchTimeout" in item.error
    p.close()
    assert not p._thread.is_alive()
    never.set()


def test_prefetcher_close_is_idempotent_and_fast_mid_stream():
    p = stream._Prefetcher(provider, range(1000), depth=2)
    next(iter(p))
    t0 = time.monotonic()
    p.close()
    p.close()
    assert time.monotonic() - t0 < 5.0
    assert not p._thread.is_alive()


def test_watchdog_timeout_is_retryable():
    """A stall that clears on the second attempt is recovered by retries."""
    calls = []

    def stalls_once(cid):
        calls.append(cid)
        if cid == 1 and calls.count(1) == 1:
            time.sleep(5.0)
        return provider(cid)

    cfg = cfg_for(n_chunks=3, fetch_timeout_s=0.3, retries=1,
                  retry_backoff_s=0.0)
    _, m = runner.run(stalls_once, cfg, n_features=8)
    assert m.chunks_done == 3 and m.chunks_failed == 0
    assert calls.count(1) == 2


# ---------------------------------------------------------------------------
# accounting under prefetch with bursty failures (satellite 3)


@pytest.mark.parametrize("prefetch", [0, 2, 4])
def test_bursty_failures_reconcile_at_every_depth(prefetch):
    bad = {3, 4, 5}  # a consecutive burst mid-stream
    fetched = []

    def bursty(cid):
        fetched.append(cid)
        if cid in bad:
            raise RuntimeError(f"burst {cid}")
        return provider(cid)

    cfg = cfg_for(n_chunks=10, prefetch=prefetch)
    st, m = runner.run(bursty, cfg, n_features=8)
    assert m.chunks_failed == 3 and m.chunks_done == 7
    reconcile(m, len(fetched))
    assert sorted(t[1] for t in m.trace if t[0] == "fetch_error") == [3, 4, 5]
    # stash for the cross-depth comparison below
    _BURST_RUNS[prefetch] = (np.asarray(st.centroids), float(st.f_best),
                             [t for t in m.trace if t[0] == "accept"]
                             or m.trace)


_BURST_RUNS: dict = {}


def test_bursty_failure_trajectories_match_across_depths():
    """Replay invariance survives faults: per-chunk keys are fold_in(key,
    cid), so the surviving-chunk trajectory is bitwise identical whether
    fetches were synchronous or pipelined."""
    assert set(_BURST_RUNS) == {0, 2, 4}, "parametrized test must run first"
    c0, f0, _ = _BURST_RUNS[0]
    for depth in (2, 4):
        c, f, _ = _BURST_RUNS[depth]
        np.testing.assert_array_equal(c0, c)
        assert f0 == f


# ---------------------------------------------------------------------------
# checkpoint self-healing (satellite 2)


def ckpt_tree():
    return (np.arange(12, dtype=np.float32).reshape(3, 4),
            np.float32(7.0))


def test_checkpoint_save_writes_digests(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 5, ckpt_tree())
    meta = json.loads(
        (tmp_path / "step_000000000005" / "meta.json").read_text())
    assert "arrays.npz" in meta["digests"]
    assert checkpoint.verify_step(d, 5)
    assert checkpoint.latest_intact_step(d) == 5


def test_truncated_checkpoint_falls_back_to_previous(tmp_path):
    d = str(tmp_path)
    tree = ckpt_tree()
    checkpoint.save(d, 5, (tree[0], np.float32(5.0)))
    checkpoint.save(d, 9, (tree[0], np.float32(9.0)))
    faults.corrupt_checkpoint(d)             # mangles newest (step 9)

    assert checkpoint.latest_step(d) == 9    # still listed...
    assert not checkpoint.verify_step(d, 9)  # ...but detected corrupt
    assert checkpoint.latest_intact_step(d) == 5
    restored = checkpoint.restore(d, tree)
    assert float(restored[1]) == 5.0         # fell back, didn't crash


def test_restore_all_corrupt_raises_not_garbage(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 3, ckpt_tree())
    faults.corrupt_checkpoint(d, step=3)
    with pytest.raises(FileNotFoundError, match="no intact checkpoint"):
        checkpoint.restore(d, ckpt_tree())


def test_save_cleans_stale_tmp_dirs(tmp_path):
    stale = tmp_path / "tmp.000000000001"
    stale.mkdir()
    (stale / "arrays.npz").write_bytes(b"torn write")
    checkpoint.save(str(tmp_path), 2, ckpt_tree())
    assert not stale.exists()
    assert checkpoint.steps(str(tmp_path)) == [2]


def test_runner_resumes_from_intact_step_after_corruption(tmp_path):
    """End-to-end self-healing: corrupt the newest checkpoint, resume, and
    the run falls back to the previous step with a trace event."""
    cfg = cfg_for(n_chunks=8, ckpt_dir=str(tmp_path), ckpt_every=3)
    runner.run(provider, cfg, n_features=8)
    assert len(checkpoint.steps(str(tmp_path))) >= 2
    newest = checkpoint.latest_step(str(tmp_path))
    faults.corrupt_checkpoint(str(tmp_path))

    cfg2 = cfg.replace(n_chunks=10)
    st, m = runner.run(provider, cfg2, n_features=8)
    fallbacks = [t for t in m.trace if t[0] == "ckpt_fallback"]
    assert fallbacks and fallbacks[0][1] < newest
    assert np.isfinite(float(st.f_best))


def test_runner_fresh_start_when_every_step_corrupt(tmp_path):
    cfg = cfg_for(n_chunks=4, ckpt_dir=str(tmp_path), ckpt_every=2)
    runner.run(provider, cfg, n_features=8)
    for s in checkpoint.steps(str(tmp_path)):
        faults.corrupt_checkpoint(str(tmp_path), step=s)
    st, m = runner.run(provider, cfg, n_features=8)
    assert ("ckpt_fallback", None) in m.trace    # restarted from scratch
    assert m.chunks_done == 4                    # full rerun, not resumed
    assert np.isfinite(float(st.f_best))


# ---------------------------------------------------------------------------
# graceful kernel degradation


@pytest.fixture
def clean_demotions():
    from repro.kernels import ops
    ops.reset_kernel_demotions()
    yield ops
    ops.reset_kernel_demotions()


def test_kernel_failure_demotes_once_and_falls_back(clean_demotions):
    ops = clean_demotions
    x = np.asarray(gmm_chunk(SPEC, 0, 96), dtype=np.float32)
    c = x[:5].copy()
    want = ops.fused_step(jnp.asarray(x), jnp.asarray(c), impl="ref")
    with faults.kernel_failure("fused"):
        with pytest.warns(RuntimeWarning, match="fused"):
            got = ops.fused_step(jnp.asarray(x), jnp.asarray(c),
                                 impl="pallas_interpret")
        # second call at the demoted shape: silent ref path, no new record
        ops.fused_step(jnp.asarray(x), jnp.asarray(c),
                       impl="pallas_interpret")
    demos = ops.kernel_demotions()
    assert len(demos) == 1
    assert demos[0]["op"] == "fused" and "injected" in demos[0]["error"]
    for w, g in zip(want, got):
        np.testing.assert_allclose(np.asarray(w), np.asarray(g), rtol=1e-5)


def test_kernel_fallback_surfaces_on_fit_result(clean_demotions):
    X = np.asarray(gmm_chunk(SPEC, 0, 4096), dtype=np.float32)
    cfg = BigMeansConfig(k=5, s=768, n_chunks=3, seed=1,
                         impl="pallas_interpret", autotune=False)
    with faults.kernel_failure("fused"), \
            pytest.warns(RuntimeWarning, match="fused"):
        result = fit(X, cfg, method="sequential")
    kinds = {t[0] for t in result.trace}
    assert "kernel_fallback" in kinds
    assert result.health and result.health["kernel_fallbacks"]
    assert np.isfinite(result.objective)


# ---------------------------------------------------------------------------
# invariant guard


def _guard_ctx(f_best, last_s=512, mode="fold"):
    class State:
        pass

    st = State()
    st.f_best = np.asarray(f_best, dtype=np.float32)
    ctx = middleware.EngineContext(cfg=None, key=None, metrics=None,
                                   state=st, last_s=last_s)
    ctx.extras["stream_mode"] = mode
    return ctx


def test_invariant_guard_rejects_nan_and_neg_inf():
    guard = middleware.InvariantGuard()
    with pytest.raises(faults.InvariantViolation, match="poisoned"):
        guard.after_window(_guard_ctx(np.nan))
    with pytest.raises(faults.InvariantViolation, match="poisoned"):
        guard.after_window(_guard_ctx(-np.inf))


def test_invariant_guard_rejects_rising_incumbent_in_fold_mode():
    guard = middleware.InvariantGuard()
    guard.after_window(_guard_ctx(100.0))
    guard.after_window(_guard_ctx(90.0))          # improving: fine
    with pytest.raises(faults.InvariantViolation, match="rose"):
        guard.after_window(_guard_ctx(140.0))


def test_invariant_guard_tolerates_rescale_and_persistent_mode():
    guard = middleware.InvariantGuard()
    guard.after_window(_guard_ctx(100.0, last_s=512))
    guard.after_window(_guard_ctx(200.0, last_s=1024))  # same per point
    # persistent mode: raw objectives incomparable, only finiteness checked
    guard2 = middleware.InvariantGuard()
    guard2.after_window(_guard_ctx(10.0, mode="persistent"))
    guard2.after_window(_guard_ctx(50.0, mode="persistent"))


# ---------------------------------------------------------------------------
# chaos end-to-end


def test_chaos_run_completes_and_reconciles(tmp_path):
    """The whole stack under one seeded plan: transient faults (recovered),
    a permanent failure, a poisoned chunk, a corrupted checkpoint — and the
    run still completes with exact accounting and a sane objective."""
    cfg = BigMeansConfig(k=5, s=512, n_chunks=16, prefetch=2, seed=1,
                         retries=2, retry_backoff_s=0.0,
                         fetch_timeout_s=5.0,
                         ckpt_dir=str(tmp_path), ckpt_every=5)
    clean = fit(provider, cfg.replace(ckpt_dir=None), method="streaming",
                n_features=8)

    # stage checkpoints, then corrupt the newest before the chaos run
    runner.run(provider, cfg.replace(n_chunks=11), n_features=8)
    faults.corrupt_checkpoint(str(tmp_path))

    # faults sit past chunk 11 so they hit even after the checkpoint resume
    plan = faults.FaultPlan(seed=13, transient_rate=0.25,
                            transient_attempts=1,
                            permanent_ids=(12,), nan_ids=(14,))
    wrapped = plan.wrap(provider)
    result = fit(wrapped, cfg, method="streaming", n_features=8)

    h = result.health
    assert h is not None
    assert (h["chunks_done"] + h["chunks_failed"] + h["chunks_dropped"]
            + h["chunks_quarantined"]) == h["chunks_fetched"]
    assert h["chunks_failed"] == 1           # the permanent fault only
    assert h["chunks_quarantined"] == 1      # the NaN chunk
    assert h["ckpt_fallback"] is not None    # healed past the torn write
    assert h["quarantine_reasons"] == [(14, "non-finite values (NaN/Inf)")]
    assert np.isfinite(result.objective)
    # dropping two i.i.d. chunks and resuming mid-stream must not move the
    # objective materially (gate tolerance is 5%)
    assert result.objective <= clean.objective * 1.05
