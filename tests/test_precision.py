"""Mixed-precision kernel stack + block-size autotuner.

Covers the ISSUE 3 acceptance matrix:

* bf16 numerics: objective within rtol=1e-2 of the f32 oracle; batch=1
  batched kernel == single kernel under bf16; padding (lanes and features)
  never wins an argmin or leaks into sums.
* ``fit(..., precision='bf16')`` within 1% relative ``f_best`` of the f32
  run on the paper-regime synthetic workload (same seeds).
* autotuner: tile choice never changes numerics; on-disk cache write +
  reload round-trip; ops consults the tuner under ``pallas_interpret``.
* ``ops.fused_step`` two-pass fallback honors ``impl='ref_chunked'``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops, ref
from repro.kernels import precision as px
from repro.kernels.fused_step import fused_step_batched_pallas, fused_step_pallas


def _blobs(m=400, n=28, k=25, seed=0):
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    centers = jax.random.normal(kc, (k, n)) * 4.0
    ids = jax.random.randint(jax.random.PRNGKey(seed + 1), (m,), 0, k)
    x = centers[ids] + jax.random.normal(kx, (m, n)) * 0.3
    c = centers + 0.05
    return x, c


# ---------------------------------------------------------------------------
# precision policy helpers
# ---------------------------------------------------------------------------


def test_precision_validation():
    with pytest.raises(ValueError, match="unknown precision"):
        px.check("fp8")
    assert px.check("bf16") == "bf16"
    assert px.storage_dtype("bf16") == jnp.bfloat16
    assert px.storage_dtype("bf16x3") == jnp.float32
    # 'auto' follows the data dtype (legacy behaviour); concrete values win
    assert px.resolve("auto", jnp.bfloat16) == "bf16"
    assert px.resolve(None, jnp.float32) == "f32"
    assert px.resolve("f32", jnp.bfloat16) == "f32"


def test_bf16x3_compensation_beats_bf16():
    x, c = _blobs()
    d32 = ref.pairwise_sqdist_ref(x, c, precision="f32")
    dbf = ref.pairwise_sqdist_ref(x, c, precision="bf16")
    dx3 = ref.pairwise_sqdist_ref(x, c, precision="bf16x3")
    err_bf = float(jnp.max(jnp.abs(dbf - d32)))
    err_x3 = float(jnp.max(jnp.abs(dx3 - d32)))
    assert err_x3 < err_bf / 4, (err_x3, err_bf)


# ---------------------------------------------------------------------------
# bf16 kernel numerics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n,k", [(300, 28, 25), (257, 100, 37)])
def test_bf16_fused_objective_close_to_f32_oracle(m, n, k):
    # Unit-scale blobs: the per-iteration kernel objective carries raw bf16
    # dot rounding (the compensated f32 epilogue is lloyd's, tested below),
    # so the comparison runs where distances are not cancellation-dominated.
    kx, kc = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (m, n))
    c = jax.random.normal(kc, (k, n))
    _, _, obj_bf = fused_step_pallas(x, c, precision="bf16", interpret=True)
    ids, d = ref.assign_ref(x, c, precision="f32")
    obj_f32 = float(jnp.sum(d))
    np.testing.assert_allclose(float(obj_bf), obj_f32, rtol=1e-2)


def test_bf16_lloyd_objective_close_to_f32_oracle():
    from repro.core import kmeans
    from repro.core.kmeanspp import kmeanspp

    x, _ = _blobs(m=2000, n=12, k=6, seed=7)
    c0 = kmeanspp(x, jax.random.PRNGKey(5), 6)
    res32 = kmeans.lloyd(x, c0, impl="ref", precision="f32")
    resbf = kmeans.lloyd(x, c0, impl="ref", precision="bf16")
    np.testing.assert_allclose(float(resbf.objective), float(res32.objective),
                               rtol=1e-2)


@pytest.mark.parametrize("precision", ["f32", "bf16"])
def test_batched_batch1_matches_single_kernel(precision):
    x, c = _blobs(m=300, n=28, k=25)
    s1, n1, o1 = fused_step_pallas(x, c, precision=precision, interpret=True)
    sb, nb, ob = fused_step_batched_pallas(
        x[None], c[None], precision=precision, interpret=True)
    np.testing.assert_array_equal(np.asarray(nb[0]), np.asarray(n1))
    np.testing.assert_allclose(np.asarray(sb[0]), np.asarray(s1),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(ob[0]), float(o1), rtol=1e-6)


@pytest.mark.parametrize("m,n,k", [(257, 29, 5), (100, 130, 129)])
def test_bf16_padding_invariance(m, n, k):
    """Padded lanes must never win an argmin; padded features never leak."""
    x, c = _blobs(m, n, k, seed=3)
    ids, d = ops.assign(x, c, impl="pallas_interpret", precision="bf16")
    assert int(jnp.max(ids)) < k
    assert int(jnp.min(ids)) >= 0
    assert bool(jnp.all(d >= 0))
    # same inputs embedded in a larger feature space padded with zeros:
    # distances and assignments are unchanged (bf16 zero-padding is exact)
    ids_ref, d_ref = ref.assign_ref(
        x.astype(jnp.bfloat16), c, precision="bf16")
    agree = np.mean(np.asarray(ids) == np.asarray(ids_ref))
    assert agree > 0.99, agree
    sums, counts = ops.update(x, ids, k, impl="pallas_interpret",
                              precision="bf16")
    assert float(jnp.sum(counts)) == m
    # zero-feature padding in the kernel cannot contribute to sums: compare
    # against the oracle over identical assignments
    sums_ref, counts_ref = ref.update_ref(x.astype(jnp.bfloat16), ids, k,
                                          precision="bf16")
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(counts_ref))
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sums_ref),
                               rtol=1e-2, atol=1e-2)


def test_fit_bf16_within_1pct_of_f32():
    """Acceptance: paper-regime synthetic workload, same seeds, <1% f_best."""
    from repro.api import BigMeansConfig, fit, synthetic

    X = synthetic.gmm_dataset(
        synthetic.GMMSpec(m=60_000, n=20, components=25, seed=12))
    cfg = BigMeansConfig(k=25, s=8192, n_chunks=8, impl="ref", seed=0)
    r32 = fit(X, cfg)
    rbf = fit(X, cfg, precision="bf16")
    rel = abs(rbf.objective - r32.objective) / r32.objective
    assert rel < 0.01, (r32.objective, rbf.objective, rel)


def test_streaming_runner_serves_bf16_chunks():
    from repro.api import BigMeansConfig, as_source, fit

    rng = np.random.default_rng(0)
    X = rng.normal(size=(20_000, 8)).astype(np.float32)
    src = as_source(X)
    fetch = src.provider(1024, seed=0, dtype=__import__("ml_dtypes").bfloat16)
    chunk = fetch(0)
    assert chunk.dtype == np.dtype(__import__("ml_dtypes").bfloat16)
    cfg = BigMeansConfig(k=5, s=1024, n_chunks=6, impl="ref",
                         precision="bf16", prefetch=2)
    res = fit(src, cfg, method="streaming")
    assert np.isfinite(res.objective)
    assert res.n_chunks == 6


def test_memmap_provider_explicit_dtype_wins(tmp_path):
    from repro.api import MemmapSource

    X = np.random.default_rng(2).normal(size=(200, 4)).astype(np.float64)
    path = tmp_path / "data.npy"
    np.save(path, X)
    src = MemmapSource(path, dtype=np.float64)
    assert src.provider(16)(0).dtype == np.float64          # native default
    assert src.provider(16, dtype=np.float32)(0).dtype == np.float32


def test_config_precision_validation():
    from repro.api import BigMeansConfig

    with pytest.raises(ValueError, match="unknown precision"):
        BigMeansConfig(k=3, s=10, precision="fp16")
    with pytest.raises(ValueError, match="autotune"):
        BigMeansConfig(k=3, s=10, autotune=1)
    cfg = BigMeansConfig(k=3, s=10, precision="bf16", autotune=True)
    assert cfg.precision == "bf16"


# ---------------------------------------------------------------------------
# satellite: ops.fused_step fallback honors ref_chunked
# ---------------------------------------------------------------------------


def test_fused_step_fallback_honors_ref_chunked(monkeypatch):
    x, c = _blobs(m=200, n=16, k=4)
    seen = []
    real_assign = ops.assign

    def spy(xa, ca, **kw):
        seen.append(kw.get("impl"))
        return real_assign(xa, ca, **kw)

    monkeypatch.setattr(ops, "assign", spy)
    # weights force the two-pass fallback even inside the fused envelope
    w = jnp.ones((x.shape[0],))
    ops.fused_step(x, c, weights=w, impl="ref_chunked")
    assert seen == ["ref_chunked"]
    # envelope miss (k > MAX_K) also keeps the bounded-working-set path
    seen.clear()
    kbig = 130
    cbig = jax.random.normal(jax.random.PRNGKey(0), (kbig, 2000))
    xbig = jax.random.normal(jax.random.PRNGKey(1), (64, 2000))
    ops.fused_step(xbig, cbig, impl="ref_chunked")
    assert seen == ["ref_chunked"]


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------


@pytest.fixture
def clean_autotune():
    autotune.clear()
    was_enabled, was_path = autotune.enabled(), autotune.cache_path()
    yield
    autotune.clear()
    autotune.enable(was_enabled)
    autotune.set_cache_path(was_path)


def test_autotune_tilings_never_change_numerics(clean_autotune):
    """Acceptance: identical (sums, counts, obj) across candidate tilings.

    Integer-valued data makes every partial sum exactly representable in
    f32, so the comparison is bitwise — any tile-dependent accumulation
    difference would fail loudly.
    """
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (700, 24), -8, 8).astype(jnp.float32)
    c = jax.random.randint(jax.random.PRNGKey(1), (25, 24), -8, 8).astype(
        jnp.float32)
    outs = [fused_step_pallas(x, c, block_m=bm, interpret=True)
            for bm in (128, 256, 512)]
    for s, n, o in outs[1:]:
        np.testing.assert_array_equal(np.asarray(s), np.asarray(outs[0][0]))
        np.testing.assert_array_equal(np.asarray(n), np.asarray(outs[0][1]))
        assert float(o) == float(outs[0][2])

    xb, cb = x[None], c[None]
    outs = [fused_step_batched_pallas(xb, cb, block_m=bm, block_k=bk,
                                      block_n=bn, interpret=True)
            for bm, bk, bn in ((256, 128, 512), (128, 256, 256))]
    np.testing.assert_array_equal(np.asarray(outs[0][0]),
                                  np.asarray(outs[1][0]))
    np.testing.assert_array_equal(np.asarray(outs[0][1]),
                                  np.asarray(outs[1][1]))
    np.testing.assert_array_equal(np.asarray(outs[0][2]),
                                  np.asarray(outs[1][2]))


def test_autotune_candidates_include_shape_derived_default(clean_autotune):
    """Tuning must always time the tiling the un-tuned kernel would use,
    so a cached winner can never be slower than not tuning (n=20 resolves
    block_n=128, which the generic candidate grid does not contain)."""
    cands = autotune.candidates("fused_batched", b=4, m=16384, k=25, n=20,
                                precision="f32")
    assert cands[0] == {"block_m": 256, "block_k": 128, "block_n": 128}


def test_autotune_disabled_returns_defaults(clean_autotune):
    autotune.enable(False)
    blocks = autotune.get_blocks(
        "fused", lambda blk: (lambda: None),
        backend="interpret", b=1, m=256, k=25, n=20, precision="f32")
    assert blocks == {"block_m": 256}


def test_autotune_cache_roundtrip(tmp_path, clean_autotune):
    """Cache write + reload: the winner is timed once, then served from disk."""
    cache = tmp_path / "tune.json"
    autotune.set_cache_path(cache)
    autotune.enable(True)

    calls = []

    def bench_factory(blocks):
        def run():
            calls.append(dict(blocks))
        return run

    kw = dict(backend="interpret", b=1, m=256, k=25, n=20, precision="bf16")
    first = autotune.get_blocks("fused", bench_factory, **kw)
    assert cache.exists()
    assert calls, "tuning should have timed candidates"

    # fresh process simulation: drop the in-memory cache, keep the file
    autotune.clear(disk=False)
    calls.clear()
    again = autotune.get_blocks("fused", bench_factory, **kw)
    assert again == first
    assert calls == [], "disk hit must not re-time"

    key = autotune.cache_key("fused", **kw)
    import json
    entries = json.loads(cache.read_text())["entries"]
    assert entries[key] == first


def test_fit_with_autotune_flag(clean_autotune):
    """cfg.autotune=True tunes for the call's duration, then restores the
    previous enable state (no sticky process-wide surprise sweeps)."""
    from repro.api import BigMeansConfig, fit

    autotune.enable(False)
    rng = np.random.default_rng(1)
    X = rng.normal(size=(5_000, 8)).astype(np.float32)
    cfg = BigMeansConfig(k=4, s=512, n_chunks=4, impl="ref", autotune=True)
    res = fit(X, cfg)
    assert not autotune.enabled()
    assert np.isfinite(res.objective)


def test_autotune_smoke_via_ops_interpret(clean_autotune):
    """ops consults the tuner and the tuned launch matches the oracle."""
    autotune.enable(True)
    x, c = _blobs(m=300, n=28, k=25)
    s_p, n_p, o_p = ops.fused_step(x, c, impl="pallas_interpret",
                                   precision="bf16")
    s_r, n_r, o_r = ops.fused_step(x, c, impl="ref", precision="bf16")
    np.testing.assert_array_equal(np.asarray(n_p), np.asarray(n_r))
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r),
                               rtol=1e-2, atol=1e-2)
    np.testing.assert_allclose(float(o_p), float(o_r), rtol=1e-2)
