"""Mixed-precision kernel stack + block-size autotuner.

Covers the ISSUE 3 acceptance matrix:

* bf16 numerics: objective within rtol=1e-2 of the f32 oracle; batch=1
  batched kernel == single kernel under bf16; padding (lanes and features)
  never wins an argmin or leaks into sums.
* ``fit(..., precision='bf16')`` within 1% relative ``f_best`` of the f32
  run on the paper-regime synthetic workload (same seeds).
* autotuner: tile choice never changes numerics; on-disk cache write +
  reload round-trip; ops consults the tuner under ``pallas_interpret``.
* ``ops.fused_step`` two-pass fallback honors ``impl='ref_chunked'``.

And the ISSUE 9 kernel-depth matrix:

* int8 numerics: bitwise ref-vs-Pallas parity on integer data; padding
  invariance; ``fit(..., precision='int8')`` within 1% of f32 on the
  evalsuite quick datasets; ``warm_assign`` demotes the int8 serving
  shape under injected kernel failure.
* k > 128 argmin tiling: a k=256 shape (legacy envelope miss) runs the
  single fused kernel and matches the two-pass oracle; the autotuner's
  candidate set covers it.
* double-buffered DMA pipeline: 'dma' matches 'blocks' bitwise on
  integer data and both are autotune candidates.
* committed profile round-trip: ``results/autotune/interpret.json``
  loads, is consulted by ops, and corrupt / stale-schema cache files are
  ignored with a recorded event instead of crashing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops, ref
from repro.kernels import precision as px
from repro.kernels.fused_step import fused_step_batched_pallas, fused_step_pallas


def _blobs(m=400, n=28, k=25, seed=0):
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    centers = jax.random.normal(kc, (k, n)) * 4.0
    ids = jax.random.randint(jax.random.PRNGKey(seed + 1), (m,), 0, k)
    x = centers[ids] + jax.random.normal(kx, (m, n)) * 0.3
    c = centers + 0.05
    return x, c


# ---------------------------------------------------------------------------
# precision policy helpers
# ---------------------------------------------------------------------------


def test_precision_validation():
    with pytest.raises(ValueError, match="unknown precision"):
        px.check("fp8")
    assert px.check("bf16") == "bf16"
    assert px.storage_dtype("bf16") == jnp.bfloat16
    assert px.storage_dtype("bf16x3") == jnp.float32
    # 'auto' follows the data dtype (legacy behaviour); concrete values win
    assert px.resolve("auto", jnp.bfloat16) == "bf16"
    assert px.resolve(None, jnp.float32) == "f32"
    assert px.resolve("f32", jnp.bfloat16) == "f32"


def test_bf16x3_compensation_beats_bf16():
    x, c = _blobs()
    d32 = ref.pairwise_sqdist_ref(x, c, precision="f32")
    dbf = ref.pairwise_sqdist_ref(x, c, precision="bf16")
    dx3 = ref.pairwise_sqdist_ref(x, c, precision="bf16x3")
    err_bf = float(jnp.max(jnp.abs(dbf - d32)))
    err_x3 = float(jnp.max(jnp.abs(dx3 - d32)))
    assert err_x3 < err_bf / 4, (err_x3, err_bf)


# ---------------------------------------------------------------------------
# bf16 kernel numerics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n,k", [(300, 28, 25), (257, 100, 37)])
def test_bf16_fused_objective_close_to_f32_oracle(m, n, k):
    # Unit-scale blobs: the per-iteration kernel objective carries raw bf16
    # dot rounding (the compensated f32 epilogue is lloyd's, tested below),
    # so the comparison runs where distances are not cancellation-dominated.
    kx, kc = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (m, n))
    c = jax.random.normal(kc, (k, n))
    _, _, obj_bf = fused_step_pallas(x, c, precision="bf16", interpret=True)
    ids, d = ref.assign_ref(x, c, precision="f32")
    obj_f32 = float(jnp.sum(d))
    np.testing.assert_allclose(float(obj_bf), obj_f32, rtol=1e-2)


def test_bf16_lloyd_objective_close_to_f32_oracle():
    from repro.core import kmeans
    from repro.core.kmeanspp import kmeanspp

    x, _ = _blobs(m=2000, n=12, k=6, seed=7)
    c0 = kmeanspp(x, jax.random.PRNGKey(5), 6)
    res32 = kmeans.lloyd(x, c0, impl="ref", precision="f32")
    resbf = kmeans.lloyd(x, c0, impl="ref", precision="bf16")
    np.testing.assert_allclose(float(resbf.objective), float(res32.objective),
                               rtol=1e-2)


@pytest.mark.parametrize("precision", ["f32", "bf16"])
def test_batched_batch1_matches_single_kernel(precision):
    x, c = _blobs(m=300, n=28, k=25)
    s1, n1, o1 = fused_step_pallas(x, c, precision=precision, interpret=True)
    sb, nb, ob = fused_step_batched_pallas(
        x[None], c[None], precision=precision, interpret=True)
    np.testing.assert_array_equal(np.asarray(nb[0]), np.asarray(n1))
    np.testing.assert_allclose(np.asarray(sb[0]), np.asarray(s1),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(ob[0]), float(o1), rtol=1e-6)


@pytest.mark.parametrize("m,n,k", [(257, 29, 5), (100, 130, 129)])
def test_bf16_padding_invariance(m, n, k):
    """Padded lanes must never win an argmin; padded features never leak."""
    x, c = _blobs(m, n, k, seed=3)
    ids, d = ops.assign(x, c, impl="pallas_interpret", precision="bf16")
    assert int(jnp.max(ids)) < k
    assert int(jnp.min(ids)) >= 0
    assert bool(jnp.all(d >= 0))
    # same inputs embedded in a larger feature space padded with zeros:
    # distances and assignments are unchanged (bf16 zero-padding is exact)
    ids_ref, d_ref = ref.assign_ref(
        x.astype(jnp.bfloat16), c, precision="bf16")
    agree = np.mean(np.asarray(ids) == np.asarray(ids_ref))
    assert agree > 0.99, agree
    sums, counts = ops.update(x, ids, k, impl="pallas_interpret",
                              precision="bf16")
    assert float(jnp.sum(counts)) == m
    # zero-feature padding in the kernel cannot contribute to sums: compare
    # against the oracle over identical assignments
    sums_ref, counts_ref = ref.update_ref(x.astype(jnp.bfloat16), ids, k,
                                          precision="bf16")
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(counts_ref))
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sums_ref),
                               rtol=1e-2, atol=1e-2)


def test_fit_bf16_within_1pct_of_f32():
    """Acceptance: paper-regime synthetic workload, same seeds, <1% f_best."""
    from repro.api import BigMeansConfig, fit, synthetic

    X = synthetic.gmm_dataset(
        synthetic.GMMSpec(m=60_000, n=20, components=25, seed=12))
    cfg = BigMeansConfig(k=25, s=8192, n_chunks=8, impl="ref", seed=0)
    r32 = fit(X, cfg)
    rbf = fit(X, cfg, precision="bf16")
    rel = abs(rbf.objective - r32.objective) / r32.objective
    assert rel < 0.01, (r32.objective, rbf.objective, rel)


def test_streaming_runner_serves_bf16_chunks():
    from repro.api import BigMeansConfig, as_source, fit

    rng = np.random.default_rng(0)
    X = rng.normal(size=(20_000, 8)).astype(np.float32)
    src = as_source(X)
    fetch = src.provider(1024, seed=0, dtype=__import__("ml_dtypes").bfloat16)
    chunk = fetch(0)
    assert chunk.dtype == np.dtype(__import__("ml_dtypes").bfloat16)
    cfg = BigMeansConfig(k=5, s=1024, n_chunks=6, impl="ref",
                         precision="bf16", prefetch=2)
    res = fit(src, cfg, method="streaming")
    assert np.isfinite(res.objective)
    assert res.n_chunks == 6


def test_memmap_provider_explicit_dtype_wins(tmp_path):
    from repro.api import MemmapSource

    X = np.random.default_rng(2).normal(size=(200, 4)).astype(np.float64)
    path = tmp_path / "data.npy"
    np.save(path, X)
    src = MemmapSource(path, dtype=np.float64)
    assert src.provider(16)(0).dtype == np.float64          # native default
    assert src.provider(16, dtype=np.float32)(0).dtype == np.float32


def test_config_precision_validation():
    from repro.api import BigMeansConfig

    with pytest.raises(ValueError, match="unknown precision"):
        BigMeansConfig(k=3, s=10, precision="fp16")
    with pytest.raises(ValueError, match="autotune"):
        BigMeansConfig(k=3, s=10, autotune=1)
    cfg = BigMeansConfig(k=3, s=10, precision="bf16", autotune=True)
    assert cfg.precision == "bf16"


# ---------------------------------------------------------------------------
# satellite: ops.fused_step fallback honors ref_chunked
# ---------------------------------------------------------------------------


def test_fused_step_fallback_honors_ref_chunked(monkeypatch):
    x, c = _blobs(m=200, n=16, k=4)
    seen = []
    real_assign = ops.assign

    def spy(xa, ca, **kw):
        seen.append(kw.get("impl"))
        return real_assign(xa, ca, **kw)

    monkeypatch.setattr(ops, "assign", spy)
    # weights force the two-pass fallback even inside the fused envelope
    w = jnp.ones((x.shape[0],))
    ops.fused_step(x, c, weights=w, impl="ref_chunked")
    assert seen == ["ref_chunked"]
    # envelope miss (k > MAX_K) also keeps the bounded-working-set path
    seen.clear()
    kbig = 130
    cbig = jax.random.normal(jax.random.PRNGKey(0), (kbig, 2000))
    xbig = jax.random.normal(jax.random.PRNGKey(1), (64, 2000))
    ops.fused_step(xbig, cbig, impl="ref_chunked")
    assert seen == ["ref_chunked"]


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------


@pytest.fixture
def clean_autotune():
    autotune.clear()
    was_enabled, was_path = autotune.enabled(), autotune.cache_path()
    yield
    autotune.clear()
    autotune.enable(was_enabled)
    autotune.set_cache_path(was_path)


def test_autotune_tilings_never_change_numerics(clean_autotune):
    """Acceptance: identical (sums, counts, obj) across candidate tilings.

    Integer-valued data makes every partial sum exactly representable in
    f32, so the comparison is bitwise — any tile-dependent accumulation
    difference would fail loudly.
    """
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (700, 24), -8, 8).astype(jnp.float32)
    c = jax.random.randint(jax.random.PRNGKey(1), (25, 24), -8, 8).astype(
        jnp.float32)
    outs = [fused_step_pallas(x, c, block_m=bm, interpret=True)
            for bm in (128, 256, 512)]
    for s, n, o in outs[1:]:
        np.testing.assert_array_equal(np.asarray(s), np.asarray(outs[0][0]))
        np.testing.assert_array_equal(np.asarray(n), np.asarray(outs[0][1]))
        assert float(o) == float(outs[0][2])

    xb, cb = x[None], c[None]
    outs = [fused_step_batched_pallas(xb, cb, block_m=bm, block_k=bk,
                                      block_n=bn, interpret=True)
            for bm, bk, bn in ((256, 128, 512), (128, 256, 256))]
    np.testing.assert_array_equal(np.asarray(outs[0][0]),
                                  np.asarray(outs[1][0]))
    np.testing.assert_array_equal(np.asarray(outs[0][1]),
                                  np.asarray(outs[1][1]))
    np.testing.assert_array_equal(np.asarray(outs[0][2]),
                                  np.asarray(outs[1][2]))


def test_autotune_candidates_include_shape_derived_default(clean_autotune):
    """Tuning must always time the tiling the un-tuned kernel would use,
    so a cached winner can never be slower than not tuning (n=20 resolves
    block_n=128, which the generic candidate grid does not contain)."""
    cands = autotune.candidates("fused_batched", b=4, m=16384, k=25, n=20,
                                precision="f32")
    assert cands[0] == {"block_m": 256, "block_k": 128, "block_n": 128}


def test_autotune_disabled_returns_defaults(clean_autotune):
    autotune.enable(False)
    blocks = autotune.get_blocks(
        "fused", lambda blk: (lambda: None),
        backend="interpret", b=1, m=256, k=25, n=20, precision="f32")
    assert blocks == {"block_m": 256, "block_k": None, "block_n": None,
                      "pipeline": "blocks"}


def test_autotune_cache_roundtrip(tmp_path, clean_autotune):
    """Cache write + reload: the winner is timed once, then served from disk."""
    cache = tmp_path / "tune.json"
    autotune.set_cache_path(cache)
    autotune.enable(True)

    calls = []

    def bench_factory(blocks):
        def run():
            calls.append(dict(blocks))
        return run

    kw = dict(backend="interpret", b=1, m=256, k=25, n=20, precision="bf16")
    first = autotune.get_blocks("fused", bench_factory, **kw)
    assert cache.exists()
    assert calls, "tuning should have timed candidates"

    # fresh process simulation: drop the in-memory cache, keep the file
    autotune.clear(disk=False)
    calls.clear()
    again = autotune.get_blocks("fused", bench_factory, **kw)
    assert again == first
    assert calls == [], "disk hit must not re-time"

    key = autotune.cache_key("fused", **kw)
    import json
    entries = json.loads(cache.read_text())["entries"]
    assert entries[key] == first


def test_fit_with_autotune_flag(clean_autotune):
    """cfg.autotune=True tunes for the call's duration, then restores the
    previous enable state (no sticky process-wide surprise sweeps)."""
    from repro.api import BigMeansConfig, fit

    autotune.enable(False)
    rng = np.random.default_rng(1)
    X = rng.normal(size=(5_000, 8)).astype(np.float32)
    cfg = BigMeansConfig(k=4, s=512, n_chunks=4, impl="ref", autotune=True)
    res = fit(X, cfg)
    assert not autotune.enabled()
    assert np.isfinite(res.objective)


def test_autotune_smoke_via_ops_interpret(clean_autotune):
    """ops consults the tuner and the tuned launch matches the oracle."""
    autotune.enable(True)
    x, c = _blobs(m=300, n=28, k=25)
    s_p, n_p, o_p = ops.fused_step(x, c, impl="pallas_interpret",
                                   precision="bf16")
    s_r, n_r, o_r = ops.fused_step(x, c, impl="ref", precision="bf16")
    np.testing.assert_array_equal(np.asarray(n_p), np.asarray(n_r))
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r),
                               rtol=1e-2, atol=1e-2)
    np.testing.assert_allclose(float(o_p), float(o_r), rtol=1e-2)


# ---------------------------------------------------------------------------
# int8 numerics (ISSUE 9)
# ---------------------------------------------------------------------------


def _int8_exact_blobs(m=300, n=24, k=25, seed=0):
    """Integer data on which int8 quantization is *exact*.

    One point row of +/-127 pins every per-feature scale to exactly 1
    (``s[f] = max|x[:, f]| / 127``); a 127 column in the centroids pins
    every per-row scale ``t[j]`` to 1.  Codes then reproduce the values
    bit-for-bit and every contraction/accumulation stays on integers below
    2^24, so ref-vs-Pallas comparisons are bitwise whatever the tiling.
    """
    rng = np.random.default_rng(seed)
    x = rng.integers(-8, 9, size=(m, n)).astype(np.float32)
    x[0, :] = 127.0
    x[1, :] = -127.0
    c = rng.integers(-8, 9, size=(k, n)).astype(np.float32)
    c[:, 0] = 127.0
    return jnp.asarray(x), jnp.asarray(c)


def test_int8_precision_policy():
    assert px.check("int8") == "int8"
    assert px.storage_dtype("int8") == jnp.int8
    assert px.resolve("auto", jnp.int8) == "int8"
    qx = px.cast_storage(jnp.ones((4, 3)), "int8")
    assert isinstance(qx, px.QuantizedChunk)
    assert px.cast_storage(qx, "int8") is qx               # idempotent


def test_int8_quantization_exact_on_pinned_data():
    x, _ = _int8_exact_blobs()
    qx = px.quantize_chunk(x)
    np.testing.assert_array_equal(np.asarray(qx.scale),
                                  np.ones(x.shape[1], np.float32))
    np.testing.assert_array_equal(np.asarray(px.dequantize(qx)),
                                  np.asarray(x))
    # host-thread quantization is the bitwise twin of the device path
    qh, sh = px.host_quantize(np.asarray(x))
    np.testing.assert_array_equal(np.asarray(qx.q), qh)
    np.testing.assert_array_equal(np.asarray(qx.scale), sh)


@pytest.mark.parametrize("pipeline", ["blocks", "dma"])
def test_int8_fused_pallas_bitwise_matches_ref(pipeline):
    """Acceptance: ref-vs-Pallas parity on integer data is *bitwise* —
    int8 contractions are exact int32 and every f32 value is an integer
    below 2^24, so any tiling- or pipeline-dependent difference in the
    quantized math fails loudly, on both pipelines."""
    x, c = _int8_exact_blobs()
    s_r, n_r, o_r = ops.fused_step(x, c, impl="ref", precision="int8")
    for bm in (128, 256):
        s_p, n_p, o_p = fused_step_pallas(
            x, c, precision="int8", block_m=bm, pipeline=pipeline,
            interpret=True)
        np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_r))
        np.testing.assert_array_equal(np.asarray(n_p), np.asarray(n_r))
        assert float(o_p) == float(o_r), (bm, pipeline)
    # a pre-quantized chunk (what the streaming prefetcher ships) is the
    # same computation as quantize-at-entry
    s_q, n_q, o_q = fused_step_pallas(
        px.quantize_chunk(x), c, pipeline=pipeline, interpret=True)
    np.testing.assert_array_equal(np.asarray(s_q), np.asarray(s_r))
    assert float(o_q) == float(o_r)


@pytest.mark.parametrize("m,n,k", [(257, 29, 5), (100, 30, 129)])
def test_int8_assign_parity_and_padding_invariance(m, n, k):
    """Padded lanes never win an argmin; zero-padded features change
    nothing (their quantization scale floors, codes stay 0)."""
    x, c = _int8_exact_blobs(m, n, k, seed=3)
    ids_p, d_p = ops.assign(x, c, impl="pallas_interpret", precision="int8")
    ids_r, d_r = ref.assign_ref(x, c, precision="int8")
    assert int(jnp.max(ids_p)) < k and int(jnp.min(ids_p)) >= 0
    np.testing.assert_array_equal(np.asarray(ids_p), np.asarray(ids_r))
    np.testing.assert_array_equal(np.asarray(d_p), np.asarray(d_r))
    # same data embedded in a wider zero-padded feature space: identical
    xw = jnp.pad(x, ((0, 0), (0, 7)))
    cw = jnp.pad(c, ((0, 0), (0, 7)))
    ids_w, d_w = ops.assign(xw, cw, impl="pallas_interpret",
                            precision="int8")
    np.testing.assert_array_equal(np.asarray(ids_w), np.asarray(ids_r))
    np.testing.assert_array_equal(np.asarray(d_w), np.asarray(d_r))


@pytest.mark.parametrize("dataset", ["hepmass-16k", "road3d-24k"])
def test_fit_int8_within_1pct_of_f32_on_quick_datasets(dataset):
    """Acceptance: <1% relative f_best drift vs the f32 run, same seeds,
    on the evalsuite quick-tier datasets (real registry memmaps, reduced
    chunk budget to keep tier-1 wall time down)."""
    from repro.api import BigMeansConfig, fit
    from repro.evalsuite import datasets as ds

    spec = ds.get_dataset(dataset)
    src = ds.source(spec)
    cfg = BigMeansConfig(k=spec.k, s=spec.s, n_chunks=8, impl="ref", seed=0)
    r32 = fit(src, cfg)
    r8 = fit(src, cfg, precision="int8")
    rel = abs(r8.objective - r32.objective) / r32.objective
    assert rel < 0.01, (dataset, r32.objective, r8.objective, rel)


@pytest.fixture
def clean_demotions():
    ops.reset_kernel_demotions()
    yield
    ops.reset_kernel_demotions()


def test_warm_assign_int8_demotes_under_kernel_failure(clean_demotions):
    """A Pallas failure on the int8 serving shape demotes exactly that
    (shape, precision) key during warmup and serving falls back to ref."""
    from repro.engine import faults

    with faults.kernel_failure("assign"):
        got = ops.warm_assign(32, 256, 16, impl="pallas_interpret",
                              precision="int8")
    assert got == "ref"
    demos = [d for d in ops.kernel_demotions()
             if d["op"] == "assign" and d["shape"] == (1, 32, 256, 16)
             and d["precision"] == "int8"]
    assert demos, ops.kernel_demotions()
    # the demoted shape serves bitwise-correct results through the ref path
    x, c = _int8_exact_blobs(32, 16, 256, seed=5)
    ids, d = ops.assign(x, c, impl="pallas_interpret", precision="int8")
    ids_r, d_r = ref.assign_ref(x, c, precision="int8")
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids_r))


# ---------------------------------------------------------------------------
# k > 128 argmin tiling + DMA pipeline (ISSUE 9)
# ---------------------------------------------------------------------------


def test_k256_runs_single_fused_kernel_matches_oracle():
    """Acceptance: a k=256 shape that the legacy envelope (k <= 128) sent
    to the two-pass fallback now runs the single fused kernel, bitwise
    equal to the oracle on integer data, on both pipelines."""
    from repro.kernels.fused_step import LEGACY_MAX_K, fits

    k, n = 256, 20
    assert k > LEGACY_MAX_K and fits(k, n)
    x, c = _int8_exact_blobs(m=200, n=n, k=k, seed=7)
    s_r, n_r, o_r = ops.fused_step(x, c, impl="ref", precision="f32")
    for pipeline in ("blocks", "dma"):
        s_p, n_p, o_p = fused_step_pallas(x, c, precision="f32",
                                          pipeline=pipeline, interpret=True)
        np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_r))
        np.testing.assert_array_equal(np.asarray(n_p), np.asarray(n_r))
        assert float(o_p) == float(o_r), pipeline


def test_autotune_candidates_cover_k256_and_dma(clean_autotune):
    """The tuner's fused candidate set covers the widened envelope: the
    k=256 cell gets real candidates, both pipelines are timed, and the
    shape-derived 'blocks' default stays first (ties keep history)."""
    cands = autotune.candidates("fused", b=1, m=4096, k=256, n=20,
                                precision="f32")
    assert cands[0]["pipeline"] == "blocks"
    assert any(c["pipeline"] == "dma" for c in cands)
    from repro.kernels import fused_step as fused
    for c in cands:
        k_pad, n_pad, _, _ = fused._batched_tiles(
            256, 20, c["block_k"], c["block_n"])
        assert k_pad * n_pad <= fused._MAX_KN_ELEMS, c


def test_unknown_pipeline_rejected():
    x, c = _int8_exact_blobs(m=64, n=8, k=4)
    with pytest.raises(ValueError, match="unknown pipeline"):
        fused_step_pallas(x, c, pipeline="prefetch", interpret=True)


# ---------------------------------------------------------------------------
# committed autotune profile + cache observability (ISSUE 9)
# ---------------------------------------------------------------------------

_PROFILE = __import__("pathlib").Path(__file__).resolve().parent.parent \
    / "results" / "autotune" / "interpret.json"


def test_committed_profile_loads_and_is_consulted(clean_autotune):
    """Every entry in the committed per-backend profile round-trips: the
    lazy disk load accepts the file, get_blocks serves each key without
    re-timing (the bench spy must never run), and no load anomaly event
    is recorded."""
    import json

    data = json.loads(_PROFILE.read_text())
    assert data["version"] == 1 and data["entries"]
    autotune.set_cache_path(_PROFILE)
    autotune.enable(True)
    n_events = len(autotune.events())

    timed = []

    def bench_factory(blocks):
        return lambda: timed.append(dict(blocks))

    for key, entry in data["entries"].items():
        kind, backend, b, m, k, n, prec = key.split("|")
        got = autotune.get_blocks(
            kind, bench_factory, backend=backend, b=int(b[1:]),
            m=int(m[1:]), k=int(k[1:]), n=int(n[1:]), precision=prec)
        assert got == entry, key
    assert timed == [], "profile hits must not re-time candidates"
    assert autotune.events()[n_events:] == []


def test_corrupt_cache_ignored_with_event(tmp_path, clean_autotune):
    cache = tmp_path / "tune.json"
    cache.write_text("{this is not json")
    autotune.set_cache_path(cache)
    autotune.enable(True)
    n_events = len(autotune.events())
    blocks = autotune.get_blocks(
        "fused", None, backend="interpret", b=1, m=64, k=5, n=8,
        precision="f32")
    assert blocks == {"block_m": 256, "block_k": None, "block_n": None,
                      "pipeline": "blocks"}
    new = autotune.events()[n_events:]
    assert len(new) == 1
    kind, path, reason = new[0]
    assert kind == "autotune_cache_ignored"
    assert path == str(cache)
    assert reason.startswith("unreadable")


def test_stale_schema_cache_ignored_with_event(tmp_path, clean_autotune):
    import json

    cache = tmp_path / "tune.json"
    cache.write_text(json.dumps({"version": 99, "entries": {}}))
    autotune.set_cache_path(cache)
    n_events = len(autotune.events())
    autotune.get_blocks("fused", None, backend="interpret", b=1, m=64,
                        k=5, n=8, precision="f32")
    new = autotune.events()[n_events:]
    assert new == [("autotune_cache_ignored", str(cache),
                    "stale schema version 99")]


def test_malformed_cache_entry_ignored_with_event(tmp_path, clean_autotune):
    """One bad entry is skipped (with an event); good entries still load."""
    import json

    good_key = autotune.cache_key("fused", backend="interpret", b=1, m=64,
                                  k=5, n=8, precision="f32")
    bad_key = autotune.cache_key("fused", backend="interpret", b=1, m=64,
                                 k=5, n=8, precision="bf16")
    good = {"block_m": 128, "block_k": 128, "block_n": 256,
            "pipeline": "dma"}
    cache = tmp_path / "tune.json"
    cache.write_text(json.dumps({
        "version": 1,
        "entries": {good_key: good, bad_key: {"block_m": [128]}}}))
    autotune.set_cache_path(cache)
    n_events = len(autotune.events())
    got = autotune.get_blocks("fused", None, backend="interpret", b=1,
                              m=64, k=5, n=8, precision="f32")
    assert got == good
    assert autotune.events()[n_events:] == [
        ("autotune_cache_entry_ignored", str(cache), bad_key)]


def test_fit_surfaces_cache_ignored_event_in_trace(tmp_path, clean_autotune):
    """End-to-end observability: a corrupt on-disk cache consulted during
    fit()'s pre-tune lands in the run trace instead of crashing (or being
    silently swallowed)."""
    from repro.api import BigMeansConfig, fit

    cache = tmp_path / "tune.json"
    cache.write_text("%% corrupt %%")
    autotune.set_cache_path(cache)
    rng = np.random.default_rng(3)
    # an unusual shape: block sizes are read at trace time, so a shape any
    # other test already jitted would skip get_blocks (and the lazy load)
    X = rng.normal(size=(4_200, 9)).astype(np.float32)
    cfg = BigMeansConfig(k=7, s=600, n_chunks=2, impl="pallas_interpret",
                         seed=0)
    res = fit(X, cfg)
    assert np.isfinite(res.objective)
    evs = [t for t in res.trace
           if isinstance(t, tuple) and isinstance(t[0], str)
           and t[0] == "autotune_cache_ignored"]
    assert evs and evs[0][1] == str(cache), res.trace[-5:]
