"""Per-kernel correctness: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    (7, 3, 2),        # tiny, everything sub-block
    (300, 37, 10),    # non-aligned everything
    (256, 128, 8),    # exactly one block
    (1000, 130, 129), # k crosses a block boundary
    (513, 260, 5),    # feature dim crosses a block boundary
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _data(m, n, k, dtype, seed=0):
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, n), jnp.float32).astype(dtype)
    c = jax.random.normal(kc, (k, n), jnp.float32).astype(dtype)
    return x, c


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_assign_matches_ref(m, n, k, dtype):
    x, c = _data(m, n, k, dtype)
    ids_r, d_r = ops.assign(x, c, impl="ref")
    ids_p, d_p = ops.assign(x, c, impl="pallas_interpret")
    np.testing.assert_allclose(d_p, d_r, rtol=2e-4, atol=1e-3)
    # ids may differ only where two centroids are (numerically) tied
    diff = np.asarray(ids_p != ids_r)
    if diff.any():
        d_full = np.asarray(ref.pairwise_sqdist_ref(x, c))
        ties = np.abs(
            d_full[np.arange(m), np.asarray(ids_p)]
            - d_full[np.arange(m), np.asarray(ids_r)]
        )
        assert ties[diff].max() < 1e-3


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_update_matches_ref(m, n, k, dtype):
    x, c = _data(m, n, k, dtype)
    ids, _ = ops.assign(x, c, impl="ref")
    s_r, n_r = ops.update(x, ids, k, impl="ref")
    s_p, n_p = ops.update(x, ids, k, impl="pallas_interpret")
    np.testing.assert_allclose(n_p, n_r, atol=0)
    np.testing.assert_allclose(s_p, s_r, rtol=2e-4, atol=2e-3)


def test_assign_chunked_matches_ref():
    x, c = _data(5000, 17, 11, jnp.float32)
    ids_r, d_r = ops.assign(x, c, impl="ref")
    ids_c, d_c = ops.assign(x, c, impl="ref_chunked", chunk=512)
    np.testing.assert_array_equal(ids_c, ids_r)
    np.testing.assert_allclose(d_c, d_r, rtol=1e-6)


def test_update_weighted():
    x, c = _data(200, 5, 4, jnp.float32)
    ids, _ = ops.assign(x, c, impl="ref")
    w = jax.random.uniform(jax.random.PRNGKey(3), (200,))
    s, n = ops.update(x, ids, 4, weights=w)
    # total mass conservation
    np.testing.assert_allclose(np.sum(n), np.sum(w), rtol=1e-5)
    np.testing.assert_allclose(
        np.sum(s, axis=0), np.sum(np.asarray(x) * np.asarray(w)[:, None], axis=0),
        rtol=1e-4,
    )


def test_update_ignores_out_of_range_ids():
    x = jnp.ones((10, 4))
    ids = jnp.array([0, 1, 2, 3, -1, -1, 7, 9, 5, 0], jnp.int32)
    s, n = ops.update(x, ids, 4, impl="pallas_interpret")
    s_r, n_r = ops.update(x, ids, 4, impl="ref")
    np.testing.assert_allclose(s, s_r)
    np.testing.assert_allclose(n, n_r)
    assert float(jnp.sum(n)) == 5.0   # only ids < 4 and >= 0 counted


def test_min_update_ref():
    x = jax.random.normal(jax.random.PRNGKey(0), (50, 8))
    d0 = jnp.full((50,), jnp.inf)
    c_new = x[7]
    d = ref.min_update_ref(d0, x, c_new)
    assert float(d[7]) < 1e-10
    assert (np.asarray(d) >= 0).all()
