"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward + one train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.registry import LM_ARCHS, get_config, model_fns
from repro.train.optimizer import adamw
from repro.train.train_step import make_train_step

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


def make_batch(cfg):
    if cfg.family == "vlm":
        St = S - cfg.frontend_len
    else:
        St = S
    batch = {
        "tokens": jax.random.randint(KEY, (B, St), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (B, St), 0, cfg.vocab_size),
    }
    if cfg.frontend:
        flen = cfg.frontend_len if cfg.family == "vlm" else 16
        batch["frontend"] = jax.random.normal(
            KEY, (B, flen, cfg.frontend_dim))
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    mod = model_fns(cfg)
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg)

    # forward: logits shape + finite
    if cfg.family == "encdec":
        logits, _ = mod.forward(cfg, params, batch["tokens"],
                                batch["frontend"])
        exp_len = batch["tokens"].shape[1]
    elif cfg.family == "vlm":
        logits, _ = mod.forward(cfg, params, batch["tokens"],
                                frontend=batch["frontend"])
        exp_len = S
    else:
        logits, _ = mod.forward(cfg, params, batch["tokens"])
        exp_len = S
    assert logits.shape == (B, exp_len, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    # one full train step: loss finite, params updated, no NaNs anywhere
    opt = adamw(1e-3)
    step = make_train_step(cfg, opt)
    opt_state = opt.init(params)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    leaves = jax.tree.leaves(new_params)
    assert all(bool(jnp.isfinite(l.astype(jnp.float32)).all()) for l in leaves)
    # at least one parameter moved
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), leaves))
    assert moved


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_full_config_constants(arch):
    """The full (unreduced) configs carry the exact assigned constants."""
    cfg = get_config(arch)
    expected = {
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff if not cfg.moe else cfg.moe_d_ff, cfg.vocab_size)
    assert got == expected
    if arch == "deepseek-moe-16b":
        assert (cfg.num_experts, cfg.top_k, cfg.num_shared_experts) == (64, 6, 2)
    if arch == "qwen3-moe-235b-a22b":
        assert (cfg.num_experts, cfg.top_k) == (128, 8)
    if arch == "hymba-1.5b":
        assert cfg.ssm_state == 16
