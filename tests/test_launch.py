"""Launch-layer units that run on one device: HLO collective parser, input
specs, sharding rules, roofline math."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.shapes import SHAPES
from repro.launch import hlo_analysis, roofline, specs
from repro.models.registry import LM_ARCHS, get_config
from repro.train import sharding as sh

HLO = """
HloModule test
ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ag = f32[64,128]{1,0} all-gather(f32[16,128]{1,0} %p0), replica_groups={}
  %c = f32[16,128]{1,0} constant(0)
  %ar = f32[16,128]{1,0} all-reduce(f32[16,128]{1,0} %c), to_apply=%add
  %rs-start = f32[4,128]{1,0} reduce-scatter-start(f32[16,128]{1,0} %c)
  %rs-done = f32[4,128]{1,0} reduce-scatter-done(%rs-start)
  %add2 = f32[16,128]{1,0} add(%p0, %c)
  ROOT %out = f32[16,128]{1,0} copy(%add2)
}
"""


def test_collective_parser():
    res = hlo_analysis.collective_bytes(HLO)
    f = 16 * 128 * 4
    assert res["by_op"]["all-gather"] == f
    assert res["by_op"]["all-reduce"] == f
    assert res["by_op"]["reduce-scatter"] == f
    assert res["count"] == 3
    assert res["total"] == 3 * f


def test_collective_parser_ignores_compute():
    res = hlo_analysis.collective_bytes(
        "%d = f32[8,8]{1,0} dot(f32[8,8] %a, f32[8,8] %b)")
    assert res["total"] == 0


@pytest.mark.parametrize("arch", LM_ARCHS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_all_cells(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    sp = specs.input_specs(cfg, shape)
    if shape.kind == "train":
        B, St = sp["tokens"].shape
        assert B == shape.global_batch
        total = St + (cfg.frontend_len if cfg.family == "vlm" else 0)
        assert total == shape.seq_len
    if shape.kind == "decode":
        assert sp["token"].shape == (shape.global_batch, 1)
        if cfg.family != "ssm":
            assert sp["cache"]["k"].shape[2] == shape.seq_len
        # no array was allocated
        assert isinstance(sp["token"], jax.ShapeDtypeStruct)


def test_param_pspec_rules():
    import types
    import numpy as np
    # fabricated 4x16 mesh: spec() only reads axis_names / devices.shape
    mesh = types.SimpleNamespace(axis_names=("data", "model"),
                                 devices=np.zeros((4, 16)))
    # divisible dims: sharded as requested
    assert sh.spec(mesh, "model", "fsdp", shape=(128, 64)) == \
        P("model", "data")
    # non-divisible dim falls back to replicated (e.g. vocab 127 on 16-way)
    spec = sh.spec(mesh, "model", "fsdp", shape=(127, 64))
    assert spec[0] is None
    assert spec[1] == "data"


def test_roofline_terms():
    out = roofline.roofline_terms(197e12, 819e9 * 2, 50e9)
    assert out["dominant"] == "memory"
    assert abs(out["compute_s"] - 1.0) < 1e-9
    assert abs(out["memory_s"] - 2.0) < 1e-9
    assert abs(out["roofline_fraction"] - 0.5) < 1e-9


def test_model_flops_kinds():
    cfg = get_config("hymba-1.5b")
    tr = roofline.model_flops(cfg, SHAPES["train_4k"])
    pf = roofline.model_flops(cfg, SHAPES["prefill_32k"])
    dc = roofline.model_flops(cfg, SHAPES["decode_32k"])
    n = cfg.param_count()
    assert tr == 6.0 * n * 256 * 4096
    assert pf == 2.0 * n * 32 * 32768
    assert dc == 2.0 * n * 128


def test_moe_active_flops():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert cfg.active_param_count() < 0.12 * cfg.param_count()
