"""The reproduction & regression harness (`repro.evalsuite`).

Covers the acceptance contract of the suite subsystem:
* schema round-trip validation (and that the validator actually rejects);
* ε / success-rate / time-to-target math against hand-computed values;
* gate pass/fail on synthetic regressions, including a non-zero exit
  against the *committed* baseline artifact;
* determinism of registry dataset generation (same spec ⇒ bitwise
  identical memmap);
* a miniature end-to-end suite run through `repro.api.fit`.
"""
import copy
import json
import math
import os

import numpy as np
import pytest

from repro.evalsuite import datasets as ds
from repro.evalsuite import gate, metrics, schema, suite

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(REPO, "results", "BENCH_baseline.json")


def _suite_doc() -> dict:
    """A minimal hand-built, schema-valid BENCH_suite document."""
    rows = [
        {"dataset": "d0", "method": "bm/sequential", "kind": "bigmeans",
         "seed": s, "f_full": f, "epsilon": (f - 100.0) / 100.0,
         "success": f <= 105.0, "wall_s": w, "n_chunks": 8,
         "n_iterations": 40, "n_accepted": 3}
        for s, f, w in [(0, 100.0, 2.0), (1, 104.0, 1.0), (2, 110.0, 3.0)]
    ]
    cells = [metrics.aggregate_cell("d0", "bm/sequential", "bigmeans", rows,
                                    success_tol=0.05)]
    return schema.envelope(
        "suite", rows, tier="quick", success_tol=0.05, protocol="test",
        datasets=[{"name": "d0", "paper_name": "kegg", "m": 1000, "n": 20,
                   "k": 5, "s": 100, "n_chunks": 8, "f_star": 100.0}],
        cells=cells)


# ---------------------------------------------------------------- schema

class TestSchema:
    def test_roundtrip_valid(self):
        doc = json.loads(json.dumps(_suite_doc()))
        assert schema.validate(doc, schema.SUITE_SCHEMA) == []
        assert schema.validate(doc, schema.ENVELOPE_SCHEMA) == []

    @pytest.mark.parametrize("mutate, fragment", [
        (lambda d: d.pop("cells"), "missing required field 'cells'"),
        (lambda d: d.update(schema_version="bogus/9"), "expected"),
        (lambda d: d.update(tier="weekly"), "not in"),
        (lambda d: d["rows"][0].update(wall_s="fast"), "expected type"),
        (lambda d: d["rows"][0].pop("epsilon"), "missing required"),
        (lambda d: d.update(rows=[]), ">= 1 items"),
        (lambda d: d["cells"][0].update(success_rate=-0.5), "minimum"),
    ])
    def test_rejects_corruptions(self, mutate, fragment):
        doc = _suite_doc()
        mutate(doc)
        errors = schema.validate(doc, schema.SUITE_SCHEMA)
        assert errors and any(fragment in e for e in errors), errors

    def test_check_raises_with_every_error(self):
        doc = _suite_doc()
        del doc["rows"][0]["epsilon"], doc["rows"][1]["f_full"]
        with pytest.raises(ValueError, match="2 error"):
            schema.check(doc, schema.SUITE_SCHEMA)

    def test_unknown_schema_keyword_is_programming_error(self):
        with pytest.raises(ValueError, match="unsupported schema keywords"):
            schema.validate({}, {"type": "object", "patternProperties": {}})

    def test_write_bench_refuses_invalid(self, tmp_path):
        doc = schema.envelope("x", rows=[{"a": 1}])
        del doc["host"]
        with pytest.raises(ValueError, match="host"):
            schema.write_bench(str(tmp_path / "b.json"), doc)
        assert not (tmp_path / "b.json").exists()

    def test_committed_bench_artifacts_are_schema_valid(self):
        for name in ("BENCH_batched.json", "BENCH_precision.json",
                     "BENCH_engine.json"):
            path = os.path.join(REPO, name)
            if not os.path.exists(path):
                continue
            with open(path) as f:
                doc = json.load(f)
            # migrated onto the shared envelope in this PR; older artifacts
            # regenerate on the next benchmark run
            if doc.get("schema_version") == schema.SCHEMA_VERSION:
                assert schema.validate(doc, schema.ENVELOPE_SCHEMA) == []


# --------------------------------------------------------------- metrics

class TestMetrics:
    def test_relative_error(self):
        assert metrics.relative_error(110.0, 100.0) == pytest.approx(0.10)
        assert metrics.relative_error(95.0, 100.0) == pytest.approx(-0.05)
        with pytest.raises(ValueError):
            metrics.relative_error(1.0, 0.0)
        with pytest.raises(ValueError):
            metrics.relative_error(1.0, math.nan)

    def test_success_rate(self):
        assert metrics.success_rate([0.01, 0.2, 0.04], 0.05) == pytest.approx(2 / 3)
        assert metrics.success_rate([0.5], 0.05) == 0.0
        assert metrics.success_rate([math.nan, 0.0], 0.05) == 0.5
        with pytest.raises(ValueError):
            metrics.success_rate([], 0.05)

    def test_time_to_target_curve(self):
        runs = [(2.0, True), (1.0, True), (3.0, False)]
        # grid defaults to the successful runs' own wall times
        assert metrics.time_to_target_curve(runs) == [
            [1.0, 1 / 3], [2.0, 2 / 3]]
        assert metrics.time_to_target_curve(runs, grid=[0.5, 10.0]) == [
            [0.5, 0.0], [10.0, 2 / 3]]
        # nothing succeeded: one flat zero point at the slowest run
        assert metrics.time_to_target_curve([(4.0, False)]) == [[4.0, 0.0]]

    def test_aggregate_cell_hand_computed(self):
        rows = [
            {"epsilon": 0.00, "wall_s": 2.0, "success": True},
            {"epsilon": 0.04, "wall_s": 1.0, "success": True},
            {"epsilon": 0.10, "wall_s": 3.0, "success": False},
        ]
        cell = metrics.aggregate_cell("d", "m", "bigmeans", rows,
                                      success_tol=0.05)
        assert cell["epsilon_mean"] == pytest.approx(0.14 / 3)
        assert cell["epsilon_min"] == 0.0
        assert cell["epsilon_max"] == pytest.approx(0.10)
        assert cell["success_rate"] == pytest.approx(2 / 3)
        assert cell["wall_mean_s"] == pytest.approx(2.0)
        assert cell["time_to_target"] == [[1.0, 1 / 3], [2.0, 2 / 3]]


# ------------------------------------------------------------------ gate

class TestGate:
    def test_identical_docs_pass(self):
        doc = _suite_doc()
        result = gate.compare(doc, copy.deepcopy(doc))
        assert result.ok and result.checked == 1
        assert "PASS" in result.report()

    def test_eps_regression_fails(self):
        base, fresh = _suite_doc(), _suite_doc()
        fresh["cells"][0]["epsilon_mean"] += 0.06      # > default 0.05 tol
        result = gate.compare(base, fresh)
        assert not result.ok
        assert any("epsilon_mean" in f for f in result.failures)

    def test_eps_improvement_only_warns(self):
        base, fresh = _suite_doc(), _suite_doc()
        fresh["cells"][0]["epsilon_mean"] -= 0.06
        result = gate.compare(base, fresh)
        assert result.ok
        assert any("improved" in w for w in result.warnings)

    def test_success_drop_fails(self):
        base, fresh = _suite_doc(), _suite_doc()
        fresh["cells"][0]["success_rate"] = 0.0        # baseline is 2/3
        result = gate.compare(base, fresh)
        assert not result.ok
        assert any("success_rate" in f for f in result.failures)

    def test_wall_regression_fails_and_no_wall_skips(self):
        base, fresh = _suite_doc(), _suite_doc()
        fresh["cells"][0]["wall_mean_s"] *= 3.0        # > default 2.5x
        assert not gate.compare(base, fresh).ok
        assert gate.compare(base, fresh, check_wall=False).ok

    def test_wall_floor_exempts_fast_cells(self):
        base, fresh = _suite_doc(), _suite_doc()
        base["cells"][0]["wall_mean_s"] = 0.01
        fresh["cells"][0]["wall_mean_s"] = 0.4          # 40x but tiny
        assert gate.compare(base, fresh).ok

    def test_missing_cell_fails_new_cell_warns(self):
        base, fresh = _suite_doc(), _suite_doc()
        extra = copy.deepcopy(fresh["cells"][0])
        extra["method"] = "bm/new"
        fresh["cells"].append(extra)
        assert any("new cell" in w for w in gate.compare(base, fresh).warnings)
        fresh["cells"] = [extra]                       # original cell gone
        result = gate.compare(base, fresh)
        assert any("missing from fresh" in f for f in result.failures)

    def test_schema_invalid_artifact_fails_gate(self):
        base, fresh = _suite_doc(), _suite_doc()
        del fresh["cells"][0]["epsilon_mean"]
        result = gate.compare(base, fresh)
        assert not result.ok
        assert any("schema-invalid" in f for f in result.failures)

    def test_gate_cli_exits_nonzero_vs_committed_baseline(self, tmp_path):
        """Acceptance: ε degraded beyond tolerance vs the COMMITTED
        baseline makes `python -m repro.evalsuite.gate` exit non-zero."""
        assert os.path.exists(BASELINE), "committed baseline must exist"
        with open(BASELINE) as f:
            fresh = json.load(f)
        report = tmp_path / "report.txt"

        # unmodified re-run of the committed artifact passes
        ok_path = tmp_path / "fresh_ok.json"
        ok_path.write_text(json.dumps(fresh))
        assert gate.main(["--baseline", BASELINE, "--fresh", str(ok_path),
                          "--report", str(report)]) == 0

        # degrade every cell's ε beyond tolerance -> exit 1 + report
        for cell in fresh["cells"]:
            cell["epsilon_mean"] += 0.2
        bad_path = tmp_path / "fresh_bad.json"
        bad_path.write_text(json.dumps(fresh))
        rc = gate.main(["--baseline", BASELINE, "--fresh", str(bad_path),
                        "--report", str(report)])
        assert rc == 1
        assert "FAIL" in report.read_text()


# -------------------------------------------------- datasets / registry

class TestDatasets:
    def test_registry_tiers(self):
        quick = ds.list_datasets("quick")
        assert quick and set(quick) <= set(ds.list_datasets("full")), \
            "quick datasets must be a subset of full: nightly must cover " \
            "every PR-gated cell"
        with pytest.raises(KeyError, match="unknown dataset"):
            ds.get_dataset("nope")

    def test_quick_registry_f_star_committed(self):
        for name in ds.list_datasets("quick"):
            assert ds.get_dataset(name).f_star is not None, \
                f"{name}: the PR gate needs a committed f_star"

    def test_memmap_generation_deterministic(self, tmp_path):
        """Same spec ⇒ bitwise-identical memmap (the registry's contract:
        every run and CI job clusters byte-identical data)."""
        from repro.data.synthetic import GMMSpec, gmm_dataset, gmm_memmap

        spec = GMMSpec(m=2048, n=7, components=4, seed=9)
        a = gmm_memmap(spec, str(tmp_path / "a.npy"))
        b = gmm_memmap(spec, str(tmp_path / "b.npy"))
        with open(a, "rb") as fa, open(b, "rb") as fb:
            assert fa.read() == fb.read()
        # and the memmap holds the same rows the in-core path generates
        np.testing.assert_array_equal(
            np.load(a), np.asarray(gmm_dataset(spec))[:, :])

    def test_materialize_reuses_existing_file(self, tmp_path):
        spec = ds.DatasetSpec(name="t-tiny", paper_name="kegg", m=1024, n=20,
                              components=4, k=3, s=128, n_chunks=4)
        p1 = ds.materialize(spec, str(tmp_path))
        mtime = os.path.getmtime(p1)
        p2 = ds.materialize(spec, str(tmp_path))
        assert p1 == p2 and os.path.getmtime(p2) == mtime

    def test_dataset_record_is_schema_valid(self):
        for name in ds.list_datasets():
            record = ds.get_dataset(name).to_record()
            assert schema.validate(record, schema._DATASET_SCHEMA) == [], name


# ------------------------------------------------------- suite (end-to-end)

class TestSuiteRun:
    @pytest.fixture(scope="class")
    def mini_doc(self, tmp_path_factory):
        """One tiny dataset x (one strategy + one baseline) x 2 seeds."""
        spec = ds.DatasetSpec(name="t-mini", paper_name="kegg", m=1536, n=20,
                              components=6, k=4, s=192, n_chunks=4,
                              f_star=None, tiers=("quick",))
        ds.REGISTRY[spec.name] = spec
        try:
            yield suite.run_suite(
                "quick", seeds=(0, 1), dataset_names=["t-mini"],
                method_names=["bm/sequential", "baseline/forgy"],
                data_root=str(tmp_path_factory.mktemp("evalsuite")),
                verbose=False)
        finally:
            del ds.REGISTRY[spec.name]

    def test_doc_schema_valid(self, mini_doc):
        assert schema.validate(mini_doc, schema.SUITE_SCHEMA) == []

    def test_equal_budget_and_bootstrap_f_star(self, mini_doc):
        (record,) = mini_doc["datasets"]
        assert record["f_star_source"].startswith("run-best")
        best = min(r["f_full"] for r in mini_doc["rows"])
        assert record["f_star"] == best
        eps_best = min(r["epsilon"] for r in mini_doc["rows"])
        assert eps_best == pytest.approx(0.0)
        for r in mini_doc["rows"]:
            assert r["success"] == (r["epsilon"] <= mini_doc["success_tol"])
        # the big-means rows consumed exactly the registry chunk budget
        for r in mini_doc["rows"]:
            if r["kind"] == "bigmeans":
                assert r["n_chunks"] == 4

    def test_cells_cover_matrix(self, mini_doc):
        keys = {(c["dataset"], c["method"]) for c in mini_doc["cells"]}
        assert keys == {("t-mini", "bm/sequential"),
                        ("t-mini", "baseline/forgy")}

    def test_write_outputs(self, mini_doc, tmp_path):
        json_path = str(tmp_path / "BENCH_suite.json")
        csv_path = str(tmp_path / "runs.csv")
        suite.write_outputs(mini_doc, json_path, csv_path)
        with open(json_path) as f:
            assert schema.validate(json.load(f), schema.SUITE_SCHEMA) == []
        with open(csv_path) as f:
            lines = f.read().strip().splitlines()
        assert len(lines) == 1 + len(mini_doc["rows"])

    def test_unknown_method_name_raises(self):
        with pytest.raises(KeyError, match="unknown methods"):
            suite.run_suite("quick",
                            method_names=["bm/seqential", "baseline/forgy"])

    def test_method_matrix_meets_acceptance(self):
        """The quick tier must cover >= 2 big-means strategies and >= 3
        baselines (ISSUE 5 acceptance criteria)."""
        quick = [m for m in suite.METHODS if "quick" in m.tiers]
        strategies = {m.method for m in quick if m.kind == "bigmeans"}
        baselines = [m for m in quick if m.kind == "baseline"]
        assert len(strategies) >= 2
        assert len(baselines) >= 3


# ------------------------------------------------------------- api hooks

class TestFitRowHook:
    def test_fit_records_dispatch_extras_and_to_row(self):
        import jax

        from repro.api import BigMeansConfig, fit

        X = np.asarray(
            jax.random.normal(jax.random.PRNGKey(0), (512, 8)))
        cfg = BigMeansConfig(k=4, s=64, n_chunks=4, seed=7)
        res = fit(X, cfg, method="sequential")
        assert res.extras["fit"]["method"] == "sequential"
        assert res.extras["fit"]["seed"] == 7
        assert res.extras["fit"]["source"] == "ArraySource"
        row = res.to_row()
        json.dumps(row)                      # JSON-safe by contract
        assert row["algorithm"] == "big_means"
        assert row["fit"]["impl"] == cfg.resolved_impl()
